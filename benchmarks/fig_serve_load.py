"""Open-loop serving load: sustained requests/s under continuous batching.

The paper's headline claim is end-to-end base-calling THROUGHPUT (6x vs
prior PIMs, Fig 9/26 are per-stage sweeps); this benchmark measures the
serving counterpart: an open-loop load generator (arrivals on a fixed
schedule, independent of completions — so queueing is real, not
self-throttled) drives ``repro.serve.Server`` over BOTH engines and
reports requests/s, slot occupancy, queue behaviour, and p50/p99 latency
from the server's own ``metrics()`` snapshot.

    PYTHONPATH=src python benchmarks/fig_serve_load.py --smoke
    PYTHONPATH=src python benchmarks/fig_serve_load.py \
        --engine basecall --requests 32 --rate 8 --slots 8

Also runs inside the harness: ``python -m benchmarks.run --only serve_load``.
"""
import argparse
import time

import numpy as np


def _build_basecall_server(slots: int, backpressure: str, max_queue: int):
    import jax

    from repro.core.quant import QuantConfig
    from repro.pipeline import BasecallPipeline
    from repro.serve import Server
    from repro.serve.basecall_engine import BasecallEngine

    pipe = BasecallPipeline.from_preset(
        "guppy", scale="tiny",
        quant=QuantConfig(enabled=True, bits_w=5, bits_a=5),
        backend="auto", beam_width=3)
    pipe.init_params(jax.random.PRNGKey(0))
    eng = BasecallEngine(pipe, batch_slots=slots)
    return Server(eng, max_queue=max_queue, backpressure=backpressure), pipe


def _basecall_requests(pipe, n: int, seed: int = 0):
    from repro.serve import BasecallRequest

    rng = np.random.default_rng(seed)
    win = pipe.mcfg.input_len
    # mixed read lengths: 1-4 windows, so short reads retire early
    return [BasecallRequest(signal=rng.standard_normal(
        int(rng.integers(1, 5) * win * 0.9)).astype(np.float32))
        for _ in range(n)]


def _build_multitenant_server(model_ids, slots: int, backpressure: str,
                              max_queue: int):
    """One Server hosting every named serving tier (``configs.SERVE_TIERS``)
    behind a ``ModelRegistry``, multiplexed over per-model slot groups."""
    from repro import configs
    from repro.serve import ModelRegistry, Server
    from repro.serve.multitenant import MultiModelBasecallEngine

    reg = ModelRegistry(budget_bytes=256 << 20)
    pipes = {}
    for i, tier in enumerate(model_ids):
        pipe = configs.serve_tier_pipeline(tier, seed=i, backend="auto")
        reg.register_basecaller(tier, pipe)
        pipes[tier] = pipe
    eng = MultiModelBasecallEngine(reg, model_ids, batch_slots=slots)
    srv = Server(eng, max_queue=max_queue, backpressure=backpressure)
    return srv, reg, pipes


def _multitenant_requests(pipes, n: int, seed: int = 0):
    """Round-robin the hosted tiers with mixed read lengths, so every
    model's group sees load and short reads retire early."""
    from repro.serve import BasecallRequest

    rng = np.random.default_rng(seed)
    ids = list(pipes)
    out = []
    for i in range(n):
        mid = ids[i % len(ids)]
        win = pipes[mid].mcfg.input_len
        out.append(BasecallRequest(
            signal=rng.standard_normal(
                int(rng.integers(1, 5) * win * 0.9)).astype(np.float32),
            model=mid))
    return out


def _build_lm_server(slots: int, backpressure: str, max_queue: int,
                     max_len: int = 64, **engine_kw):
    import jax

    from repro.models import lm as lm_lib
    from repro.serve import Server
    from repro.serve.engine import ServingEngine

    cfg = lm_lib.LMConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                          d_ff=64, vocab_size=64, remat=False)
    params = lm_lib.init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, batch_slots=slots, max_len=max_len,
                        **engine_kw)
    return Server(eng, max_queue=max_queue, backpressure=backpressure), cfg


def _lm_requests(cfg, n: int, max_tokens: int, seed: int = 0):
    from repro.serve import LMRequest

    rng = np.random.default_rng(seed)
    return [LMRequest(prompt=rng.integers(0, cfg.vocab_size,
                                          int(rng.integers(2, 8))),
                      max_tokens=int(rng.integers(2, max_tokens + 1)))
            for _ in range(n)]


def open_loop(srv, requests, rate: float):
    """Drive ``srv`` under a fixed arrival schedule (``rate`` req/s).

    Arrivals are submitted when their scheduled time passes regardless of
    how far behind the server is — the open-loop discipline that makes
    sustained throughput and queue depth meaningful."""
    t0 = srv.clock()
    arrivals = [i / rate for i in range(len(requests))]
    i = 0
    max_queue_depth = 0
    max_active = 0
    while i < len(requests) or srv.pending():
        now = srv.clock() - t0
        while i < len(requests) and arrivals[i] <= now:
            srv.submit(requests[i])
            i += 1
        max_queue_depth = max(max_queue_depth,
                              len(srv.engine.sched.queue))
        if srv.pending():
            srv.step()
            max_active = max(max_active,
                             int(srv.engine.sched.active_mask().sum()))
        elif i < len(requests):
            time.sleep(min(arrivals[i] - now, 0.005))
    return max_queue_depth, max_active


def _one_engine(name: str, srv, requests, rate: float, units_of):
    # warm the jitted paths so compile time doesn't pollute the open loop
    srv.submit(requests[0]).result()
    srv.reset_metrics()
    depth, max_active = open_loop(srv, requests, rate)
    m = srv.metrics()
    rows = m.rows(prefix=f"serve_load/{name}")
    rows.append((f"serve_load/{name}/max_queue_depth", str(depth),
                 f"offered rate {rate:.1f} req/s"))
    rows.append((f"serve_load/{name}/max_sustained_lanes", str(max_active),
                 "peak concurrently-active slots over the run"))
    units = sum(units_of(r) for r in srv.results.values() if r.ok)
    rows.append((f"serve_load/{name}/units_per_s",
                 f"{units / m.elapsed_s:.1f}",
                 "decoded windows/s"
                 if name in ("basecall", "multitenant") else "tokens/s"))
    return rows


def run(smoke: bool = True, engine: str = "both", requests: int = None,
        rate: float = None, slots: int = None, max_tokens: int = 8,
        backpressure: str = "shed-oldest", models: str = None):
    n = requests or (6 if smoke else 32)
    slots = slots or (2 if smoke else 8)
    rate = rate or (4.0 if smoke else 8.0)
    rows = []
    if models:
        from repro.serve import BasecallRequest

        ids = [m.strip() for m in models.split(",") if m.strip()]
        srv, reg, pipes = _build_multitenant_server(
            ids, slots, backpressure, max_queue=max(2 * n, 4))
        # warm every tenant's jitted decode before the open loop
        for mid, pipe in pipes.items():
            srv.submit(BasecallRequest(
                signal=np.zeros(pipe.mcfg.input_len, np.float32),
                model=mid)).result()
        reqs = _multitenant_requests(pipes, n)
        rows += _one_engine("multitenant", srv, reqs, rate,
                            lambda r: r.value.window_reads.shape[0])
        rows += [(name, f"{val:.0f}", "")
                 for name, val in reg.stats().rows(
                     prefix="serve_load/multitenant/registry")]
        return rows              # --models runs the fleet sweep alone
    if engine in ("both", "basecall"):
        srv, pipe = _build_basecall_server(slots, backpressure,
                                           max_queue=max(2 * n, 4))
        reqs = _basecall_requests(pipe, n)
        rows += _one_engine("basecall", srv, reqs, rate,
                            lambda r: r.value.window_reads.shape[0])
    if engine in ("both", "lm"):
        srv, cfg = _build_lm_server(slots, backpressure,
                                    max_queue=max(2 * n, 4))
        reqs = _lm_requests(cfg, n, max_tokens)
        rows += _one_engine("lm", srv, reqs, rate,
                            lambda r: len(r.value))
        rows += _paged_sweep(smoke, backpressure, max_tokens)
    return rows


def _paged_sweep(smoke: bool, backpressure: str, max_tokens: int):
    """Dense vs paged KV at a FIXED arena budget (same KV tokens of
    memory): the dense layout reserves ``max_len`` per lane, so its lane
    count is ``budget / max_len``; the paged layout spends the same
    budget on ``budget / kv_block`` pooled blocks and lets short requests
    pack many more concurrent lanes (preemption keeps overflow correct).

    Emits per layout: max sustained concurrent lanes, p50/p99 latency,
    tokens/s — the concurrency axis of the paged-cache tentpole.
    """
    max_len = 64
    kv_block = 8
    dense_slots = 2 if smoke else 8
    budget = dense_slots * max_len          # KV tokens, both layouts
    n = 16 if smoke else 64
    rate = 200.0                            # saturating offered load
    layouts = (
        ("dense", dense_slots, {}),
        ("paged", 4 * dense_slots,
         {"kv_layout": "paged", "kv_block": kv_block,
          "kv_blocks": budget // kv_block}),
    )
    rows = []
    for name, slots, kw in layouts:
        srv, cfg = _build_lm_server(slots, backpressure,
                                    max_queue=max(2 * n, 4),
                                    max_len=max_len, **kw)
        reqs = _lm_requests(cfg, n, max_tokens, seed=7)
        sub = _one_engine(f"kv_budget/{name}", srv, reqs, rate,
                          lambda r: len(r.value))
        keep = ("max_sustained_lanes", "latency_p50_s", "latency_p99_s",
                "units_per_s")
        rows += [r for r in sub if r[0].rsplit("/", 1)[-1] in keep]
        if name == "paged":
            eng = srv.engine
            rows.append((f"serve_load/kv_budget/paged/preemptions",
                         str(eng.preemptions),
                         f"{eng.n_kv_blocks} blocks x {kv_block} tokens "
                         f"= {budget} KV-token budget"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configs / few requests (CI)")
    ap.add_argument("--engine", default="both",
                    choices=["both", "basecall", "lm"])
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=None,
                    help="offered load, requests/s")
    ap.add_argument("--slots", type=int, default=None)
    ap.add_argument("--max-tokens", type=int, default=8)
    ap.add_argument("--backpressure", default="shed-oldest",
                    choices=["reject", "block", "shed-oldest"])
    ap.add_argument("--models", default=None,
                    help="comma-separated configs.SERVE_TIERS ids (e.g. "
                         "small,large): run the multi-tenant fleet sweep "
                         "instead of the single-model engines")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, val, derived in run(smoke=args.smoke, engine=args.engine,
                                  requests=args.requests, rate=args.rate,
                                  slots=args.slots,
                                  max_tokens=args.max_tokens,
                                  backpressure=args.backpressure,
                                  models=args.models):
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
