"""Render the §Dry-run / §Roofline markdown tables from sweep artifacts.

    PYTHONPATH=src python -m benchmarks.make_experiments_tables > tables.md
"""
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def cells(mesh):
    out = []
    for p in sorted(glob.glob(os.path.join(ART, f"*__{mesh}.json"))):
        out.append(json.load(open(p)))
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    out.sort(key=lambda c: (c["arch"], order[c["shape"]]))
    return out


def dryrun_table():
    lines = ["| arch | shape | pod1 | pod2 | mem/chip (pod1) | fits 16G |",
             "|---|---|---|---|---|---|"]
    p1 = {(c["arch"], c["shape"]): c for c in cells("pod1")}
    p2 = {(c["arch"], c["shape"]): c for c in cells("pod2")}
    for key in p1:
        a, s = key
        c1, c2 = p1[key], p2.get(key, {})
        st1, st2 = c1["status"], c2.get("status", "-")
        if st1 == "ok":
            mem = f"{c1['memory']['per_device_bytes']/2**30:.2f} GiB"
            fits = "yes" if c1["memory"]["fits_v5e_16g"] else "**no**"
        else:
            mem = fits = "—"
        lines.append(f"| {a} | {s} | {st1} | {st2} | {mem} | {fits} |")
    return "\n".join(lines)


def roofline_table(mesh="pod1"):
    lines = ["| arch | shape | t_compute | t_memory† | t_collective | "
             "dominant | MODEL/HLO flops | wire GiB/step |",
             "|---|---|---|---|---|---|---|---|"]
    for c in cells(mesh):
        if c["status"] != "ok":
            lines.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                         f"{c['status']} | — | — |")
            continue
        r = c["roofline"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {r['t_compute_s']:.3g} s | "
            f"{r['t_memory_s']:.3g} s | {r['t_collective_s']:.3g} s | "
            f"{r['dominant']} | {c['useful_flops_frac']:.2f} | "
            f"{c['collective_wire_bytes_loop_aware']/2**30:.2f} |")
    return "\n".join(lines)


def summary():
    p1 = cells("pod1")
    ok = [c for c in p1 if c["status"] == "ok"]
    fits = sum(c["memory"]["fits_v5e_16g"] for c in ok)
    doms = {}
    for c in ok:
        doms[c["roofline"]["dominant"]] = doms.get(
            c["roofline"]["dominant"], 0) + 1
    return (f"pod1 cells: {len(ok)} compiled ok, "
            f"{sum(c['status'] == 'skipped' for c in p1)} skipped, "
            f"{fits}/{len(ok)} fit 16 GiB/chip; dominant terms: {doms}")


if __name__ == "__main__":
    print("## Dry-run matrix\n")
    print(summary() + "\n")
    print(dryrun_table())
    print("\n## Roofline (single pod, 256 chips)\n")
    print(roofline_table("pod1"))
