"""Fig 7: quantized-matmul throughput vs bit-width.

On CPU we measure the real int8-container kernel (interpret-mode Pallas is
Python-speed, so the jnp oracle path stands in for kernel timing) against
the fp32 matmul; the derived column reports the speedup and the fake-quant
accuracy cost at each width — the trend the figure shows.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.kernels import registry
from ._util import time_call

M, K, N = 256, 512, 256


def run():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((M, K)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32))
    f32 = jax.jit(lambda a, b: a @ b)
    t_f32 = time_call(f32, x, w)
    rows = [("fig7/matmul_f32", t_f32, "baseline")]
    backend = registry.resolve_backend(None)
    if backend == "interpret":
        backend = "ref"   # interpreter is Python-speed; oracle stands in
    qmm = jax.jit(registry.get_op("quant_matmul", backend))
    # widths that fit the int8 container (16-bit codes would clip)
    for bits in (8, 5, 4, 3):
        xq, sx = quant.pack_act(x, bits)
        wq, sw = quant.pack_weight(w, bits)
        t = time_call(qmm, xq, wq, sx, sw)
        err = float(jnp.abs(qmm(xq, wq, sx, sw) - x @ w).max()
                    / jnp.abs(x @ w).max())
        rows.append((f"fig7/matmul_int_{bits}b_{backend}", t,
                     f"speedup={t_f32/t:.2f}x relerr={err:.4f}"))
    return rows
