"""Fig 25: SOT-MRAM ADC arrays vs low-resolution CMOS ADCs."""
from repro.core import pim


def run():
    rows = []
    helix = pim.scheme("Helix", "guppy")
    for bits, paper_pw, paper_pm in ((5, 27.9, 21.8), (6, 37.3, 21.3)):
        cmos = pim.scheme(f"cmos{bits}", "guppy")
        pw = ((helix.throughput / helix.power_w)
              / (cmos.throughput / cmos.power_w) - 1) * 100
        pm = ((helix.throughput / helix.area_mm2)
              / (cmos.throughput / cmos.area_mm2) - 1) * 100
        rows.append((f"fig25/sot_vs_cmos{bits}", "-",
                     f"perW +{pw:.1f}% (paper +{paper_pw}%) "
                     f"permm2 +{pm:.1f}% (paper +{paper_pm}%)"))
    return rows
