"""Sharded basecall scaling: decoded windows/s vs dp device count.

The paper's throughput story is scale-out — PIM arrays basecall many
signal windows concurrently — and the repo's counterpart is the
dp-sharded ``BasecallPipeline`` path: the window batch splits over a
``dist.sharding`` mesh's data-parallel devices, the serving artifact is
replicated, and per-window reads are all-gathered before the stitch.
This benchmark times the same long read through meshes of growing device
count and reports windows/s per count (plus the speedup over 1 device).

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m benchmarks.run --only shard_scaling
    PYTHONPATH=src python benchmarks/fig_shard_scaling.py --devices 4

Standalone invocation forces the host device count itself (before jax
loads); through ``benchmarks.run`` it sweeps whatever devices the already
initialized process has (real accelerators included).  On CPU the fake
host devices share the same cores, so windows/s is a plumbing check —
the scaling *shape* is only meaningful on real parallel hardware.
"""
import argparse
import time

import numpy as np


def _pipeline(backend: str):
    import jax

    from repro.core.quant import QuantConfig
    from repro.pipeline import BasecallPipeline

    pipe = BasecallPipeline.from_preset(
        "guppy", scale="tiny",
        quant=QuantConfig(enabled=True, bits_w=5, bits_a=5),
        backend=backend, beam_width=3)
    pipe.init_params(jax.random.PRNGKey(0))
    return pipe


def _device_counts(limit: int):
    import jax

    n = len(jax.devices())
    counts = [c for c in (1, 2, 4, 8, 16) if c <= min(n, limit)]
    return counts or [1]


def run(smoke: bool = False, backend: str = "auto", max_devices: int = 16,
        repeats: int = None):
    """windows/s through ``pipe.basecall`` per dp device count."""
    import jax

    from repro.dist import sharding as shd
    from repro.pipeline import chunking

    pipe = _pipeline(backend)
    repeats = repeats or (2 if smoke else 5)
    n_win = 16 if smoke else 64
    rng = np.random.default_rng(0)
    sig = rng.standard_normal(
        pipe.mcfg.input_len + (n_win - 1) * pipe.chunk.hop
    ).astype(np.float32)
    n_windows = chunking.n_windows(sig.shape[0], pipe.chunk)

    rows = []
    base = None
    for c in _device_counts(max_devices):
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:c]), ("data",))
        with shd.use_mesh(mesh):
            pipe.basecall(sig)                       # compile + place
            t0 = time.perf_counter()
            for _ in range(repeats):
                res = pipe.basecall(sig)
            dt = (time.perf_counter() - t0) / repeats
        assert res.window_reads.shape[0] == n_windows
        wps = n_windows / dt
        base = base or wps
        rows.append((f"shard_scaling/dp{c}/windows_per_s", f"{wps:.1f}",
                     f"{n_windows} windows, {c} device(s), "
                     f"speedup x{wps / base:.2f}"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4,
                    help="host devices to force (standalone runs only; "
                         "must be set before jax initializes)")
    ap.add_argument("--smoke", action="store_true",
                    help="short read / few repeats (CI)")
    ap.add_argument("--backend", default="auto")
    args = ap.parse_args()
    # must precede the first jax import (run() imports it lazily)
    from repro.hostdev import force_host_devices
    force_host_devices(args.devices)
    print("name,us_per_call,derived")
    for name, val, derived in run(smoke=args.smoke, backend=args.backend,
                                  max_devices=args.devices):
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
