"""Fig 24: the ISAAC -> Helix scheme ladder from the analytical PIM model."""
from repro.core import pim


def run():
    rows = []
    lad = pim.ladder()
    for name in pim.SCHEMES:
        v = lad[name]
        rows.append((f"fig24/{name}/throughput", "-",
                     f"{v['throughput_x']:.2f}x_ISAAC"))
        rows.append((f"fig24/{name}/per_watt", "-", f"{v['per_watt_x']:.2f}x"))
        rows.append((f"fig24/{name}/per_mm2", "-", f"{v['per_mm2_x']:.2f}x"))
    h = lad["Helix"]
    rows.append(("fig24/paper_check", "-",
                 f"throughput {h['throughput_x']:.1f}x (paper 6x), "
                 f"perW {h['per_watt_x']:.1f}x (paper 11.9x), "
                 f"permm2 {h['per_mm2_x']:.1f}x (paper 7.5x)"))
    rows.append(("fig24/power_area", "-",
                 f"ISAAC {pim.chip_power_area('cmos',8)[0]:.1f}W/"
                 f"{pim.chip_power_area('cmos',8)[1]:.1f}mm2 vs Helix "
                 f"{pim.chip_power_area('sot', comparators=True)[0]:.1f}W/"
                 f"{pim.chip_power_area('sot', comparators=True)[1]:.1f}mm2"))
    return rows
