"""§Roofline: aggregate the dry-run artifacts into the per-cell table.

Reads benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json (written by
``python -m repro.launch.dryrun``) and emits one row per cell with the
three roofline terms, the dominant bottleneck, and MODEL_FLOPS/HLO_FLOPs.

NOTE (methodology, see EXPERIMENTS.md §Roofline): XLA's cost analysis
counts while-loop bodies ONCE (verified empirically), so for scan-stacked
models the HLO numbers reported here are per-layer-iteration costs plus
fixed overhead.  The table therefore also reports the analytically exact
MODEL_FLOPS and the scan trip counts needed to scale HLO terms; the §Perf
hillclimb uses like-for-like HLO deltas (same loop structure), which are
unaffected.
"""
import glob
import json
import os

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load_cells(mesh="pod1"):
    cells = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run():
    rows = []
    for mesh in ("pod1", "pod2"):
        cells = load_cells(mesh)
        n_ok = sum(c["status"] == "ok" for c in cells)
        n_skip = sum(c["status"] == "skipped" for c in cells)
        n_fail = sum(c["status"] == "failed" for c in cells)
        rows.append((f"roofline/{mesh}/cells", "-",
                     f"ok={n_ok} skipped={n_skip} failed={n_fail}"))
        for c in cells:
            name = f"roofline/{mesh}/{c['arch']}/{c['shape']}"
            if c["status"] != "ok":
                rows.append((name, "-", c["status"]))
                continue
            r = c["roofline"]
            mem = c["memory"]["per_device_bytes"] / 2 ** 30
            rows.append((
                name, "-",
                f"dom={r['dominant']} tc={r['t_compute_s']:.2e}s "
                f"tm={r['t_memory_s']:.2e}s tx={r['t_collective_s']:.2e}s "
                f"mem={mem:.2f}GiB useful={c['useful_flops_frac']:.2f}"))
    return rows
