"""§Roofline: aggregate the dry-run artifacts into the per-cell table.

Reads benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json (written by
``python -m repro.launch.dryrun``) and emits one row per cell with the
three roofline terms, the dominant bottleneck, and MODEL_FLOPS/HLO_FLOPs.

NOTE (methodology, see EXPERIMENTS.md §Roofline): XLA's cost analysis
counts while-loop bodies ONCE (verified empirically), so for scan-stacked
models the HLO numbers reported here are per-layer-iteration costs plus
fixed overhead.  The table therefore also reports the analytically exact
MODEL_FLOPS and the scan trip counts needed to scale HLO terms; the §Perf
hillclimb uses like-for-like HLO deltas (same loop structure), which are
unaffected.
"""
import glob
import json
import os

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load_cells(mesh="pod1"):
    cells = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def _launch_rows():
    """Static kernel-launch counts of the serving traces, fused vs the
    per-step/per-frame paths they replace — the launch-overhead axis of
    the roofline (each launch pays fixed dispatch cost regardless of
    arithmetic intensity).  Counted on the tiny preset; the ratio is
    shape-independent (one launch per layer/direction vs one per step)."""
    import functools

    import jax

    from repro.analysis.jaxpr_tools import kernel_launch_count
    from repro.core import ctc as ctc_lib
    from repro.core.quant import QuantConfig
    from repro.kernels.registry import Backend
    from repro.models import basecaller as bc

    cfg = bc.tiny_preset("guppy").with_quant(
        QuantConfig(enabled=True, bits_w=5, bits_a=5))
    params = bc.init_basecaller(jax.random.PRNGKey(0), cfg)
    sig = jax.numpy.zeros((2, cfg.input_len, 1))
    be = Backend("interpret")   # kernel bodies present off-TPU too

    def count(fn, *args):
        return kernel_launch_count(jax.make_jaxpr(fn)(*args))

    l_step = count(functools.partial(
        bc.apply_basecaller, cfg=cfg, backend=be, fused_rnn=False),
        params, sig)
    l_seq = count(functools.partial(
        bc.apply_basecaller, cfg=cfg, backend=be, fused_rnn=True),
        params, sig)
    lp = jax.numpy.zeros((2, 24, cfg.n_classes))
    dec = functools.partial(ctc_lib.ctc_beam_search_hash_batch,
                            beam_width=5, max_len=16, backend="interpret")
    l_frame = count(dec, lp)
    l_strip = count(functools.partial(dec, strip_frames=8), lp)
    return [
        ("roofline/launches/dnn", "-",
         f"per_step={l_step} persistent={l_seq} "
         f"({l_step/max(l_seq, 1):.0f}x fewer; gru_seq)"),
        ("roofline/launches/ctc_decode", "-",
         f"per_frame={l_frame} strip={l_strip} "
         f"({l_frame/max(l_strip, 1):.0f}x fewer; "
         "beam_merge_multiframe F=8)"),
    ]


def run():
    rows = _launch_rows()
    for mesh in ("pod1", "pod2"):
        cells = load_cells(mesh)
        n_ok = sum(c["status"] == "ok" for c in cells)
        n_skip = sum(c["status"] == "skipped" for c in cells)
        n_fail = sum(c["status"] == "failed" for c in cells)
        rows.append((f"roofline/{mesh}/cells", "-",
                     f"ok={n_ok} skipped={n_skip} failed={n_fail}"))
        for c in cells:
            name = f"roofline/{mesh}/{c['arch']}/{c['shape']}"
            if c["status"] != "ok":
                rows.append((name, "-", c["status"]))
                continue
            r = c["roofline"]
            mem = c["memory"]["per_device_bytes"] / 2 ** 30
            rows.append((
                name, "-",
                f"dom={r['dominant']} tc={r['t_compute_s']:.2e}s "
                f"tm={r['t_memory_s']:.2e}s tx={r['t_collective_s']:.2e}s "
                f"mem={mem:.2f}GiB useful={c['useful_flops_frac']:.2f}"))
    return rows
