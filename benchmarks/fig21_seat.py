"""Fig 10/21/22: SEAT vs plain CTC loss under aggressive quantization.

Trains a reduced Guppy on the synthetic nanopore channel three ways —
fp32+loss0, 4-bit+loss0, 4-bit+SEAT(loss1) — and reports read error (before
vote) and vote error (after 3-view consensus).  The paper's claim is the
TREND: quantization inflates the post-vote (systematic) error, and SEAT
pulls it back toward fp32.  (Simulator-relative numbers; DESIGN.md §8.)
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ctc as ctc_lib
from repro.core import metrics, seat as seat_lib, voting
from repro.core.quant import QuantConfig
from repro.data import genome
from repro.models import basecaller as bc
from repro.train.optimizer import AdamW

STEPS = 300
BATCH = 8
EVAL_BATCH = 24

SCFG = seat_lib.SEATConfig(n_views=3, view_stride=8, max_read_len=40,
                           consensus_span=80, eta=1.0)
MCFG0 = bc.demo_preset("guppy")
# 1-mer demo channel: CPU-trainable in minutes (DESIGN.md §8); the TREND
# (quantization hurts post-vote accuracy, SEAT recovers it) is the claim
DCFG = genome.SignalConfig(window=MCFG0.input_len, margin=SCFG.margin,
                           max_label_len=40, kmer=1, mean_dwell=6.0)


def _train(quant_cfg, use_seat, seed=0, steps=STEPS):
    """Two-phase recipe (§4.1/Fig 10): warm up on loss0, then enable SEAT
    for the final third of training."""
    from repro.train.optimizer import warmup_cosine
    mcfg = MCFG0.with_quant(quant_cfg)
    params = bc.init_basecaller(jax.random.PRNGKey(seed), mcfg)
    opt = AdamW(lr=warmup_cosine(4e-3, 15, steps), clip_norm=1.0)
    state = opt.init(params)

    def make_step(scfg):
        @jax.jit
        def step(params, state, batch):
            def loss_fn(p):
                fn = lambda s: bc.apply_basecaller(p, s, mcfg)
                loss, m = seat_lib.seat_loss(fn, batch["signal"],
                                             batch["labels"],
                                             batch["label_length"], scfg)
                return loss, m
            (loss, m), g = jax.value_and_grad(loss_fn,
                                              has_aux=True)(params)
            params, state = opt.update(g, state, params)
            return params, state, loss
        return step

    warm = make_step(dataclasses.replace(SCFG, enabled=False))
    full = make_step(SCFG)
    # a short SEAT tail (~1/6 of training) is the stable recipe at this
    # scale: the gap^2 term is strong medicine — longer tails at demo
    # learning rates over-regularize (measured: 100-step tail degrades)
    switch = steps - steps // 6 if use_seat else steps
    for i in range(steps):
        batch = genome.batch_for_step(i, BATCH, DCFG, seed=seed + 1)
        params, state, loss = (warm if i < switch else full)(
            params, state, batch)
    return params, mcfg


def evaluate(params, mcfg, seed=123):
    """(read_error, vote_error) on held-out data with 3-view voting."""
    batch = genome.batch_for_step(10_000, EVAL_BATCH, DCFG, seed=seed)

    @jax.jit
    def decode_views(signal):
        views, center = seat_lib.make_views(signal, SCFG)
        lps = jnp.stack([bc.apply_basecaller(params, v, mcfg)
                         for v in views])
        C, C_len = seat_lib.consensus_reads(lps, center, SCFG)
        reads, lens = jax.vmap(ctc_lib.ctc_greedy_decode)(lps[center])
        return reads, lens, C, C_len

    reads, lens, C, C_len = decode_views(batch["signal"])
    truth = np.asarray(batch["labels"])
    tlen = np.asarray(batch["label_length"])
    read_err = metrics.error_rate(np.asarray(reads), np.asarray(lens),
                                  truth, tlen)
    vote_err = metrics.error_rate(np.asarray(C), np.asarray(C_len),
                                  truth, tlen)
    return read_err, vote_err


def run(steps=STEPS):
    rows = []
    results = {}
    # 3-bit: the most aggressive width in the paper's sweep (Fig 22) and
    # the one whose systematic-error inflation is visible at demo scale
    for name, qc, use_seat in (
            ("fp32_loss0", QuantConfig(enabled=False), False),
            ("q3_loss0", QuantConfig(enabled=True, bits_w=3, bits_a=3),
             False),
            ("q3_seat", QuantConfig(enabled=True, bits_w=3, bits_a=3),
             True)):
        params, mcfg = _train(qc, use_seat, steps=steps)
        read_err, vote_err = evaluate(params, mcfg)
        results[name] = (read_err, vote_err)
        rows.append((f"fig21/{name}", "-",
                     f"read_err={read_err:.3f} vote_err={vote_err:.3f}"))
    gap_q = results["q3_loss0"][1] - results["fp32_loss0"][1]
    gap_seat = results["q3_seat"][1] - results["fp32_loss0"][1]
    rows.append(("fig21/seat_recovers", "-",
                 f"quant_vote_gap={gap_q:+.3f} seat_vote_gap={gap_seat:+.3f}"
                 f" (paper: SEAT closes the post-vote gap)"))
    return rows
