"""Table 3: base-caller MAC/param counts — computed vs paper."""
import jax

from repro.models import basecaller as bc

PAPER = {"guppy": (36.3e6, 0.244e6), "scrappie": (8.47e6, 0.45e6),
         "chiron": (615.2e6, 2.2e6)}


def run():
    rows = []
    for name, (p_macs, p_params) in PAPER.items():
        cfg = bc.PRESETS[name]
        macs = bc.count_macs(cfg)
        params = bc.count_params(
            bc.init_basecaller(jax.random.PRNGKey(0), cfg))
        rows.append((f"table3/{name}/macs", "-",
                     f"ours={macs['total']/1e6:.2f}M paper={p_macs/1e6:.1f}M"
                     f" conv={macs['conv']/1e6:.2f}M rnn={macs['rnn']/1e6:.2f}M"))
        rows.append((f"table3/{name}/params", "-",
                     f"ours={params/1e6:.3f}M paper={p_params/1e6:.3f}M"))
    return rows
