"""Benchmark harness: one module per paper table/figure + the roofline
aggregation. Prints ``name,us_per_call,derived`` CSV rows.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
                                               [--backend B]

``--backend`` rebinds the process-wide default in
``repro.kernels.registry`` so every suite's kernel calls route through the
chosen implementation (auto / ref / interpret / pallas).

``--only shard_scaling`` sweeps the dp-sharded basecall path over the
process's devices (set ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
before launch to fake N host devices on CPU; see
``benchmarks/fig_shard_scaling.py`` for a standalone entry that sets it
for you).
"""
import argparse
import sys
import traceback

from repro.kernels import registry

from ._util import emit


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer training steps for fig21")
    ap.add_argument("--only", default=None)
    ap.add_argument("--backend", default="auto",
                    choices=list(registry.BACKENDS),
                    help="kernel backend for every suite (registry-wide)")
    ap.add_argument("--packed", dest="packed", action="store_true",
                    default=True,
                    help="measure the quantize-once PackedParams serving "
                         "path next to repack-per-call (default)")
    ap.add_argument("--no-packed", dest="packed", action="store_false",
                    help="skip the packed-artifact rows (repack-per-call "
                         "baseline only)")
    args = ap.parse_args()
    registry.set_default_backend(args.backend)

    from . import (fig7_quant_throughput, fig9_breakdown, fig21_seat,
                   fig24_pim, fig25_adc, fig26_beamwidth, fig_serve_load,
                   fig_shard_scaling, fig_stream_latency, roofline,
                   table3_models)
    suites = [
        ("table3", table3_models.run),
        ("fig7", fig7_quant_throughput.run),
        ("fig9", lambda: fig9_breakdown.run(packed=args.packed,
                                            smoke=args.quick)),
        ("fig21", (lambda: fig21_seat.run(steps=40)) if args.quick
         else fig21_seat.run),
        ("fig24", fig24_pim.run),
        ("fig25", fig25_adc.run),
        ("fig26", fig26_beamwidth.run),
        ("roofline", roofline.run),
        ("serve_load", lambda: fig_serve_load.run(smoke=args.quick)),
        ("shard_scaling", lambda: fig_shard_scaling.run(smoke=args.quick)),
        ("stream_latency", lambda: fig_stream_latency.run(smoke=args.quick)),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if args.only and args.only != name:
            continue
        try:
            emit(fn())
        except Exception:  # noqa: BLE001 — report and continue
            failures += 1
            print(f"{name},ERROR,{traceback.format_exc(limit=2)!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
