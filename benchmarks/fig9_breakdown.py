"""Fig 9: execution-time breakdown of the quantized base-calling pipeline
(DNN vs CTC decode vs read vote), measured on our CPU implementation.
Paper (GPU, 16-bit Guppy): DNN 46.3 %, CTC 16.7 %, vote 37 %.

Also times the serving DNN both ways — repack-per-call (weights
re-quantized inside every jitted forward) vs the quantize-once
``PackedParams`` artifact — so the pack-once win is measured, not
asserted (``run.py --packed/--no-packed``).
"""
import functools

import jax
import jax.numpy as jnp

from repro.core import ctc as ctc_lib
from repro.core import voting
from repro.core.quant import QuantConfig
from repro.data import genome
from repro.kernels.registry import Backend
from repro.models import basecaller as bc
from ._util import time_call

B = 8


def run(packed: bool = True):
    cfg = bc.tiny_preset("guppy").with_quant(
        QuantConfig(enabled=True, bits_w=5, bits_a=5))
    params = bc.init_basecaller(jax.random.PRNGKey(0), cfg)
    dcfg = genome.SignalConfig(window=cfg.input_len, max_label_len=48)
    batch = genome.sample_batch(jax.random.PRNGKey(1), B, dcfg)

    dnn = jax.jit(lambda p, s: bc.apply_basecaller(p, s, cfg))
    lp = dnn(params, batch["signal"])
    t_dnn = time_call(dnn, params, batch["signal"])

    # the serving decoder (hash-merge; compiled merge path — see fig26)
    beam = jax.jit(functools.partial(ctc_lib.ctc_beam_search_hash_batch,
                                     beam_width=10, max_len=48,
                                     backend="ref"))
    reads, lens, _ = beam(lp)
    t_ctc = time_call(beam, lp)

    top = reads[:, 0]
    toplen = lens[:, 0]
    grp = jnp.stack([top[: B // 2], top[B // 2:]], axis=1)   # 2-read coverage
    grplen = jnp.stack([toplen[: B // 2], toplen[B // 2:]], axis=1)
    vote = jax.jit(functools.partial(voting.vote_batch, span=96))
    vote(grp, grplen)
    t_vote = time_call(vote, grp, grplen)

    total = t_dnn + t_ctc + t_vote
    rows = [
        ("fig9/dnn", t_dnn, f"{100*t_dnn/total:.1f}% (paper GPU 46.3%)"),
        ("fig9/ctc_decode", t_ctc, f"{100*t_ctc/total:.1f}% (paper 16.7%)"),
        ("fig9/read_vote", t_vote, f"{100*t_vote/total:.1f}% (paper 37%)"),
        ("fig9/ctc_plus_vote", t_ctc + t_vote,
         f"{100*(t_ctc+t_vote)/total:.1f}% (paper 53.7%)"),
    ]

    # serving DNN: repack-per-call vs the quantize-once artifact (PR 3)
    be = Backend("auto")
    serve = jax.jit(lambda p, s: bc.apply_basecaller(p, s, cfg, backend=be))
    serve(params, batch["signal"])
    t_repack = time_call(serve, params, batch["signal"], iters=15)
    rows.append(("fig9/dnn_serve_repack", t_repack,
                 "weights re-quantized inside every forward"))
    if packed:
        artifact = jax.block_until_ready(bc.pack_basecaller(params, cfg))
        serve(artifact, batch["signal"])
        t_packed = time_call(serve, artifact, batch["signal"], iters=15)
        rows.append(("fig9/dnn_serve_packed", t_packed,
                     f"{t_repack / t_packed:.2f}x vs repack "
                     "(PackedParams, quantize-once)"))
    return rows
