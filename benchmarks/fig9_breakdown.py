"""Fig 9: execution-time breakdown of the quantized base-calling pipeline
(DNN vs CTC decode vs read vote), measured on our CPU implementation.
Paper (GPU, 16-bit Guppy): DNN 46.3 %, CTC 16.7 %, vote 37 %.

Also times the serving DNN both ways — repack-per-call (weights
re-quantized inside every jitted forward) vs the quantize-once
``PackedParams`` artifact — so the pack-once win is measured, not
asserted (``run.py --packed/--no-packed``).

Persistent-kernel rows (``fig9/fused_*``): each hot stage measured on
BOTH its per-step/per-frame path and the persistent fused kernel that
replaced it (``gru_seq`` whole-layer walk, ``beam_merge_multiframe``
F-frame strips), alongside the static kernel-launch count each trace
compiles to (``repro.analysis.jaxpr_tools.kernel_launch_count``) — the
quantity the persistent kernels exist to shrink.

The beam-search stage routes through the registry default backend, so
``run.py --backend`` (or running standalone with ``--backend``) selects
the implementation for every stage; nothing is pinned to ``ref``.

Standalone: ``PYTHONPATH=src python benchmarks/fig9_breakdown.py
[--smoke] [--backend B] [--no-packed]``.
"""
import argparse
import functools

import jax
import jax.numpy as jnp

from repro.analysis.jaxpr_tools import kernel_launch_count
from repro.core import ctc as ctc_lib
from repro.core import voting
from repro.core.quant import QuantConfig
from repro.data import genome
from repro.kernels import registry
from repro.kernels.registry import Backend
from repro.models import basecaller as bc

try:
    from ._util import emit, time_call
except ImportError:      # standalone: python benchmarks/fig9_breakdown.py
    from _util import emit, time_call

B = 8
STRIP = 8      # frames per persistent beam strip (pipeline default)


def _launches(fn, *args) -> int:
    """Static Pallas-launch count of one call of ``fn`` (0 on "ref")."""
    return kernel_launch_count(jax.make_jaxpr(fn)(*args))


def run(packed: bool = True, smoke: bool = False):
    b = 4 if smoke else B
    iters = 2 if smoke else 5
    cfg = bc.tiny_preset("guppy").with_quant(
        QuantConfig(enabled=True, bits_w=5, bits_a=5))
    params = bc.init_basecaller(jax.random.PRNGKey(0), cfg)
    dcfg = genome.SignalConfig(window=cfg.input_len, max_label_len=48)
    batch = genome.sample_batch(jax.random.PRNGKey(1), b, dcfg)

    dnn = jax.jit(lambda p, s: bc.apply_basecaller(p, s, cfg))
    lp = dnn(params, batch["signal"])
    t_dnn = time_call(dnn, params, batch["signal"], iters=iters)

    # the serving decoder (hash-merge, persistent F-frame strips); the
    # backend comes from the registry default so --backend reaches it
    beam = jax.jit(functools.partial(ctc_lib.ctc_beam_search_hash_batch,
                                     beam_width=10, max_len=48,
                                     strip_frames=STRIP))
    reads, lens, _ = beam(lp)
    t_ctc = time_call(beam, lp, iters=iters)

    top = reads[:, 0]
    toplen = lens[:, 0]
    grp = jnp.stack([top[: b // 2], top[b // 2:]], axis=1)   # 2-read coverage
    grplen = jnp.stack([toplen[: b // 2], toplen[b // 2:]], axis=1)
    vote = jax.jit(functools.partial(voting.vote_batch, span=96))
    vote(grp, grplen)
    t_vote = time_call(vote, grp, grplen, iters=iters)

    total = t_dnn + t_ctc + t_vote
    rows = [
        ("fig9/dnn", t_dnn, f"{100*t_dnn/total:.1f}% (paper GPU 46.3%)"),
        ("fig9/ctc_decode", t_ctc, f"{100*t_ctc/total:.1f}% (paper 16.7%)"),
        ("fig9/read_vote", t_vote, f"{100*t_vote/total:.1f}% (paper 37%)"),
        ("fig9/ctc_plus_vote", t_ctc + t_vote,
         f"{100*(t_ctc+t_vote)/total:.1f}% (paper 53.7%)"),
    ]

    # --- persistent kernels vs the per-step/per-frame paths they replace,
    # on the serving backend, with static launch counts -------------------
    be = Backend("auto")    # "auto" follows set_default_backend(--backend)
    fwd_fused = jax.jit(
        lambda p, s: bc.apply_basecaller(p, s, cfg, be, fused_rnn=True))
    fwd_step = jax.jit(
        lambda p, s: bc.apply_basecaller(p, s, cfg, be, fused_rnn=False))
    t_ff = time_call(fwd_fused, params, batch["signal"], iters=iters)
    t_fs = time_call(fwd_step, params, batch["signal"], iters=iters)
    l_ff = _launches(fwd_fused, params, batch["signal"])
    l_fs = _launches(fwd_step, params, batch["signal"])
    rows.append(("fig9/fused_dnn/per_step", t_fs,
                 f"launches={l_fs} (gru_cell under lax.scan)"))
    rows.append(("fig9/fused_dnn/persistent", t_ff,
                 f"launches={l_ff} ({t_fs/t_ff:.2f}x vs per-step, "
                 f"{l_fs/max(l_ff, 1):.0f}x fewer launches; gru_seq)"))

    dec_frame = jax.jit(functools.partial(
        ctc_lib.ctc_beam_search_hash_batch, beam_width=10, max_len=48))
    dec_strip = jax.jit(functools.partial(
        ctc_lib.ctc_beam_search_hash_batch, beam_width=10, max_len=48,
        strip_frames=STRIP))
    dec_frame(lp)
    t_df = time_call(dec_frame, lp, iters=iters)
    t_ds = time_call(dec_strip, lp, iters=iters)
    l_df = _launches(dec_frame, lp)
    l_ds = _launches(dec_strip, lp)
    rows.append(("fig9/fused_decode/per_frame", t_df,
                 f"launches={l_df} (beam_merge_topk per frame)"))
    rows.append(("fig9/fused_decode/strip", t_ds,
                 f"launches={l_ds} ({t_df/t_ds:.2f}x vs per-frame, "
                 f"{l_df/max(l_ds, 1):.0f}x fewer launches; "
                 f"beam_merge_multiframe F={STRIP})"))

    # serving DNN: repack-per-call vs the quantize-once artifact (PR 3)
    serve = jax.jit(lambda p, s: bc.apply_basecaller(p, s, cfg, backend=be))
    serve(params, batch["signal"])
    t_repack = time_call(serve, params, batch["signal"], iters=15)
    rows.append(("fig9/dnn_serve_repack", t_repack,
                 "weights re-quantized inside every forward"))
    if packed:
        artifact = jax.block_until_ready(bc.pack_basecaller(params, cfg))
        serve(artifact, batch["signal"])
        t_packed = time_call(serve, artifact, batch["signal"], iters=15)
        rows.append(("fig9/dnn_serve_packed", t_packed,
                     f"{t_repack / t_packed:.2f}x vs repack "
                     "(PackedParams, quantize-once)"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller batch / fewer timing iters (CI)")
    ap.add_argument("--backend", default="auto",
                    choices=list(registry.BACKENDS),
                    help="kernel backend for every stage (registry-wide)")
    ap.add_argument("--no-packed", dest="packed", action="store_false",
                    default=True)
    args = ap.parse_args()
    registry.set_default_backend(args.backend)
    print("name,us_per_call,derived")
    emit(run(packed=args.packed, smoke=args.smoke))


if __name__ == "__main__":
    main()
