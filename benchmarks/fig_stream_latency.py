"""Streaming basecalling latency: time-to-first-base, per-chunk step
tails vs. pore count, and throughput recovered by adaptive ejection.

Batch serving answers "how many reads per second"; the ReadUntil loop
lives or dies on *responsiveness* — how quickly after a pore starts
emitting does the caller see provisional bases (time-to-first-base), and
how the per-step latency tail grows with concurrently streaming pores.
The eject sweep measures the adaptive-sampling payoff itself: the wall
clock to drain a pore pool as the fraction of ejectable (uninteresting)
reads rises.

    PYTHONPATH=src python benchmarks/fig_stream_latency.py --smoke
    PYTHONPATH=src python benchmarks/fig_stream_latency.py \
        --pores 4 16 64 --chunk 60

Also runs inside the harness:
``python -m benchmarks.run --only stream_latency``.
"""
import argparse
import time

import numpy as np


def _build(slots: int):
    import jax

    from repro.core.quant import QuantConfig
    from repro.pipeline import BasecallPipeline
    from repro.serve import Server
    from repro.serve.streaming import StreamingBasecallEngine

    pipe = BasecallPipeline.from_preset(
        "guppy", scale="tiny",
        quant=QuantConfig(enabled=True, bits_w=5, bits_a=5),
        backend="auto", beam_width=3)
    pipe.init_params(jax.random.PRNGKey(0))
    srv = Server(StreamingBasecallEngine(pipe, batch_slots=slots),
                 max_queue=4096)
    return srv, pipe


def _pore(pipe, n_windows: float, chunk: int, seed: int):
    """One pore's chunk feed covering ~n_windows overlap windows."""
    win, hop = pipe.chunk.window, pipe.chunk.hop
    n = int(win + max(n_windows - 1, 0) * hop)
    sig = np.random.default_rng(seed).standard_normal(n).astype(np.float32)
    return [sig[i:i + chunk] for i in range(0, n, chunk)]


def _drain_timed(srv):
    """Step to idle, timing each server step (per-chunk service tail)."""
    steps = []
    while srv.pending():
        t0 = time.perf_counter()
        srv.step()
        steps.append(time.perf_counter() - t0)
    return np.asarray(steps)


def _warm(srv, pipe, chunk):
    from repro.serve.streaming import StreamRequest

    srv.submit(StreamRequest(chunks=_pore(pipe, 2, chunk, 0))).result()
    srv.reset_metrics()


def run(smoke: bool = True, pores=None, chunk: int = None,
        windows: float = None):
    """(name, value, derived) rows: TTFB + step tails per pore count,
    then the eject-rate sweep."""
    from repro.serve.streaming import EJECT, StreamRequest

    pore_counts = pores or ([2, 4] if smoke else [4, 16, 64])
    slots = max(pore_counts)
    chunk = chunk or 60
    windows = windows or (2.0 if smoke else 6.0)
    rows = []

    # -- time-to-first-base + per-chunk step tails vs concurrent pores --
    srv, pipe = _build(slots)
    _warm(srv, pipe, chunk)
    for n_pores in pore_counts:
        srv.reset_metrics()
        for p in range(n_pores):
            srv.submit(StreamRequest(
                chunks=_pore(pipe, windows, chunk, seed=p + 1),
                chunks_per_step=1))          # fixed arrival cadence
        steps = _drain_timed(srv)
        m = srv.metrics()
        tag = f"stream_latency/pores{n_pores}"
        rows.append((f"{tag}/ttfb_p50_s", f"{m.ttfe_p50_s:.4f}",
                     f"{n_pores} pores, chunk={chunk} samples"))
        rows.append((f"{tag}/ttfb_p99_s", f"{m.ttfe_p99_s:.4f}", ""))
        rows.append((f"{tag}/step_p50_us",
                     f"{np.percentile(steps, 50) * 1e6:.0f}",
                     f"{len(steps)} engine steps"))
        rows.append((f"{tag}/step_p99_us",
                     f"{np.percentile(steps, 99) * 1e6:.0f}", ""))
        rows.append((f"{tag}/occupancy", f"{m.occupancy:.3f}",
                     f"{slots} slots"))

    # -- eject-rate sweep: wall clock to drain a pool as the fraction ---
    # of ejectable pores rises (the ReadUntil payoff)
    n_pool = 8 if smoke else 32
    long_windows = windows * (2 if smoke else 4)
    for eject_pct in (0, 50, 100):
        srv, pipe = _build(max(4, slots // 2))
        _warm(srv, pipe, chunk)
        n_eject = n_pool * eject_pct // 100
        t0 = time.perf_counter()
        for p in range(n_pool):
            eject = (lambda prog: EJECT) if p < n_eject else None
            srv.submit(StreamRequest(
                chunks=_pore(pipe, long_windows, chunk, seed=100 + p),
                eject=eject, eject_after_chunks=2))
        srv.run_until_idle()
        wall = time.perf_counter() - t0
        m = srv.metrics()
        rows.append((f"stream_latency/eject{eject_pct}/drain_s",
                     f"{wall:.3f}",
                     f"{n_pool} pores, {m.ejected} ejected"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny pore counts / short streams (CI)")
    ap.add_argument("--pores", type=int, nargs="+", default=None,
                    help="concurrent pore counts to sweep")
    ap.add_argument("--chunk", type=int, default=None,
                    help="samples per arriving chunk")
    ap.add_argument("--windows", type=float, default=None,
                    help="overlap windows per pore stream")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, val, derived in run(smoke=args.smoke, pores=args.pores,
                                  chunk=args.chunk, windows=args.windows):
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
