"""Shared benchmark helpers: timing + CSV row emission."""
from __future__ import annotations

import time

import jax


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (us) of a jitted call (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows):
    """Print ``name,us_per_call,derived`` CSV rows."""
    for name, us, derived in rows:
        us_s = f"{us:.1f}" if isinstance(us, (int, float)) else us
        print(f"{name},{us_s},{derived}")
