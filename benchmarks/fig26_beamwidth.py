"""Fig 26: CTC-scheme gain grows with beam-search width.

Two views of the same claim:

* measured — the hash-merge serving decoder (``ctc_beam_search_hash``,
  fused ``beam_merge_topk`` registry op) against the dense-merge oracle
  decoder on identical (T, A) log-probs, per beam width.  The dense merge
  materializes an O(C^2*L) prefix-equality tensor per frame, so its cost
  grows quadratically with width; the hash merge compares single-word
  rolling hashes, which is where the paper's width-scaling win lives.
* analytic — the paper's NVM timing model (``core.pim``), unchanged.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ctc as ctc_lib
from repro.core import pim
from repro.kernels import registry

from ._util import time_call

T, A = 128, 5  # frames per window x [A, C, G, T, blank]


def run():
    rng = np.random.default_rng(0)
    lp = jax.nn.log_softmax(
        jnp.asarray(rng.standard_normal((T, A)).astype(np.float32)), -1)

    # time a COMPILED merge path: the Pallas interpreter exists for CPU
    # correctness checks and would only measure interpreter overhead
    backend = registry.resolve_backend(None)
    if backend == "interpret":
        backend = "ref"

    rows = []
    for w in (5, 8, 10, 20, 40):
        dense = jax.jit(
            lambda x, w=w: ctc_lib.ctc_beam_search(x, beam_width=w))
        hashed = jax.jit(
            lambda x, w=w: ctc_lib.ctc_beam_search_hash(
                x, beam_width=w, backend=backend))
        us_dense = time_call(dense, lp)
        us_hash = time_call(hashed, lp)
        adc = pim.scheme("ADC", "guppy", beam_width=w)
        ctc = pim.scheme("CTC", "guppy", beam_width=w)
        rows.append((
            f"fig26/width_{w}", f"{us_hash:.1f}",
            f"hash_over_dense={us_dense / us_hash:.2f}x "
            f"dense_us={us_dense:.1f} "
            f"CTC_over_ADC={adc.time / ctc.time:.2f}x"))
    return rows
