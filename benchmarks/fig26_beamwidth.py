"""Fig 26: CTC-scheme gain grows with beam-search width."""
from repro.core import pim


def run():
    rows = []
    for w in (5, 10, 20, 40):
        adc = pim.scheme("ADC", "guppy", beam_width=w)
        ctc = pim.scheme("CTC", "guppy", beam_width=w)
        rows.append((f"fig26/width_{w}", "-",
                     f"CTC_over_ADC={adc.time/ctc.time:.2f}x"))
    return rows
