"""Training substrate: optimizer, checkpoint, fault tolerance, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import collectives
from repro.train import checkpoint as ckpt_lib
from repro.train import fault as fault_lib
from repro.train.optimizer import AdamW, warmup_cosine, global_norm
from repro.train.trainer import Trainer, TrainerConfig, make_train_step

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _quadratic_problem(seed=0):
    key = jax.random.PRNGKey(seed)
    target = jax.random.normal(key, (8, 4))
    params = {"w": jnp.zeros((8, 4))}

    def loss_fn(p, batch=None):
        return jnp.mean((p["w"] - target) ** 2), {}

    return params, loss_fn, target


@pytest.mark.parametrize("state_bits", [32, 8])
def test_adamw_converges(state_bits):
    params, loss_fn, target = _quadratic_problem()
    opt = AdamW(lr=0.05, state_bits=state_bits, clip_norm=None)
    state = opt.init(params)
    grad_fn = jax.jit(jax.grad(lambda p: loss_fn(p)[0]))
    for _ in range(300):
        params, state = opt.update(grad_fn(params), state, params)
    err = float(jnp.mean((params["w"] - target) ** 2))
    assert err < 1e-2, err


def test_adamw_8bit_tracks_fp32():
    params, loss_fn, _ = _quadratic_problem(1)
    grad_fn = jax.jit(jax.grad(lambda p: loss_fn(p)[0]))
    trajs = {}
    for bits in (32, 8):
        p = jax.tree_util.tree_map(jnp.copy, params)
        opt = AdamW(lr=0.05, state_bits=bits, clip_norm=None)
        s = opt.init(p)
        for _ in range(50):
            p, s = opt.update(grad_fn(p), s, p)
        trajs[bits] = p["w"]
    diff = float(jnp.abs(trajs[32] - trajs[8]).max())
    scale = float(jnp.abs(trajs[32]).max())
    assert diff < 0.1 * max(scale, 1.0), diff


def test_grad_clipping():
    opt = AdamW(lr=0.0, clip_norm=1.0)  # lr 0: only test the clip path runs
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    big = {"w": jnp.full((4,), 1e6)}
    newp, _ = opt.update(big, state, params)
    np.testing.assert_allclose(np.asarray(newp["w"]), 1.0)


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, 10, 100)
    assert float(sched(0)) == 0.0
    np.testing.assert_allclose(float(sched(10)), 1.0, rtol=1e-5)
    assert float(sched(100)) < float(sched(50)) < float(sched(10))
    np.testing.assert_allclose(float(sched(100)), 0.1, atol=1e-6)


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    np.testing.assert_allclose(float(global_norm(t)),
                               np.sqrt(3 + 16), rtol=1e-6)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    ckpt_lib.save(str(tmp_path), 7, tree)
    template = jax.tree_util.tree_map(jnp.zeros_like, tree)
    out, step = ckpt_lib.restore(str(tmp_path), template)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_keep_and_latest(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        ckpt_lib.save(str(tmp_path), s, tree, keep=2)
    assert ckpt_lib.all_steps(str(tmp_path)) == [4, 5]
    assert ckpt_lib.latest_step(str(tmp_path)) == 5


def test_checkpoint_async(tmp_path):
    tree = {"x": jnp.full((128,), 3.0)}
    t = ckpt_lib.save_async(str(tmp_path), 1, tree)
    t.join(10)
    out, step = ckpt_lib.restore(str(tmp_path), tree)
    np.testing.assert_allclose(np.asarray(out["x"]), 3.0)


def test_checkpoint_elastic_resharding(tmp_path):
    """Host-global arrays restore onto a different device layout."""
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ckpt_lib.save(str(tmp_path), 0, tree)
    # "new cluster": single-device sharding spec (degenerate but exercises
    # the device_put path with an explicit Sharding object)
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    sh = NamedSharding(mesh, P("data", None))
    out, _ = ckpt_lib.restore(str(tmp_path), tree, sharding_tree=sh)
    assert out["w"].sharding == sh
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt_lib.save(str(tmp_path), 0, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        ckpt_lib.restore(str(tmp_path), {"w": jnp.zeros((3, 3))})


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_heartbeat_and_quorum():
    clock = [0.0]
    hb = fault_lib.Heartbeat(timeout_s=10, clock=lambda: clock[0])
    hb.beat("w0"); hb.beat("w1")
    assert hb.quorum(2)
    clock[0] = 15.0
    hb.beat("w0")
    assert hb.alive("w0") and not hb.alive("w1")
    assert hb.dead_workers() == ["w1"]
    assert not hb.quorum(2)


def test_straggler_detector():
    det = fault_lib.StragglerDetector(z_threshold=3.0)
    flags = [det.observe(1.0 + 0.01 * (i % 3)) for i in range(30)]
    assert not any(flags)
    assert det.observe(10.0)   # 10x step time => straggler
    assert not det.observe(1.0)


def test_trainer_crash_restart_is_deterministic(tmp_path):
    """A run with an injected crash equals an uninterrupted run, bit-for-bit
    (per-step data + checkpoints => full replay determinism)."""
    def make(run_dir, fail):
        params, loss_fn, target = _quadratic_problem(3)

        def data_fn(step):
            return {"step": jnp.asarray(step)}

        def loss(p, batch):
            return jnp.mean((p["w"] - target) ** 2), {}

        tr = Trainer(loss, data_fn, params, AdamW(lr=0.05, clip_norm=None),
                     TrainerConfig(steps=20, ckpt_every=5, log_every=0,
                                   ckpt_dir=str(run_dir), ckpt_async=False))
        if fail:
            tr.fault_injector = fault_lib.FaultInjector(fail_at=[12])
        return tr

    clean = make(tmp_path / "clean", fail=False)
    clean.run(max_restarts=0)

    faulty = make(tmp_path / "faulty", fail=True)
    faulty.run(max_restarts=2)

    np.testing.assert_array_equal(np.asarray(clean.params["w"]),
                                  np.asarray(faulty.params["w"]))


def test_run_resilient_gives_up_after_max_restarts():
    calls = []

    def run_from(start):
        calls.append(start)
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError):
        fault_lib.run_resilient(run_from, lambda: 0, max_restarts=2)
    assert len(calls) == 3  # initial + 2 restarts


# ---------------------------------------------------------------------------
# grad accumulation & compression
# ---------------------------------------------------------------------------

def test_grad_accum_matches_full_batch():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (8, 2))
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    y = jax.random.normal(jax.random.PRNGKey(2), (16, 2))

    def loss(p, batch):
        pred = batch["x"] @ p["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    opt = AdamW(lr=1e-2, clip_norm=None)
    outs = {}
    for accum in (1, 4):
        params = {"w": w}
        state = opt.init(params)
        step = make_train_step(loss, opt, grad_accum=accum, donate=False)
        params, state, _ = step(params, state, {"x": x, "y": y})
        outs[accum] = params["w"]
    np.testing.assert_allclose(np.asarray(outs[1]), np.asarray(outs[4]),
                               rtol=2e-4, atol=2e-5)


def test_compress_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    codes, scale = collectives.compress(x)
    assert codes.dtype == jnp.int8
    y = collectives.decompress(codes, scale, x.shape)
    blocks_max = float(jnp.abs(x).max())
    assert float(jnp.abs(x - y).max()) <= blocks_max / 127 + 1e-6


def test_error_feedback_preserves_sum():
    """Σ_t wire_t + residual_T == Σ_t grad_t (exact bookkeeping)."""
    key = jax.random.PRNGKey(1)
    res = jnp.zeros((300,))
    total_wire = jnp.zeros((300,))
    total_grad = jnp.zeros((300,))
    for t in range(20):
        g = jax.random.normal(jax.random.fold_in(key, t), (300,))
        wire, res = collectives.ef_compress(g, res)
        total_wire += wire
        total_grad += g
    np.testing.assert_allclose(np.asarray(total_wire + res),
                               np.asarray(total_grad), rtol=1e-4, atol=1e-4)


def test_compression_ratio():
    r = collectives.compression_ratio((1024, 1024))
    assert 3.5 < r < 4.0  # ~4x vs fp32 with per-block scale overhead
