"""Multi-tenant serving: routing, artifact caching, per-model isolation.

The property suite pinning PR 10's fleet semantics:

* grouped ``SlotScheduler`` — random admit/step/retire/cancel
  interleavings over 3 model groups never leak a slot across a group
  boundary, conserve requests per group, and keep KV blocks inside their
  group's arena partitions;
* ``ModelRegistry`` — hypothesis sweeps of artifact/pin/evict sequences
  hold resident bytes to the byte budget, never evict an in-use
  artifact (deferred instead), and re-pack bitwise-identically;
* routing — ``Server.submit(model=m)`` is bitwise-identical to model
  ``m``'s standalone ``pipe.basecall``, interleaved with other tenants,
  after an LRU evict -> re-pack cycle, on the golden read, and under the
  4-device host mesh;
* metrics — per-model rows, atomic reset, unknown-model errors counted
  once (error, never also a queue rejection).
"""
import numpy as np
import pytest

pytest.importorskip("jax")

import jax  # noqa: E402
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.serve.api import BasecallRequest, LMRequest, Server  # noqa: E402
from repro.serve.multitenant import MultiModelBasecallEngine  # noqa: E402
from repro.serve.registry import ModelRegistry  # noqa: E402
from repro.serve.scheduler import SlotScheduler  # noqa: E402


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_pipes():
    """Two genuinely different tenants: a tiny Guppy and a tiny Chiron."""
    from repro.pipeline import BasecallPipeline

    def mk(preset, seed):
        p = BasecallPipeline.from_preset(preset, scale="tiny",
                                         backend="ref", beam_width=3)
        p.init_params(jax.random.PRNGKey(seed))
        return p

    return {"small": mk("guppy", 0), "large": mk("chiron", 1)}


def _registry(pipes, **kw):
    reg = ModelRegistry(**kw)
    for mid, p in pipes.items():
        reg.register_basecaller(mid, p)
    return reg


def _server(pipes, batch_slots=2, **srv_kw):
    reg = _registry(pipes)
    eng = MultiModelBasecallEngine(reg, list(pipes), batch_slots=batch_slots)
    return Server(eng, **srv_kw), reg, eng


def _sig(pipe, rng, n_windows=2.5):
    return rng.standard_normal(
        int(n_windows * pipe.mcfg.input_len)).astype(np.float32)


def _same_result(a, b):
    return np.array_equal(a.read, b.read) and a.length == b.length


# ---------------------------------------------------------------------------
# grouped SlotScheduler: the interleaving property sweep
# ---------------------------------------------------------------------------

class _Tok:
    __slots__ = ("rid", "gid")

    def __init__(self, rid, gid):
        self.rid, self.gid = rid, gid


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6),
       paged=st.sampled_from([False, True]))
def test_scheduler_group_interleaving_property(seed, paged):
    """Random admit/retire/release/cancel interleavings over 3 model
    groups: no slot leakage across groups, per-group request
    conservation, KV blocks confined to the owning group's partitions."""
    rng = np.random.default_rng(seed)
    groups = {"a": 2, "b": 4, "c": 2}
    kv_groups, kv_blocks = (4, 16) if paged else (1, 0)
    sched = SlotScheduler(8, kv_blocks=kv_blocks, kv_groups=kv_groups,
                          slot_groups=groups)
    spp = 8 // kv_groups
    submitted = {g: 0 for g in groups}
    finished = {g: 0 for g in groups}
    dropped = {g: 0 for g in groups}   # released or cancelled
    next_rid = 0

    def check():
        # 1) no leakage: every occupied slot's request belongs to the
        #    group owning that slot
        for s, req in enumerate(sched.slots):
            if req is not None:
                assert sched.group_of_slot(s) == req.gid
        # 2) per-group conservation
        for g in groups:
            active = sum(1 for s in sched.group_range(g)
                         if sched.slots[s] is not None)
            queued = sum(1 for q in sched.queue if q.gid == g)
            pending_fin = sum(1 for q in sched.finished.values()
                              if q.gid == g)
            assert (active + queued + finished[g] + pending_fin
                    + dropped[g]) == submitted[g], g
        # 3) KV blocks never cross the owning group's partitions, and
        #    nothing leaks (free + held == arena)
        if kv_blocks:
            held = 0
            for s, blocks in enumerate(sched.slot_blocks):
                held += len(blocks)
                for b in blocks:
                    assert (sched.group_of_partition(b // (kv_blocks
                                                           // kv_groups))
                            == sched.group_of_slot(s))
                    assert sched.group_of(s) == b // (kv_blocks // kv_groups)
            assert held + sched.free_blocks() == kv_blocks

    need_fn = (lambda r: 1 + (r.rid % 2)) if paged else None
    for _ in range(60):
        op = rng.integers(0, 4)
        if op == 0:                                        # submit
            gid = ("a", "b", "c")[rng.integers(0, 3)]
            sched.submit(_Tok(next_rid, gid))
            submitted[gid] += 1
            next_rid += 1
        elif op == 1:                                      # admit
            sched.admit(lambda slot, r: None, need_fn=need_fn,
                        group_fn=lambda r: r.gid)
        elif op == 2:                                      # retire/release
            occupied = [s for s, r in enumerate(sched.slots)
                        if r is not None]
            if occupied:
                s = occupied[rng.integers(0, len(occupied))]
                req = sched.slots[s]
                if rng.integers(0, 2):
                    sched.retire(s, req.rid)
                else:
                    sched.release(s)
                    dropped[req.gid] += 1
        else:                                              # cancel / drain
            if sched.queue and rng.integers(0, 2):
                q = sched.queue[rng.integers(0, len(sched.queue))]
                assert sched.cancel_queued(q)
                dropped[q.gid] += 1
            else:
                for rid, req in sched.drain_finished().items():
                    finished[req.gid] += 1
        check()
    # partitions must subdivide groups cleanly in the paged layout
    if paged:
        for g in groups:
            rng_g = sched.group_range(g)
            assert rng_g.start % spp == 0 and len(rng_g) % spp == 0


def test_scheduler_group_validation():
    # lane counts must sum to the pool
    with pytest.raises(ValueError, match="sum"):
        SlotScheduler(8, slot_groups={"a": 2, "b": 2})
    # every group must cover whole KV partitions
    with pytest.raises(ValueError, match="partition"):
        SlotScheduler(8, kv_blocks=16, kv_groups=4,
                      slot_groups={"a": 3, "b": 5})
    # multiple groups need a group_fn at admit
    s = SlotScheduler(4, slot_groups={"a": 2, "b": 2})
    s.submit(_Tok(0, "a"))
    with pytest.raises(ValueError, match="group_fn"):
        s.admit(lambda slot, r: None)
    # unknown group id surfaces, not silently mis-places
    s.submit(_Tok(1, "zz"))
    with pytest.raises(KeyError, match="zz"):
        s.admit(lambda slot, r: None, group_fn=lambda r: r.gid)


def test_scheduler_per_group_head_of_line():
    """A full group blocks only ITS OWN queue tail; other groups admit
    past it (the single-group case keeps classic global HOL blocking)."""
    s = SlotScheduler(4, slot_groups={"a": 2, "b": 2})
    for rid, gid in enumerate(["a", "a", "a", "b"]):
        s.submit(_Tok(rid, gid))
    got = s.admit(lambda slot, r: None, group_fn=lambda r: r.gid)
    assert got == [0, 1, 2]        # both a-lanes + the b request behind
    assert [q.gid for q in s.queue] == ["a"]
    assert s.occupancy(group="a") == 1.0
    assert s.occupancy(group="b") == 0.5
    assert s.occupancy() == 0.75


# ---------------------------------------------------------------------------
# ModelRegistry: budget accounting property sweep
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_registry_budget_property(seed):
    """Random artifact/pin/unpin/evict sequences: resident bytes stay at
    or under the budget except for in-use (pinned/deferred) entries, a
    pinned artifact is never dropped, rebuilds are value-identical."""
    rng = np.random.default_rng(seed)
    ids = [f"m{i}" for i in range(4)]
    reg = ModelRegistry(budget_bytes=1200)
    first_build = {}
    for i, mid in enumerate(ids):
        def pack(i=i, mid=mid):
            return np.full(50 * (i + 1), i, np.float64)  # 400/800/1200/1600 B
        reg.register(mid, pack)
    pins = {mid: 0 for mid in ids}

    def check():
        over = reg.resident_bytes - 1200
        if over > 0:
            # every byte over budget is excused by an in-use entry
            # (deferred eviction), never by a silently-ignored budget
            excused = [mid for mid in reg.resident()
                       if reg._entries[mid].pins > 0
                       or reg._entries[mid].evict_deferred
                       or reg._entries[mid].evict_requested]
            assert excused, (reg.resident(), reg.resident_bytes)
        for mid, n in pins.items():
            if n > 0:
                assert mid in reg.resident(), f"pinned {mid} evicted"

    for _ in range(50):
        mid = ids[rng.integers(0, len(ids))]
        op = rng.integers(0, 4)
        if op == 0:
            art = reg.artifact(mid)
            if mid in first_build:
                assert np.array_equal(art, first_build[mid])
            else:
                first_build[mid] = np.array(art, copy=True)
        elif op == 1:
            if mid in reg.resident():
                reg.pin(mid)
                pins[mid] += 1
        elif op == 2:
            if pins[mid] > 0:
                reg.unpin(mid)
                pins[mid] -= 1
        else:
            reg.evict(mid)
        check()
    # drain: with every pin released, the budget must be enforceable
    for mid, n in pins.items():
        for _ in range(n):
            reg.unpin(mid)
    reg.sweep()
    assert reg.resident_bytes <= 1200


def test_registry_inflight_eviction_deferred_not_dropped():
    reg = ModelRegistry(budget_bytes=500)
    reg.register("hot", lambda: np.zeros(50, np.float64))    # 400 B
    reg.register("cold", lambda: np.zeros(50, np.float64))
    reg.artifact("hot")
    reg.pin("hot")
    # explicit evict of the in-use artifact: deferred, not dropped
    assert reg.evict("hot") is False
    assert "hot" in reg.resident()
    # budget pressure from another tenant cannot drop it either
    reg.artifact("cold")
    assert "hot" in reg.resident()
    assert reg.stats().deferred >= 1
    # once idle, the deferral lands at the next registry operation
    reg.unpin("hot")
    assert "hot" not in reg.resident()
    # the recipe survives eviction: the artifact comes back on demand
    assert reg.artifact("hot") is not None
    assert reg.stats().rebuilds >= 1


def test_registry_lru_evicts_coldest():
    reg = ModelRegistry(budget_bytes=900)                    # fits two
    for mid in ("a", "b", "c"):
        reg.register(mid, lambda mid=mid: np.zeros(50, np.float64))
    reg.artifact("a")
    reg.artifact("b")
    reg.artifact("a")              # a is now hotter than b
    reg.artifact("c")              # must evict b, the coldest
    assert set(reg.resident()) == {"a", "c"}
    with pytest.raises(KeyError, match="unknown model"):
        reg.artifact("nope")


def test_registry_bitwise_recall_real_artifacts(tiny_pipes):
    """Evict -> re-pack returns a bitwise-identical artifact, for a
    basecaller PackedParams and an LM pack_lm_serving bundle alike."""
    from repro.core.quant import QuantConfig
    from repro.models import lm as lm_lib

    reg = _registry(tiny_pipes)
    cfg = lm_lib.LMConfig(
        n_layers=1, d_model=16, n_heads=2, n_kv_heads=2, d_ff=32,
        vocab_size=32, quant=QuantConfig(enabled=True, bits_w=5, bits_a=5),
        remat=False)
    reg.register_lm("lm", lm_lib.init_lm(jax.random.PRNGKey(7), cfg), cfg)
    for mid in ("small", "large", "lm"):
        a1 = jax.tree_util.tree_leaves(reg.artifact(mid))
        assert reg.evict(mid)
        a2 = jax.tree_util.tree_leaves(reg.artifact(mid))
        assert len(a1) == len(a2)
        for l1, l2 in zip(a1, a2):
            assert np.array_equal(np.asarray(l1), np.asarray(l2)), mid
    assert reg.stats().rebuilds == 3


# ---------------------------------------------------------------------------
# routing: Server.submit(model=m) ≡ standalone pipe.basecall, bitwise
# ---------------------------------------------------------------------------

def test_routing_parity_interleaved(tiny_pipes):
    srv, _, _ = _server(tiny_pipes)
    rng = np.random.default_rng(3)
    jobs = []
    for i in range(3):
        for mid, pipe in tiny_pipes.items():
            sig = _sig(pipe, rng, n_windows=1.5 + i)
            jobs.append((mid, sig,
                         srv.submit(BasecallRequest(signal=sig, model=mid))))
    srv.run_until_idle()
    for mid, sig, fut in jobs:
        got = fut.result()
        assert got.status == "ok"
        assert _same_result(got.value, tiny_pipes[mid].basecall(sig)), mid


def test_routing_parity_after_evict_repack(tiny_pipes):
    srv, reg, _ = _server(tiny_pipes)
    rng = np.random.default_rng(4)
    sigs = {mid: _sig(p, rng) for mid, p in tiny_pipes.items()}
    for mid, pipe in tiny_pipes.items():
        r1 = srv.submit(
            BasecallRequest(signal=sigs[mid], model=mid)).result().value
        assert reg.evict(mid), mid       # cold between requests -> dropped
        r2 = srv.submit(
            BasecallRequest(signal=sigs[mid], model=mid)).result().value
        assert _same_result(r1, r2)
        assert _same_result(r2, pipe.basecall(sigs[mid]))
    assert reg.stats().rebuilds == len(tiny_pipes)


def test_default_model_routing(tiny_pipes):
    srv, _, eng = _server(tiny_pipes)
    rng = np.random.default_rng(5)
    sig = _sig(tiny_pipes[eng.default_model], rng)
    res = srv.submit(BasecallRequest(signal=sig)).result()   # no model=
    assert res.status == "ok"
    assert _same_result(res.value,
                        tiny_pipes[eng.default_model].basecall(sig))


def test_routing_parity_golden_read(golden_pipeline, golden_read,
                                    tiny_pipes):
    """The acceptance bar: a Server hosting the golden demo model next to
    a tiny tenant routes per-request and stays bitwise-identical to each
    model's standalone pipeline on the golden read — including after an
    LRU evict -> re-pack cycle."""
    golden_pipe, _, _ = golden_pipeline
    _, sig = golden_read
    tenants = {"golden": golden_pipe, "tiny": tiny_pipes["large"]}
    reg = ModelRegistry()
    for mid, p in tenants.items():
        reg.register_basecaller(mid, p)
    srv = Server(MultiModelBasecallEngine(reg, list(tenants)))
    for mid, pipe in tenants.items():
        got = srv.submit(BasecallRequest(signal=sig, model=mid)).result()
        assert got.status == "ok"
        assert _same_result(got.value, pipe.basecall(sig)), mid
    # evict BOTH artifacts; recall must reproduce the same reads
    for mid in tenants:
        assert reg.evict(mid)
    for mid, pipe in tenants.items():
        got = srv.submit(BasecallRequest(signal=sig, model=mid)).result()
        assert _same_result(got.value, pipe.basecall(sig)), mid
    assert reg.stats().rebuilds == 2


def test_routing_parity_mesh4(tiny_pipes, host_mesh4):
    """1-dev ≡ 4-dev: the multi-tenant engine under the host mesh returns
    the same bits as each tenant's (single-device) standalone pipeline."""
    from repro.dist import sharding as shd

    reg = _registry(tiny_pipes)
    with shd.use_mesh(host_mesh4):
        eng = MultiModelBasecallEngine(reg, {"small": 2, "large": 1})
    assert eng.dp == 4 and eng.B == 12
    srv = Server(eng)
    rng = np.random.default_rng(6)
    jobs = []
    for mid, pipe in tiny_pipes.items():
        sig = _sig(pipe, rng)
        jobs.append((mid, sig,
                     srv.submit(BasecallRequest(signal=sig, model=mid))))
    srv.run_until_idle()
    for mid, sig, fut in jobs:
        assert _same_result(fut.result().value,
                            tiny_pipes[mid].basecall(sig)), mid
    met = srv.metrics()
    assert met.devices == 4 and len(met.occupancy_per_device) == 4


# ---------------------------------------------------------------------------
# metrics: per-model rows, atomic reset, errors counted once
# ---------------------------------------------------------------------------

def test_unknown_model_error_counted_once(tiny_pipes):
    srv, _, _ = _server(tiny_pipes)
    rng = np.random.default_rng(7)
    sig = _sig(tiny_pipes["small"], rng)
    res = srv.submit(BasecallRequest(signal=sig, model="nope")).result()
    assert res.status == "error"
    assert "unknown model" in res.error and "'nope'" in res.error
    met = srv.metrics()
    # counted ONCE: an error, never also a queue rejection
    assert met.errors == 1 and met.rejected == 0
    assert met.per_model["nope"].errors == 1
    assert met.per_model["nope"].submitted == 1
    # an unknown-model EMPTY signal is still an error, not an empty ok
    res = srv.submit(BasecallRequest(signal=np.zeros((0,), np.float32),
                                     model="nope")).result()
    assert res.status == "error"
    assert srv.metrics().errors == 2


def test_per_model_metrics_rows_and_atomic_reset(tiny_pipes):
    srv, _, _ = _server(tiny_pipes)
    rng = np.random.default_rng(8)
    for mid, pipe in tiny_pipes.items():
        srv.submit(BasecallRequest(signal=_sig(pipe, rng), model=mid))
    srv.run_until_idle()
    met = srv.metrics()
    assert set(met.per_model) == {"small", "large"}
    for mid in tiny_pipes:
        pm = met.per_model[mid]
        assert pm.submitted == 1 and pm.completed == 1 and pm.errors == 0
        assert pm.occupancy > 0.0
        assert pm.latency_p99_s >= pm.latency_p50_s >= 0.0
    names = [r[0] for r in met.rows()]
    for mid in tiny_pipes:
        for leaf in ("requests_per_s", "occupancy", "latency_p50_s",
                     "latency_p99_s", "errors"):
            assert f"serve/model/{mid}/{leaf}" in names
    # atomic reset: pool-wide counters AND every per-model slice zero in
    # the same call — no epoch skew between them
    srv.reset_metrics()
    met = srv.metrics()
    assert met.submitted == 0 and met.completed == 0 and met.errors == 0
    assert met.per_model == {}
    assert met.steps == 0 and met.occupancy == 0.0


def test_per_model_isolation_under_load(tiny_pipes):
    """A burst that saturates one tenant's group never borrows the other
    tenant's lanes, and the starved tenant keeps completing."""
    srv, _, eng = _server(tiny_pipes, batch_slots=2, max_queue=64)
    rng = np.random.default_rng(9)
    futs = {"small": [], "large": []}
    for _ in range(6):
        futs["small"].append(srv.submit(BasecallRequest(
            signal=_sig(tiny_pipes["small"], rng, 4.0), model="small")))
    futs["large"].append(srv.submit(BasecallRequest(
        signal=_sig(tiny_pipes["large"], rng, 6.0), model="large")))
    # drive a few steps: small's group (2 lanes) is saturated, large must
    # still admit into its own group immediately
    for _ in range(2):
        srv.step()
    small_rng = eng.sched.group_range("small")
    large_rng = eng.sched.group_range("large")
    for s in small_rng:
        if eng.sched.slots[s] is not None:
            assert eng.sched.slots[s].model == "small"
    assert any(eng.sched.slots[s] is not None for s in large_rng)
    for s in large_rng:
        if eng.sched.slots[s] is not None:
            assert eng.sched.slots[s].model == "large"
    srv.run_until_idle()
    for mid, fs in futs.items():
        for f in fs:
            assert f.result().status == "ok", mid


# ---------------------------------------------------------------------------
# single-model engines: model_id routing + registry construction
# ---------------------------------------------------------------------------

def test_basecall_engine_model_id_routing(tiny_pipes):
    from repro.serve.basecall_engine import BasecallEngine

    reg = _registry(tiny_pipes)
    eng = BasecallEngine.from_registry(reg, "small", batch_slots=2)
    srv = Server(eng)
    rng = np.random.default_rng(10)
    sig = _sig(tiny_pipes["small"], rng)
    ok = srv.submit(BasecallRequest(signal=sig, model="small")).result()
    assert ok.status == "ok"
    assert _same_result(ok.value, tiny_pipes["small"].basecall(sig))
    # unrouted requests still serve (engine default)
    assert srv.submit(BasecallRequest(signal=sig)).result().status == "ok"
    bad = srv.submit(BasecallRequest(signal=sig, model="large")).result()
    assert bad.status == "error" and "unknown model" in bad.error
    assert srv.metrics().per_model["large"].errors == 1


def test_lm_engine_from_registry_and_routing():
    from repro.core.quant import QuantConfig
    from repro.models import lm as lm_lib
    from repro.serve.engine import ServingEngine

    cfg = lm_lib.LMConfig(
        n_layers=1, d_model=16, n_heads=2, n_kv_heads=2, d_ff=32,
        vocab_size=32, quant=QuantConfig(enabled=True, bits_w=5, bits_a=5),
        remat=False)
    params = lm_lib.init_lm(jax.random.PRNGKey(11), cfg)
    reg = ModelRegistry()
    reg.register_lm("lm-a", params, cfg)
    eng = ServingEngine.from_registry(reg, "lm-a", batch_slots=2, max_len=16)
    oracle = ServingEngine(params, cfg, batch_slots=2, max_len=16)
    prompt = np.asarray([1, 2, 3], np.int32)
    req = LMRequest(prompt=prompt, max_tokens=4, model="lm-a")
    got = Server(eng).submit(req).result()
    ref = Server(oracle).submit(LMRequest(prompt=prompt,
                                          max_tokens=4)).result()
    assert got.status == "ok" and got.value == ref.value
    # misrouted LM requests error clearly, counted once
    srv = Server(eng)
    bad = srv.submit(LMRequest(prompt=prompt, max_tokens=4,
                               model="lm-b")).result()
    assert bad.status == "error" and "unknown model" in bad.error
    met = srv.metrics()
    assert met.errors == 1 and met.rejected == 0
    # a registry entry that is not an LM is rejected at construction
    reg2 = ModelRegistry()
    reg2.register("notlm", lambda: np.zeros(4))
    with pytest.raises(TypeError, match="not an lm"):
        ServingEngine.from_registry(reg2, "notlm")


def test_streaming_engine_model_routing(tiny_pipes):
    from repro.serve.streaming import StreamingBasecallEngine, StreamRequest

    eng = StreamingBasecallEngine(tiny_pipes["small"], batch_slots=2,
                                  model_id="small")
    srv = Server(eng)
    rng = np.random.default_rng(12)
    sig = _sig(tiny_pipes["small"], rng, 1.5)
    chunks = np.array_split(sig, 3)
    ok = srv.submit(StreamRequest(chunks=chunks, model="small")).result()
    assert ok.status == "ok"
    bad = srv.submit(StreamRequest(chunks=chunks, model="large")).result()
    assert bad.status == "error" and "unknown model" in bad.error


def test_multitenant_engine_direct_submit_validates(tiny_pipes):
    _, _, eng = _server(tiny_pipes)
    with pytest.raises(ValueError, match="unknown model"):
        eng.submit(eng.make_request(
            0, BasecallRequest(signal=np.zeros(8, np.float32),
                               model="nope")))
