"""Persistent-kernel differentials: ``gru_seq`` + ``beam_merge_multiframe``.

Both persistent kernels must be BITWISE interchangeable with the per-step
paths they replace, on every backend:

  gru_seq                 ≡ lax.scan over the per-step ``gru_cell`` op
  strip-mode hash decode  ≡ per-frame ``beam_merge_topk`` decode

including ragged tails (batch not a tile multiple, ``logit_length`` <
frames, frame count not a strip multiple), the golden read, and the
dp-sharded 4-device mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ctc as ctc_lib
from repro.dist import sharding as shd
from repro.kernels import registry
from repro.models import basecaller as bc

jax.config.update("jax_platform_name", "cpu")

BACKENDS = ("auto", "ref", "interpret")
NEG = -1.0e9


# ---------------------------------------------------------------------------
# gru_seq: one persistent launch ≡ per-step scan
# ---------------------------------------------------------------------------

def _per_step_scan(xp, h0, u, b, backend):
    cell = registry.get_op("gru_cell", backend)

    def step(h, x):
        hn = cell(x, h, u, b)
        return hn, hn

    _, ys = jax.lax.scan(step, h0, xp)
    return ys


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), T=st.integers(1, 9),
       B=st.integers(1, 30), H=st.sampled_from((8, 48)))
def test_gru_seq_matches_per_step_scan(seed, T, B, H):
    """The whole-layer walk must equal the per-step oracle bit for bit on
    every backend (batch deliberately ragged vs the bb=128 tile)."""
    rng = np.random.default_rng(seed)
    xp = jnp.asarray(rng.standard_normal((T, B, 3 * H)).astype(np.float32))
    h0 = jnp.asarray(rng.standard_normal((B, H)).astype(np.float32))
    u = jnp.asarray(rng.standard_normal((H, 3 * H)).astype(np.float32) * 0.3)
    b = jnp.asarray(rng.standard_normal((3 * H,)).astype(np.float32) * 0.1)
    for backend in BACKENDS:
        want = _per_step_scan(xp, h0, u, b, backend)
        got = registry.get_op("gru_seq", backend)(xp, h0, u, b)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"backend={backend}")


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_forward_matches_per_step_forward(backend):
    """apply_basecaller(fused_rnn=True) ≡ fused_rnn=False bitwise, float
    and packed params, forward AND reverse (alt-direction) layers."""
    cfg = bc.tiny_preset().with_quant(
        bc.QuantConfig(enabled=True, bits_w=5, bits_a=5))
    params = bc.init_basecaller(jax.random.PRNGKey(0), cfg)
    sig = jax.random.normal(jax.random.PRNGKey(1), (3, cfg.input_len, 1))
    be = registry.Backend(backend)
    base = bc.apply_basecaller(params, sig, cfg, be, fused_rnn=False)
    fused = bc.apply_basecaller(params, sig, cfg, be, fused_rnn=True)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(base))
    packed = bc.pack_basecaller(params, cfg)
    pk0 = bc.apply_basecaller_packed(packed, sig, cfg, be, fused_rnn=False)
    pk1 = bc.apply_basecaller_packed(packed, sig, cfg, be, fused_rnn=True)
    np.testing.assert_array_equal(np.asarray(pk1), np.asarray(pk0))


# ---------------------------------------------------------------------------
# beam_merge_multiframe: strip decode ≡ per-frame decode
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), T=st.integers(1, 14),
       W=st.integers(1, 6), F=st.sampled_from((2, 4, 8)))
def test_strip_decode_matches_per_frame_decode(seed, T, W, F):
    """Full decoder outputs (prefixes, lengths, scores) bitwise equal
    between strip mode and the per-frame oracle on every backend —
    including ragged tails (logit_length < T, T not a multiple of F)."""
    rng = np.random.default_rng(seed)
    B, A = 3, 5
    lp = jax.nn.log_softmax(jnp.asarray(
        rng.standard_normal((B, T, A)).astype(np.float32) * 2), axis=-1)
    ll = jnp.asarray(rng.integers(0, T + 1, (B,)), jnp.int32)
    want = ctc_lib.ctc_beam_search_hash_batch(
        lp, beam_width=W, max_len=max(T // 2, 1), logit_lengths=ll,
        backend="ref")
    for backend in BACKENDS:
        got = ctc_lib.ctc_beam_search_hash_batch(
            lp, beam_width=W, max_len=max(T // 2, 1), logit_lengths=ll,
            backend=backend, strip_frames=F)
        for w, g, name in zip(want, got, ("prefixes", "lengths", "scores")):
            np.testing.assert_array_equal(
                np.asarray(w), np.asarray(g),
                err_msg=f"backend={backend} F={F} {name}")


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_beam_merge_multiframe_op_backend_parity(seed):
    """The raw op on arbitrary (not merely reachable) state: all six
    outputs bitwise equal across backends."""
    rng = np.random.default_rng(seed)
    B, F, A, W, L = 2, 5, 5, 6, 9
    lp = jax.nn.log_softmax(jnp.asarray(
        rng.standard_normal((B, F, A)).astype(np.float32)), axis=-1)
    active = jnp.asarray(rng.integers(0, 2, (B, F)), jnp.int32)
    keys = jnp.asarray(rng.integers(-2**31, 2**31 - 1, (B, W)), jnp.int32)
    pb = jnp.asarray(rng.standard_normal((B, W)).astype(np.float32) * 4)
    pnb = jnp.asarray(rng.standard_normal((B, W)).astype(np.float32) * 4)
    last = jnp.asarray(rng.integers(-1, A - 1, (B, W)), jnp.int32)
    lengths = jnp.asarray(rng.integers(0, L + 1, (B, W)), jnp.int32)
    want = registry.get_op("beam_merge_multiframe", "ref")(
        lp, active, keys, pb, pnb, last, lengths, blank=A - 1, L=L)
    for backend in ("interpret", "auto"):
        got = registry.get_op("beam_merge_multiframe", backend)(
            lp, active, keys, pb, pnb, last, lengths, blank=A - 1, L=L)
        for w, g, name in zip(want, got,
                              ("idx", "keys", "pb", "pnb", "last", "len")):
            np.testing.assert_array_equal(
                np.asarray(w), np.asarray(g),
                err_msg=f"backend={backend} {name}")


# ---------------------------------------------------------------------------
# end to end: golden read + 4-device mesh
# ---------------------------------------------------------------------------

def test_golden_read_fused_equals_oracle_pipeline(golden_pipeline,
                                                  golden_read):
    """The golden read through the default (persistent-kernel) pipeline ≡
    a per-frame (decode_strip=None) oracle pipeline, bit for bit."""
    from repro.pipeline import BasecallPipeline

    pipe, params, _ = golden_pipeline
    seq, sig = golden_read
    oracle = BasecallPipeline(pipe.mcfg, backend=pipe.backend,
                              beam_width=pipe.beam_width, decode_strip=None,
                              params=params)
    got = pipe.basecall(sig)
    want = oracle.basecall(sig)
    assert got.length == want.length
    np.testing.assert_array_equal(got.read, want.read)
    np.testing.assert_array_equal(got.window_reads, want.window_reads)
    np.testing.assert_array_equal(got.window_lengths, want.window_lengths)
    # and the default pipeline still decodes the golden genome faithfully
    assert got.length > 0


@pytest.mark.parametrize("backend", ("ref", "interpret"))
def test_strip_decode_mesh_parity(host_mesh4, backend):
    """1-device ≡ 4-device dp mesh on the strip-decode serving path."""
    from repro.pipeline import BasecallPipeline

    pipe = BasecallPipeline.from_preset(
        "guppy", scale="tiny",
        quant=bc.QuantConfig(enabled=True, bits_w=5, bits_a=5),
        backend=backend, beam_width=3, decode_strip=4)
    pipe.init_params(jax.random.PRNGKey(2))
    sig = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (700,)))
    single = pipe.basecall(sig)
    with shd.use_mesh(host_mesh4):
        sharded = pipe.basecall(sig)
    assert single.length == sharded.length
    np.testing.assert_array_equal(single.read, sharded.read)
    np.testing.assert_array_equal(single.window_reads, sharded.window_reads)
    np.testing.assert_array_equal(single.window_lengths,
                                  sharded.window_lengths)
