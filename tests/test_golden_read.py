"""Golden-read regression: deterministic genome -> signal -> basecall.

The session-scoped ``golden_pipeline`` fixture (conftest.py) trains the
quickstart recipe with fixed seeds, and ``golden_read`` renders a known
60-base genome through the synthetic pore channel.  Every threshold here
is pinned comfortably below the deterministically achieved value, so a
decoder / merge-kernel / voting change that silently degrades accuracy
trips the gate while numerics-level jitter does not.

Achieved values at pin time (jax CPU, seed-fixed):
  window read accuracy  0.543
  consensus identity    0.467
"""
import numpy as np

from repro.core import metrics

WINDOW_ACC_FLOOR = 0.45
CONSENSUS_IDENTITY_FLOOR = 0.35


def _identity(read, length, truth) -> float:
    return 1.0 - metrics.edit_distance(read[: int(length)], truth) / len(truth)


def test_golden_window_read_accuracy(golden_pipeline):
    """Fixed-window serving path: beam-decoded reads vs training labels."""
    from repro.data import genome

    pipe, params, dcfg = golden_pipeline
    batch = genome.batch_for_step(9999, 8, dcfg)          # held-out step
    _, _, top, top_len, _ = pipe.basecall_windows(batch["signal"], params)
    acc = metrics.accuracy(np.asarray(top), np.asarray(top_len),
                           np.asarray(batch["labels"]),
                           np.asarray(batch["label_length"]))
    assert acc >= WINDOW_ACC_FLOOR, f"window read accuracy {acc:.3f}"


def test_golden_consensus_identity(golden_pipeline, golden_read):
    """Long-read path: chunk -> hash-merge beam decode -> vote, vs truth."""
    pipe, params, _ = golden_pipeline
    seq, sig = golden_read
    res = pipe.basecall(sig, params)
    ident = _identity(res.read, res.length, seq)
    assert ident >= CONSENSUS_IDENTITY_FLOOR, (
        f"consensus identity {ident:.3f} (len {res.length} vs {len(seq)})")


def test_golden_packed_bitwise_equals_repack(golden_pipeline, golden_read):
    """PR 3 acceptance: the quantize-once PackedParams serving path is
    bitwise identical to the pre-refactor repack-per-call path on the
    golden read — window reads, lengths AND voted consensus."""
    from repro.pipeline import BasecallPipeline

    pipe, params, _ = golden_pipeline
    _, sig = golden_read
    unpacked = BasecallPipeline(pipe.mcfg, backend=pipe.backend,
                                scfg=pipe.scfg, chunk=pipe.chunk,
                                beam_width=pipe.beam_width, packed=False,
                                params=params)
    want = unpacked.basecall(sig)            # per-call weight repacking
    got = pipe.basecall(sig, params)         # packed artifact (default)
    np.testing.assert_array_equal(got.window_reads, want.window_reads)
    np.testing.assert_array_equal(got.window_lengths, want.window_lengths)
    assert got.length == want.length
    np.testing.assert_array_equal(got.read, want.read)


def test_golden_consensus_matches_engine(golden_pipeline, golden_read):
    """The continuous-batching engine (behind the serving API) must
    reproduce the pipeline's golden consensus exactly (same windows, same
    logit_lengths, same decoder)."""
    from repro.serve import BasecallRequest, Server
    from repro.serve.basecall_engine import BasecallEngine

    pipe, params, _ = golden_pipeline
    seq, sig = golden_read
    want = pipe.basecall(sig, params)
    srv = Server(BasecallEngine(pipe, params=params, batch_slots=2))
    got = srv.submit(BasecallRequest(signal=sig)).result().value
    assert got.length == want.length
    np.testing.assert_array_equal(got.read[: got.length],
                                  want.read[: want.length])
