"""SEAT loss (Eq. 4): views, consensus, loss semantics, gradients."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import seat as seat_lib
from repro.core.quant import QuantConfig
from repro.data import genome
from repro.models import basecaller as bc

jax.config.update("jax_platform_name", "cpu")

CFG = seat_lib.SEATConfig(n_views=3, view_stride=8, max_read_len=32,
                          consensus_span=64, eta=1.0)
MCFG = bc.tiny_preset("guppy")
DCFG = genome.SignalConfig(window=MCFG.input_len, margin=CFG.margin,
                           max_label_len=32)


def _setup(seed=0):
    params = bc.init_basecaller(jax.random.PRNGKey(seed), MCFG)
    batch = genome.sample_batch(jax.random.PRNGKey(seed + 1), 4, DCFG)
    return params, batch


def test_make_views_shapes_and_overlap():
    sig = jnp.arange(2 * (100 + 2 * CFG.margin) * 1, dtype=jnp.float32
                     ).reshape(2, -1, 1)
    views, center = seat_lib.make_views(sig, CFG)
    assert views.shape == (3, 2, 100, 1)
    assert center == 1
    # consecutive views are stride-shifted copies
    np.testing.assert_array_equal(np.asarray(views[0][:, CFG.view_stride:]),
                                  np.asarray(views[1][:, :-CFG.view_stride]))


def test_seat_loss_runs_and_is_finite():
    params, batch = _setup()
    fn = functools.partial(bc.apply_basecaller, params, cfg=MCFG)
    loss, metrics = seat_lib.seat_loss(
        lambda s: fn(s), batch["signal"], batch["labels"],
        batch["label_length"], CFG)
    assert np.isfinite(float(loss))
    assert float(metrics["ctc_g"]) > 0
    assert float(metrics["ctc_c"]) > 0


def test_seat_reduces_to_ctc_when_disabled():
    params, batch = _setup()
    fn = lambda s: bc.apply_basecaller(params, s, MCFG)
    import dataclasses
    off = dataclasses.replace(CFG, enabled=False)
    loss_off, m = seat_lib.seat_loss(fn, batch["signal"], batch["labels"],
                                     batch["label_length"], off)
    # equals plain CTC on the center view
    views, center = seat_lib.make_views(batch["signal"], off)
    from repro.core import ctc as ctc_lib
    want = ctc_lib.ctc_loss_batch(fn(views[center]), batch["labels"],
                                  batch["label_length"]).mean()
    np.testing.assert_allclose(float(loss_off), float(want), rtol=1e-6)


def test_seat_loss_gradients_finite_and_nonzero():
    params, batch = _setup()

    def loss_fn(p):
        fn = lambda s: bc.apply_basecaller(p, s, MCFG)
        loss, _ = seat_lib.seat_loss(fn, batch["signal"], batch["labels"],
                                     batch["label_length"], CFG)
        return loss

    g = jax.grad(loss_fn)(params)
    leaves = jax.tree_util.tree_leaves(g)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in leaves)
    assert any(float(jnp.abs(x).max()) > 0 for x in leaves)


def test_consensus_gap_zero_when_views_agree_with_truth():
    """If the model decodes the ground truth deterministically on every view,
    the consensus equals G and the penalty term vanishes."""
    # build synthetic log-probs directly: (V=3, B=1, T, A) peaked on a path
    A, T = 5, 20
    labels = jnp.asarray([[0, 1, 2, 3, 0, 1]], jnp.int32)
    path = []
    for s in np.asarray(labels[0]):
        path += [int(s), 4]  # symbol then blank
    path += [4] * (T - len(path))
    lp = jnp.log(jax.nn.one_hot(jnp.asarray(path), A) * 0.9999 + 1e-5)
    view_lps = jnp.stack([lp[None], lp[None], lp[None]])  # (3, 1, T, A)

    C, C_len = seat_lib.consensus_reads(view_lps, 1, CFG)
    assert int(C_len[0]) == 6
    np.testing.assert_array_equal(np.asarray(C[0][:6]),
                                  np.asarray(labels[0]))


def test_seat_penalizes_systematic_disagreement():
    """A consensus that differs from G must make loss1 > eta*loss0."""
    params, batch = _setup()
    fn = lambda s: bc.apply_basecaller(params, s, MCFG)
    loss1, m = seat_lib.seat_loss(fn, batch["signal"], batch["labels"],
                                  batch["label_length"], CFG)
    # untrained net decodes garbage => consensus != G => positive gap term
    assert float(loss1) >= CFG.eta * float(m["ctc_g"]) - 1e-5
    assert float(m["consensus_gap"]) > 0
