"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracles,
swept over shapes/dtypes (the kernels target TPU; interpret=True executes
the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import voting
from repro.kernels.ctc_merge.ops import beam_merge_topk, masked_logsumexp
from repro.kernels.ctc_merge.ref import beam_merge_topk_ref, ctc_merge_ref
from repro.kernels.gru_cell.ops import gru_cell
from repro.kernels.gru_cell.ref import gru_cell_ref
from repro.kernels.quant_matmul.ops import qmm_from_float, quant_matmul
from repro.kernels.quant_matmul.ref import quant_matmul_ref
from repro.kernels.vote_cmp.ops import best_match, mismatch_bits
from repro.kernels.vote_cmp.ref import (mismatch_matrix_ref, substring_bits,
                                        vote_cmp_ref)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N", [
    (128, 128, 128),          # exactly one MXU tile
    (256, 384, 128),          # multi-tile K loop
    (64, 100, 33),            # ragged: exercises padding
    (1, 128, 256),            # single row (decode shape)
])
def test_quant_matmul_vs_ref(M, K, N):
    rng = np.random.default_rng(M + K + N)
    xq = jnp.asarray(rng.integers(-15, 16, (M, K)), jnp.int8)
    wq = jnp.asarray(rng.integers(-15, 16, (K, N)), jnp.int8)
    sx = jnp.asarray([[0.017]], jnp.float32)
    sw = jnp.asarray(rng.random((1, N)).astype(np.float32) * 0.05 + 1e-3)
    got = quant_matmul(xq, wq, sx, sw)
    want = quant_matmul_ref(xq, wq, sx, sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("bits", [4, 5, 8])
def test_qmm_float_path_accuracy_scales_with_bits(bits):
    rng = np.random.default_rng(bits)
    x = jnp.asarray(rng.standard_normal((32, 128)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((128, 64)).astype(np.float32))
    y = qmm_from_float(x, w, bits=bits)
    ref = x @ w
    rel = float(jnp.abs(y - ref).max() / jnp.abs(ref).max())
    assert rel < {4: 0.3, 5: 0.12, 8: 0.01}[bits]


def test_quant_matmul_int8_extremes():
    """Full-range int8 codes must not overflow the int32 accumulator."""
    K = 512
    xq = jnp.full((8, K), 127, jnp.int8)
    wq = jnp.full((K, 8), -127, jnp.int8)
    got = quant_matmul(xq, wq, jnp.ones((1, 1)), jnp.ones((1, 8)))
    np.testing.assert_allclose(np.asarray(got), 127 * -127 * K)


# ---------------------------------------------------------------------------
# vote_cmp
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("L1,L2,K", [(40, 40, 8), (100, 64, 16), (33, 57, 5)])
def test_vote_cmp_vs_refs(L1, L2, K):
    rng = np.random.default_rng(L1 + L2 + K)
    r1 = jnp.asarray(rng.integers(0, 4, L1), jnp.int32)
    r2 = jnp.asarray(rng.integers(0, 4, L2), jnp.int32)
    got = mismatch_bits(r1, r2, K)
    want_bits = vote_cmp_ref(substring_bits(r1, K), substring_bits(r2, K))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want_bits))
    # zero bit-mismatch <=> zero symbol-mismatch (encoding is injective)
    sym = mismatch_matrix_ref(r1, r2, K)
    np.testing.assert_array_equal(np.asarray(got == 0), np.asarray(sym == 0))


def test_vote_cmp_finds_planted_match():
    rng = np.random.default_rng(3)
    K = 12
    probe = jnp.asarray(rng.integers(0, 4, K), jnp.int32)
    r1 = jnp.concatenate([jnp.asarray(rng.integers(0, 4, 20), jnp.int32),
                          probe,
                          jnp.asarray(rng.integers(0, 4, 8), jnp.int32)])
    r2 = jnp.concatenate([jnp.asarray(rng.integers(0, 4, 5), jnp.int32),
                          probe,
                          jnp.asarray(rng.integers(0, 4, 30), jnp.int32)])
    i, j, found = best_match(r1, r2, K)
    assert bool(found)
    np.testing.assert_array_equal(np.asarray(r1[int(i):int(i) + K]),
                                  np.asarray(r2[int(j):int(j) + K]))


# ---------------------------------------------------------------------------
# ctc_merge
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,C", [(2, 128), (4, 50), (1, 300)])
def test_ctc_merge_vs_ref(B, C):
    rng = np.random.default_rng(B * C)
    eq = rng.integers(0, 2, (B, C, C)).astype(np.int8)
    eq = np.maximum(eq, np.eye(C, dtype=np.int8)[None])   # self-connected
    scores = rng.standard_normal((B, C)).astype(np.float32) * 5
    got = masked_logsumexp(jnp.asarray(eq), jnp.asarray(scores))
    want = ctc_merge_ref(jnp.asarray(eq), jnp.asarray(scores))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_ctc_merge_paper_fig18():
    """p(A) = p(A A)+p(A -)+p(- A)+p(- -... merge of 4 collapsing candidates."""
    # candidates: [AA, A-, -A, --]; first three collapse to "A"
    p = np.log(np.asarray([[0.09, 0.15, 0.12, 0.2]], np.float32))
    eq = np.zeros((1, 4, 4), np.int8)
    eq[0, :3, :3] = 1       # AA ~ A- ~ -A
    eq[0, 3, 3] = 1         # -- alone
    merged = masked_logsumexp(jnp.asarray(eq), jnp.asarray(p))
    np.testing.assert_allclose(float(jnp.exp(merged[0, 0])), 0.36, atol=1e-6)
    np.testing.assert_allclose(float(jnp.exp(merged[0, 3])), 0.2, atol=1e-6)


def test_ctc_merge_identity_mask_is_noop():
    scores = jnp.asarray(np.random.default_rng(0)
                         .standard_normal((3, 64)).astype(np.float32))
    eq = jnp.broadcast_to(jnp.eye(64, dtype=jnp.int8), (3, 64, 64))
    out = masked_logsumexp(eq, scores)
    np.testing.assert_allclose(np.asarray(out), np.asarray(scores),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# beam_merge_topk (fused hash-merge + top-k)
# ---------------------------------------------------------------------------

NEG = -1.0e9


def _topk_case(rng, B, C, n_keys):
    keys = jnp.asarray(rng.integers(0, n_keys, (B, C)) * 7919 + 13,
                       jnp.int32)   # duplicates guaranteed when n_keys < C
    pb = jnp.asarray(rng.standard_normal((B, C)).astype(np.float32) * 4)
    pnb = jnp.asarray(rng.standard_normal((B, C)).astype(np.float32) * 4)
    return keys, pb, pnb


@pytest.mark.parametrize("B,C,W", [
    (2, 128, 8),     # exactly one lane tile
    (3, 20, 6),      # ragged C (padding path), duplicates
    (1, 300, 16),    # multi-tile padded C
    (2, 5, 5),       # W == C
    (2, 7, 1),       # top-1
])
def test_beam_merge_topk_interpret_vs_ref(B, C, W):
    rng = np.random.default_rng(B * C + W)
    keys, pb, pnb = _topk_case(rng, B, C, max(2, C // 3))
    ir, pr, nr = beam_merge_topk(keys, pb, pnb, W, backend="ref")
    ii, pi, ni = beam_merge_topk(keys, pb, pnb, W, backend="interpret")
    np.testing.assert_array_equal(np.asarray(ir), np.asarray(ii))
    np.testing.assert_allclose(np.asarray(pr), np.asarray(pi),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(nr), np.asarray(ni),
                               rtol=1e-6, atol=1e-6)


def test_beam_merge_topk_matches_bruteforce():
    rng = np.random.default_rng(0)
    B, C, W = 3, 24, 7
    keys, pb, pnb = _topk_case(rng, B, C, 8)
    idx, opb, opnb = beam_merge_topk(keys, pb, pnb, W, backend="ref")
    k, p, n = np.asarray(keys), np.asarray(pb, np.float64), \
        np.asarray(pnb, np.float64)
    for b in range(B):
        canon = [i for i in range(C) if k[b, i] not in k[b, :i]]

        def lse(v):
            m = v.max()
            return m + np.log(np.exp(v - m).sum())

        score = {i: np.logaddexp(lse(p[b, k[b] == k[b, i]]),
                                 lse(n[b, k[b] == k[b, i]])) for i in canon}
        order = sorted(canon, key=lambda i: (-score[i], i))[:W]
        np.testing.assert_array_equal(np.asarray(idx[b]), order)
        for w, i in enumerate(order):
            np.testing.assert_allclose(float(opb[b, w]),
                                       lse(p[b, k[b] == k[b, i]]), rtol=1e-5)


def test_beam_merge_topk_neg_inf_lanes():
    """Dead (NEG) lanes must neither win nor poison the pooled masses, on
    both backends, including when every duplicate of a key is dead."""
    keys = jnp.asarray([[5, 5, 9, 9, 9, 3, 3, 2]], jnp.int32)
    pb = jnp.asarray([[-1., NEG, NEG, NEG, NEG, -2., NEG, NEG]], jnp.float32)
    pnb = jnp.asarray([[NEG, NEG, NEG, NEG, NEG, NEG, -3., NEG]], jnp.float32)
    for backend in ("ref", "interpret"):
        idx, opb, opnb = beam_merge_topk(keys, pb, pnb, 4, backend=backend)
        # live keys 5 (pb=-1) and 3 (lse(-2,-3)) outrank everything dead;
        # all dead lanes tie at NEG in f32 (log-count vanishes below the
        # ulp at 1e9), so the remaining ranks fall to the lowest indices
        np.testing.assert_array_equal(np.asarray(idx[0]), [0, 5, 1, 2])
        np.testing.assert_allclose(float(opb[0, 0]), -1.0, atol=1e-6)
        np.testing.assert_allclose(float(opnb[0, 1]), -3.0, atol=1e-6)


def test_beam_merge_topk_w_greater_than_c():
    rng = np.random.default_rng(9)
    keys, pb, pnb = _topk_case(rng, 2, 6, 4)
    ir, pr, nr = beam_merge_topk(keys, pb, pnb, 10, backend="ref")
    ii, pi, ni = beam_merge_topk(keys, pb, pnb, 10, backend="interpret")
    np.testing.assert_array_equal(np.asarray(ir), np.asarray(ii))
    np.testing.assert_allclose(np.asarray(pr), np.asarray(pi),
                               rtol=1e-6, atol=1e-6)
    # ranks past C are (C-1, NEG, NEG) filler
    assert np.all(np.asarray(ir)[:, 6:] == 5)
    assert np.all(np.asarray(pr)[:, 6:] == NEG)
    assert np.all(np.asarray(nr)[:, 6:] == NEG)


def test_beam_merge_topk_strips_duplicate_mass():
    """Regression: duplicate (non-canonical) lanes selected into a wide
    beam must carry NEG mass, not a second copy of the pooled mass —
    otherwise the decoder double-counts probability."""
    keys = jnp.full((1, 4), 77, jnp.int32)       # all one prefix
    pb = jnp.asarray([[-1.0, -1.5, -2.0, -2.5]], jnp.float32)
    pnb = jnp.full((1, 4), NEG, jnp.float32)
    for backend in ("ref", "interpret"):
        idx, opb, opnb = beam_merge_topk(keys, pb, pnb, 4, backend=backend)
        assert int(idx[0, 0]) == 0
        want = np.log(np.exp(-1.0) + np.exp(-1.5) + np.exp(-2.0)
                      + np.exp(-2.5))
        np.testing.assert_allclose(float(opb[0, 0]), want, rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(opb[0, 1:]),
                                      np.full(3, NEG, np.float32))


def test_beam_merge_topk_accepts_uint32_keys():
    """The decoder passes rolling hashes as uint32; both backends must
    bitcast rather than convert (values above 2^31 stay distinct)."""
    keys = jnp.asarray([[0xFFFFFFFF, 0x80000000, 1, 0xFFFFFFFF]], jnp.uint32)
    pb = jnp.asarray([[-1., -2., -3., -4.]], jnp.float32)
    pnb = jnp.full((1, 4), NEG, jnp.float32)
    for backend in ("ref", "interpret"):
        idx, opb, _ = beam_merge_topk(keys, pb, pnb, 3, backend=backend)
        np.testing.assert_array_equal(np.asarray(idx[0]), [0, 1, 2])
        np.testing.assert_allclose(
            float(opb[0, 0]), np.logaddexp(-1.0, -4.0), rtol=1e-6)


# ---------------------------------------------------------------------------
# gru_cell
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H", [(128, 96), (64, 128), (7, 96), (256, 64)])
def test_gru_cell_vs_ref(B, H):
    rng = np.random.default_rng(B + H)
    xp = jnp.asarray(rng.standard_normal((B, 3 * H)).astype(np.float32))
    h = jnp.asarray(rng.standard_normal((B, H)).astype(np.float32))
    u = jnp.asarray(rng.standard_normal((H, 3 * H)).astype(np.float32) * 0.1)
    b = jnp.asarray(rng.standard_normal((3 * H,)).astype(np.float32) * 0.1)
    got = gru_cell(xp, h, u, b)
    want = gru_cell_ref(xp, h, u, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_gru_cell_matches_model_cell():
    """Kernel == the cell used inside models.basecaller (same math)."""
    from repro.core.quant import QuantConfig
    from repro.models.basecaller import gru_cell as model_cell
    rng = np.random.default_rng(9)
    B, H = 16, 32
    xp = jnp.asarray(rng.standard_normal((B, 3 * H)).astype(np.float32))
    h = jnp.asarray(rng.standard_normal((B, H)).astype(np.float32))
    u = jnp.asarray(rng.standard_normal((H, 3 * H)).astype(np.float32) * 0.1)
    b = jnp.asarray(rng.standard_normal((3 * H,)).astype(np.float32) * 0.1)
    got = gru_cell(xp, h, u, b)
    want = model_cell(h, xp, u, b, QuantConfig(enabled=False))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode_attn
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,L,Kv,G,D,bl", [
    (2, 64, 2, 4, 16, 16),
    (3, 100, 1, 8, 32, 32),   # MHA-as-GQA, ragged L (padding path)
    (2, 48, 4, 1, 8, 16),     # one group (MQA-style)
])
def test_decode_attn_vs_ref(B, L, Kv, G, D, bl):
    from repro.kernels.decode_attn.ops import decode_attn
    from repro.kernels.decode_attn.ref import decode_attn_ref
    rng = np.random.default_rng(B * L + D)
    q = jnp.asarray(rng.standard_normal((B, Kv * G, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, L, Kv, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, L, Kv, D)).astype(np.float32))
    nv = jnp.asarray(rng.integers(1, L + 1, (B,)), jnp.int32)
    got = decode_attn(q, k, v, nv, groups=G, bl=bl)
    want = decode_attn_ref(q, k, v, nv.reshape(-1, 1), G)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attn_ring_semantics():
    """Only the first n_valid slots may influence the output."""
    from repro.kernels.decode_attn.ops import decode_attn
    rng = np.random.default_rng(7)
    B, L, Kv, G, D = 1, 32, 2, 2, 8
    q = jnp.asarray(rng.standard_normal((B, Kv * G, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, L, Kv, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, L, Kv, D)).astype(np.float32))
    nv = jnp.asarray([10], jnp.int32)
    base = decode_attn(q, k, v, nv, groups=G, bl=8)
    # corrupt slots >= n_valid: output must not change
    k2 = k.at[:, 10:].set(999.0)
    v2 = v.at[:, 10:].set(-999.0)
    got = decode_attn(q, k2, v2, nv, groups=G, bl=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-6, atol=1e-6)
