"""Flash-chunked attention vs naive oracle: forward AND custom-VJP grads."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import NEG, decode_attention, flash_attention

jax.config.update("jax_platform_name", "cpu")


def naive_attention(q, k, v, causal=True, window=None):
    B, S, H, D = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    qf = q.reshape(B, S, Kv, G, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= (qpos - kpos) < window
    s = jnp.where(ok, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)


def _qkv(B=2, S=48, T=48, H=4, Kv=2, D=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, Kv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, Kv, D), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal,window,bq,bk", [
    (True, None, 16, 16),
    (True, None, 64, 64),    # single block (no chunk boundary)
    (False, None, 16, 32),
    (True, 8, 16, 16),       # sliding window
    (True, 20, 48, 16),
])
def test_flash_forward_matches_naive(causal, window, bq, bk):
    q, k, v = _qkv()
    got = flash_attention(q, k, v, causal=causal, window=window, bq=bq,
                          bk=bk)
    want = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_forward_ragged_shapes():
    q, k, v = _qkv(S=37, T=53)   # not multiples of the chunk
    got = flash_attention(q, k, v, causal=False, bq=16, bk=16)
    want = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_cross_attention_different_lengths():
    q, k, v = _qkv(S=24, T=64)
    got = flash_attention(q, k, v, causal=False, bq=8, bk=32)
    want = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 8)])
def test_flash_custom_vjp_matches_naive_grads(causal, window):
    q, k, v = _qkv(S=32, T=32)
    dout = jax.random.normal(jax.random.PRNGKey(9), q.shape)

    def loss_flash(q, k, v):
        o = flash_attention(q, k, v, causal=causal, window=window,
                            bq=16, bk=16)
        return jnp.sum(o * dout)

    def loss_naive(q, k, v):
        return jnp.sum(naive_attention(q, k, v, causal=causal,
                                       window=window) * dout)

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gn, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4,
                                   err_msg=f"d{name}")


def test_flash_grads_finite_bf16():
    q, k, v = _qkv()
    q, k, v = (x.astype(jnp.bfloat16) for x in (q, k, v))

    def f(q, k, v):
        return flash_attention(q, k, v, bq=16, bk=16).astype(
            jnp.float32).sum()

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for x in g:
        assert bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))


def test_decode_matches_flash_last_position():
    q, k, v = _qkv(S=16, T=16)
    full = flash_attention(q, k, v, causal=True, bq=8, bk=8)
    valid = jnp.ones((2, 16), bool)
    got = decode_attention(q[:, -1:], k, v, valid)
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("causal,window,S,T,bq,bk", [
    (True, None, 64, 64, 16, 16),
    (True, 12, 64, 64, 16, 16),
    (True, None, 48, 48, 16, 8),     # bq != bk
    (False, None, 32, 64, 16, 16),   # cross-attn: skip degenerates safely
])
def test_flash_causal_skip_matches_naive(causal, window, S, T, bq, bk):
    """§Perf H1: statically skipped blocks must not change results/grads."""
    q, k, v = _qkv(S=S, T=T)
    dout = jax.random.normal(jax.random.PRNGKey(5), q.shape)

    def loss(fn):
        def f(q, k, v):
            return jnp.sum(fn(q, k, v) * dout)
        return f

    base = functools.partial(flash_attention, causal=causal, window=window,
                             bq=bq, bk=bk, causal_skip=False)
    skip = functools.partial(flash_attention, causal=causal, window=window,
                             bq=bq, bk=bk, causal_skip=True)
    np.testing.assert_allclose(np.asarray(skip(q, k, v)),
                               np.asarray(base(q, k, v)),
                               rtol=2e-5, atol=2e-5)
    gs = jax.grad(loss(skip), argnums=(0, 1, 2))(q, k, v)
    gb = jax.grad(loss(base), argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gs, gb, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4,
                                   err_msg=f"d{name}")
