"""Loop-aware HLO cost model: validated against unrolled lowerings.

The core claim (EXPERIMENTS.md §Roofline methodology): XLA cost_analysis
counts while bodies once; our reconstruction multiplies by parsed trip
counts and must agree with an UNROLLED lowering of the same computation.
Runs in a subprocess so the multi-device XLA_FLAGS never leak into the
test process.
"""
import json
import subprocess
import sys

import pytest

PROBE = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, functools
from repro.launch.analysis import loop_aware_cost

def model(x, ws, use_scan, L):
    if use_scan:
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return x
    for i in range(L):
        x = jnp.tanh(x @ ws[i])
    return x

out = {}
for L in (2, 8):
    xs = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    wss = jax.ShapeDtypeStruct((L, 256, 256), jnp.float32)
    for use_scan in (True, False):
        c = jax.jit(functools.partial(model, use_scan=use_scan, L=L)
                    ).lower(xs, wss).compile()
        la = loop_aware_cost(c.as_text(), 4)
        rep = c.cost_analysis()
        if isinstance(rep, (list, tuple)):   # older jax: list of one dict
            rep = rep[0]
        out[f"{L}_{use_scan}"] = {"la_flops": la[0], "la_bytes": la[1],
                                  "xla_flops": float(rep["flops"])}

def nested(x):
    def outer(c, _):
        def inner(ci, _):
            return jnp.tanh(ci @ ci.T) @ ci, None
        c, _ = jax.lax.scan(inner, c, None, length=3)
        return c, None
    x, _ = jax.lax.scan(outer, x, None, length=5)
    return x
c = jax.jit(nested).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)
                          ).compile()
out["nested"] = {"la_flops": loop_aware_cost(c.as_text(), 4)[0]}
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def probe():
    r = subprocess.run([sys.executable, "-c", PROBE], capture_output=True,
                       text=True, env={**__import__("os").environ,
                                        "PYTHONPATH": "src"},
                       timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_scan_flops_match_unrolled(probe):
    for L in (2, 8):
        scan = probe[f"{L}_True"]["la_flops"]
        unrolled = probe[f"{L}_False"]["la_flops"]
        assert abs(scan - unrolled) / unrolled < 0.02, (L, scan, unrolled)


def test_xla_reported_flops_do_not_scale_with_trip_count(probe):
    """The motivating defect: XLA's own numbers are L-independent for scan."""
    assert probe["2_True"]["xla_flops"] == probe["8_True"]["xla_flops"]
    assert probe["8_False"]["xla_flops"] > 3 * probe["8_True"]["xla_flops"]


def test_scan_bytes_close_to_unrolled(probe):
    for L in (8,):
        scan = probe[f"{L}_True"]["la_bytes"]
        unrolled = probe[f"{L}_False"]["la_bytes"]
        assert abs(scan - unrolled) / unrolled < 0.25, (scan, unrolled)


def test_nested_loop_multiplication(probe):
    want = 5 * 3 * 2 * (2 * 64 ** 3)
    assert abs(probe["nested"]["la_flops"] - want) / want < 0.02
