"""Multi-device sharded basecalling: the dp-over-windows serving path.

The tentpole invariant: under a 4-way host-device mesh
(``conftest`` forces ``--xla_force_host_platform_device_count=4``) every
pipeline/engine surface must produce BITWISE identical output to the
single-device path — dp sharding splits the window batch, replicates the
serving artifact, and all-gathers per-window reads before the shared
stitch/vote, none of which may perturb a single bit.  Plus the
``dist.sharding`` degradation contract: no mesh -> no-op, indivisible
batch -> a clear ValueError, never an XLA shape crash.
"""
from __future__ import annotations

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.quant import QuantConfig  # noqa: E402
from repro.dist import sharding as shd  # noqa: E402
from repro.pipeline import BasecallPipeline  # noqa: E402
from repro.serve import BasecallRequest, Server  # noqa: E402
from repro.serve.basecall_engine import BasecallEngine  # noqa: E402


@pytest.fixture(scope="module")
def tiny_pipe():
    pipe = BasecallPipeline.from_preset(
        "guppy", scale="tiny",
        quant=QuantConfig(enabled=True, bits_w=5, bits_a=5),
        backend="ref", beam_width=3)
    pipe.init_params(jax.random.PRNGKey(0))
    return pipe


def _assert_same_result(a, b):
    assert a.length == b.length
    assert np.array_equal(a.read, b.read)
    assert np.array_equal(a.window_reads, b.window_reads)
    assert np.array_equal(a.window_lengths, b.window_lengths)


# ---------------------------------------------------------------------------
# pipeline parity: 1 device vs 4 devices, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_windows", [1.0, 3.0, 5.3])
def test_basecall_parity_1dev_vs_4dev(tiny_pipe, host_mesh4, n_windows):
    """basecall under the mesh ≡ basecall without it, including batches
    that are not multiples of the device count."""
    rng = np.random.default_rng(int(n_windows * 10))
    sig = rng.standard_normal(
        int(tiny_pipe.mcfg.input_len * n_windows)).astype(np.float32)
    single = tiny_pipe.basecall(sig)
    with shd.use_mesh(host_mesh4):
        sharded = tiny_pipe.basecall(sig)
    _assert_same_result(single, sharded)


def test_basecall_ragged_last_batch(tiny_pipe, host_mesh4):
    """A window count that leaves a ragged final device batch (the padded
    lanes carry logit_length 0 and must not contribute reads)."""
    # batch_windows=8 rounds to 8 under dp=4; 10 windows => final batch of 2
    rng = np.random.default_rng(7)
    hop = tiny_pipe.chunk.hop
    n_samples = tiny_pipe.mcfg.input_len + 9 * hop - hop // 2
    sig = rng.standard_normal(n_samples).astype(np.float32)
    single = tiny_pipe.basecall(sig)
    assert single.window_reads.shape[0] % 4 != 0  # genuinely ragged
    with shd.use_mesh(host_mesh4):
        sharded = tiny_pipe.basecall(sig)
    _assert_same_result(single, sharded)


def test_basecall_iter_pins_creation_mesh(tiny_pipe, host_mesh4):
    """The mesh is captured when ``basecall_iter`` is CALLED: a generator
    created under a mesh shards every batch even when consumed entirely
    outside the ``use_mesh`` block, and one created outside stays
    single-device even when consumed inside — placement and decode trace
    never mix meshes."""
    rng = np.random.default_rng(13)
    sig = rng.standard_normal(
        int(tiny_pipe.mcfg.input_len * 12.5)).astype(np.float32)
    want = [(r.copy(), l.copy()) for r, l in tiny_pipe.basecall_iter(sig)]

    with shd.use_mesh(host_mesh4):
        sharded_it = tiny_pipe.basecall_iter(sig)
    got = list(sharded_it)             # consumed with no ambient mesh
    assert len(got) == len(want) > 1
    for (gr, gl), (wr, wl) in zip(got, want):
        assert np.array_equal(gr, wr)
        assert np.array_equal(gl, wl)

    plain_it = tiny_pipe.basecall_iter(sig)
    with shd.use_mesh(host_mesh4):     # consumed inside a mesh block
        got = list(plain_it)
    for (gr, gl), (wr, wl) in zip(got, want):
        assert np.array_equal(gr, wr)
        assert np.array_equal(gl, wl)


def test_basecall_empty_signal_under_mesh(tiny_pipe, host_mesh4):
    with shd.use_mesh(host_mesh4):
        res = tiny_pipe.basecall(np.zeros((0,), np.float32))
    assert res.length == 0
    assert res.window_reads.shape[0] == 0


def test_basecall_windows_parity(tiny_pipe, host_mesh4):
    rng = np.random.default_rng(3)
    margin = tiny_pipe.scfg.margin
    batch = rng.standard_normal(
        (4, tiny_pipe.mcfg.input_len + 2 * margin, 1)).astype(np.float32)
    single = [np.asarray(t) for t in tiny_pipe.basecall_windows(batch)]
    with shd.use_mesh(host_mesh4):
        sharded = [np.asarray(t) for t in tiny_pipe.basecall_windows(batch)]
    for s, m in zip(single, sharded):
        assert np.array_equal(s, m)


def test_golden_read_parity_under_mesh(golden_pipeline, golden_read,
                                       host_mesh4):
    """The golden genome -> signal -> basecall round-trip is bitwise
    identical under the 4-way mesh (the acceptance-criteria pin)."""
    pipe, params, _ = golden_pipeline
    _, sig = golden_read
    single = pipe.basecall(sig, params)
    with shd.use_mesh(host_mesh4):
        sharded = pipe.basecall(sig, params)
    _assert_same_result(single, sharded)


# ---------------------------------------------------------------------------
# dist.sharding degradation contract (the bugfix satellite)
# ---------------------------------------------------------------------------

def test_constrain_no_mesh_is_noop():
    x = np.arange(6.0).reshape(3, 2)
    y = shd.constrain(x, ("dp", None))
    assert y is x
    assert shd.replicate(x) is x
    assert shd.dp_size() == 1


def test_constrain_indivisible_skips_by_default(host_mesh4):
    """Non-strict constrain on an indivisible dim degrades to identity
    (never hands GSPMD an uneven shard)."""
    x = jax.numpy.ones((3, 2))
    with shd.use_mesh(host_mesh4):
        y = shd.constrain(x, ("dp", None))
    assert y is x


def test_constrain_indivisible_strict_raises(host_mesh4):
    x = jax.numpy.ones((3, 2))
    with shd.use_mesh(host_mesh4):
        with pytest.raises(ValueError, match="cannot shard dim of size 3"):
            shd.constrain(x, ("dp", None), strict=True)


def test_basecall_windows_indivisible_raises(tiny_pipe, host_mesh4):
    """The pipeline surfaces the divisibility failure as a clear error at
    the API boundary, not an XLA shape crash."""
    rng = np.random.default_rng(5)
    margin = tiny_pipe.scfg.margin
    batch = rng.standard_normal(
        (3, tiny_pipe.mcfg.input_len + 2 * margin, 1)).astype(np.float32)
    with shd.use_mesh(host_mesh4):
        with pytest.raises(ValueError, match="does not divide the mesh"):
            tiny_pipe.basecall_windows(batch)


def test_training_path_bakes_no_mesh(tiny_pipe, host_mesh4):
    """The training forward (backend=None) must carry ZERO sharding
    constraints even under an ambient mesh: the trainer's jits are not
    mesh-keyed, so a baked mesh would silently outlive its use_mesh
    block (regression for the serving-only constrain scoping)."""
    from repro.analysis import jaxpr_tools as jt
    from repro.models import basecaller as bc

    sig = jax.numpy.zeros((4, tiny_pipe.mcfg.input_len, 1))  # 4 % dp == 0

    def count_constraints(backend):
        with shd.use_mesh(host_mesh4):
            closed = jax.make_jaxpr(
                lambda p, s: bc.apply_basecaller(p, s, tiny_pipe.mcfg,
                                                 backend=backend)
            )(tiny_pipe.params, sig)
        return jt.count_primitive(closed, "sharding_constraint")

    assert count_constraints(None) == 0          # training: mesh-free
    assert count_constraints(tiny_pipe.backend) > 0   # serving: constrained


def test_place_params_caches_by_mesh_value(tiny_pipe, host_mesh4):
    """A mesh built per call (as the docs snippets do) must hit the
    placement cache, not re-transfer the serving artifact every call.
    (jax interns equal Mesh objects, but the cache keys by VALUE so it
    stays a hit even if that implementation detail changes.)"""
    packed = tiny_pipe.serving_params()
    placed1 = tiny_pipe._place_params(packed, host_mesh4)
    clone = jax.make_mesh((4,), ("data",))
    assert clone == host_mesh4
    placed2 = tiny_pipe._place_params(packed, clone)
    assert placed2 is placed1
    assert len(tiny_pipe._placed_cache) == 1


def test_replicated_sharding_tree(tiny_pipe, host_mesh4):
    """The serving artifact placement: every leaf fully replicated."""
    packed = tiny_pipe.serving_params()
    tree = shd.replicated_sharding_tree(packed, host_mesh4)
    for s in jax.tree_util.tree_leaves(
            tree, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding)):
        assert all(ax is None for ax in s.spec)  # replicated on every dim


# ---------------------------------------------------------------------------
# serving stack scale-out
# ---------------------------------------------------------------------------

def test_engine_capacity_scales_with_mesh(tiny_pipe, host_mesh4):
    with shd.use_mesh(host_mesh4):
        eng = BasecallEngine(tiny_pipe, batch_slots=2)
    assert eng.dp == 4
    assert eng.B == 8
    eng1 = BasecallEngine(tiny_pipe, batch_slots=2)
    assert eng1.dp == 1 and eng1.B == 2


def test_server_engine_parity_under_mesh(tiny_pipe, host_mesh4):
    """Server.submit over a mesh-scaled engine ≡ pipe.basecall, and
    metrics() reports one occupancy entry per dp device."""
    rng = np.random.default_rng(11)
    sigs = [rng.standard_normal(
        int(tiny_pipe.mcfg.input_len * k)).astype(np.float32)
        for k in (1.4, 2.7, 0.6)]
    expected = [tiny_pipe.basecall(s) for s in sigs]
    with shd.use_mesh(host_mesh4):
        eng = BasecallEngine(tiny_pipe, batch_slots=2)
        srv = Server(eng)
        futs = [srv.submit(BasecallRequest(signal=s)) for s in sigs]
        results = [f.result() for f in futs]
    for got, want in zip(results, expected):
        assert got.ok
        _assert_same_result(got.value, want)
    m = srv.metrics()
    assert m.devices == 4
    assert len(m.occupancy_per_device) == 4
    assert all(0.0 <= o <= 1.0 for o in m.occupancy_per_device)
    # the pool-wide mean is the mean of the per-device means (equal groups)
    assert np.isclose(m.occupancy, np.mean(m.occupancy_per_device))


def test_lm_engine_capacity_scales_with_mesh(host_mesh4):
    from repro.models import lm as lm_lib
    from repro.serve.engine import ServingEngine

    cfg = lm_lib.LMConfig(n_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
                          d_ff=32, vocab_size=32, remat=False)
    params = lm_lib.init_lm(jax.random.PRNGKey(0), cfg)
    with shd.use_mesh(host_mesh4):
        eng = ServingEngine(params, cfg, batch_slots=2, max_len=16)
    assert eng.dp == 4 and eng.B == 8
    assert eng.cache["pos"].shape[0] == 8
