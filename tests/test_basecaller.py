"""Base-caller model family + synthetic data pipeline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import QuantConfig
from repro.data import genome
from repro.models import basecaller as bc

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("name", ["guppy", "scrappie", "chiron"])
def test_tiny_forward_shapes_and_finiteness(name):
    cfg = bc.tiny_preset(name)
    params = bc.init_basecaller(jax.random.PRNGKey(0), cfg)
    sig = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.input_len, 1))
    lp = bc.apply_basecaller(params, sig, cfg)
    assert lp.shape == (2, cfg.output_len, cfg.n_classes)
    assert bool(jnp.all(jnp.isfinite(lp)))
    # proper log-probs
    np.testing.assert_allclose(np.asarray(jnp.exp(lp).sum(-1)), 1.0,
                               rtol=1e-5)


@pytest.mark.parametrize("name,table_macs,table_params", [
    ("guppy", 36.3e6, 0.244e6),
    ("scrappie", 8.47e6, 0.45e6),
    ("chiron", 615.2e6, 2.2e6),
])
def test_full_presets_in_paper_ballpark(name, table_macs, table_params):
    """Computed MACs/params in the ballpark of Table 3.

    The table is internally inconsistent (e.g. Scrappie's "0.31M FC params"
    is 1025*5*60 — time-multiplied like a MAC count), so the bound is loose:
    within 4x. benchmarks/table3_models.py reports exact side-by-side values.
    """
    cfg = bc.PRESETS[name]
    macs = bc.count_macs(cfg)["total"]
    params = bc.init_basecaller(jax.random.PRNGKey(0), cfg)
    n_params = bc.count_params(params)
    assert table_macs / 4 < macs < table_macs * 4, (name, macs)
    assert table_params / 4 < n_params < table_params * 4, (name, n_params)


def test_quantized_forward_close_to_fp_at_8bit():
    cfg = bc.tiny_preset("guppy")
    params = bc.init_basecaller(jax.random.PRNGKey(0), cfg)
    sig = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.input_len, 1))
    lp_fp = bc.apply_basecaller(params, sig, cfg)
    q8 = cfg.with_quant(QuantConfig(enabled=True, bits_w=8, bits_a=8))
    lp_q8 = bc.apply_basecaller(params, sig, q8)
    q3 = cfg.with_quant(QuantConfig(enabled=True, bits_w=3, bits_a=3))
    lp_q3 = bc.apply_basecaller(params, sig, q3)
    err8 = float(jnp.abs(lp_fp - lp_q8).mean())
    err3 = float(jnp.abs(lp_fp - lp_q3).mean())
    assert err8 < err3  # coarser grid => larger deviation
    assert err8 < 0.15


def test_basecaller_grads_flow_through_quant():
    cfg = bc.tiny_preset("guppy").with_quant(
        QuantConfig(enabled=True, bits_w=5, bits_a=5))
    params = bc.init_basecaller(jax.random.PRNGKey(0), cfg)
    sig = jax.random.normal(jax.random.PRNGKey(1), (1, cfg.input_len, 1))

    def loss(p):
        return bc.apply_basecaller(p, sig, cfg).sum()

    g = jax.grad(loss)(params)
    norms = [float(jnp.abs(x).max()) for x in jax.tree_util.tree_leaves(g)]
    assert all(np.isfinite(n) for n in norms)
    assert sum(n > 0 for n in norms) > len(norms) * 0.8  # STE keeps grads alive


# ---------------------------------------------------------------------------
# synthetic data
# ---------------------------------------------------------------------------

def test_signal_shapes_and_normalization():
    cfg = genome.SignalConfig(window=120, margin=16, max_label_len=48)
    ex = genome.sample_example(jax.random.PRNGKey(0), cfg)
    assert ex["signal"].shape == (120 + 32, 1)
    assert abs(float(ex["signal"].mean())) < 1e-3
    assert abs(float(ex["signal"].std()) - 1.0) < 1e-2
    n = int(ex["label_length"])
    assert 0 < n <= 48
    labs = np.asarray(ex["labels"][:n])
    assert labs.min() >= 0 and labs.max() < 4


def test_label_count_tracks_dwell():
    """~window/mean_dwell bases per window."""
    cfg = genome.SignalConfig(window=240, mean_dwell=8.0, max_label_len=96)
    batch = genome.sample_batch(jax.random.PRNGKey(1), 32, cfg)
    mean_labels = float(batch["label_length"].mean())
    assert 240 / 8 * 0.5 < mean_labels < 240 / 8 * 2.0


def test_data_is_deterministic_per_step():
    cfg = genome.SignalConfig(window=60)
    a = genome.batch_for_step(7, 4, cfg)
    b = genome.batch_for_step(7, 4, cfg)
    c = genome.batch_for_step(8, 4, cfg)
    np.testing.assert_array_equal(np.asarray(a["signal"]),
                                  np.asarray(b["signal"]))
    assert not np.array_equal(np.asarray(a["signal"]),
                              np.asarray(c["signal"]))


def test_same_sequence_different_noise_same_labels():
    """Two reads of the same molecule: same bases, different signal."""
    cfg = genome.SignalConfig(window=100)
    key = jax.random.PRNGKey(3)
    ex = genome.sample_example(key, cfg)
    # label derivation is independent of the noise draw by construction:
    # regenerate with same key => identical
    ex2 = genome.sample_example(key, cfg)
    np.testing.assert_array_equal(np.asarray(ex["labels"]),
                                  np.asarray(ex2["labels"]))
