"""Kernel backend registry: ref ≡ interpret parity sweep + dispatch rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import registry

jax.config.update("jax_platform_name", "cpu")

RAGGED = 0  # marker: every shape below is deliberately non-tile-multiple


def _quant_matmul_args(rng):
    M, K, N = 37, 100, 51                       # ragged vs 128 tiles
    xq = jnp.asarray(rng.integers(-15, 16, (M, K)), jnp.int8)
    wq = jnp.asarray(rng.integers(-15, 16, (K, N)), jnp.int8)
    sx = jnp.asarray([[0.021]], jnp.float32)
    sw = jnp.asarray(rng.random((1, N)).astype(np.float32) * 0.05 + 1e-3)
    return (xq, wq, sx, sw), {}


def _gru_cell_args(rng):
    B, H = 23, 48                                # ragged vs bb=128
    xp = jnp.asarray(rng.standard_normal((B, 3 * H)).astype(np.float32))
    h = jnp.asarray(rng.standard_normal((B, H)).astype(np.float32))
    u = jnp.asarray(rng.standard_normal((H, 3 * H)).astype(np.float32) * 0.1)
    b = jnp.asarray(rng.standard_normal((3 * H,)).astype(np.float32) * 0.1)
    return (xp, h, u, b), {}


def _masked_logsumexp_args(rng):
    B, C = 3, 45                                 # ragged vs bi=128
    eq = rng.integers(0, 2, (B, C, C))
    eq |= np.eye(C, dtype=eq.dtype)[None]        # rows self-connected
    scores = rng.standard_normal((B, C)).astype(np.float32)
    return (jnp.asarray(eq, jnp.int8), jnp.asarray(scores)), {}


def _decode_attn_args(rng):
    B, L, Kv, G, D = 2, 75, 2, 3, 16             # ragged vs bl=256
    q = jnp.asarray(rng.standard_normal((B, Kv * G, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, L, Kv, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, L, Kv, D)).astype(np.float32))
    n_valid = jnp.asarray([31, 75], jnp.int32)
    return (q, k, v, n_valid), {"groups": G}


def _paged_decode_attn_args(rng):
    B, N, bs, Kv, G, D = 2, 16, 8, 2, 3, 16      # non-contiguous tables
    q = jnp.asarray(rng.standard_normal((B, Kv * G, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((N, bs, Kv, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((N, bs, Kv, D)).astype(np.float32))
    tables = jnp.asarray([[3, 7, 1], [12, 0, 5]], jnp.int32)
    n_valid = jnp.asarray([5, 20], jnp.int32)    # ragged vs nb*bs = 24
    return (q, k, v, tables, n_valid), {"groups": G}


def _mismatch_bits_args(rng):
    r1 = jnp.asarray(rng.integers(0, 4, (41,)), jnp.int32)
    r2 = jnp.asarray(rng.integers(0, 4, (29,)), jnp.int32)
    return (r1, r2), {"K": 5}


def _beam_merge_topk_args(rng):
    B, C = 2, 45                                 # ragged vs the 128 lane tile
    keys = jnp.asarray(rng.integers(0, 12, (B, C)), jnp.int32)  # duplicates
    pb = jnp.asarray(rng.standard_normal((B, C)).astype(np.float32) * 4)
    pnb = jnp.asarray(rng.standard_normal((B, C)).astype(np.float32) * 4)
    return (keys, pb, pnb), {"W": 7}


def _gru_seq_args(rng):
    T, B, H = 7, 23, 48                          # ragged vs bb=128, odd T
    xp = jnp.asarray(rng.standard_normal((T, B, 3 * H)).astype(np.float32))
    h0 = jnp.asarray(rng.standard_normal((B, H)).astype(np.float32))
    u = jnp.asarray(rng.standard_normal((H, 3 * H)).astype(np.float32) * 0.1)
    b = jnp.asarray(rng.standard_normal((3 * H,)).astype(np.float32) * 0.1)
    return (xp, h0, u, b), {}


def _beam_merge_multiframe_args(rng):
    B, F, A, W, L = 2, 3, 5, 4, 11               # one padded (ragged) frame
    NEG = -1.0e9
    lp = jnp.asarray(np.log(
        rng.dirichlet(np.ones(A), (B, F))).astype(np.float32))
    active = jnp.asarray([[1, 1, 1], [1, 1, 0]], jnp.int32)
    keys = jnp.zeros((B, W), jnp.int32)
    pb = jnp.full((B, W), NEG, jnp.float32).at[:, 0].set(0.0)
    pnb = jnp.full((B, W), NEG, jnp.float32)
    last = jnp.full((B, W), -1, jnp.int32)
    lengths = jnp.zeros((B, W), jnp.int32)
    return ((lp, active, keys, pb, pnb, last, lengths),
            {"blank": A - 1, "L": L})


_CASES = {
    "quant_matmul": _quant_matmul_args,
    "gru_cell": _gru_cell_args,
    "gru_seq": _gru_seq_args,
    "masked_logsumexp": _masked_logsumexp_args,
    "beam_merge_topk": _beam_merge_topk_args,
    "beam_merge_multiframe": _beam_merge_multiframe_args,
    "decode_attn": _decode_attn_args,
    "paged_decode_attn": _paged_decode_attn_args,
    "mismatch_bits": _mismatch_bits_args,
}


def test_registry_knows_all_registered_ops():
    assert set(registry.list_ops()) == set(_CASES)


@pytest.mark.parametrize("name", sorted(_CASES))
def test_ref_matches_interpret_on_ragged_shapes(name):
    """get_op(name, "ref") ≡ get_op(name, "interpret"): the padding done by
    the Pallas wrapper must be invisible on non-tile-multiple shapes."""
    rng = np.random.default_rng(hash(name) % 2**31)
    args, kw = _CASES[name](rng)
    ref = registry.get_op(name, "ref")(*args, **kw)
    interp = registry.get_op(name, "interpret")(*args, **kw)
    ref_leaves = jax.tree_util.tree_leaves(ref)
    interp_leaves = jax.tree_util.tree_leaves(interp)
    assert len(ref_leaves) == len(interp_leaves), name
    for r, i in zip(ref_leaves, interp_leaves):
        assert r.shape == i.shape, name
        np.testing.assert_allclose(np.asarray(r), np.asarray(i),
                                   rtol=1e-5, atol=1e-5)


def test_unknown_op_suggests_nearest():
    with pytest.raises(KeyError, match="quant_matmul"):
        registry.get_op("quant_matmui")


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        registry.get_op("gru_cell", "cuda")
    with pytest.raises(ValueError):
        registry.Backend("cuda")


def test_backend_auto_resolves_off_tpu_to_interpret():
    assert registry.Backend("auto").resolved in ("interpret", "pallas")
    assert registry.resolve_backend("ref") == "ref"


def test_default_backend_rebinding():
    registry.set_default_backend("ref")
    try:
        assert registry.resolve_backend(None) == "ref"
        assert registry.resolve_backend("auto") == "ref"
        assert registry.resolve_backend("interpret") == "interpret"
    finally:
        registry.set_default_backend("auto")


def test_public_wrappers_resolve_exclusively_through_registry():
    """Re-registering an op must intercept the public ops.py wrapper —
    proof there is no residual per-op dispatch path."""
    from repro.kernels.gru_cell import ops as gru_ops

    entry = registry._REGISTRY["gru_cell"]
    seen = []

    def fake_ref(x_proj, h, u, b, **kw):
        seen.append(x_proj.shape)
        return entry.ref(x_proj, h, u, b, **kw)

    registry.register_op("gru_cell", ref=fake_ref, pallas=entry.pallas)
    try:
        rng = np.random.default_rng(0)
        # unique shape so the wrapper's jit cache cannot serve a stale trace
        (xp, h, u, b), _ = _gru_cell_args(rng)
        xp, h = xp[:11], h[:11]
        out = gru_ops.gru_cell(xp, h, u, b, backend="ref")
        assert seen, "wrapper did not route through the registry"
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(entry.ref(xp, h, u, b)),
            rtol=1e-5, atol=1e-6)
    finally:
        registry.register_op("gru_cell", ref=entry.ref, pallas=entry.pallas)


def test_old_auto_interpret_helpers_are_gone():
    """The five copy-pasted per-op ``_auto_interpret`` dispatchers are gone;
    backend choice lives in the registry alone."""
    import repro.kernels.ctc_merge.ops as m1
    import repro.kernels.decode_attn.ops as m2
    import repro.kernels.gru_cell.ops as m3
    import repro.kernels.quant_matmul.ops as m4
    import repro.kernels.vote_cmp.ops as m5
    for mod in (m1, m2, m3, m4, m5):
        assert not hasattr(mod, "_auto_interpret"), mod.__name__


def test_default_backend_takes_effect_after_prior_trace():
    """Rebinding the default must not be defeated by a stale jit cache:
    the wrapper resolves the backend BEFORE its jit boundary."""
    from repro.kernels.gru_cell import ops as gru_ops

    rng = np.random.default_rng(7)
    (xp, h, u, b), _ = _gru_cell_args(rng)
    _ = gru_ops.gru_cell(xp, h, u, b)          # traces under the default

    entry = registry._REGISTRY["gru_cell"]
    calls = []

    def spy_ref(x_proj, hh, uu, bb_, **kw):
        calls.append("ref")
        return entry.ref(x_proj, hh, uu, bb_, **kw)

    registry.register_op("gru_cell", ref=spy_ref, pallas=entry.pallas)
    registry.set_default_backend("ref")
    try:
        _ = gru_ops.gru_cell(xp, h, u, b)      # SAME shapes as before
        assert calls == ["ref"], "stale trace served instead of new default"
    finally:
        registry.set_default_backend("auto")
        registry.register_op("gru_cell", ref=entry.ref, pallas=entry.pallas)
