"""repro.analysis: clean on the repo, and every rule fires on its mutant.

Three layers:

1. Positive controls — the shipped traces/kernels/tree produce ZERO
   findings (the CI gate ``python -m repro.analysis --strict`` relies on
   this staying true).
2. Negative paths — each violation class is planted (unpacked params in
   a serving trace, dropped constrain, stray pallas_call, indivisible
   block shape, ...) and the matching rule must catch it with an
   actionable message.
3. The registry first-use backend validation satellite (bad
   ``REPRO_DEFAULT_BACKEND`` must NOT crash import, must raise a listed
   ValueError at first resolve).
"""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.analysis import jaxpr_tools as jt  # noqa: E402
from repro.analysis import kernel_checks as kc  # noqa: E402
from repro.analysis import repolint  # noqa: E402
from repro.analysis import trace_invariants as ti  # noqa: E402
from repro.analysis.findings import ERROR, WARNING, Finding, errors  # noqa: E402
from repro.core.quant import QuantConfig  # noqa: E402
from repro.kernels import registry  # noqa: E402
from repro.kernels.registry import Backend  # noqa: E402
from repro.models import basecaller as bc  # noqa: E402

REPO = Path(__file__).resolve().parents[1]
QUANT = QuantConfig(enabled=True, bits_w=5, bits_a=5)


def _env(**extra):
    env = {**os.environ, "PYTHONPATH": "src", "JAX_PLATFORM_NAME": "cpu"}
    env.update(extra)
    return env


# ---------------------------------------------------------------------------
# Pass 1: trace invariants
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def meshed_cases(host_mesh4):
    """The guppy serving traces under the 4-way mesh (built once)."""
    return ti.build_pipeline_cases("guppy", host_mesh4)


def test_repo_serving_traces_clean(meshed_cases):
    """Every trace rule is silent on the shipped serving traces."""
    cases = ti.build_pipeline_cases("guppy", None) + list(meshed_cases)
    cases.append(ti.build_lm_engine_case(None))
    cases.append(ti.build_paged_lm_engine_case(None))
    for case in cases:
        for name, rule in ti.TRACE_RULES.items():
            assert rule(case) == [], (case.name, name)


def test_weight_quant_rule_fires_on_unpacked_serving_trace():
    """Mutant: serving the FLOAT checkpoint re-quantizes weights in-trace."""
    cfg = bc.tiny_preset("guppy").with_quant(QUANT)
    params = bc.init_basecaller(jax.random.PRNGKey(0), cfg)
    sig = jnp.zeros((2, cfg.input_len, 1), jnp.float32)
    closed = jax.make_jaxpr(
        lambda p, s: bc.apply_basecaller(p, s, cfg, backend=Backend("ref"))
    )(params, sig)
    case = ti.TraceCase("mutant.unpacked", closed,
                        len(jax.tree_util.tree_leaves(params)))
    fs = ti.rule_weight_quant(case)
    assert len(fs) == 1
    assert "weight-quantization" in fs[0].message
    assert "quantize-once" in fs[0].message            # actionable fix


def test_stage_coverage_rule_fires_on_dropped_constrain(host_mesh4):
    """Mutant: a declared boundary whose constrain was dropped — modeled
    by the training forward (no constrains at all) traced under the mesh
    with the serving boundaries declared."""
    cfg = bc.tiny_preset("guppy").with_quant(QUANT)
    params = bc.init_basecaller(jax.random.PRNGKey(0), cfg)
    sig = jnp.zeros((4, cfg.input_len, 1), jnp.float32)
    from repro.dist import sharding as shd
    with shd.use_mesh(host_mesh4):
        closed = jax.make_jaxpr(
            lambda p, s: bc.apply_basecaller(p, s, cfg, backend=None)
        )(params, sig)
    case = ti.TraceCase("mutant.dropped_constrain", closed, 0,
                        boundaries=bc.serving_stage_boundaries(cfg),
                        meshed=True)
    fs = ti.rule_sharding(case)
    assert len(fs) == 1 and fs[0].rule == "trace-stage-coverage"
    assert "signal_in" in fs[0].message                # names the boundary
    assert "shd.constrain" in fs[0].message            # actionable fix


def test_stage_coverage_rule_fires_on_partial_drop(meshed_cases):
    """A single extra declared-but-unrealized boundary is reported."""
    good = meshed_cases[0]
    assert ti.rule_sharding(good) == []
    bad = dataclasses.replace(good,
                              boundaries=good.boundaries + ("attn0",))
    fs = ti.rule_sharding(bad)
    assert len(fs) == 1 and "attn0" in fs[0].message


def test_mesh_bake_rule_fires_on_meshed_trace_marked_unmeshed(meshed_cases):
    """Mutant: sharding constraints baked where no mesh is expected."""
    baked = dataclasses.replace(meshed_cases[0], meshed=False)
    fs = ti.rule_sharding(baked)
    assert len(fs) == 1 and fs[0].rule == "trace-mesh-bake"
    assert "use_mesh" in fs[0].message


def test_dequant_rule_fires_outside_scope_only():
    """int8 codes -> float is flagged everywhere EXCEPT under the
    declared dequant scope."""
    codes = jnp.zeros((4, 4), jnp.int8)

    leaky = jax.make_jaxpr(lambda q: q.astype(jnp.float32) * 0.1)(codes)
    assert len(jt.unsanctioned_dequant_eqns(leaky)) == 1

    def sanctioned(q):
        from repro.core.quant import DEQUANT_SCOPE
        with jax.named_scope(DEQUANT_SCOPE):
            return q.astype(jnp.float32) * 0.1

    assert jt.unsanctioned_dequant_eqns(
        jax.make_jaxpr(sanctioned)(codes)) == []

    # widening int8 -> int32 keeps carrying the taint through arithmetic
    def widened(q):
        return (q.astype(jnp.int32) @ q.astype(jnp.int32).T
                ).astype(jnp.float32)

    assert len(jt.unsanctioned_dequant_eqns(
        jax.make_jaxpr(widened)(codes))) == 1

    # packing a float INTO codes is not dequantization
    packed = jax.make_jaxpr(
        lambda x: jnp.round(x * 10).astype(jnp.int8))(jnp.zeros((4,)))
    assert jt.unsanctioned_dequant_eqns(packed) == []


def test_f64_and_host_transfer_rules_fire():
    jax.config.update("jax_enable_x64", True)
    try:
        closed = jax.make_jaxpr(
            lambda x: x.astype(jnp.float64) * 2)(jnp.zeros((2,)))
    finally:
        jax.config.update("jax_enable_x64", False)
    assert len(jt.f64_eqns(closed)) >= 1

    def cb(x):
        return jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    closed = jax.make_jaxpr(cb)(jnp.zeros((2,)))
    assert len(jt.host_transfer_eqns(closed)) == 1


def test_retrace_guard_clean_on_repo():
    assert ti.retrace_findings(None) == []


def test_walker_counts_through_higher_order_prims():
    """count_primitive recurses into scan/cond/pjit sub-jaxprs."""

    def fn(x):
        def body(c, _):
            return jnp.sin(c), None
        y, _ = jax.lax.scan(body, x, None, length=3)
        return jax.lax.cond(y.sum() > 0,
                            lambda v: jnp.sin(v), lambda v: v, y)

    closed = jax.make_jaxpr(jax.jit(fn))(jnp.zeros((2,)))
    assert jt.count_primitive(closed, "sin") == 2      # scan body + branch
    counts = jt.primitive_counts(closed)
    assert counts["scan"] == 1 and counts["cond"] == 1


# ---------------------------------------------------------------------------
# Pass 2: kernel checks
# ---------------------------------------------------------------------------

def test_kernel_checks_clean_on_registry():
    assert kc.run() == []


def _bad_blockspec_trace():
    from jax.experimental import pallas as pl

    def k(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def bad(x):
        return pl.pallas_call(
            k, out_shape=jax.ShapeDtypeStruct((10, 8), jnp.float32),
            grid=(2,),
            in_specs=[pl.BlockSpec((3, 8), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((3, 8), lambda i: (i, 0)),
            interpret=True)(x)

    return jax.make_jaxpr(bad)(jnp.zeros((10, 8), jnp.float32))


def test_block_divisibility_rule_fires():
    """Mutant: a (3, 8) block over a (10, 8) operand."""
    eqns = kc.pallas_call_eqns(_bad_blockspec_trace())
    assert len(eqns) == 1
    fs = [f for f in kc.check_pallas_eqn(eqns[0], "mutant")
          if f.rule == "kernel-block-div"]
    assert fs and "10 % 3" in fs[0].message
    assert "pad the operand" in fs[0].message          # actionable fix


def test_vmem_budget_rule_fires():
    eqns = kc.pallas_call_eqns(_bad_blockspec_trace())
    fs = [f for f in kc.check_pallas_eqn(eqns[0], "mutant", budget=4)
          if f.rule == "kernel-vmem"]
    assert fs and "budget" in fs[0].message


def test_signature_parity_rule_fires():
    def ref_impl(a, b):
        return a + b

    def pallas_impl(a, c, *, interpret=False):
        return a + c

    fs = kc.check_signature_parity("mutant", ref_impl, pallas_impl)
    assert len(fs) == 1 and "positional args" in fs[0].message

    def pallas_no_interp(a, b):
        return a + b

    fs = kc.check_signature_parity("mutant", ref_impl, pallas_no_interp)
    assert len(fs) == 1 and "interpret" in fs[0].message


def test_missing_example_flagged():
    entry = registry._REGISTRY["gru_cell"] if "gru_cell" in \
        registry._REGISTRY else registry._ensure("gru_cell")
    registry._REGISTRY["tmp_op"] = dataclasses.replace(
        entry, name="tmp_op", example=None)
    try:
        fs = kc.run(ops=("tmp_op",))
        assert len(fs) == 1 and fs[0].rule == "kernel-example"
        assert "register_op" in fs[0].message
    finally:
        del registry._REGISTRY["tmp_op"]


def test_example_survives_reregistration():
    """Tests that swap impls (spies) must not lose the example factory."""
    entry = registry._ensure("gru_cell")
    assert entry.example is not None
    registry.register_op("gru_cell", ref=entry.ref, pallas=entry.pallas)
    try:
        assert registry._REGISTRY["gru_cell"].example is entry.example
    finally:
        registry._REGISTRY["gru_cell"] = entry


# ---------------------------------------------------------------------------
# Pass 3: repo lint (planted trees under tmp_path)
# ---------------------------------------------------------------------------

def _plant(tmp_path: Path, files: dict) -> Path:
    for rel, body in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(body))
    return tmp_path


def test_lint_clean_on_repo():
    assert repolint.run(REPO) == []


def test_stray_pallas_call_flagged(tmp_path):
    root = _plant(tmp_path, {"src/repro/rogue.py": """
        from jax.experimental import pallas as pl

        def f(x):
            return pl.pallas_call(lambda i, o: None, out_shape=x)(x)
        """})
    fs = repolint.run(root)
    assert [f.rule for f in fs] == ["lint-pallas-call"]
    assert fs[0].subject == "src/repro/rogue.py:5"
    assert "registry.get_op" in fs[0].message          # actionable fix


def test_kernel_internal_import_flagged(tmp_path):
    root = _plant(tmp_path, {"src/repro/rogue.py": """
        import repro.kernels.gru_cell.ref
        from repro.kernels.quant_matmul import kernel
        from repro.kernels.registry import get_op        # allowed
        from repro.kernels.quant_matmul.ops import qmm_packed  # allowed
        """})
    fs = repolint.run(root)
    assert sorted(f.subject for f in fs) == ["src/repro/rogue.py:2",
                                             "src/repro/rogue.py:3"]
    assert all(f.rule == "lint-kernel-import" for f in fs)


def test_interpret_kwarg_flagged_and_suppressible(tmp_path):
    root = _plant(tmp_path, {"src/repro/rogue.py": """
        def f(op, x):
            return op(x, interpret=True)

        def g(op, x):
            return op(x, interpret=True)  # repro: allow[lint-interpret-kwarg]
        """})
    fs = repolint.run(root)
    assert [f.subject for f in fs] == ["src/repro/rogue.py:3"]
    assert fs[0].rule == "lint-interpret-kwarg"


def test_public_wrapper_interpret_param_flagged(tmp_path):
    root = _plant(tmp_path, {"src/repro/kernels/myop/ops.py": """
        __all__ = ["myop"]

        def myop(x, *, interpret=False):
            return x

        def _impl_pallas(x, *, interpret=False):   # private: allowed
            return x
        """})
    fs = repolint.run(root)
    rules = [f.rule for f in fs]
    assert "lint-wrapper-interpret" in rules
    wrapper = [f for f in fs if f.rule == "lint-wrapper-interpret"]
    assert len(wrapper) == 1 and "myop()" in wrapper[0].message


def test_registry_completeness_flags_missing_pieces(tmp_path):
    root = _plant(tmp_path, {
        "src/repro/kernels/newop/ops.py": """
            from repro.kernels import registry
            registry.register_op("newop", ref=None, pallas=None)
            """,
        "tests/test_other.py": "def test_nothing():\n    pass\n",
    })
    fs = repolint.run(root)
    rules = sorted(f.rule for f in fs)
    assert rules == ["lint-registry-complete"] * 3     # ref.py, kernel.py,
    msgs = " ".join(f.message for f in fs)             # test coverage
    assert "ref.py" in msgs and "kernel.py" in msgs and "tests/" in msgs


# ---------------------------------------------------------------------------
# registry backend validation (the bugfix satellite)
# ---------------------------------------------------------------------------

def test_bad_env_backend_errors_at_first_use_not_import():
    """REPRO_DEFAULT_BACKEND=cuda: importing the registry (and the kernel
    modules registering into it) must succeed; the FIRST backend resolve
    raises one ValueError naming the env var and the valid backends."""
    probe = textwrap.dedent("""
        import repro.kernels.registry as r
        import repro.kernels.gru_cell.ops          # registration is fine
        try:
            r.get_op("gru_cell")
            print("NO_ERROR")
        except ValueError as e:
            msg = str(e)
            assert "REPRO_DEFAULT_BACKEND" in msg, msg
            assert "'cuda'" in msg, msg
            assert "interpret" in msg, msg          # lists BACKENDS
            print("FIRST_USE_OK")
        # an explicit backend never touches the env default
        r.get_op("gru_cell", "ref")
        print("EXPLICIT_OK")
    """)
    r = subprocess.run(
        [sys.executable, "-c", probe], capture_output=True, text=True,
        cwd=REPO, env=_env(REPRO_DEFAULT_BACKEND="cuda"), timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "FIRST_USE_OK" in r.stdout
    assert "EXPLICIT_OK" in r.stdout


def test_good_env_backend_still_honored():
    probe = ("import repro.kernels.registry as r; "
             "print(r.resolve_backend(None), r.resolve_backend('auto'))")
    r = subprocess.run(
        [sys.executable, "-c", probe], capture_output=True, text=True,
        cwd=REPO, env=_env(REPRO_DEFAULT_BACKEND="ref"), timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert r.stdout.split() == ["ref", "ref"]


def test_set_default_backend_invalid_lists_backends():
    with pytest.raises(ValueError, match="interpret"):
        registry.set_default_backend("cuda")
    with pytest.raises(ValueError, match="interpret"):
        registry.resolve_backend("cuda")


# ---------------------------------------------------------------------------
# findings plumbing + CLI
# ---------------------------------------------------------------------------

def test_findings_severity_and_disable():
    fs = [Finding("a-rule", "s", "m", ERROR),
          Finding("b-rule", "s", "m", WARNING)]
    assert errors(fs) == [fs[0]]
    assert errors(fs, strict=True) == fs
    from repro.analysis.findings import drop_disabled
    assert drop_disabled(fs, ["a-rule"]) == [fs[1]]


def test_cli_list_rules_and_bad_pass():
    from repro.analysis import cli
    assert cli.main(["--list-rules"]) == 0
    assert cli.main(["--passes", "nope"]) == 2


def test_cli_lint_pass_subprocess_clean_and_fails_on_mutant(tmp_path):
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--passes", "lint",
         "--strict"],
        capture_output=True, text=True, cwd=REPO, env=_env(), timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert "OK" in r.stdout

    _plant(tmp_path, {"src/repro/rogue.py": """
        def f(op, x):
            return op(x, interpret=True)
        """})
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--passes", "lint",
         "--root", str(tmp_path)],
        capture_output=True, text=True, cwd=REPO, env=_env(), timeout=300)
    assert r.returncode == 1, r.stdout + r.stderr[-2000:]
    assert "lint-interpret-kwarg" in r.stdout
    # --disable waives exactly that rule
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--passes", "lint",
         "--root", str(tmp_path), "--disable", "lint-interpret-kwarg"],
        capture_output=True, text=True, cwd=REPO, env=_env(), timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
