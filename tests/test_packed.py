"""The quantize-once serving artifact contract (PR 3).

Three obligations:

1. **Bitwise parity** — `pack_basecaller` + the packed apply path produce
   bit-for-bit the outputs of the legacy repack-per-call serving path, on
   every backend, end to end through `BasecallPipeline` and
   `BasecallEngine`; same for `pack_lm_serving` + `ServingEngine`.
2. **Zero weight-quantization ops in the serving trace** — a dataflow
   analysis over the jitted jaxpr: no quantization primitive (round /
   clamp / weight-scale reduce_max / float->int8 convert) may consume a
   value derived ONLY from weights.  The repack-per-call trace is the
   positive control (the detector must fire there).
3. **Cache discipline** — the pipeline packs once per checkpoint identity
   and re-packs when `init_params` / `pipe.params = ...` rebinds.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import jaxpr_tools as jt
from repro.core.quant import QuantConfig
from repro.kernels.quant_matmul.ops import qmm_packed
from repro.core import quant as quant_lib
from repro.kernels.registry import Backend
from repro.models import basecaller as bc
from repro.models import lm as lm_lib
from repro.pipeline import BasecallPipeline
from repro.serve import BasecallRequest, LMRequest, Server
from repro.serve.basecall_engine import BasecallEngine
from repro.serve.engine import ServingEngine

jax.config.update("jax_platform_name", "cpu")

QUANT = QuantConfig(enabled=True, bits_w=5, bits_a=5)
BACKENDS = ["auto", "interpret", "ref"]


def _pipe(backend="ref", packed=True, name="guppy", **kw):
    pipe = BasecallPipeline.from_preset(name, scale="tiny", quant=QUANT,
                                        backend=backend, beam_width=3,
                                        packed=packed, **kw)
    pipe.init_params(jax.random.PRNGKey(0))
    return pipe


def _signal(n, seed=0):
    return np.random.default_rng(seed).standard_normal(n).astype(np.float32)


# ---------------------------------------------------------------------------
# 1. bitwise parity: packed artifact == repack-per-call, every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", ["guppy", "chiron"])  # GRU + LSTM families
def test_packed_apply_bitwise_equals_repack(backend, name):
    cfg = bc.tiny_preset(name).with_quant(QUANT)
    params = bc.init_basecaller(jax.random.PRNGKey(0), cfg)
    packed = bc.pack_basecaller(params, cfg)
    sig = jnp.asarray(_signal(3 * cfg.input_len, seed=1).reshape(
        3, cfg.input_len, 1))
    be = Backend(backend)
    a = jax.jit(lambda p, s: bc.apply_basecaller(p, s, cfg, backend=be))(
        params, sig)
    b = jax.jit(lambda p, s: bc.apply_basecaller(p, s, cfg, backend=be))(
        packed, sig)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_packed_apply_bitwise_quant_disabled():
    cfg = bc.tiny_preset("guppy")            # fp path: packing is a no-op
    params = bc.init_basecaller(jax.random.PRNGKey(0), cfg)
    packed = bc.pack_basecaller(params, cfg)
    sig = jnp.asarray(_signal(2 * cfg.input_len).reshape(2, cfg.input_len, 1))
    be = Backend("ref")
    a = bc.apply_basecaller(params, sig, cfg, backend=be)
    b = bc.apply_basecaller(packed, sig, cfg, backend=be)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("backend", BACKENDS)
def test_pipeline_packed_bitwise_equals_unpacked(backend):
    sig = _signal(3 * 120 + 31, seed=2)
    un = _pipe(backend, packed=False)
    pk = BasecallPipeline(un.mcfg, backend=backend, scfg=un.scfg,
                          chunk=un.chunk, beam_width=un.beam_width,
                          packed=True, params=un.params)
    a, b = un.basecall(sig), pk.basecall(sig)
    np.testing.assert_array_equal(a.window_reads, b.window_reads)
    np.testing.assert_array_equal(a.window_lengths, b.window_lengths)
    assert a.length == b.length
    np.testing.assert_array_equal(a.read[: a.length], b.read[: b.length])


def test_fused_window_path_packed_parity():
    un = _pipe("ref", packed=False)
    pk = BasecallPipeline(un.mcfg, backend="ref", scfg=un.scfg,
                          beam_width=un.beam_width, packed=True,
                          params=un.params)
    batch = jnp.asarray(_signal(
        2 * (un.mcfg.input_len + 2 * un.scfg.margin), seed=3).reshape(
        2, un.mcfg.input_len + 2 * un.scfg.margin, 1))
    for a, b in zip(un.basecall_windows(batch), pk.basecall_windows(batch)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_holds_packed_artifact_and_matches_pipeline():
    pipe = _pipe("ref", packed=True)
    eng = BasecallEngine(pipe, batch_slots=2)
    assert bc.is_packed(eng.params)          # the artifact, not float weights
    sigs = [_signal(n, seed=20 + i) for i, n in enumerate((130, 470))]
    srv = Server(eng)
    for s in sigs:
        srv.submit(BasecallRequest(signal=s))
    done = srv.run_until_idle()
    for i, s in enumerate(sigs):
        want = pipe.basecall(s)
        np.testing.assert_array_equal(done[i].value.read[: want.length],
                                      want.read[: want.length])
        assert done[i].value.length == want.length


def test_qmm_packed_matches_reference():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((5, 24)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((24, 12)).astype(np.float32))
    wq, sw = quant_lib.pack_weight(w, 5)
    got = qmm_packed(x, wq, sw, bits_a=5, backend="ref")
    want = quant_lib.packed_dense_reference(x, wq, sw, bits_a=5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# 2. jaxpr inspection: the packed serving trace quantizes no weights
# ---------------------------------------------------------------------------
#
# Dataflow taint analysis: a value is "weight-only" if it derives from
# params leaves alone (never mixed with an activation).  Weight
# quantization == a quantization primitive consuming a weight-only value;
# activation packing keeps its round/clamp ops (they consume signal-mixed
# values) and is NOT flagged.  The walker itself lives in
# ``repro.analysis.jaxpr_tools`` (the repo's ONE jaxpr-analysis
# implementation — the CLI's trace pass runs the same code).


def _count_weight_quant_ops(params, cfg, backend):
    be = Backend(backend)
    sig = jnp.zeros((2, cfg.input_len, 1), jnp.float32)
    closed = jax.make_jaxpr(
        lambda p, s: bc.apply_basecaller(p, s, cfg, backend=be))(params, sig)
    n_param_leaves = len(jax.tree_util.tree_leaves(params))
    return len(jt.weight_quant_eqns(closed, n_param_leaves))


@pytest.mark.parametrize("name", ["guppy", "chiron"])
def test_packed_trace_has_zero_weight_quant_ops(name):
    cfg = bc.tiny_preset(name).with_quant(QUANT)
    params = bc.init_basecaller(jax.random.PRNGKey(0), cfg)
    packed = bc.pack_basecaller(params, cfg)
    # positive control: the detector must fire on the repack-per-call path
    assert _count_weight_quant_ops(params, cfg, "ref") > 0
    # the artifact's serving trace quantizes no weights
    assert _count_weight_quant_ops(packed, cfg, "ref") == 0


def test_packed_decode_windows_trace_has_zero_weight_quant_ops():
    """End to end: the pipeline's whole jitted DNN+decode serving stage."""
    pipe = _pipe("ref", packed=True)
    packed = pipe.serving_params()
    windows = jnp.zeros((2, pipe.mcfg.input_len, 1), jnp.float32)
    lengths = jnp.full((2,), pipe.mcfg.input_len, jnp.int32)
    mcfg, be, W, L = pipe.mcfg, pipe.backend, pipe.beam_width, \
        pipe.max_read_len

    from repro.core import ctc as ctc_lib

    def stage(p, w, ll):
        lps = bc.apply_basecaller(p, w, mcfg, backend=be)
        reads, lens, _ = ctc_lib.ctc_beam_search_hash_batch(
            lps, beam_width=W, max_len=L, logit_lengths=ll, backend=be)
        return reads[:, 0], lens[:, 0]

    closed = jax.make_jaxpr(stage)(packed, windows, lengths)
    n = len(jax.tree_util.tree_leaves(packed))
    assert jt.weight_quant_eqns(closed, n) == []


def test_lm_packed_trace_has_zero_weight_quant_ops():
    """Guard for ``pack_lm_serving``'s snap allowlist: if a new ``qdense``
    weight is added to the LM without extending the allowlist, it would be
    served UNQUANTIZED under ``weights_prequantized`` — but its fq ops in
    the unpacked trace would vanish from the packed one without a matching
    pre-snap, while any still-quantizing weight shows up here as a
    weight-only quant op.  Either way this asserts the packed LM trace
    quantizes no weights at all."""
    cfg = lm_lib.LMConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                          d_ff=64, vocab_size=64, quant=QUANT, remat=False)
    params = lm_lib.init_lm(jax.random.PRNGKey(0), cfg)
    packed, scfg = lm_lib.pack_lm_serving(params, cfg)
    batch = {"tokens": jnp.zeros((2, 5), jnp.int32)}

    def count(p, c):
        closed = jax.make_jaxpr(
            lambda p, b: lm_lib.forward(p, c, b)[0])(p, batch)
        n = len(jax.tree_util.tree_leaves(p))
        return len(jt.weight_quant_eqns(closed, n))

    assert count(params, cfg) > 0       # positive control: per-call path
    assert count(packed, scfg) == 0     # the artifact quantizes no weights


# ---------------------------------------------------------------------------
# 3. cache discipline: pack once, invalidate on rebind
# ---------------------------------------------------------------------------

def test_pipeline_packs_once_and_repacks_on_rebind():
    pipe = _pipe("ref", packed=True)
    a = pipe.serving_params()
    assert bc.is_packed(a)
    assert pipe.serving_params() is a            # cached, same checkpoint
    pipe.basecall(_signal(130))
    assert pipe.serving_params() is a            # serving reused the cache

    override = jax.tree_util.tree_map(lambda x: x + 0.1, pipe.params)
    d = pipe.serving_params(override)            # params= override packs too
    assert d is not a
    # default + override artifacts coexist: alternating (pipeline serving
    # checkpoint A, an engine serving checkpoint B) never repacks
    assert pipe.serving_params() is a
    assert pipe.serving_params(override) is d

    pipe.init_params(jax.random.PRNGKey(1))      # new checkpoint => repack
    b = pipe.serving_params()
    assert b is not a

    newp = jax.tree_util.tree_map(lambda x: x * 0.5, pipe.params)
    pipe.params = newp                           # trainer-style rebind
    c = pipe.serving_params()
    assert c is not b and bc.is_packed(c)


def test_unpacked_pipeline_serves_float_weights():
    pipe = _pipe("ref", packed=False)
    assert pipe.serving_params() is pipe.params
    assert not bc.is_packed(pipe.serving_params())


def test_packed_apply_requires_backend():
    cfg = bc.tiny_preset("guppy").with_quant(QUANT)
    params = bc.init_basecaller(jax.random.PRNGKey(0), cfg)
    packed = bc.pack_basecaller(params, cfg)
    sig = jnp.zeros((1, cfg.input_len, 1), jnp.float32)
    with pytest.raises(ValueError, match="serving artifact"):
        bc.apply_basecaller(packed, sig, cfg)


# ---------------------------------------------------------------------------
# LM engine: pack_lm_serving parity through continuous batching
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tie", [False, True])
def test_lm_pack_serving_forward_bitwise(tie):
    cfg = lm_lib.LMConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                          d_ff=64, vocab_size=64, tie_embeddings=tie,
                          quant=QUANT)
    params = lm_lib.init_lm(jax.random.PRNGKey(0), cfg)
    packed, scfg = lm_lib.pack_lm_serving(params, cfg)
    assert scfg.quant.weights_prequantized
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 9),
                                          0, 64)}
    a, _ = jax.jit(lambda p, b: lm_lib.forward(p, cfg, b))(params, batch)
    b, _ = jax.jit(lambda p, b: lm_lib.forward(p, scfg, b))(packed, batch)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serving_engine_packed_matches_unpacked():
    cfg = lm_lib.LMConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                          d_ff=64, vocab_size=64, quant=QUANT)
    params = lm_lib.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 64, n).astype(np.int32) for n in (3, 5, 4)]

    outs = []
    for pack in (True, False):
        eng = ServingEngine(params, cfg, batch_slots=2, max_len=64,
                            pack=pack)
        if pack:
            assert eng.cfg.quant.weights_prequantized
        srv = Server(eng)
        for p in prompts:
            srv.submit(LMRequest(prompt=p, max_tokens=6))
        done = srv.run_until_idle()
        outs.append({i: done[i].value for i in done})
    assert outs[0] == outs[1]


def test_pack_lm_serving_noop_without_quant():
    cfg = lm_lib.LMConfig(n_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
                          d_ff=32, vocab_size=32)
    params = lm_lib.init_lm(jax.random.PRNGKey(0), cfg)
    packed, scfg = lm_lib.pack_lm_serving(params, cfg)
    assert packed is params and scfg is cfg
