"""Paged KV-cache serving: kernel parity, engine oracle parity, the
free-block allocator, and the ring-overflow / stale-KV regressions.

Layers of proof:
  * ``paged_decode_attn`` ref == interpret == the dense-gather oracle on
    ragged lane validity and shuffled block tables;
  * the paged ``ServingEngine`` (folded and unfolded admission, with and
    without arena contention/preemption, with and without a dp mesh) is
    bitwise identical to the dense engine and to sequential full-forward
    decoding;
  * property-style allocator sweep — random admit/grow/retire/release
    sequences never double-assign a block, never cross a partition,
    never exceed the arena, and reclaim every block on drain;
  * regressions: over-length requests resolve with a clear error result
    instead of wedging a lane (sliding-window configs keep their
    intentional wrap), and a slot reused after a mid-flight release can
    never attend the previous tenant's keys.
"""
import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfg_reg
from repro.models import lm as lm_lib
from repro.serve import LMRequest, Server, SlotScheduler
from repro.serve.engine import Request, ServingEngine

jax.config.update("jax_platform_name", "cpu")


def _setup(seed=0):
    cfg = dataclasses.replace(cfg_reg.get_smoke("qwen2.5-3b"), remat=False)
    params = lm_lib.init_lm(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def _reference_generate(params, cfg, prompt, n_tokens):
    """Greedy decode by repeatedly running the full forward (oracle)."""
    toks = list(prompt)
    out = []
    for _ in range(n_tokens):
        logits, _ = lm_lib.forward(params, cfg,
                                   {"tokens": jnp.asarray([toks])})
        nxt = int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))
        out.append(nxt)
        toks.append(nxt)
    return out


def _serve_all(eng, prompts, budgets):
    srv = Server(eng)
    futs = [srv.submit(LMRequest(prompt=np.asarray(p), max_tokens=m))
            for p, m in zip(prompts, budgets)]
    res = srv.run_until_idle()
    return [res[f.rid].value for f in futs]


# ---------------------------------------------------------------------------
# kernel parity: paged_decode_attn across backends vs the gather oracle
# ---------------------------------------------------------------------------

def test_paged_decode_attn_backend_parity():
    from repro.kernels.decode_attn.ops import paged_decode_attn
    from repro.models.layers import paged_decode_attention

    rng = np.random.default_rng(0)
    B, N, bs, Kv, G, D, nb = 3, 16, 8, 2, 3, 16, 3
    q = jnp.asarray(rng.normal(size=(B, Kv * G, D)), jnp.float32)
    k_a = jnp.asarray(rng.normal(size=(N, bs, Kv, D)), jnp.float32)
    v_a = jnp.asarray(rng.normal(size=(N, bs, Kv, D)), jnp.float32)
    # non-contiguous tables; lanes ragged vs nb*bs (incl. single token)
    bt = jnp.asarray([[3, 7, 1], [12, 0, 5], [9, 2, 14]], jnp.int32)
    nv = jnp.asarray([5, 24, 1], jnp.int32)

    oracle = paged_decode_attention(q[:, None], k_a, v_a, bt, nv)[:, 0]
    for backend in ("ref", "interpret"):
        got = paged_decode_attn(q, k_a, v_a, bt, nv, groups=G,
                                backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(oracle),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=backend)


def test_paged_decode_attn_matches_dense_gather():
    """A lane's paged attention == dense attention over its own tokens."""
    from repro.models.layers import decode_attention, paged_decode_attention

    rng = np.random.default_rng(1)
    N, bs, Kv, G, D = 8, 4, 2, 2, 8
    q = jnp.asarray(rng.normal(size=(1, 1, Kv * G, D)), jnp.float32)
    k_a = jnp.asarray(rng.normal(size=(N, bs, Kv, D)), jnp.float32)
    v_a = jnp.asarray(rng.normal(size=(N, bs, Kv, D)), jnp.float32)
    bt = jnp.asarray([[6, 1, 4]], jnp.int32)
    nv = jnp.asarray([9], jnp.int32)

    got = paged_decode_attention(q, k_a, v_a, bt, nv)
    k = k_a[bt[0]].reshape(1, 3 * bs, Kv, D)
    v = v_a[bt[0]].reshape(1, 3 * bs, Kv, D)
    valid = jnp.arange(3 * bs)[None] < nv[:, None]
    want = decode_attention(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# engine oracle parity
# ---------------------------------------------------------------------------

def test_paged_engine_matches_dense_and_reference():
    cfg, params = _setup()
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (4, 7, 2, 5, 8, 3)]
    budgets = [6, 4, 8, 5, 3, 7]
    dense = _serve_all(ServingEngine(params, cfg, batch_slots=2,
                                     max_len=32), prompts, budgets)
    paged = _serve_all(ServingEngine(params, cfg, batch_slots=2,
                                     max_len=32, kv_layout="paged",
                                     kv_block=4), prompts, budgets)
    assert paged == dense
    for p, m, got in zip(prompts[:3], budgets[:3], paged[:3]):
        assert got == _reference_generate(params, cfg, p, m)


def test_paged_folded_admission_matches_unfolded():
    """Folded (scan) prompt admission == per-token decode_step oracle."""
    cfg, params = _setup(1)
    prompt = np.asarray([7, 3, 9, 1, 5], np.int32)

    outs = []
    for admit in ("_admit_one", "_admit_one_unfolded"):
        eng = ServingEngine(params, cfg, batch_slots=2, max_len=32,
                            kv_layout="paged", kv_block=4)
        req = Request(rid=0, prompt=prompt, max_tokens=6)
        getattr(eng, admit)(0, req)
        eng.sched.slots[0] = req
        for _ in range(6):
            eng.step()
        outs.append(list(req.out_tokens))
    assert outs[0] == outs[1]
    assert outs[0] == _reference_generate(params, cfg, prompt.tolist(), 6)


def test_paged_preemption_resumes_bitwise():
    """An arena too small for all lanes preempts, requeues, and still
    reproduces the uncontended results exactly (greedy determinism)."""
    cfg, params = _setup(3)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (5, 8, 3, 6, 4, 7)]
    budgets = [8] * 6
    dense = _serve_all(ServingEngine(params, cfg, batch_slots=2,
                                     max_len=32), prompts, budgets)
    eng = ServingEngine(params, cfg, batch_slots=2, max_len=32,
                        kv_layout="paged", kv_block=4, kv_blocks=6)
    assert _serve_all(eng, prompts, budgets) == dense
    assert eng.preemptions > 0, "arena was sized to force preemption"
    assert eng.sched.free_blocks() == eng.n_kv_blocks


def test_paged_engine_dp_sharded(host_mesh4):
    from repro.dist import sharding as shd

    cfg, params = _setup(5)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (4, 6, 3, 7, 5, 2, 8, 4)]
    budgets = [5, 3, 7, 4, 6, 8, 2, 5]
    dense = _serve_all(ServingEngine(params, cfg, batch_slots=2,
                                     max_len=32), prompts, budgets)
    with shd.use_mesh(host_mesh4):
        eng = ServingEngine(params, cfg, batch_slots=1, max_len=32,
                            kv_layout="paged", kv_block=4)
    assert eng.dp == 4 and eng.B == 4
    assert eng.n_kv_blocks % eng.dp == 0
    assert _serve_all(eng, prompts, budgets) == dense
    assert eng.sched.free_blocks() == eng.n_kv_blocks


# ---------------------------------------------------------------------------
# ring-overflow regression (the admission bug)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_layout", ["dense", "paged"])
def test_overflowing_request_resolves_with_error(kv_layout):
    """prompt + max_tokens > max_len must resolve as a clear error result
    at submit — not wedge a lane and silently wrap the KV ring."""
    cfg, params = _setup()
    kw = {"kv_block": 4} if kv_layout == "paged" else {}
    eng = ServingEngine(params, cfg, batch_slots=2, max_len=16,
                        kv_layout=kv_layout, **kw)
    srv = Server(eng)
    bad = srv.submit(LMRequest(prompt=np.arange(1, 10), max_tokens=16))
    ok = srv.submit(LMRequest(prompt=np.asarray([5, 9, 2]), max_tokens=4))
    res_bad, res_ok = bad.result(), ok.result()
    assert res_bad.status == "error" and res_bad.value is None
    assert "max_len" in res_bad.error
    assert res_ok.ok and len(res_ok.value) == 4
    assert srv.metrics().errors == 1
    assert not any(eng.active_mask()) and not eng.sched.queue


def test_overflowing_request_engine_direct_raises():
    cfg, params = _setup()
    eng = ServingEngine(params, cfg, batch_slots=1, max_len=8)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(rid=0, prompt=np.arange(1, 7), max_tokens=8))


@pytest.mark.parametrize("admit", ["_admit_one", "_admit_one_unfolded"])
def test_at_capacity_request_still_admits(admit):
    """Exactly prompt + max_tokens == max_len is servable — both folded
    and unfolded admission paths fill the cache to the brim correctly."""
    cfg, params = _setup(2)
    prompt = [4, 1, 7, 2]
    want = _reference_generate(params, cfg, prompt, 4)
    eng = ServingEngine(params, cfg, batch_slots=1, max_len=8)
    assert eng.validate(Request(rid=0, prompt=np.asarray(prompt),
                                max_tokens=4)) is None
    req = Request(rid=0, prompt=np.asarray(prompt, np.int32), max_tokens=4)
    getattr(eng, admit)(0, req)
    eng.sched.slots[0] = req
    for _ in range(4):
        eng.step()
    assert req.out_tokens == want


def test_sliding_window_keeps_intentional_wrap():
    """SWA configs ring-wrap by design: validation must not reject them."""
    cfg, params = _setup(3)
    cfg = dataclasses.replace(cfg, window=8)
    eng = ServingEngine(params, cfg, batch_slots=1, max_len=16)
    long_req = LMRequest(prompt=np.asarray([3, 1, 4]), max_tokens=32)
    assert eng.validate(long_req) is None
    res = Server(eng).submit(long_req).result()
    assert res.ok and len(res.value) == 32


def test_paged_rejects_window_config():
    cfg, params = _setup()
    cfg = dataclasses.replace(cfg, window=8)
    with pytest.raises(ValueError, match="sliding-window"):
        ServingEngine(params, cfg, batch_slots=1, max_len=16,
                      kv_layout="paged")


def test_paged_rejects_request_larger_than_partition():
    cfg, params = _setup()
    eng = ServingEngine(params, cfg, batch_slots=2, max_len=32,
                        kv_layout="paged", kv_block=4, kv_blocks=4)
    err = eng.validate(Request(rid=0, prompt=np.arange(1, 12),
                               max_tokens=16))
    assert err is not None and "arena partition" in err


# ---------------------------------------------------------------------------
# stale-KV isolation (slot reuse after mid-flight release)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_layout", ["dense", "paged"])
def test_slot_reuse_after_release_never_attends_stale_kv(kv_layout):
    """Cancel a request mid-flight, admit another into the same slot: its
    output must equal a fresh engine's.  Pins the isolation argument:
    ``_reset_slot`` zeroes only pos, but attention validity is the prefix
    ``< pos + 1`` (dense) / the lane's own block table (paged), so the
    previous tenant's keys are unreachable."""
    cfg, params = _setup(4)
    kw = {"kv_block": 4} if kv_layout == "paged" else {}
    prompt_b, budget_b = [6, 2, 8], 6

    fresh = _serve_all(ServingEngine(params, cfg, batch_slots=1,
                                     max_len=32, kv_layout=kv_layout, **kw),
                       [prompt_b], [budget_b])[0]

    eng = ServingEngine(params, cfg, batch_slots=1, max_len=32,
                        kv_layout=kv_layout, **kw)
    srv = Server(eng)
    vic = srv.submit(LMRequest(prompt=np.asarray([9, 9, 9, 9, 9]),
                               max_tokens=20))
    for _ in range(4):          # fill slot 0's cache with victim KV
        srv.step()
    assert vic.cancel()
    res = srv.submit(LMRequest(prompt=np.asarray(prompt_b),
                               max_tokens=budget_b)).result()
    assert res.ok and res.value == fresh


# ---------------------------------------------------------------------------
# allocator properties
# ---------------------------------------------------------------------------

def _check_alloc_invariants(sched: SlotScheduler, kv_blocks, kv_groups):
    per = kv_blocks // kv_groups
    held = [b for blocks in sched.slot_blocks for b in blocks]
    assert len(held) == len(set(held)), "block double-assigned"
    free = [b for g in range(kv_groups) for b in sched._free[g]]
    assert sorted(held + free) == list(range(kv_blocks)), \
        "blocks leaked or invented"
    for slot, blocks in enumerate(sched.slot_blocks):
        g = sched.group_of(slot)
        assert all(g * per <= b < (g + 1) * per for b in blocks), \
            f"slot {slot} holds blocks outside partition {g}"


@pytest.mark.parametrize("kv_groups", [1, 2, 4])
def test_allocator_random_sequences_never_leak(kv_groups):
    rng = random.Random(kv_groups)
    n_slots, kv_blocks = 8, 32
    sched = SlotScheduler(n_slots, kv_blocks=kv_blocks, kv_groups=kv_groups)
    rid = 0
    for _ in range(400):
        op = rng.choice(["submit", "admit", "grow", "retire", "release",
                         "cancel"])
        if op == "submit":
            sched.submit(Request(rid=rid, prompt=np.asarray([1])))
            rid += 1
        elif op == "admit":
            sched.admit(lambda s, r: None,
                        need_fn=lambda r: rng.randint(1, 3))
        elif op == "grow":
            occupied = [s for s in range(n_slots)
                        if sched.slots[s] is not None]
            if occupied:
                sched.grow_block(rng.choice(occupied))
        elif op in ("retire", "release"):
            occupied = [s for s in range(n_slots)
                        if sched.slots[s] is not None]
            if occupied:
                s = rng.choice(occupied)
                if op == "retire":
                    sched.retire(s, sched.slots[s].rid)
                else:
                    sched.release(s)
        elif op == "cancel" and sched.queue:
            sched.cancel_queued(rng.choice(sched.queue))
        _check_alloc_invariants(sched, kv_blocks, kv_groups)
    # drain: retire everything -> every block back on a free list
    for s in range(n_slots):
        if sched.slots[s] is not None:
            sched.retire(s, sched.slots[s].rid)
    sched.queue.clear()
    assert sched.free_blocks() == kv_blocks
    _check_alloc_invariants(sched, kv_blocks, kv_groups)


def test_allocator_admission_head_of_line_blocking():
    """When the queue head cannot fit, admission stops — smaller later
    requests must not starve it."""
    sched = SlotScheduler(2, kv_blocks=4, kv_groups=1)
    big = Request(rid=0, prompt=np.asarray([1]))
    small = Request(rid=1, prompt=np.asarray([1]))
    sched.submit(big)
    sched.submit(small)
    needs = {id(big): 5, id(small): 1}   # big can never fit (4-block arena)
    admitted = sched.admit(lambda s, r: None,
                           need_fn=lambda r: needs[id(r)])
    assert admitted == [] and sched.queue == [big, small]


def test_allocator_partition_exhaustion_and_grow():
    sched = SlotScheduler(2, kv_blocks=4, kv_groups=2)   # 2 blocks/group
    a = Request(rid=0, prompt=np.asarray([1]))
    sched.submit(a)
    assert sched.admit(lambda s, r: None, need_fn=lambda r: 1) == [0]
    assert sched.grow_block(0) is not None
    assert sched.grow_block(0) is None          # partition 0 dry
    assert sched.free_blocks(1) == 2            # partition 1 untouched
    sched.release(0)
    assert sched.free_blocks(0) == 2            # reclaimed
