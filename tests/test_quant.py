"""Quantization numerics: fake-quant, STE, packing, bit-width behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quant

jax.config.update("jax_platform_name", "cpu")


def test_qmax():
    assert quant.qmax(5) == 15
    assert quant.qmax(8) == 127
    assert quant.qmax(2) == 1


@pytest.mark.parametrize("bits", [3, 4, 5, 8])
def test_fake_quant_grid(bits):
    rng = np.random.default_rng(bits)
    x = jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32))
    xq = quant.fake_quant(x, bits)
    scale = float(jnp.max(jnp.abs(x))) / quant.qmax(bits)
    grid = np.asarray(xq) / scale
    np.testing.assert_allclose(grid, np.round(grid), atol=1e-4)
    assert np.abs(grid).max() <= quant.qmax(bits) + 1e-4


def test_fake_quant_error_decreases_with_bits():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((256, 128)).astype(np.float32))
    errs = [float(jnp.mean((quant.fake_quant(x, b) - x) ** 2))
            for b in (3, 4, 5, 8, 12)]
    assert all(a > b for a, b in zip(errs, errs[1:]))
    assert errs[-1] < 1e-6


def test_ste_gradient_is_identity():
    x = jnp.linspace(-1.0, 1.0, 11)
    g = jax.grad(lambda v: quant.fake_quant(v, 4).sum())(x)
    np.testing.assert_allclose(np.asarray(g), 1.0, atol=1e-6)


def test_per_channel_beats_per_tensor():
    """Per-channel scales must not be worse on badly-scaled channels."""
    rng = np.random.default_rng(1)
    w = rng.standard_normal((64, 8)).astype(np.float32)
    w[:, 0] *= 100.0  # one dominant channel wrecks a per-tensor scale
    w = jnp.asarray(w)
    pt = quant.fake_quant(w, 5)                    # per-tensor
    pc = quant.fake_quant(w, 5, axis=(0,))         # per-channel (out dim last)
    err_pt = float(jnp.mean((pt - w)[:, 1:] ** 2))
    err_pc = float(jnp.mean((pc - w)[:, 1:] ** 2))
    assert err_pc < err_pt / 10


def test_qdense_matches_dense_at_high_bits():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((4, 32)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    cfg = quant.QuantConfig(enabled=True, bits_w=16, bits_a=16)
    np.testing.assert_allclose(np.asarray(quant.qdense(x, w, cfg)),
                               np.asarray(x @ w), rtol=1e-3, atol=1e-3)


def test_qdense_disabled_is_exact():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((4, 8)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((8, 8)).astype(np.float32))
    cfg = quant.QuantConfig(enabled=False)
    np.testing.assert_allclose(np.asarray(quant.qdense(x, w, cfg)),
                               np.asarray(x @ w))


@settings(max_examples=20, deadline=None)
@given(bits=st.integers(2, 8), seed=st.integers(0, 1000))
def test_pack_roundtrip_bounded_error(bits, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    wq, scale = quant.pack_weight(w, bits)
    assert wq.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(wq))) <= quant.qmax(bits)
    deq = wq.astype(jnp.float32) * scale
    # max error bounded by half a quantization step per channel
    step = np.asarray(scale)
    assert np.all(np.abs(np.asarray(deq - w)) <= step / 2 + 1e-6)


def test_int_matmul_reference_matches_fq_matmul():
    """int32-accumulate dequant == fake-quant matmul (same grid)."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((8, 64)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32))
    bits = 5
    xq, sx = quant.pack_act(x, bits)
    wq, sw = quant.pack_weight(w, bits)
    got = quant.dequant_matmul_reference(xq, sx, wq, sw)
    want = (xq.astype(jnp.float32) * sx) @ (wq.astype(jnp.float32) * sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_tree_fake_quant_only_touches_matrices():
    w = jnp.linspace(-1.0, 1.0, 16).reshape(4, 4)  # off-grid values
    b = jnp.linspace(-1.0, 1.0, 4)
    out = quant.tree_fake_quant({"w": w, "b": b},
                                quant.QuantConfig(enabled=True, bits_w=4))
    assert not np.allclose(np.asarray(out["w"]), np.asarray(w))
    np.testing.assert_allclose(np.asarray(out["b"]), np.asarray(b))
