"""Serving engine: continuous batching == sequential reference decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfg_reg
from repro.models import decode as decode_lib
from repro.models import lm as lm_lib
from repro.serve.engine import Request, ServingEngine

jax.config.update("jax_platform_name", "cpu")


def _setup(seed=0):
    cfg = dataclasses.replace(cfg_reg.get_smoke("qwen2.5-3b"), remat=False)
    params = lm_lib.init_lm(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def _reference_generate(params, cfg, prompt, n_tokens):
    """Greedy decode by repeatedly running the full forward (oracle)."""
    toks = list(prompt)
    out = []
    for _ in range(n_tokens):
        logits, _ = lm_lib.forward(params, cfg,
                                   {"tokens": jnp.asarray([toks])})
        nxt = int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))
        out.append(nxt)
        toks.append(nxt)
    return out


def test_engine_matches_reference_single():
    cfg, params = _setup()
    prompt = [5, 9, 2, 7]
    want = _reference_generate(params, cfg, prompt, 6)
    eng = ServingEngine(params, cfg, batch_slots=2, max_len=64)
    eng.submit(Request(rid=0, prompt=np.asarray(prompt), max_tokens=6))
    done = eng.run()
    assert done[0].out_tokens == want


def test_engine_continuous_batching_multiple_requests():
    """3 requests through 2 slots: each result equals its solo reference."""
    cfg, params = _setup(1)
    prompts = [[1, 2, 3], [9, 8, 7, 6, 5], [4, 4]]
    budgets = [5, 4, 6]
    eng = ServingEngine(params, cfg, batch_slots=2, max_len=64)
    for i, (p, m) in enumerate(zip(prompts, budgets)):
        eng.submit(Request(rid=i, prompt=np.asarray(p), max_tokens=m))
    done = eng.run()
    assert sorted(done) == [0, 1, 2]
    for i, (p, m) in enumerate(zip(prompts, budgets)):
        want = _reference_generate(params, cfg, p, m)
        assert done[i].out_tokens == want, f"request {i}"


def test_engine_eos_retires_slot():
    cfg, params = _setup(2)
    want = _reference_generate(params, cfg, [3, 1], 8)
    # eos == the first generated token: retire immediately after one step
    eng = ServingEngine(params, cfg, batch_slots=1, max_len=64)
    eng.submit(Request(rid=7, prompt=np.asarray([3, 1]), max_tokens=8,
                       eos_id=want[0]))
    done = eng.run()
    assert done[7].out_tokens == want[:1]
    assert not any(eng.active_mask())


def test_decode_active_mask_freezes_lane():
    """Inactive lanes: no cache write, no position advance, same state."""
    cfg, params = _setup(3)
    cache = decode_lib.init_cache(cfg, 2, 32)
    toks = jnp.asarray([4, 4], jnp.int32)
    active = jnp.asarray([True, False])
    _, c1 = decode_lib.decode_step(params, cfg, cache, tokens=toks,
                                   active=active)
    assert int(c1["pos"][0]) == 1 and int(c1["pos"][1]) == 0
    k0 = np.asarray(jax.tree_util.tree_leaves(cache["blocks"])[0])
    k1 = np.asarray(jax.tree_util.tree_leaves(c1["blocks"])[0])
    # lane 1 (frozen) untouched, lane 0 wrote slot 0
    np.testing.assert_array_equal(k1[:, 1], k0[:, 1])
    assert not np.array_equal(k1[:, 0], k0[:, 0])


def test_folded_prompt_admission_matches_per_token_reference():
    """The single-scan prompt fold must equal one decode_step per token:
    identical caches (bitwise) and identical generations."""
    cfg, params = _setup(4)
    prompt = np.asarray([5, 9, 2, 7, 1])  # body of 4 -> padded bucket of 4

    folded = ServingEngine(params, cfg, batch_slots=2, max_len=64)
    folded._admit_one(0, Request(rid=0, prompt=prompt, max_tokens=4))

    ref = ServingEngine(params, cfg, batch_slots=2, max_len=64)
    ref._admit_one_unfolded(0, Request(rid=1, prompt=prompt, max_tokens=4))

    np.testing.assert_array_equal(np.asarray(folded.cache["pos"]),
                                  np.asarray(ref.cache["pos"]))
    for a, b in zip(jax.tree_util.tree_leaves(folded.cache["blocks"]),
                    jax.tree_util.tree_leaves(ref.cache["blocks"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(folded.last_token, ref.last_token)


def test_folded_admission_generation_end_to_end():
    """Engine with folded admission still equals the forward-pass oracle,
    including a ragged prompt length (bucket padding exercised)."""
    cfg, params = _setup(5)
    prompt = [3, 8, 6]                      # body of 2 -> bucket of 2
    want = _reference_generate(params, cfg, prompt, 5)
    eng = ServingEngine(params, cfg, batch_slots=2, max_len=64)
    eng.submit(Request(rid=0, prompt=np.asarray(prompt), max_tokens=5))
    done = eng.run()
    assert done[0].out_tokens == want
