"""Serving layer: the ``repro.serve.Server`` request lifecycle.

Three layers of proof:
  * engine oracle parity — continuous batching through the Server equals
    sequential full-forward decoding (the LM engine's ground truth);
  * lifecycle properties — random admit/cancel/retire interleavings never
    leak or double-occupy a slot; backpressure policies, deadlines,
    priorities and degenerate requests behave as specified (driven on a
    jax-free toy engine so hundreds of interleavings run in milliseconds);
  * golden parity — ``Server.submit``/``stream`` over ``BasecallEngine``
    is bitwise identical to ``BasecallPipeline.basecall``.
"""
import dataclasses
import random
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfg_reg
from repro.models import decode as decode_lib
from repro.models import lm as lm_lib
from repro.serve import (BasecallRequest, LMRequest, QueueFull, Server,
                         SlotScheduler)
from repro.serve.engine import Request, ServingEngine

jax.config.update("jax_platform_name", "cpu")


def _setup(seed=0):
    cfg = dataclasses.replace(cfg_reg.get_smoke("qwen2.5-3b"), remat=False)
    params = lm_lib.init_lm(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def _reference_generate(params, cfg, prompt, n_tokens):
    """Greedy decode by repeatedly running the full forward (oracle)."""
    toks = list(prompt)
    out = []
    for _ in range(n_tokens):
        logits, _ = lm_lib.forward(params, cfg,
                                   {"tokens": jnp.asarray([toks])})
        nxt = int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))
        out.append(nxt)
        toks.append(nxt)
    return out


# ---------------------------------------------------------------------------
# engine oracle parity, now through the Server front-end
# ---------------------------------------------------------------------------

def test_server_matches_reference_single():
    cfg, params = _setup()
    prompt = [5, 9, 2, 7]
    want = _reference_generate(params, cfg, prompt, 6)
    srv = Server(ServingEngine(params, cfg, batch_slots=2, max_len=64))
    res = srv.submit(LMRequest(prompt=np.asarray(prompt),
                               max_tokens=6)).result()
    assert res.ok and res.value == want


def test_server_continuous_batching_multiple_requests():
    """3 requests through 2 slots: each result equals its solo reference."""
    cfg, params = _setup(1)
    prompts = [[1, 2, 3], [9, 8, 7, 6, 5], [4, 4]]
    budgets = [5, 4, 6]
    srv = Server(ServingEngine(params, cfg, batch_slots=2, max_len=64))
    futs = [srv.submit(LMRequest(prompt=np.asarray(p), max_tokens=m))
            for p, m in zip(prompts, budgets)]
    done = srv.run_until_idle()
    assert sorted(done) == [0, 1, 2]
    for i, (p, m) in enumerate(zip(prompts, budgets)):
        want = _reference_generate(params, cfg, p, m)
        assert done[i].value == want, f"request {i}"
    assert all(f.done() for f in futs)


def test_server_eos_retires_slot():
    cfg, params = _setup(2)
    want = _reference_generate(params, cfg, [3, 1], 8)
    # eos == the first generated token: retire immediately after one step
    eng = ServingEngine(params, cfg, batch_slots=1, max_len=64)
    srv = Server(eng)
    res = srv.submit(LMRequest(prompt=np.asarray([3, 1]), max_tokens=8,
                               eos_id=want[0])).result()
    assert res.value == want[:1]
    assert not any(eng.active_mask())


def test_server_streams_tokens_incrementally():
    cfg, params = _setup(6)
    prompt = [2, 5, 1]
    want = _reference_generate(params, cfg, prompt, 4)
    srv = Server(ServingEngine(params, cfg, batch_slots=2, max_len=64))
    events = list(srv.stream(LMRequest(prompt=np.asarray(prompt),
                                       max_tokens=4)))
    toks = [e for e in events if e.kind == "token"]
    assert [e.payload for e in toks] == want
    assert [e.index for e in toks] == list(range(4))
    assert events[-1].kind == "final" and events[-1].payload.value == want


def test_decode_active_mask_freezes_lane():
    """Inactive lanes: no cache write, no position advance, same state."""
    cfg, params = _setup(3)
    cache = decode_lib.init_cache(cfg, 2, 32)
    toks = jnp.asarray([4, 4], jnp.int32)
    active = jnp.asarray([True, False])
    _, c1 = decode_lib.decode_step(params, cfg, cache, tokens=toks,
                                   active=active)
    assert int(c1["pos"][0]) == 1 and int(c1["pos"][1]) == 0
    k0 = np.asarray(jax.tree_util.tree_leaves(cache["blocks"])[0])
    k1 = np.asarray(jax.tree_util.tree_leaves(c1["blocks"])[0])
    # lane 1 (frozen) untouched, lane 0 wrote slot 0
    np.testing.assert_array_equal(k1[:, 1], k0[:, 1])
    assert not np.array_equal(k1[:, 0], k0[:, 0])


def test_folded_prompt_admission_matches_per_token_reference():
    """The single-scan prompt fold must equal one decode_step per token:
    identical caches (bitwise) and identical generations."""
    cfg, params = _setup(4)
    prompt = np.asarray([5, 9, 2, 7, 1])  # body of 4 -> padded bucket of 4

    folded = ServingEngine(params, cfg, batch_slots=2, max_len=64)
    folded._admit_one(0, Request(rid=0, prompt=prompt, max_tokens=4))

    ref = ServingEngine(params, cfg, batch_slots=2, max_len=64)
    ref._admit_one_unfolded(0, Request(rid=1, prompt=prompt, max_tokens=4))

    np.testing.assert_array_equal(np.asarray(folded.cache["pos"]),
                                  np.asarray(ref.cache["pos"]))
    for a, b in zip(jax.tree_util.tree_leaves(folded.cache["blocks"]),
                    jax.tree_util.tree_leaves(ref.cache["blocks"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(folded.last_token, ref.last_token)


def test_folded_admission_generation_end_to_end():
    """Engine with folded admission still equals the forward-pass oracle,
    including a ragged prompt length (bucket padding exercised)."""
    cfg, params = _setup(5)
    prompt = [3, 8, 6]                      # body of 2 -> bucket of 2
    want = _reference_generate(params, cfg, prompt, 5)
    srv = Server(ServingEngine(params, cfg, batch_slots=2, max_len=64))
    res = srv.submit(LMRequest(prompt=np.asarray(prompt),
                               max_tokens=5)).result()
    assert res.value == want


# ---------------------------------------------------------------------------
# lifecycle properties on a jax-free toy engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ToyRequest:
    work: int                          # engine steps to completion
    priority: int = 0
    deadline: Optional[float] = None


class _Native:
    def __init__(self, rid, work):
        self.rid = rid
        self.work = work
        self.out: List[int] = []


class ToyEngine:
    """Minimal EngineProtocol implementation: one unit of output per step,
    retire after ``work`` units.  No jax — lifecycle tests run in ms."""
    event_kind = "unit"

    def __init__(self, batch_slots: int):
        self.sched: SlotScheduler[_Native] = SlotScheduler(batch_slots)
        self.steps = 0

    def make_request(self, rid, r: ToyRequest) -> _Native:
        return _Native(rid, r.work)

    def degenerate(self, r: ToyRequest) -> bool:
        return r.work <= 0

    def empty_result(self, r: ToyRequest) -> List[int]:
        return []

    def admit(self):
        return self.sched.admit(lambda slot, req: None)

    def step(self):
        self.steps += 1
        for slot, req in enumerate(self.sched.slots):
            if req is None:
                continue
            req.out.append(len(req.out))
            if len(req.out) >= req.work:
                self.sched.retire(slot, req.rid)

    def progress(self, native: _Native) -> List[int]:
        return native.out

    def result_of(self, native: _Native) -> List[int]:
        return list(native.out)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float):
        self.t += dt


def test_scheduler_random_interleavings_never_leak_or_double_occupy():
    """Property: under random submit/admit/retire/release/cancel
    interleavings the slot table never double-occupies, and every request
    is in exactly one place (queued, active, finished, or dropped)."""
    for seed in range(25):
        rng = random.Random(seed)
        sched: SlotScheduler[_Native] = SlotScheduler(rng.randint(1, 4))
        next_rid, dropped, all_reqs = 0, set(), {}
        for _ in range(rng.randint(5, 60)):
            op = rng.choice(["submit", "admit", "retire", "release",
                             "cancel"])
            if op == "submit":
                req = _Native(next_rid, 1)
                all_reqs[next_rid] = req
                sched.submit(req)
                next_rid += 1
            elif op == "admit":
                sched.admit(lambda slot, req: None)
            elif op == "retire":
                occupied = [s for s, r in enumerate(sched.slots)
                            if r is not None]
                if occupied:
                    slot = rng.choice(occupied)
                    sched.retire(slot, sched.slots[slot].rid)
            elif op == "release":
                occupied = [s for s, r in enumerate(sched.slots)
                            if r is not None]
                if occupied:
                    slot = rng.choice(occupied)
                    dropped.add(sched.release(slot).rid)
            elif op == "cancel" and sched.queue:
                req = rng.choice(sched.queue)
                assert sched.cancel_queued(req)
                dropped.add(req.rid)

            # invariants: no identity appears twice; full conservation
            active = [r.rid for r in sched.slots if r is not None]
            queued = [r.rid for r in sched.queue]
            finished = list(sched.finished)
            assert len(active) == len(set(active)), seed
            everywhere = active + queued + finished + sorted(dropped)
            assert sorted(everywhere) == sorted(all_reqs), seed
        # drain: everything still live must complete, nothing leaks
        while sched.pending():
            sched.admit(lambda slot, req: None)
            for slot, r in enumerate(list(sched.slots)):
                if r is not None:
                    sched.retire(slot, r.rid)
        assert set(sched.finished) | dropped == set(all_reqs)


def test_server_random_lifecycle_terminates_every_request():
    """Property: random submit/cancel/step interleavings — every submitted
    request reaches exactly one terminal state and no slot stays occupied."""
    for seed in range(15):
        rng = random.Random(100 + seed)
        eng = ToyEngine(batch_slots=rng.randint(1, 3))
        srv = Server(eng, max_queue=4, backpressure="shed-oldest")
        futs = []
        for _ in range(rng.randint(5, 40)):
            op = rng.choice(["submit", "submit", "step", "cancel"])
            if op == "submit":
                futs.append(srv.submit(ToyRequest(work=rng.randint(0, 4))))
            elif op == "step":
                srv.step()
            elif op == "cancel" and futs:
                futs[rng.randrange(len(futs))].cancel()
        done = srv.run_until_idle()
        assert sorted(done) == sorted(f.rid for f in futs), seed
        statuses = {r.status for r in done.values()}
        assert statuses <= {"ok", "cancelled", "shed"}, seed
        assert not any(eng.sched.active_mask()), seed
        assert not eng.sched.queue and not eng.sched.finished, seed
        for f in futs:     # ok results carry exactly `work` units
            res = done[f.rid]
            if res.ok:
                assert res.value == list(range(len(res.value)))


def test_backpressure_reject_raises_queue_full():
    eng = ToyEngine(batch_slots=1)
    srv = Server(eng, max_queue=2, backpressure="reject")
    srv.submit(ToyRequest(work=3))
    srv.step()                                    # request 0 -> the slot
    srv.submit(ToyRequest(work=1))
    srv.submit(ToyRequest(work=1))                # queue now full (2)
    with pytest.raises(QueueFull):
        srv.submit(ToyRequest(work=1))
    assert srv.metrics().rejected == 1
    done = srv.run_until_idle()
    assert sorted(r.rid for r in done.values() if r.ok) == [0, 1, 2]


def test_backpressure_block_drives_engine_until_space():
    eng = ToyEngine(batch_slots=1)
    srv = Server(eng, max_queue=1, backpressure="block")
    f0 = srv.submit(ToyRequest(work=2))
    f1 = srv.submit(ToyRequest(work=2))           # fills the 1-deep queue
    f2 = srv.submit(ToyRequest(work=2))           # must block-step to admit
    assert eng.steps > 0                          # progress was forced
    done = srv.run_until_idle()
    assert all(done[f.rid].ok for f in (f0, f1, f2))


def test_backpressure_shed_oldest_drops_longest_queued():
    clock = FakeClock()
    eng = ToyEngine(batch_slots=1)
    srv = Server(eng, max_queue=2, backpressure="shed-oldest", clock=clock)
    srv.submit(ToyRequest(work=5))
    srv.step()                                    # rid 0 occupies the slot
    clock.advance(1.0)
    f1 = srv.submit(ToyRequest(work=1))           # oldest queued
    clock.advance(1.0)
    f2 = srv.submit(ToyRequest(work=1))
    clock.advance(1.0)
    f3 = srv.submit(ToyRequest(work=1))           # sheds f1
    assert f1.done() and f1.result().status == "shed"
    done = srv.run_until_idle()
    assert done[f2.rid].ok and done[f3.rid].ok
    assert srv.metrics().shed == 1


def test_deadline_expires_queued_request():
    clock = FakeClock()
    eng = ToyEngine(batch_slots=1)
    srv = Server(eng, clock=clock)
    f0 = srv.submit(ToyRequest(work=4))
    f1 = srv.submit(ToyRequest(work=1, deadline=2.0))   # will wait too long
    f2 = srv.submit(ToyRequest(work=1, deadline=50.0))  # comfortable
    srv.step()                                    # rid 0 admitted
    clock.advance(3.0)                            # f1's deadline passes
    done = srv.run_until_idle()
    assert done[f0.rid].ok
    assert done[f1.rid].status == "expired" and done[f1.rid].value is None
    assert done[f2.rid].ok
    assert srv.metrics().expired == 1


def test_deadline_expires_in_flight_request_and_frees_slot():
    clock = FakeClock()
    eng = ToyEngine(batch_slots=1)
    srv = Server(eng, clock=clock)
    f0 = srv.submit(ToyRequest(work=100, deadline=1.5))
    f1 = srv.submit(ToyRequest(work=2))
    srv.step()                                    # f0 admitted, starts
    clock.advance(2.0)                            # mid-flight expiry
    done = srv.run_until_idle()
    assert done[f0.rid].status == "expired"
    assert done[f1.rid].ok                        # slot was freed for f1
    assert not any(eng.sched.active_mask())


def test_priority_admits_before_fifo():
    eng = ToyEngine(batch_slots=1)
    srv = Server(eng)
    srv.submit(ToyRequest(work=2))                # occupies the slot
    srv.step()
    f_lo = srv.submit(ToyRequest(work=1, priority=0))
    f_hi = srv.submit(ToyRequest(work=1, priority=5))
    done = srv.run_until_idle()
    assert done[f_hi.rid].finished_at <= done[f_lo.rid].finished_at


def test_cancel_queued_and_active():
    eng = ToyEngine(batch_slots=1)
    srv = Server(eng)
    f0 = srv.submit(ToyRequest(work=50))
    f1 = srv.submit(ToyRequest(work=1))
    srv.step()                                    # f0 active, f1 queued
    assert f1.cancel()                            # queued cancel
    assert f0.cancel()                            # in-flight cancel
    assert not f0.cancel()                        # already terminal
    done = srv.run_until_idle()
    assert done[f0.rid].status == "cancelled"
    assert done[f1.rid].status == "cancelled"
    assert not any(eng.sched.active_mask())


def test_cancel_mid_stream_terminates_consumer_generator():
    """Regression: cancel() on a request being consumed via stream() must
    terminate the generator with a final "cancelled" event — the consumer
    must not block forever waiting for events that will never come."""
    eng = ToyEngine(batch_slots=1)
    srv = Server(eng)
    events = []
    for ev in srv.stream(ToyRequest(work=50), max_steps=200):
        events.append(ev)
        if len(events) == 3:
            assert srv.cancel(ev.rid)
    final = events[-1]
    assert final.kind == "final"
    assert final.payload.status == "cancelled"
    assert len(events) < 50 + 1                   # terminated early
    assert not any(eng.sched.active_mask())       # lane reclaimed


def test_degenerate_toy_request_completes_inline():
    eng = ToyEngine(batch_slots=1)
    srv = Server(eng, max_queue=1)
    res = srv.submit(ToyRequest(work=0)).result()
    assert res.ok and res.value == [] and eng.steps == 0


def test_terminal_records_evicted_beyond_retention():
    """A long-lived server keeps only the last ``retain_results`` terminal
    records: old futures age out, memory stays bounded."""
    eng = ToyEngine(batch_slots=2)
    srv = Server(eng, retain_results=3)
    futs = [srv.submit(ToyRequest(work=1)) for _ in range(8)]
    srv.run_until_idle()
    assert len(srv.results) == 3 and len(srv._records) == 3
    assert sorted(srv.results) == [f.rid for f in futs[-3:]]
    assert futs[-1].result().ok                  # recent: still readable
    with pytest.raises(KeyError, match="aged out"):
        futs[0].result()                         # evicted: explicit error
    m = srv.metrics()
    assert m.completed == 8                      # counters are not evicted


def test_server_ignores_requests_submitted_straight_to_engine():
    """Mixed mode: natives submitted directly to the engine (even with
    colliding rids) are never delivered to the server's futures, and the
    server's own requests still resolve with their own results."""
    eng = ToyEngine(batch_slots=1)
    # a foreign native whose rid will collide with the server's first rid
    eng.sched.submit(_Native(rid=0, work=2))
    srv = Server(eng)
    fut = srv.submit(ToyRequest(work=3))         # server also assigns rid 0
    while not fut.done():
        srv.step()
    res = fut.result()
    assert res.ok and res.value == [0, 1, 2]     # OUR 3 units, not the 2
    # the foreign native completed on the engine but was not delivered
    assert srv.metrics().completed == 1
    assert not eng.sched.pending()


# ---------------------------------------------------------------------------
# degenerate requests on the REAL engines (admission validation)
# ---------------------------------------------------------------------------

def test_lm_degenerate_requests_do_not_wedge_slots():
    """max_tokens <= 0 and empty prompts complete with empty results, and
    the pool still serves real work afterwards."""
    cfg, params = _setup(7)
    srv = Server(ServingEngine(params, cfg, batch_slots=1, max_len=64))
    r0 = srv.submit(LMRequest(prompt=np.asarray([3, 1]),
                              max_tokens=0)).result()
    r1 = srv.submit(LMRequest(prompt=np.asarray([], np.int32),
                              max_tokens=4)).result()
    r2 = srv.submit(LMRequest(prompt=np.asarray([3, 1]),
                              max_tokens=-2)).result()
    assert (r0.ok and r0.value == [] and r1.ok and r1.value == []
            and r2.ok and r2.value == [])
    want = _reference_generate(params, cfg, [3, 1], 3)
    res = srv.submit(LMRequest(prompt=np.asarray([3, 1]),
                               max_tokens=3)).result()
    assert res.value == want                     # the slot was never wedged


def test_basecall_degenerate_request_completes_empty():
    from repro.core.quant import QuantConfig
    from repro.pipeline import BasecallPipeline
    from repro.serve.basecall_engine import BasecallEngine

    pipe = BasecallPipeline.from_preset(
        "guppy", scale="tiny",
        quant=QuantConfig(enabled=True, bits_w=5, bits_a=5),
        backend="ref", beam_width=3)
    pipe.init_params(jax.random.PRNGKey(0))
    srv = Server(BasecallEngine(pipe, batch_slots=1))
    res = srv.submit(BasecallRequest(
        signal=np.zeros((0,), np.float32))).result()
    assert res.ok and res.value.length == 0 and res.value.sequence() == ""
    sig = np.random.default_rng(0).standard_normal(130).astype(np.float32)
    res2 = srv.submit(BasecallRequest(signal=sig)).result()
    want = pipe.basecall(sig)
    assert res2.value.length == want.length      # still serving after it


# ---------------------------------------------------------------------------
# golden parity: Server over BasecallEngine ≡ BasecallPipeline.basecall
# ---------------------------------------------------------------------------

def test_server_submit_bitwise_matches_pipeline_golden(golden_pipeline,
                                                       golden_read):
    from repro.serve.basecall_engine import BasecallEngine

    pipe, params, _ = golden_pipeline
    _, sig = golden_read
    want = pipe.basecall(sig, params)
    srv = Server(BasecallEngine(pipe, params=params, batch_slots=2))
    got = srv.submit(BasecallRequest(signal=sig)).result().value
    np.testing.assert_array_equal(got.window_reads, want.window_reads)
    np.testing.assert_array_equal(got.window_lengths, want.window_lengths)
    assert got.length == want.length
    np.testing.assert_array_equal(got.read, want.read)


def test_server_stream_bitwise_matches_pipeline_golden(golden_pipeline,
                                                       golden_read):
    """Incremental per-window events carry exactly the pipeline's window
    reads, in window order, ending with the identical consensus."""
    from repro.serve.basecall_engine import BasecallEngine

    pipe, params, _ = golden_pipeline
    _, sig = golden_read
    want = pipe.basecall(sig, params)
    srv = Server(BasecallEngine(pipe, params=params, batch_slots=2))
    events = list(srv.stream(BasecallRequest(signal=sig)))
    windows = [e for e in events if e.kind == "window"]
    assert len(windows) == want.window_reads.shape[0]
    for ev in windows:
        read, length = ev.payload
        np.testing.assert_array_equal(np.asarray(read),
                                      want.window_reads[ev.index])
        assert int(length) == int(want.window_lengths[ev.index])
    final = events[-1]
    assert final.kind == "final"
    np.testing.assert_array_equal(final.payload.value.read, want.read)
    assert final.payload.value.length == want.length


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_snapshot_counts_and_tails():
    clock = FakeClock()
    eng = ToyEngine(batch_slots=2)
    srv = Server(eng, clock=clock)
    futs = [srv.submit(ToyRequest(work=w)) for w in (1, 2, 3)]
    while srv.pending():
        srv.step()
        clock.advance(0.1)
    m = srv.metrics()
    assert m.submitted == 3 and m.completed == 3
    assert m.queue_depth == 0 and m.active == 0
    assert m.steps == eng.steps > 0
    assert 0.0 < m.occupancy <= 1.0
    assert m.requests_per_s > 0
    assert 0.0 < m.latency_p50_s <= m.latency_p99_s
    assert all(srv.results[f.rid].n_events == len([
        e for e in f.events() if e.kind == "unit"]) for f in futs)
