"""Streaming basecalling (ReadUntil): chunk-size invariance, provisional
patch reconstruction, adaptive ejection, and the serving lifecycle.

Layers of proof:
  * ``WindowBuffer`` is bitwise ``chunk_signal`` under ANY chunking of
    the stream (1-sample / ragged / whole-read; hypothesis-driven), with
    bounded memory;
  * ``StreamingSession.finalize`` ≡ ``BasecallPipeline.basecall`` on the
    concatenated signal — bitwise, for every chunking, short (< window)
    and empty streams included, with and without a 4-device dp mesh;
  * folding every ``ProvisionalBases`` patch a stream emits reconstructs
    the exact final consensus (the incremental stitcher's contract);
  * ``StreamingBasecallEngine`` under ``Server``: golden-read parity,
    eject after N chunks frees the lane (slot conservation) and resolves
    ``"ejected"`` without perturbing concurrent lanes, cancel mid-stream
    terminates the consumer's generator, TTFE/ejected metrics;
  * the model-level chunk-boundary contract:
    ``apply_basecaller(rnn_state=..., return_state=True)`` splits a
    forward-only stack bitwise at any boundary, and refuses alternating
    stacks whose reversed walks integrate future samples.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.quant import QuantConfig
from repro.pipeline import BasecallPipeline
from repro.pipeline.chunking import (ChunkConfig, WindowBuffer, chunk_signal,
                                     complete_windows, n_windows,
                                     overlap_depth, window_valid_samples)
from repro.serve.api import Server, STATUS_EJECTED
from repro.serve.streaming import (ACCEPT, CONTINUE, EJECT, ProvisionalBases,
                                   ScoreEjectPolicy, StreamingBasecallEngine,
                                   StreamProgress, StreamRequest,
                                   apply_patches)

jax.config.update("jax_platform_name", "cpu")

QUANT = QuantConfig(enabled=True, bits_w=5, bits_a=5)


def _pipe(backend="auto", **kw):
    pipe = BasecallPipeline.from_preset("guppy", scale="tiny", quant=QUANT,
                                        backend=backend, beam_width=3, **kw)
    pipe.init_params(jax.random.PRNGKey(0))
    return pipe


_CACHE = {}


def _tiny_pipe():
    # module-level cache instead of a fixture: @given tests (whose shim
    # wrapper hides the signature from pytest) share it too
    if "pipe" not in _CACHE:
        _CACHE["pipe"] = _pipe()
    return _CACHE["pipe"]


@pytest.fixture(scope="module")
def tiny_pipe():
    return _tiny_pipe()


def _signal(n, seed=0):
    return np.random.default_rng(seed).standard_normal(n).astype(np.float32)


def _chunkings(sig, rng):
    """Three chunkings of one signal: whole-read, ragged, 1-sample."""
    n = len(sig)
    cuts = np.sort(rng.integers(0, n + 1, size=rng.integers(1, 8)))
    ragged = np.split(sig, cuts)
    ones = [sig[i:i + 1] for i in range(n)]
    return {"whole": [sig], "ragged": ragged, "one": ones}


# ---------------------------------------------------------------------------
# WindowBuffer ≡ chunk_signal
# ---------------------------------------------------------------------------

@settings(max_examples=25)
@given(n=st.integers(min_value=0, max_value=400),
       seed=st.integers(min_value=0, max_value=10_000))
def test_window_buffer_bitwise_matches_chunk_signal(n, seed):
    cfg = ChunkConfig(window=120, hop=60, batch_windows=4)
    rng = np.random.default_rng(seed)
    sig = rng.standard_normal(n).astype(np.float32)
    want = chunk_signal(sig, cfg)
    want_valid = window_valid_samples(n, cfg)
    for name, chunks in _chunkings(sig, rng).items():
        buf = WindowBuffer(cfg)
        got, valids = [], []
        for c in chunks:
            buf.feed(c)
            while buf.ready() > 0:           # complete windows stream early
                w, v = buf.next_window()
                got.append(w)
                valids.append(v)
        buf.end()
        while buf.ready() > 0:
            w, v = buf.next_window()
            got.append(w)
            valids.append(v)
        assert buf.total_windows == want.shape[0]
        assert len(got) == want.shape[0], name
        if got:
            np.testing.assert_array_equal(np.stack(got), want, err_msg=name)
            np.testing.assert_array_equal(np.asarray(valids), want_valid)


def test_window_buffer_bounded_memory():
    """Consumed samples are dropped: the buffer never holds more than
    window + hop samples no matter how long the stream runs."""
    cfg = ChunkConfig(window=120, hop=60)
    buf = WindowBuffer(cfg)
    for i in range(200):
        buf.feed(np.full(17, float(i), np.float32))
        while buf.ready() > 0:
            buf.next_window()
        held = 0 if buf._buf is None else buf._buf.shape[0]
        assert held <= cfg.window + cfg.hop


def test_window_buffer_misuse_raises():
    cfg = ChunkConfig(window=10, hop=5)
    buf = WindowBuffer(cfg)
    with pytest.raises(RuntimeError):
        buf.next_window()                    # nothing ready
    buf.feed(np.zeros((3, 2), np.float32))
    with pytest.raises(ValueError):
        buf.feed(np.zeros((3, 3), np.float32))   # channel mismatch
    with pytest.raises(ValueError):
        buf.feed(np.zeros((2, 2, 2), np.float32))
    buf.end()
    with pytest.raises(RuntimeError):
        buf.feed(np.zeros(3, np.float32))    # feed after end


def test_complete_windows_consistent_with_n_windows():
    cfg = ChunkConfig(window=120, hop=60)
    for n in range(0, 400, 7):
        c, total = complete_windows(n, cfg), n_windows(n, cfg)
        assert 0 <= c <= total
        # complete windows never change as more samples arrive
        assert complete_windows(n + 1, cfg) >= c
    assert overlap_depth(cfg) == 2
    assert overlap_depth(ChunkConfig(window=120, hop=120)) == 1


# ---------------------------------------------------------------------------
# chunk-size invariance: StreamingSession ≡ pipe.basecall, bitwise
# ---------------------------------------------------------------------------

def _assert_result_equal(got, want, msg=""):
    assert got.length == want.length, msg
    np.testing.assert_array_equal(got.read, want.read, err_msg=msg)
    np.testing.assert_array_equal(got.window_reads, want.window_reads,
                                  err_msg=msg)
    np.testing.assert_array_equal(got.window_lengths, want.window_lengths,
                                  err_msg=msg)


@settings(max_examples=8)
@given(n=st.integers(min_value=0, max_value=300),
       seed=st.integers(min_value=0, max_value=1_000))
def test_session_chunk_size_invariance(n, seed):
    """Any chunking of the stream — 1-sample, ragged, whole-read — yields
    the batch path's exact result, and folding the provisional patches
    reconstructs the exact final consensus."""
    pipe = _tiny_pipe()
    rng = np.random.default_rng(seed)
    sig = rng.standard_normal(n).astype(np.float32)
    want = pipe.basecall(sig)
    for name, chunks in _chunkings(sig, rng).items():
        sess = pipe.stream()
        for c in chunks:
            sess.feed(c)
        got = sess.finalize()
        _assert_result_equal(got, want, msg=name)
        np.testing.assert_array_equal(apply_patches(sess.events),
                                      want.read[:want.length], err_msg=name)


def test_session_short_and_empty_streams(tiny_pipe):
    """A first chunk smaller than one window streams a valid (possibly
    empty) read — not a shape error; an empty stream finalizes empty."""
    for n in (0, 1, 5, 119):
        sig = _signal(n, seed=n)
        sess = tiny_pipe.stream()
        if n:
            sess.feed(sig)
        got = sess.finalize()
        _assert_result_equal(got, tiny_pipe.basecall(sig), msg=f"n={n}")
    sess = tiny_pipe.stream()
    assert sess.finalize().length == 0
    with pytest.raises(RuntimeError):
        sess.feed(_signal(8))                # finalized session is closed


def test_session_finalize_idempotent(tiny_pipe):
    sess = tiny_pipe.stream()
    sess.feed(_signal(250))
    a = sess.finalize()
    b = sess.finalize()
    assert a is b


def test_session_under_mesh_matches_unmeshed(tiny_pipe, host_mesh4):
    from repro.dist import sharding as shd

    sig = _signal(400, seed=3)
    want = tiny_pipe.basecall(sig)
    with shd.use_mesh(host_mesh4):
        sess = tiny_pipe.stream()            # mesh pinned at creation
        for i in range(0, len(sig), 61):
            sess.feed(sig[i:i + 61])
        got = sess.finalize()
    _assert_result_equal(got, want, msg="dp=4 session")


# ---------------------------------------------------------------------------
# the incremental stitcher's patch contract
# ---------------------------------------------------------------------------

def test_apply_patches_semantics():
    p = [ProvisionalBases(0, np.array([1, 2, 3], np.int32)),
         ProvisionalBases(3, np.array([0, 1], np.int32)),
         ProvisionalBases(2, np.array([3], np.int32))]  # revising flush
    np.testing.assert_array_equal(apply_patches(p), [1, 2, 3])
    np.testing.assert_array_equal(apply_patches(p[:2]), [1, 2, 3, 0, 1])


def test_mid_stream_patches_are_append_only(tiny_pipe):
    sess = tiny_pipe.stream()
    emitted = 0
    for i in range(0, 700, 53):
        for patch in sess.feed(_signal(700, seed=9)[i:i + 53]):
            assert patch.start == emitted
            emitted += len(patch)
    sess.finalize()


# ---------------------------------------------------------------------------
# the streaming engine under the server
# ---------------------------------------------------------------------------

def _chunks_of(sig, k):
    for i in range(0, len(sig), k):
        yield sig[i:i + k]


def test_engine_stream_bitwise_matches_pipeline(tiny_pipe):
    sig = _signal(641, seed=7)
    want = tiny_pipe.basecall(sig)
    srv = Server(StreamingBasecallEngine(tiny_pipe, batch_slots=2))
    events = list(srv.stream(StreamRequest(chunks=_chunks_of(sig, 37))))
    final = events[-1]
    assert final.kind == "final" and final.payload.status == "ok"
    _assert_result_equal(final.payload.value, want)
    np.testing.assert_array_equal(
        apply_patches(e.payload for e in events[:-1]),
        want.read[:want.length])
    assert all(e.kind == "bases" for e in events[:-1])


def test_engine_concurrent_lanes_all_match(tiny_pipe):
    """More streams than slots, different chunkings per pore: every lane
    bitwise-matches its own batch-path result."""
    srv = Server(StreamingBasecallEngine(tiny_pipe, batch_slots=2))
    sigs = [_signal(n, seed=n) for n in (130, 380, 77, 641, 250)]
    futs = [srv.submit(StreamRequest(chunks=_chunks_of(s, 23 + 11 * i)))
            for i, s in enumerate(sigs)]
    res = srv.run_until_idle()
    for f, s in zip(futs, sigs):
        assert res[f.rid].status == "ok"
        _assert_result_equal(res[f.rid].value, tiny_pipe.basecall(s))


def test_engine_under_mesh_matches_single_device(tiny_pipe, host_mesh4):
    from repro.dist import sharding as shd

    sigs = [_signal(n, seed=n) for n in (380, 641)]
    want = [tiny_pipe.basecall(s) for s in sigs]
    with shd.use_mesh(host_mesh4):
        eng = StreamingBasecallEngine(tiny_pipe, batch_slots=1)  # B = 4
    assert eng.B == 4
    srv = Server(eng)                        # driven without ambient mesh
    futs = [srv.submit(StreamRequest(chunks=_chunks_of(s, 41)))
            for s in sigs]
    res = srv.run_until_idle()
    for f, w in zip(futs, want):
        _assert_result_equal(res[f.rid].value, w, msg="dp=4 engine")


def test_engine_degenerate_and_validation(tiny_pipe):
    srv = Server(StreamingBasecallEngine(tiny_pipe, batch_slots=2))
    res = srv.submit(StreamRequest(chunks=[])).result()
    assert res.status == "ok" and res.value.length == 0
    bad = srv.submit(StreamRequest(chunks=42)).result()
    assert bad.status == "error" and "iterable" in bad.error
    bad = srv.submit(StreamRequest(chunks=_chunks_of(_signal(10), 5),
                                   chunks_per_step=0)).result()
    assert bad.status == "error"
    # a stream of nothing but empty chunks must terminate, not livelock
    res = srv.submit(
        StreamRequest(chunks=iter([np.zeros(0, np.float32)] * 3))).result()
    assert res.status == "ok" and res.value.length == 0


# ---------------------------------------------------------------------------
# adaptive ejection (ReadUntil)
# ---------------------------------------------------------------------------

def test_eject_frees_slot_and_spares_concurrent_lanes(tiny_pipe):
    """The eject verdict retires the lane immediately: the request
    resolves "ejected" with the provisional read, the slot conserves
    (queued work admits into it), and concurrent lanes are bit-exact."""
    seen = []

    def policy(p):
        seen.append(p)
        return EJECT

    eng = StreamingBasecallEngine(tiny_pipe, batch_slots=2)
    srv = Server(eng)
    keep_sig = _signal(641, seed=1)
    f_keep = srv.submit(StreamRequest(chunks=_chunks_of(keep_sig, 37)))
    f_ej = srv.submit(StreamRequest(chunks=_chunks_of(_signal(5000, 2), 61),
                                    eject=policy, eject_after_chunks=3))
    f_queued = srv.submit(                   # waits for the ejected slot
        StreamRequest(chunks=_chunks_of(keep_sig, 50)))
    res = srv.run_until_idle()
    assert res[f_ej.rid].status == STATUS_EJECTED
    want = tiny_pipe.basecall(keep_sig)
    _assert_result_equal(res[f_keep.rid].value, want)
    _assert_result_equal(res[f_queued.rid].value, want)
    # the policy saw real progress, no earlier than the chunk threshold
    assert seen and all(isinstance(p, StreamProgress) for p in seen)
    assert seen[0].n_chunks >= 3
    # the ejected lane only consumed a prefix of its (long) stream
    assert seen[-1].n_samples < 5000
    # slots fully reclaimed
    assert eng.sched.slots.count(None) == eng.B
    assert eng.ejected == 1
    m = srv.metrics()
    assert m.ejected == 1 and m.completed == 2


def test_eject_verdicts_continue_and_accept(tiny_pipe):
    """CONTINUE keeps consulting; ACCEPT stops consulting and the read
    completes normally."""
    calls = {"n": 0}

    def accept_after_two(p):
        calls["n"] += 1
        return ACCEPT if calls["n"] >= 2 else CONTINUE

    sig = _signal(641, seed=4)
    srv = Server(StreamingBasecallEngine(tiny_pipe, batch_slots=1))
    res = srv.submit(StreamRequest(chunks=_chunks_of(sig, 37),
                                   eject=accept_after_two,
                                   eject_after_chunks=2)).result()
    assert res.status == "ok"
    _assert_result_equal(res.value, tiny_pipe.basecall(sig))
    assert calls["n"] == 2                   # ACCEPT silenced the policy


def test_score_eject_policy_thresholds():
    def prog(scores, lengths):
        return StreamProgress(
            read=np.zeros(int(sum(lengths)), np.int32),
            length=int(sum(lengths)),
            base_logprobs=np.zeros(int(sum(lengths)), np.float32),
            window_scores=np.asarray(scores, np.float32),
            window_lengths=np.asarray(lengths, np.int32),
            n_windows=len(scores), n_chunks=len(scores),
            n_samples=120 * len(scores))

    pol = ScoreEjectPolicy(threshold=-1.0, min_bases=8)
    assert pol(prog([-0.5], [4])) == CONTINUE          # not enough bases
    assert pol(prog([-4.0, -4.0], [5, 5])) == ACCEPT   # -0.8/base >= -1
    assert pol(prog([-20.0, -20.0], [5, 5])) == EJECT  # -4.0/base < -1


# ---------------------------------------------------------------------------
# cancellation mid-stream
# ---------------------------------------------------------------------------

def _endless_chunks(seed=0):
    rng = np.random.default_rng(seed)
    while True:
        yield rng.standard_normal(37).astype(np.float32)


def test_cancel_mid_stream_terminates_consumer(tiny_pipe):
    """cancel() on a stream()-consumed request must terminate the
    generator with a final "cancelled" event — even for an endless
    chunk source that would otherwise stream forever."""
    srv = Server(StreamingBasecallEngine(tiny_pipe, batch_slots=2))
    events = []
    gen = srv.stream(StreamRequest(chunks=_endless_chunks()), max_steps=500)
    for ev in gen:
        events.append(ev)
        srv.cancel(ev.rid)                   # cancel on the first event
    final = events[-1]
    assert final.kind == "final"
    assert final.payload.status == "cancelled"
    assert srv.engine.sched.slots.count(None) == srv.engine.B


def test_cancel_queued_stream_request(tiny_pipe):
    srv = Server(StreamingBasecallEngine(tiny_pipe, batch_slots=1))
    f1 = srv.submit(StreamRequest(chunks=_chunks_of(_signal(380), 37)))
    f2 = srv.submit(StreamRequest(chunks=_endless_chunks()))
    assert f2.cancel()
    assert f1.result().status == "ok"
    assert f2.result().status == "cancelled"


# ---------------------------------------------------------------------------
# observability
# ---------------------------------------------------------------------------

def test_metrics_ttfe_and_ejected_counters(tiny_pipe):
    srv = Server(StreamingBasecallEngine(tiny_pipe, batch_slots=2))
    srv.submit(StreamRequest(chunks=_chunks_of(_signal(641, 5), 37)))
    srv.submit(StreamRequest(chunks=_chunks_of(_signal(900, 6), 61),
                             eject=lambda p: EJECT, eject_after_chunks=2))
    srv.run_until_idle()
    m = srv.metrics()
    assert m.ejected == 1
    assert m.ttfe_p50_s >= 0.0 and m.ttfe_p99_s >= m.ttfe_p50_s
    rows = dict((r[0], r[1]) for r in m.rows())
    assert "serve/ttfe_p50_s" in rows and "serve/ttfe_p99_s" in rows
    srv.reset_metrics()
    m2 = srv.metrics()
    assert m2.ejected == 0 and m2.ttfe_p50_s == 0.0


# ---------------------------------------------------------------------------
# model-level chunk-boundary state contract
# ---------------------------------------------------------------------------

def test_rnn_state_split_is_bitwise_for_uni_stacks():
    """Splitting a forward-only stack at any RNN-time boundary and
    re-entering with the carried state is bitwise identical to the
    unsplit run (the gru_seq state-in/state-out contract)."""
    from repro.models import basecaller as bc

    # float math, kernel-1 conv: no receptive-field halo and no dynamic
    # per-tensor act-quant scales (whose whole-sequence abs-max would
    # differ across splits) — the state contract itself is what's tested
    cfg = dataclasses.replace(
        bc.tiny_preset(), rnn_direction="uni",
        conv=(bc.ConvSpec(1, 16, 1),))
    params = bc.init_basecaller(jax.random.PRNGKey(1), cfg)
    sig = jnp.asarray(_signal(cfg.input_len, seed=8)[:, None][None])
    full = bc.apply_basecaller(params, sig, cfg)
    for cut in (1, cfg.input_len // 3, cfg.input_len - 1):
        lps_a, state = bc.apply_basecaller(params, sig[:, :cut], cfg,
                                           return_state=True)
        lps_b = bc.apply_basecaller(params, sig[:, cut:], cfg,
                                    rnn_state=state)
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(lps_a), np.asarray(lps_b)], axis=1),
            np.asarray(full), err_msg=f"cut={cut}")


def test_rnn_state_io_rejects_non_uni_stacks():
    from repro.models import basecaller as bc

    cfg = dataclasses.replace(bc.tiny_preset(), quant=QUANT)  # alt
    params = bc.init_basecaller(jax.random.PRNGKey(1), cfg)
    sig = jnp.zeros((1, 30, 1))
    with pytest.raises(ValueError, match="uni"):
        bc.apply_basecaller(params, sig, cfg, return_state=True)
    with pytest.raises(ValueError, match="uni"):
        bc.apply_basecaller(params, sig, cfg,
                            rnn_state=bc.init_rnn_state(cfg, 1))


# ---------------------------------------------------------------------------
# golden-read parity (trained pipeline)
# ---------------------------------------------------------------------------

def test_golden_session_and_engine_bitwise_match_basecall(golden_pipeline,
                                                          golden_read):
    pipe, params, _ = golden_pipeline
    _, sig = golden_read
    want = pipe.basecall(sig, params)
    sess = pipe.stream(params)
    for i in range(0, len(sig), 100):
        sess.feed(sig[i:i + 100])
    _assert_result_equal(sess.finalize(), want, msg="golden session")
    np.testing.assert_array_equal(apply_patches(sess.events),
                                  want.read[:want.length])
    srv = Server(StreamingBasecallEngine(pipe, params=params, batch_slots=2))
    res = srv.submit(StreamRequest(chunks=_chunks_of(sig, 100))).result()
    assert res.status == "ok"
    _assert_result_equal(res.value, want, msg="golden engine")
