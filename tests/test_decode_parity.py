"""Differential harness: hash-merge beam decoder vs the dense-merge oracle.

``ctc_beam_search`` (dense O(C^2*L) prefix-equality merge) stays in the
tree as the semantic ground truth; the serving decoder
``ctc_beam_search_hash_batch`` must agree with it — top-1 prefixes
identical, scores within 1e-4 — on randomized inputs, across every
registered backend of the fused ``beam_merge_topk`` op, including
non-tile-aligned candidate counts (C = W * A is whatever the draw says,
never a lane multiple by construction).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ctc as ctc_lib
from repro.kernels import registry

jax.config.update("jax_platform_name", "cpu")

# "auto" resolves through the registry default (REPRO_DEFAULT_BACKEND in
# the CI backend matrix); ref/interpret pin the two CPU-testable paths
BACKENDS = ("auto", "ref", "interpret")


def _rand_logprobs(rng, T, A):
    x = rng.standard_normal((T, A)).astype(np.float32)
    return jax.nn.log_softmax(jnp.asarray(x), axis=-1)


def _top_prefix(prefixes, lengths):
    return tuple(np.asarray(prefixes[0][: int(lengths[0])]))


# ---------------------------------------------------------------------------
# randomized differential: hash == dense oracle
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), T=st.integers(2, 12),
       A=st.integers(2, 6), W=st.integers(1, 9))
def test_hash_decoder_matches_dense_oracle(seed, T, A, W):
    rng = np.random.default_rng(seed)
    lp = _rand_logprobs(rng, T, A)
    dp, dl, ds = ctc_lib.ctc_beam_search(lp, beam_width=W)
    want = _top_prefix(dp, dl)
    for backend in BACKENDS:
        hp, hl, hs = ctc_lib.ctc_beam_search_hash(lp, beam_width=W,
                                                  backend=backend)
        got = _top_prefix(hp, hl)
        assert got == want, f"[{backend}] {got} != {want}"
        np.testing.assert_allclose(float(hs[0]), float(ds[0]),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"backend={backend}")


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_hash_decoder_backend_parity_full_state(seed):
    """ref and interpret must agree on the ENTIRE beam state bit for bit
    (the fused kernel pads to the lane tile; padding must be inert)."""
    rng = np.random.default_rng(seed)
    lp = jax.nn.log_softmax(jnp.asarray(
        rng.standard_normal((3, 9, 5)).astype(np.float32)), -1)
    ll = jnp.asarray(rng.integers(1, 10, (3,)), jnp.int32)
    out = {}
    for backend in ("ref", "interpret"):
        out[backend] = ctc_lib.ctc_beam_search_hash_batch(
            lp, beam_width=6, logit_lengths=ll, backend=backend)
    for a, b in zip(out["ref"], out["interpret"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# logit_lengths semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_hash_decoder_masked_equals_sliced(backend):
    """Decoding T frames with logit_length=n == decoding the n-frame slice."""
    rng = np.random.default_rng(3)
    lp = _rand_logprobs(rng, 10, 5)
    for n in (1, 4, 7, 10):
        a = ctc_lib.ctc_beam_search_hash(lp, beam_width=4, logit_length=n,
                                         max_len=10, backend=backend)
        b = ctc_lib.ctc_beam_search_hash(lp[:n], beam_width=4, max_len=10,
                                         backend=backend)
        assert _top_prefix(a[0], a[1]) == _top_prefix(b[0], b[1]), n
        np.testing.assert_allclose(float(a[2][0]), float(b[2][0]),
                                   rtol=1e-5, atol=1e-5)


def test_hash_decoder_batch_matches_per_example():
    rng = np.random.default_rng(11)
    lp = jax.nn.log_softmax(jnp.asarray(
        rng.standard_normal((4, 8, 4)).astype(np.float32)), -1)
    ll = jnp.asarray([8, 2, 5, 8], jnp.int32)
    bp, bl, bs = ctc_lib.ctc_beam_search_hash_batch(
        lp, beam_width=5, logit_lengths=ll, backend="ref")
    for i in range(4):
        pp, pl, ps = ctc_lib.ctc_beam_search_hash(
            lp[i], beam_width=5, logit_length=ll[i], backend="ref")
        np.testing.assert_array_equal(np.asarray(bp[i]), np.asarray(pp))
        np.testing.assert_array_equal(np.asarray(bl[i]), np.asarray(pl))
        np.testing.assert_allclose(np.asarray(bs[i]), np.asarray(ps),
                                   rtol=1e-6, atol=1e-6)


def test_hash_decoder_zero_length_is_empty():
    rng = np.random.default_rng(0)
    lp = _rand_logprobs(rng, 6, 5)
    p, l, s = ctc_lib.ctc_beam_search_hash(lp, beam_width=3, logit_length=0,
                                           backend="ref")
    assert int(l[0]) == 0
    assert float(s[0]) == 0.0          # empty prefix, probability 1
    assert np.all(np.asarray(p) == -1)


# ---------------------------------------------------------------------------
# structure / edge cases
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_hash_decoder_monotone_in_width(seed):
    """Best score never decreases as the beam widens (same property the
    dense oracle satisfies)."""
    rng = np.random.default_rng(seed)
    lp = _rand_logprobs(rng, 6, 4)
    best = -np.inf
    for W in (1, 2, 4, 8):
        _, _, scores = ctc_lib.ctc_beam_search_hash(lp, beam_width=W,
                                                    backend="ref")
        s = float(scores[0])
        assert s >= best - 1e-5
        best = max(best, s)


def test_hash_decoder_max_len_cap():
    """A small max_len caps prefixes without corrupting live beams (capped
    extension candidates are dead lanes; dense oracle agrees on top-1)."""
    rng = np.random.default_rng(5)
    lp = _rand_logprobs(rng, 9, 4)
    dp, dl, _ = ctc_lib.ctc_beam_search(lp, beam_width=6, max_len=2)
    hp, hl, _ = ctc_lib.ctc_beam_search_hash(lp, beam_width=6, max_len=2,
                                             backend="ref")
    assert int(hl[0]) <= 2
    assert _top_prefix(hp, hl) == _top_prefix(dp, dl)


def test_hash_decoder_paper_example():
    """Fig. 4d: merging puts "A" ahead of "--" at beam width 2."""
    p = jnp.asarray([[0.3, 0.15, 0.05, 0.0, 0.5],
                     [0.3, 0.2, 0.1, 0.0, 0.4]])
    lp = jnp.log(p + 1e-9)
    prefixes, lens, scores = ctc_lib.ctc_beam_search_hash(lp, beam_width=2,
                                                          backend="ref")
    assert _top_prefix(prefixes, lens) == (0,)
    np.testing.assert_allclose(float(jnp.exp(scores[0])), 0.36, atol=1e-3)


def test_hash_decoder_dispatches_through_registry():
    """set_default_backend must steer the decoder's "auto" path."""
    rng = np.random.default_rng(1)
    lp = _rand_logprobs(rng, 5, 4)
    prev = registry.get_default_backend()
    try:
        registry.set_default_backend("ref")
        a = ctc_lib.ctc_beam_search_hash(lp, beam_width=4)
        registry.set_default_backend("interpret")
        b = ctc_lib.ctc_beam_search_hash(lp, beam_width=4)
    finally:
        registry.set_default_backend(prev)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_allclose(np.asarray(a[2]), np.asarray(b[2]),
                               rtol=1e-6, atol=1e-6)
