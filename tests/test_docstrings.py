"""Public-API docstring coverage (the documentation satellite's enforcer).

Two tiers:

* PRESENT — every symbol on the public surface carries a real docstring
  (not a stub): pipeline, serving API, engines, scheduler, registry,
  sharding.
* FULL — the key entry points additionally document their arguments,
  return value, and a usage example (``Args:`` / ``Returns:`` sections +
  an ``Example`` or doctest marker), so ``help()`` answers the questions
  the guides answer.
"""
from __future__ import annotations

import inspect

import pytest

pytest.importorskip("jax")

from repro.dist import sharding  # noqa: E402
from repro.kernels import registry  # noqa: E402
from repro.pipeline.pipeline import (BasecallPipeline,  # noqa: E402
                                     BasecallResult)
from repro.serve import api  # noqa: E402
from repro.serve import streaming  # noqa: E402
from repro.serve.basecall_engine import BasecallEngine  # noqa: E402
from repro.serve.engine import ServingEngine  # noqa: E402
from repro.serve.multitenant import MultiModelBasecallEngine  # noqa: E402
from repro.serve.registry import ModelRegistry, RegistryStats  # noqa: E402
from repro.serve.scheduler import SlotScheduler  # noqa: E402

PRESENT = {
    # pipeline facade
    "BasecallPipeline": BasecallPipeline,
    "BasecallPipeline.from_preset": BasecallPipeline.from_preset,
    "BasecallPipeline.init_params": BasecallPipeline.init_params,
    "BasecallPipeline.serving_params": BasecallPipeline.serving_params,
    "BasecallPipeline.basecall": BasecallPipeline.basecall,
    "BasecallPipeline.basecall_iter": BasecallPipeline.basecall_iter,
    "BasecallPipeline.basecall_windows": BasecallPipeline.basecall_windows,
    "BasecallPipeline.trainer": BasecallPipeline.trainer,
    "BasecallPipeline.train_step": BasecallPipeline.train_step,
    "BasecallPipeline.window_logit_lengths":
        BasecallPipeline.window_logit_lengths,
    "BasecallPipeline.data_config": BasecallPipeline.data_config,
    "BasecallResult": BasecallResult,
    "BasecallResult.sequence": BasecallResult.sequence,
    "BasecallResult.empty": BasecallResult.empty,
    "BasecallResult.from_window_reads": BasecallResult.from_window_reads,
    # serving API
    "Server": api.Server,
    "Server.submit": api.Server.submit,
    "Server.stream": api.Server.stream,
    "Server.cancel": api.Server.cancel,
    "Server.step": api.Server.step,
    "Server.pending": api.Server.pending,
    "Server.run_until_idle": api.Server.run_until_idle,
    "Server.metrics": api.Server.metrics,
    "Server.reset_metrics": api.Server.reset_metrics,
    "ServeFuture": api.ServeFuture,
    "ServeFuture.result": api.ServeFuture.result,
    "ServeFuture.done": api.ServeFuture.done,
    "ServeFuture.cancel": api.ServeFuture.cancel,
    "ServeFuture.events": api.ServeFuture.events,
    "BasecallRequest": api.BasecallRequest,
    "LMRequest": api.LMRequest,
    "ServeEvent": api.ServeEvent,
    "ServeResult": api.ServeResult,
    "ServerMetrics": api.ServerMetrics,
    "EngineProtocol": api.EngineProtocol,
    "QueueFull": api.QueueFull,
    # engines + scheduler
    "ServingEngine": ServingEngine,
    "BasecallEngine": BasecallEngine,
    # streaming (ReadUntil)
    "BasecallPipeline.stream": BasecallPipeline.stream,
    "StreamingSession": streaming.StreamingSession,
    "StreamingSession.feed": streaming.StreamingSession.feed,
    "StreamingSession.finalize": streaming.StreamingSession.finalize,
    "StreamingSession.progress": streaming.StreamingSession.progress,
    "StreamingBasecallEngine": streaming.StreamingBasecallEngine,
    "StreamRequest": streaming.StreamRequest,
    "StreamProgress": streaming.StreamProgress,
    "ProvisionalBases": streaming.ProvisionalBases,
    "ScoreEjectPolicy": streaming.ScoreEjectPolicy,
    "apply_patches": streaming.apply_patches,
    # multi-tenant fleets
    "ModelRegistry": ModelRegistry,
    "ModelRegistry.register": ModelRegistry.register,
    "ModelRegistry.register_basecaller": ModelRegistry.register_basecaller,
    "ModelRegistry.register_lm": ModelRegistry.register_lm,
    "ModelRegistry.artifact": ModelRegistry.artifact,
    "ModelRegistry.evict": ModelRegistry.evict,
    "ModelRegistry.sweep": ModelRegistry.sweep,
    "ModelRegistry.pin": ModelRegistry.pin,
    "ModelRegistry.unpin": ModelRegistry.unpin,
    "ModelRegistry.pinned": ModelRegistry.pinned,
    "ModelRegistry.add_use_hook": ModelRegistry.add_use_hook,
    "ModelRegistry.stats": ModelRegistry.stats,
    "RegistryStats": RegistryStats,
    "MultiModelBasecallEngine": MultiModelBasecallEngine,
    "MultiModelBasecallEngine.model_occupancy":
        MultiModelBasecallEngine.model_occupancy,
    "MultiModelBasecallEngine.device_occupancy":
        MultiModelBasecallEngine.device_occupancy,
    "ServingEngine.from_registry": ServingEngine.from_registry,
    "BasecallEngine.from_registry": BasecallEngine.from_registry,
    "api.ModelMetrics": api.ModelMetrics,
    "SlotScheduler": SlotScheduler,
    "SlotScheduler.submit": SlotScheduler.submit,
    "SlotScheduler.group_range": SlotScheduler.group_range,
    "SlotScheduler.group_of_slot": SlotScheduler.group_of_slot,
    "SlotScheduler.group_of_partition": SlotScheduler.group_of_partition,
    "SlotScheduler.admit": SlotScheduler.admit,
    "SlotScheduler.retire": SlotScheduler.retire,
    "SlotScheduler.release": SlotScheduler.release,
    "SlotScheduler.cancel_queued": SlotScheduler.cancel_queued,
    "SlotScheduler.slot_of": SlotScheduler.slot_of,
    "SlotScheduler.drain_finished": SlotScheduler.drain_finished,
    "SlotScheduler.group_occupancy": SlotScheduler.group_occupancy,
    "SlotScheduler.active_mask": SlotScheduler.active_mask,
    "SlotScheduler.occupancy": SlotScheduler.occupancy,
    # kernel registry
    "registry.register_op": registry.register_op,
    "registry.get_op": registry.get_op,
    "registry.list_ops": registry.list_ops,
    "registry.set_default_backend": registry.set_default_backend,
    "registry.resolve_backend": registry.resolve_backend,
    "registry.Backend": registry.Backend,
    "registry.Backend.op": registry.Backend.op,
    # dist sharding
    "sharding.use_mesh": sharding.use_mesh,
    "sharding.get_mesh": sharding.get_mesh,
    "sharding.constrain": sharding.constrain,
    "sharding.replicate": sharding.replicate,
    "sharding.dp_size": sharding.dp_size,
    "sharding.batch_sharding": sharding.batch_sharding,
    "sharding.logical_spec": sharding.logical_spec,
    "sharding.param_logical": sharding.param_logical,
    "sharding.param_sharding_tree": sharding.param_sharding_tree,
    "sharding.replicated_sharding_tree": sharding.replicated_sharding_tree,
    "sharding.path_str": sharding.path_str,
}

#: key entry points that must document Args / Returns / an Example
FULL = [
    "BasecallPipeline",
    "BasecallPipeline.from_preset",
    "BasecallPipeline.basecall",
    "BasecallPipeline.basecall_iter",
    "BasecallPipeline.basecall_windows",
    "Server.submit",
    "Server.stream",
    "Server.metrics",
    "BasecallPipeline.stream",
    "StreamingSession",
    "StreamingBasecallEngine",
    "ModelRegistry",
    "MultiModelBasecallEngine",
    "registry.register_op",
    "registry.get_op",
    "sharding.use_mesh",
    "sharding.constrain",
]


@pytest.mark.parametrize("name", sorted(PRESENT), ids=str)
def test_docstring_present(name):
    doc = inspect.getdoc(PRESENT[name])
    assert doc and len(doc.strip()) >= 20, \
        f"{name} needs a real docstring (got {doc!r})"


def _has_own_args(obj) -> bool:
    fn = obj.__init__ if inspect.isclass(obj) else obj
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return True
    return any(p for p in params if p not in ("self", "cls"))


@pytest.mark.parametrize("name", FULL, ids=str)
def test_docstring_full(name):
    obj = PRESENT[name]
    doc = inspect.getdoc(obj) or ""
    if _has_own_args(obj):
        assert "Args:" in doc, f"{name} docstring lacks an Args: section"
    assert "Returns:" in doc or "Yields:" in doc or inspect.isclass(obj), \
        f"{name} docstring lacks a Returns: section"
    assert "Example" in doc or ">>>" in doc, \
        f"{name} docstring lacks a usage example"
