"""Property tests on model-family invariants (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.layers import (MoEConfig, SSMConfig, mamba_mix, moe_ff)

jax.config.update("jax_platform_name", "cpu")


def _mamba_params(key, d, ssm):
    di, r, n = ssm.inner(d), ssm.rank(d), ssm.d_state
    ks = jax.random.split(key, 5)
    A = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di)) * 0.1,
        "conv_w": jax.random.normal(ks[1], (ssm.d_conv, di)) * 0.1,
        "conv_b": jnp.zeros((di,)),
        "x_proj": jax.random.normal(ks[2], (di, r + 2 * n)) * 0.1,
        "dt_proj": jax.random.normal(ks[3], (r, di)) * 0.1,
        "dt_bias": jnp.full((di,), -2.0),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,)),
        "out_proj": jax.random.normal(ks[4], (di, d)) * 0.1,
    }


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), t=st.integers(1, 10))
def test_mamba_is_causal(seed, t):
    """Perturbing input at time t must not change outputs before t."""
    d, S = 8, 12
    ssm = SSMConfig(d_state=4, d_conv=4, expand=2)
    p = _mamba_params(jax.random.PRNGKey(seed), d, ssm)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, S, d))
    y0, _ = mamba_mix(x, p, ssm, d)
    x2 = x.at[:, t].add(1.0)
    y1, _ = mamba_mix(x2, p, ssm, d)
    np.testing.assert_allclose(np.asarray(y0[:, :t]), np.asarray(y1[:, :t]),
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(y0[:, t:]), np.asarray(y1[:, t:]))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000))
def test_mamba_chunked_state_equals_full(seed):
    """Processing [0:k] then [k:S] with carried state == one pass."""
    d, S, k = 8, 16, 7
    ssm = SSMConfig(d_state=4, d_conv=4, expand=2)
    p = _mamba_params(jax.random.PRNGKey(seed), d, ssm)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, S, d))
    y_full, _ = mamba_mix(x, p, ssm, d)
    y1, st1 = mamba_mix(x[:, :k], p, ssm, d)
    zero_state = {"h": jnp.zeros_like(st1["h"]),
                  "conv": jnp.zeros_like(st1["conv"])}
    y1b, st1b = mamba_mix(x[:, :k], p, ssm, d, state=zero_state)
    y2, _ = mamba_mix(x[:, k:], p, ssm, d, state=st1b)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1b, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_moe_token_permutation_equivariance(seed):
    """Permuting tokens permutes outputs (dropless regime)."""
    T, d, E = 32, 8, 4
    cfg = MoEConfig(n_experts=E, top_k=2, capacity_factor=float(E))
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    p = {"router": jax.random.normal(ks[0], (d, E)),
         "w1": jax.random.normal(ks[1], (E, d, 16)) * 0.1,
         "w3": jax.random.normal(ks[2], (E, d, 16)) * 0.1,
         "w2": jax.random.normal(ks[3], (E, 16, d)) * 0.1}
    x = jax.random.normal(ks[4], (T, d))
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 9), T)
    y, _ = moe_ff(x, p, cfg)
    y_perm, _ = moe_ff(x[perm], p, cfg)
    np.testing.assert_allclose(np.asarray(y_perm), np.asarray(y[perm]),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000))
def test_moe_respects_capacity(seed):
    """With capacity_factor ~0, (almost) everything drops => output ~0."""
    T, d, E = 64, 8, 4
    cfg = MoEConfig(n_experts=E, top_k=1, capacity_factor=1e-9)
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    p = {"router": jax.random.normal(ks[0], (d, E)),
         "w1": jax.random.normal(ks[1], (E, d, 16)),
         "w3": jax.random.normal(ks[2], (E, d, 16)),
         "w2": jax.random.normal(ks[3], (E, 16, d))}
    x = jax.random.normal(ks[4], (T, d))
    y, aux = moe_ff(x, p, cfg)
    # capacity floors at 8 slots/expert: at most 32 of 64 tokens survive
    assert float(aux["drop_frac"]) >= 0.0
    kept_rows = np.abs(np.asarray(y)).sum(-1) > 0
    assert kept_rows.sum() <= 8 * E
