"""CTC loss / decode correctness vs brute-force oracles."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ctc as ctc_lib

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# brute-force oracles
# ---------------------------------------------------------------------------

def brute_force_logp(log_probs: np.ndarray, labels, blank: int) -> float:
    """Σ over ALL alignments (paths) that collapse to `labels`."""
    T, A = log_probs.shape
    total = -np.inf
    for path in itertools.product(range(A), repeat=T):
        # collapse: remove repeats then blanks
        out, prev = [], None
        for s in path:
            if s != prev and s != blank:
                out.append(s)
            prev = s
        if out == list(labels):
            lp = sum(log_probs[t, s] for t, s in enumerate(path))
            total = np.logaddexp(total, lp)
    return total


def all_decodes_ranked(log_probs: np.ndarray, blank: int):
    """Exact posterior over all label sequences (tiny T/A only)."""
    T, A = log_probs.shape
    scores = {}
    for path in itertools.product(range(A), repeat=T):
        out, prev = [], None
        for s in path:
            if s != prev and s != blank:
                out.append(s)
            prev = s
        lp = sum(log_probs[t, s] for t, s in enumerate(path))
        key = tuple(out)
        scores[key] = np.logaddexp(scores.get(key, -np.inf), lp)
    return sorted(scores.items(), key=lambda kv: -kv[1])


def _rand_logprobs(rng, T, A):
    x = rng.standard_normal((T, A)).astype(np.float32)
    return jax.nn.log_softmax(jnp.asarray(x), axis=-1)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,A,labels", [
    (4, 3, [0, 1]),
    (5, 3, [1]),
    (5, 5, [0, 0]),       # repeat needs a blank between
    (6, 5, [2, 1, 2]),
    (3, 4, []),           # empty label: all-blank paths
])
def test_ctc_loss_matches_bruteforce(T, A, labels):
    rng = np.random.default_rng(42 + T + A + len(labels))
    lp = _rand_logprobs(rng, T, A)
    blank = A - 1
    want = -brute_force_logp(np.asarray(lp), labels, blank)
    L = max(len(labels), 1)
    lab = jnp.full((L,), 0, jnp.int32).at[: len(labels)].set(
        jnp.asarray(labels, jnp.int32) if labels else jnp.zeros((0,), jnp.int32))
    got = ctc_lib.ctc_loss(lp, lab, label_length=len(labels))
    np.testing.assert_allclose(float(got), want, rtol=1e-5, atol=1e-5)


def test_ctc_loss_label_padding_invariance():
    rng = np.random.default_rng(0)
    lp = _rand_logprobs(rng, 8, 5)
    lab1 = jnp.array([0, 2, 1], jnp.int32)
    lab2 = jnp.array([0, 2, 1, 3, 3, 0], jnp.int32)  # extra garbage padding
    a = ctc_lib.ctc_loss(lp, lab1, label_length=3)
    b = ctc_lib.ctc_loss(lp, lab2, label_length=3)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-6)


def test_ctc_loss_logit_length_masking():
    rng = np.random.default_rng(1)
    lp8 = _rand_logprobs(rng, 8, 5)
    lab = jnp.array([1, 2], jnp.int32)
    a = ctc_lib.ctc_loss(lp8[:5], lab)
    b = ctc_lib.ctc_loss(lp8, lab, logit_length=5)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-6)


def test_ctc_loss_impossible_label():
    # label longer than frames => probability 0 => loss ~ +inf (NEG-bounded)
    rng = np.random.default_rng(2)
    lp = _rand_logprobs(rng, 2, 5)
    lab = jnp.array([0, 1, 2], jnp.int32)
    loss = float(ctc_lib.ctc_loss(lp, lab))
    assert loss > 1e8


def test_ctc_loss_gradients_finite():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((12, 5)).astype(np.float32))
    lab = jnp.array([0, 1, 1, 2], jnp.int32)

    def f(logits):
        return ctc_lib.ctc_loss(jax.nn.log_softmax(logits, -1), lab)

    g = jax.grad(f)(x)
    assert np.all(np.isfinite(np.asarray(g)))
    # grad wrt a softmax distribution sums to ~0 per frame
    np.testing.assert_allclose(np.asarray(g).sum(-1), 0.0, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(T=st.integers(2, 5), A=st.integers(2, 4), seed=st.integers(0, 10_000))
def test_ctc_loss_is_proper_nll(T, A, seed):
    """-ln p >= 0 i.e. p(D|R) <= 1, and total prob over decodes == 1."""
    rng = np.random.default_rng(seed)
    lp = _rand_logprobs(rng, T, A)
    ranked = all_decodes_ranked(np.asarray(lp), blank=A - 1)
    total = -np.inf
    for key, s in ranked:
        total = np.logaddexp(total, s)
        if len(key) > 0:
            loss = float(ctc_lib.ctc_loss(
                lp, jnp.asarray(key, jnp.int32), label_length=len(key)))
            assert loss >= -1e-4
            np.testing.assert_allclose(loss, -s, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(total, 0.0, atol=1e-5)  # Σ_D p(D|R) == 1


# ---------------------------------------------------------------------------
# greedy decode
# ---------------------------------------------------------------------------

def test_greedy_decode_collapse():
    A, blank = 5, 4
    # path: a a - b b - - a  -> collapse to a b a
    ids = [0, 0, 4, 1, 1, 4, 4, 0]
    lp = jnp.log(jax.nn.one_hot(jnp.asarray(ids), A) * 0.9 + 0.02)
    read, n = ctc_lib.ctc_greedy_decode(lp)
    assert int(n) == 3
    assert list(np.asarray(read[:3])) == [0, 1, 0]
    assert np.all(np.asarray(read[3:]) == -1)


def test_greedy_decode_logit_length():
    A = 5
    ids = [0, 4, 1, 4, 2, 4]
    lp = jnp.log(jax.nn.one_hot(jnp.asarray(ids), A) * 0.9 + 0.02)
    read, n = ctc_lib.ctc_greedy_decode(lp, logit_length=3)
    assert int(n) == 2
    assert list(np.asarray(read[:2])) == [0, 1]


# ---------------------------------------------------------------------------
# beam search
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,A,seed", [(3, 3, 0), (4, 3, 1), (5, 3, 2),
                                      (4, 4, 3), (5, 4, 4)])
def test_beam_search_finds_map_decode(T, A, seed):
    """With a wide beam, prefix beam search must find the exact MAP read."""
    rng = np.random.default_rng(seed)
    lp = _rand_logprobs(rng, T, A)
    ranked = all_decodes_ranked(np.asarray(lp), blank=A - 1)
    want_read, want_score = ranked[0]
    prefixes, lens, scores = ctc_lib.ctc_beam_search(lp, beam_width=16)
    got = tuple(np.asarray(prefixes[0][: int(lens[0])]))
    assert got == want_read, f"beam {got} != exact {want_read}"
    np.testing.assert_allclose(float(scores[0]), want_score, rtol=1e-4,
                               atol=1e-4)


def test_beam_search_scores_vs_forward_algorithm():
    """Pruned beam scores lower-bound the exact probability; with a beam wide
    enough to cover every reachable prefix they match it exactly."""
    rng = np.random.default_rng(7)
    # lower bound under pruning
    lp = _rand_logprobs(rng, 5, 4)
    prefixes, lens, scores = ctc_lib.ctc_beam_search(lp, beam_width=8)
    for k in range(4):
        L = int(lens[k])
        if L == 0:
            continue
        lab = jnp.asarray(np.asarray(prefixes[k][:L]), jnp.int32)
        exact = -float(ctc_lib.ctc_loss(lp, lab))
        assert float(scores[k]) <= exact + 1e-4
    # exact when nothing is pruned: T=3, A=3 has <= 15 reachable prefixes
    lp = _rand_logprobs(rng, 3, 3)
    prefixes, lens, scores = ctc_lib.ctc_beam_search(lp, beam_width=32)
    for k in range(8):
        L = int(lens[k])
        if L == 0 or float(scores[k]) < -1e8:
            continue
        lab = jnp.asarray(np.asarray(prefixes[k][:L]), jnp.int32)
        exact = -float(ctc_lib.ctc_loss(lp, lab))
        np.testing.assert_allclose(float(scores[k]), exact, rtol=1e-4, atol=1e-4)


def test_beam_search_paper_example():
    """Fig. 4d: A beats AA/A-/-A/-- after merging at t=1."""
    # probs: t0: A=0.3, -=0.5 (top-2 kept), t1: A=0.3, -=0.4
    p = jnp.asarray([[0.3, 0.15, 0.05, 0.0, 0.5],
                     [0.3, 0.2, 0.1, 0.0, 0.4]])
    lp = jnp.log(p + 1e-9)
    prefixes, lens, scores = ctc_lib.ctc_beam_search(lp, beam_width=2)
    got = tuple(np.asarray(prefixes[0][: int(lens[0])]))
    assert got == (0,)  # "A"
    # p(A) = p(AA)+p(A-)+p(-A) = .09+.12+.15 = .36 > p(--)=.2
    np.testing.assert_allclose(float(jnp.exp(scores[0])), 0.36, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_beam_search_monotone_in_width(seed):
    """Best score never decreases as beam widens (property)."""
    rng = np.random.default_rng(seed)
    lp = _rand_logprobs(rng, 6, 4)
    best = -np.inf
    for W in (1, 2, 4, 8):
        _, _, scores = ctc_lib.ctc_beam_search(lp, beam_width=W)
        s = float(scores[0])
        assert s >= best - 1e-5
        best = max(best, s)


def test_beam_search_batch_shapes():
    rng = np.random.default_rng(11)
    lp = jax.nn.log_softmax(
        jnp.asarray(rng.standard_normal((3, 7, 5)).astype(np.float32)), -1)
    prefixes, lens, scores = ctc_lib.ctc_beam_search_batch(lp, beam_width=4)
    assert prefixes.shape == (3, 4, 7)
    assert lens.shape == (3, 4)
    assert scores.shape == (3, 4)
    assert bool(jnp.all(scores[:, 0] >= scores[:, 1]))
