"""PIM analytical model must reproduce the paper's headline claims."""
import pytest

from repro.core import pim


def test_isaac_chip_power_area_match_table2():
    p, a = pim.chip_power_area("cmos", 8)
    assert abs(p - pim.PAPER_CLAIMS["isaac_power_w"]) / 55.4 < 0.05
    assert abs(a - pim.PAPER_CLAIMS["isaac_area_mm2"]) / 62.5 < 0.05


def test_helix_chip_power_area_match_table2():
    p, a = pim.chip_power_area("sot", comparators=True)
    assert abs(p - pim.PAPER_CLAIMS["helix_power_w"]) / 25.7 < 0.10
    assert abs(a - pim.PAPER_CLAIMS["helix_area_mm2"]) / 43.83 < 0.10


def test_headline_fig24_ratios():
    lad = pim.ladder()
    h = lad["Helix"]
    assert abs(h["throughput_x"] - 6.0) / 6.0 < 0.20      # 5.4x computed
    assert abs(h["per_watt_x"] - 11.9) / 11.9 < 0.15
    assert abs(h["per_mm2_x"] - 7.5) / 7.5 < 0.15


def test_per_step_speedups_guppy_profile():
    """The calibration targets are paper-reported per-step speedups."""
    def thr(name):
        return 1.0 / pim.scheme(name, "guppy").time

    assert abs(thr("CTC") / thr("ADC") - 1.678) < 0.05
    assert abs(thr("Helix") / thr("CTC") - 2.22) < 0.10
    assert thr("16-bit") / thr("ISAAC") > 1.03
    assert thr("SEAT") / thr("16-bit") > 1.0


def test_ladder_is_monotone():
    lad = pim.ladder()
    order = [lad[s]["throughput_x"] for s in pim.SCHEMES]
    assert all(b >= a - 1e-9 for a, b in zip(order, order[1:]))


def test_chiron_gains_most():
    """§6.1: Chiron's DNN-heavy profile benefits most from the PIM."""
    gains = {c: (1 / pim.scheme("Helix", c).time)
             / (1 / pim.scheme("ISAAC", c).time) for c in pim.CALLERS}
    assert gains["chiron"] > gains["guppy"]
    assert gains["chiron"] > gains["scrappie"]


def test_beam_width_sensitivity_fig26():
    """Larger beam width => CTC share grows => bigger CTC-scheme win."""
    gains = []
    for w in (5, 10, 20, 40):
        adc = pim.scheme("ADC", "guppy", beam_width=w)
        ctc = pim.scheme("CTC", "guppy", beam_width=w)
        gains.append(adc.time / ctc.time)
    assert all(b > a for a, b in zip(gains, gains[1:]))


def test_adc_resolution_sensitivity_fig25():
    """SOT-MRAM ADC beats 5-/6-bit CMOS ADCs on perf/W (27.9 %/37.3 %)."""
    helix = pim.scheme("Helix", "guppy")
    for bits, want in ((5, 1.279), (6, 1.373)):
        cmos = pim.scheme(f"cmos{bits}", "guppy")
        ratio = ((helix.throughput / helix.power_w)
                 / (cmos.throughput / cmos.power_w))
        assert ratio > 1.05, (bits, ratio)   # direction + materiality
