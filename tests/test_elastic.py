"""Elastic scaling: checkpoints move between DIFFERENT device meshes.

Runs in subprocesses (8 fake host devices) so the multi-device XLA_FLAGS
never leak into the main test process: save params sharded on a (4,2)
mesh, restore onto (2,4) and (8,1) meshes, verify bitwise equality —
the restart-with-a-different-pod-count path of train/checkpoint.py.
"""
import json
import os
import subprocess
import sys

import pytest

PROBE = r"""
import os, json, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint as ckpt_lib
from repro.dist import sharding as shd

def mesh(shape):
    kw = {}
    if hasattr(jax.sharding, "AxisType"):   # absent on older jax
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * 2
    return jax.make_mesh(shape, ("data", "model"), **kw)

tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
        "b": jnp.linspace(0, 1, 8)}
m1 = mesh((4, 2))
sh1 = {"w": NamedSharding(m1, P("data", "model")),
       "b": NamedSharding(m1, P("model"))}
placed = {k: jax.device_put(v, sh1[k]) for k, v in tree.items()}
d = tempfile.mkdtemp()
ckpt_lib.save(d, 5, placed)

out = {"ok": True}
for shape, spec_w in (((2, 4), P("model", "data")), ((8, 1), P("data", None))):
    m2 = mesh(shape)
    sh2 = {"w": NamedSharding(m2, spec_w), "b": NamedSharding(m2, P())}
    restored, step = ckpt_lib.restore(d, tree, sharding_tree=sh2)
    assert step == 5
    for k in tree:
        np.testing.assert_array_equal(np.asarray(restored[k]),
                                      np.asarray(tree[k]))
        assert restored[k].sharding == sh2[k], (shape, k)
    out[f"mesh{shape}"] = "ok"
print(json.dumps(out))
"""


def test_checkpoint_elastic_across_meshes():
    r = subprocess.run([sys.executable, "-c", PROBE], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["mesh(2, 4)"] == "ok" \
        and out["mesh(8, 1)"] == "ok"
