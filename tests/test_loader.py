"""Prefetch loader: ordering, determinism, error propagation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.loader import PrefetchLoader


def test_loader_yields_sequential_steps():
    loader = PrefetchLoader(lambda s: {"x": np.full((2,), s)}, start_step=3)
    steps = []
    for _ in range(4):
        step, batch = next(loader)
        steps.append(step)
        np.testing.assert_array_equal(np.asarray(batch["x"]), step)
    loader.close()
    assert steps == [3, 4, 5, 6]


def test_loader_places_on_device():
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    loader = PrefetchLoader(lambda s: {"x": np.ones((4,))}, sharding=sh)
    _, batch = next(loader)
    assert batch["x"].sharding == sh
    loader.close()


def test_loader_propagates_generator_errors():
    def bad(step):
        if step >= 1:
            raise ValueError("boom")
        return {"x": np.zeros(1)}

    loader = PrefetchLoader(bad)
    next(loader)
    with pytest.raises(ValueError, match="boom"):
        next(loader)
    loader.close()
