"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
shape + finiteness assertions (deliverable f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfg_reg
from repro.models import decode as decode_lib
from repro.models import lm as lm_lib

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 32


def _batch(cfg, key=0):
    k = jax.random.PRNGKey(key)
    batch = {}
    if cfg.embed_inputs:
        batch["tokens"] = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    else:
        batch["embeds"] = jax.random.normal(k, (B, S, cfg.d_model))
        batch["labels"] = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    if cfg.encoder is not None:
        batch["enc_embeds"] = jax.random.normal(k, (B, S, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", cfg_reg.LM_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = cfg_reg.get_smoke(arch)
    params = lm_lib.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)

    logits, aux = lm_lib.forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm_lib.lm_loss(p, cfg, batch), has_aux=True)(params)
    assert np.isfinite(float(loss)), arch
    gleaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in gleaves), arch
    assert any(float(jnp.abs(g).max()) > 0 for g in gleaves), arch


@pytest.mark.parametrize("arch", cfg_reg.LM_IDS)
def test_smoke_prefill_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce forward logits step by step."""
    cfg = cfg_reg.get_smoke(arch)
    cfg = dataclasses.replace(cfg, remat=False)
    if cfg.moe is not None:
        # capacity dropping is T-dependent; equivalence needs dropless routing
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    params = lm_lib.init_lm(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg, key=1)

    full_logits, _ = lm_lib.forward(params, cfg, batch)

    # prefill on the first S0 tokens, then decode the rest one at a time
    S0 = S // 2
    pre_batch = {k: v[:, :S0] for k, v in batch.items()
                 if k != "enc_embeds"}
    if cfg.encoder is not None:
        pre_batch["enc_embeds"] = batch["enc_embeds"]
    logits_pre, cache = decode_lib.prefill(params, cfg, pre_batch,
                                           max_len=S + 4, last_only=False)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(full_logits[:, :S0]),
                               rtol=2e-3, atol=2e-3)

    for t in range(S0, S):
        if cfg.embed_inputs:
            logits_t, cache = decode_lib.decode_step(
                params, cfg, cache, tokens=batch["tokens"][:, t])
        else:
            logits_t, cache = decode_lib.decode_step(
                params, cfg, cache, embeds=batch["embeds"][:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(logits_t), np.asarray(full_logits[:, t]),
            rtol=2e-2, atol=2e-2, err_msg=f"{arch} step {t}")


@pytest.mark.parametrize("arch,published_total,published_active", [
    ("qwen2.5-3b", 3.09e9, None),
    ("llama3.2-3b", 3.2e9, None),
    ("codeqwen1.5-7b", 7.25e9, None),
    ("qwen2-vl-7b", 7.0e9, None),           # text backbone of 7.6B model
    ("h2o-danube-1.8b", 1.8e9, None),
    ("hymba-1.5b", 1.5e9, None),
    ("falcon-mamba-7b", 7.27e9, None),
    ("olmoe-1b-7b", 6.9e9, 1.3e9),
    ("llama4-maverick-400b-a17b", 400e9, 17e9),
    ("seamless-m4t-large-v2", 2.3e9, None),
])
def test_full_config_param_counts(arch, published_total, published_active):
    """Analytical param counts of the FULL configs match published sizes."""
    cfg = cfg_reg.get_config(arch)
    total = cfg.param_count()
    assert 0.6 * published_total < total < 1.45 * published_total, (
        arch, f"{total/1e9:.2f}B vs {published_total/1e9:.2f}B")
    if published_active:
        active = cfg.active_param_count()
        assert 0.6 * published_active < active < 1.6 * published_active, (
            arch, f"{active/1e9:.2f}B vs {published_active/1e9:.2f}B")


def test_smoke_param_count_matches_analytical():
    """init_lm allocates exactly param_count() parameters (smoke configs)."""
    for arch in cfg_reg.LM_IDS:
        cfg = cfg_reg.get_smoke(arch)
        params = lm_lib.init_lm(jax.random.PRNGKey(0), cfg)
        n = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
        want = cfg.param_count()
        assert abs(n - want) <= 0.02 * want + 1000, (arch, n, want)


def test_swa_restricts_context():
    """With window=w, logits at position t must not depend on tokens < t-w."""
    cfg = dataclasses.replace(cfg_reg.get_smoke("h2o-danube-1.8b"), window=4)
    params = lm_lib.init_lm(jax.random.PRNGKey(2), cfg)
    t0 = jax.random.randint(jax.random.PRNGKey(3), (1, 16), 0,
                            cfg.vocab_size)
    t1 = t0.at[:, 0].set((t0[:, 0] + 1) % cfg.vocab_size)
    l0, _ = lm_lib.forward(params, cfg, {"tokens": t0})
    l1, _ = lm_lib.forward(params, cfg, {"tokens": t1})
    # position 12 is > window away from position 0
    np.testing.assert_allclose(np.asarray(l0[:, 12:]), np.asarray(l1[:, 12:]),
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(l0[:, 0]), np.asarray(l1[:, 0]))


def test_moe_routes_tokens_differently():
    cfg = cfg_reg.get_smoke("olmoe-1b-7b")
    params = lm_lib.init_lm(jax.random.PRNGKey(4), cfg)
    batch = _batch(cfg, key=4)
    _, aux = lm_lib.forward(params, cfg, batch)
    assert float(aux["lb_loss"]) > 0
