"""Read-voting: longest-match alignment + consensus (paper Fig. 19)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import voting

jax.config.update("jax_platform_name", "cpu")

A, C, G, T = 0, 1, 2, 3


def _pad(read, L):
    return jnp.asarray(read + [-1] * (L - len(read)), jnp.int32)


def test_paper_fig19_example():
    """R1=ACTA, R2=CTAG, R3=GAGAT  ->  consensus ACTAGAT."""
    reads = jnp.stack([_pad([A, C, T, A], 5), _pad([C, T, A, G], 5),
                       _pad([G, A, G, A, T], 5)])
    lens = jnp.asarray([4, 4, 5], jnp.int32)
    cons, clen = voting.vote(reads, lens, span=12)
    got = list(np.asarray(cons[: int(clen)]))
    assert got == [A, C, T, A, G, A, T], got


def test_longest_common_substring_basic():
    r1, l1 = _pad([A, C, T, A], 6), 4
    r2, l2 = _pad([C, T, A, G], 6), 4
    m, s1, s2 = voting.longest_common_substring(r1, l1, r2, l2)
    assert int(m) == 3 and int(s1) == 1 and int(s2) == 0  # "CTA"


def test_lcs_no_match():
    m, s1, s2 = voting.longest_common_substring(
        _pad([A, A], 4), 2, _pad([G, G], 4), 2)
    assert int(m) == 0


def test_lcs_respects_lengths():
    # matching chars hidden beyond the true length must not count
    r1 = _pad([A, C], 5).at[2].set(G)   # junk past len
    r2 = _pad([G, G], 5)
    m, _, _ = voting.longest_common_substring(r1, 2, r2, 2)
    assert int(m) == 0


def test_vote_majority_fixes_random_error():
    """Random error in one read is outvoted (paper Fig. 3 'random error')."""
    good = [A, C, G, T, A, C]
    bad = [A, C, G, G, A, C]  # one substitution
    reads = jnp.stack([_pad(good, 8), _pad(bad, 8), _pad(good, 8)])
    lens = jnp.asarray([6, 6, 6], jnp.int32)
    cons, clen = voting.vote(reads, lens)
    assert list(np.asarray(cons[: int(clen)])) == good


def test_vote_systematic_error_survives():
    """If ALL reads carry the same wrong base, voting cannot fix it."""
    bad = [A, C, G, G, A, C]
    reads = jnp.stack([_pad(bad, 8)] * 3)
    lens = jnp.asarray([6, 6, 6], jnp.int32)
    cons, clen = voting.vote(reads, lens)
    assert list(np.asarray(cons[: int(clen)])) == bad


def test_vote_matches_reference_oracle():
    rng = np.random.default_rng(5)
    base = rng.integers(0, 4, size=20).tolist()
    # overlapping windows of the same sequence
    reads_list = [base[0:10], base[4:14], base[8:18]]
    L = 12
    reads = jnp.stack([_pad(r, L) for r in reads_list])
    lens = jnp.asarray([len(r) for r in reads_list], jnp.int32)
    cons, clen = voting.vote(reads, lens, span=40)
    want = voting.vote_reference(reads_list)
    assert list(np.asarray(cons[: int(clen)])) == want


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 4),
       overlap=st.integers(3, 6))
def test_vote_recovers_sequence_from_clean_overlapping_reads(seed, n, overlap):
    """Clean overlapping windows of a sequence vote back the sequence."""
    rng = np.random.default_rng(seed)
    win = overlap + 4
    step = win - overlap
    length = step * (n - 1) + win
    base = rng.integers(0, 4, size=length).tolist()
    # ensure unique overlaps are likely; skip degenerate repeats
    reads_list = [base[k * step: k * step + win] for k in range(n)]
    L = win
    reads = jnp.stack([_pad(r, L) for r in reads_list])
    lens = jnp.full((n,), win, jnp.int32)
    cons, clen = voting.vote(reads, lens, span=2 * length)
    got = list(np.asarray(cons[: int(clen)]))
    want = voting.vote_reference(reads_list)
    assert got == want  # jnp implementation == python oracle


def test_vote_batch_shape():
    reads = jnp.full((3, 4, 6), -1, jnp.int32).at[:, :, :3].set(1)
    lens = jnp.full((3, 4), 3, jnp.int32)
    cons, clen = voting.vote_batch(reads, lens, span=10)
    assert cons.shape == (3, 10) and clen.shape == (3,)


def test_encode_3bit_paper_codes():
    codes = np.asarray(voting.encode_3bit(jnp.asarray([0, 1, 2, 3, 4])))
    assert codes.tolist() == [[0, 0, 1], [0, 1, 0], [1, 0, 0], [0, 0, 0],
                              [1, 0, 1]]
