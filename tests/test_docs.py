"""Documentation cannot rot: execute every fenced ``python`` block.

Extracts the fenced ``python`` code blocks from README.md and every
``docs/*.md`` guide and runs them — per file, in order, sharing one
namespace (so a guide can build on its earlier snippets, exactly as a
reader would paste them).  Each file runs in a fresh subprocess so
snippet side effects (registering demo ops, rebinding the default
backend) cannot leak into this test process, and with 4 forced host
devices so the sharding guide genuinely exercises a multi-device mesh.

The ``docs-check`` CI job runs exactly this file.
"""
from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent

DOC_FILES = sorted([REPO / "README.md"]
                   + list((REPO / "docs").glob("*.md")))

_FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$",
                    re.MULTILINE | re.DOTALL)


def extract_blocks(path: pathlib.Path):
    return [m.group(1) for m in _FENCE.finditer(path.read_text())]


def test_docs_exist_and_have_snippets():
    """README + the three guides exist, each with runnable python."""
    names = {p.name for p in DOC_FILES}
    assert "README.md" in names
    for guide in ("kernels.md", "serving.md", "sharding.md",
                  "streaming.md"):
        assert guide in names, f"docs/{guide} missing"
    for p in DOC_FILES:
        assert extract_blocks(p), f"{p.name} has no fenced python blocks"


@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_doc_snippets_execute(path):
    blocks = extract_blocks(path)
    script = "\n\n".join(
        f"# --- {path.name} block {i} ---\n{b}"
        for i, b in enumerate(blocks))
    from repro.hostdev import force_host_devices

    env = dict(os.environ)
    env["PYTHONPATH"] = (str(REPO / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env["JAX_PLATFORM_NAME"] = "cpu"
    # override any inherited device-count flag: the subprocess is
    # deliberately isolated and the sharding guide expects 4 devices
    force_host_devices(4, env, override=True)
    proc = subprocess.run([sys.executable, "-"], input=script, text=True,
                          capture_output=True, env=env, cwd=str(REPO),
                          timeout=600)
    assert proc.returncode == 0, (
        f"{path.name} snippet failed:\n--- stdout ---\n{proc.stdout}\n"
        f"--- stderr ---\n{proc.stderr}")
