"""Shared test config.

When the real ``hypothesis`` package is unavailable (the CI/container image
does not ship it and installing deps is out of scope), install a minimal
deterministic stand-in BEFORE test modules import it: ``@given`` runs the
test body over a fixed pseudo-random sample of the strategy space
(``max_examples`` draws, seeded per test name), which keeps the property
tests meaningful — just without shrinking or adaptive search.
"""
from __future__ import annotations

import functools
import importlib.util
import random
import sys
import types


def _install_hypothesis_fallback() -> None:
    mod = types.ModuleType("hypothesis")
    strategies = types.ModuleType("hypothesis.strategies")

    class _Integers:
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = lo, hi

        def sample(self, rng: random.Random) -> int:
            return rng.randint(self.lo, self.hi)

    class _SampledFrom:
        def __init__(self, options):
            self.options = list(options)

        def sample(self, rng: random.Random):
            return rng.choice(self.options)

    def integers(min_value: int, max_value: int) -> _Integers:
        return _Integers(min_value, max_value)

    def sampled_from(options) -> _SampledFrom:
        return _SampledFrom(options)

    def given(**strategy_kwargs):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 10)
                rng = random.Random(fn.__qualname__)
                for _ in range(n):
                    drawn = {k: s.sample(rng)
                             for k, s in strategy_kwargs.items()}
                    fn(*args, **drawn, **kwargs)
            # keep the test's name/docs but NOT its signature — pytest
            # must not mistake the strategy params for fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    def settings(max_examples=None, deadline=None, **_ignored):
        def deco(fn):
            if max_examples is not None:
                fn._max_examples = max_examples
            return fn
        return deco

    strategies.integers = integers
    strategies.sampled_from = sampled_from
    mod.strategies = strategies
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = types.SimpleNamespace(all=lambda: [])
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


if importlib.util.find_spec("hypothesis") is None:
    _install_hypothesis_fallback()
