"""Shared test config.

When the real ``hypothesis`` package is unavailable (the CI/container image
does not ship it and installing deps is out of scope), install a minimal
deterministic stand-in BEFORE test modules import it: ``@given`` runs the
test body over a fixed pseudo-random sample of the strategy space
(``max_examples`` draws, seeded per test name), which keeps the property
tests meaningful — just without shrinking or adaptive search.
"""
from __future__ import annotations

import functools
import importlib.util
import random
import sys
import types

# ---------------------------------------------------------------------------
# multi-device host platform: the dist-pipeline tests exercise the dp-sharded
# basecall path on 4 fake host devices.  XLA locks the device count at first
# backend init, so the flag must land BEFORE any test imports jax — conftest
# import time is the one place pytest guarantees runs first (repro.hostdev
# is jax-free, so this import initializes nothing).  Single-device tests are
# unaffected: unsharded arrays still live on device 0, and
# sharding.constrain is a no-op without an ambient mesh.
# ---------------------------------------------------------------------------
from repro.hostdev import force_host_devices  # noqa: E402

force_host_devices(4)


def _install_hypothesis_fallback() -> None:
    mod = types.ModuleType("hypothesis")
    strategies = types.ModuleType("hypothesis.strategies")

    class _Integers:
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = lo, hi

        def sample(self, rng: random.Random) -> int:
            return rng.randint(self.lo, self.hi)

    class _SampledFrom:
        def __init__(self, options):
            self.options = list(options)

        def sample(self, rng: random.Random):
            return rng.choice(self.options)

    def integers(min_value: int, max_value: int) -> _Integers:
        return _Integers(min_value, max_value)

    def sampled_from(options) -> _SampledFrom:
        return _SampledFrom(options)

    def given(**strategy_kwargs):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 10)
                rng = random.Random(fn.__qualname__)
                for _ in range(n):
                    drawn = {k: s.sample(rng)
                             for k, s in strategy_kwargs.items()}
                    fn(*args, **drawn, **kwargs)
            # keep the test's name/docs but NOT its signature — pytest
            # must not mistake the strategy params for fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    def settings(max_examples=None, deadline=None, **_ignored):
        def deco(fn):
            if max_examples is not None:
                fn._max_examples = max_examples
            return fn
        return deco

    strategies.integers = integers
    strategies.sampled_from = sampled_from
    mod.strategies = strategies
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = types.SimpleNamespace(all=lambda: [])
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


if importlib.util.find_spec("hypothesis") is None:
    _install_hypothesis_fallback()


# ---------------------------------------------------------------------------
# golden-read fixture: deterministic genome -> signal -> basecall round-trip
# ---------------------------------------------------------------------------
#
# One session-scoped trained pipeline (the quickstart recipe: demo-scale
# Guppy, 5-bit quant, warm-up + SEAT, fixed seeds end to end) plus a known
# genome rendered through the synthetic pore channel.  Tests pin consensus
# read identity against thresholds comfortably below the deterministic
# achieved values, so decoder/voting changes cannot silently degrade
# accuracy.  Built lazily — only sessions running the golden tests pay the
# ~30 s training cost.

import pytest

GOLDEN_SEED = 42
GOLDEN_GENOME_LEN = 60
GOLDEN_TRAIN_STEPS = 300


@pytest.fixture(scope="session")
def host_mesh4():
    """A 4-device data-parallel host mesh (dp = 4, no model axis).

    Skips when the process has fewer than 4 devices — e.g. when something
    imported jax before this conftest's XLA_FLAGS append could take."""
    import jax
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 host devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    return jax.make_mesh((4,), ("data",))


@pytest.fixture(scope="session")
def golden_pipeline():
    """(pipe, params, data_config) trained on the fixed golden recipe."""
    import jax
    from repro.core.quant import QuantConfig
    from repro.data import genome
    from repro.pipeline import BasecallPipeline, TrainPolicy

    pipe = BasecallPipeline.from_preset(
        "guppy", scale="demo",
        quant=QuantConfig(enabled=True, bits_w=5, bits_a=5),
        backend="ref", beam_width=5)
    dcfg = pipe.data_config(kmer=1, mean_dwell=6.0, max_label_len=40)
    params = pipe.init_params(jax.random.PRNGKey(0))
    warm = int(GOLDEN_TRAIN_STEPS * 0.73)          # quickstart's 220/80 split
    policy = TrainPolicy(warmup_steps=warm,
                         seat_steps=GOLDEN_TRAIN_STEPS - warm)
    trainer = pipe.trainer(policy)
    state = trainer.init(params)
    for step in range(policy.total_steps):
        batch = genome.batch_for_step(step, 8, dcfg)
        params, state, _, _ = pipe.train_step(params, state, batch, step)
    pipe.params = params
    return pipe, params, dcfg


@pytest.fixture(scope="session")
def golden_read(golden_pipeline):
    """(sequence (60,), signal) — a known genome through the pore model."""
    import jax
    import numpy as np
    from repro.data import genome

    _, _, dcfg = golden_pipeline
    rng = np.random.default_rng(GOLDEN_SEED)
    seq = rng.integers(0, 4, GOLDEN_GENOME_LEN).astype(np.int32)
    sig, _ = genome.render_signal(seq, dcfg, jax.random.PRNGKey(99))
    return seq, np.asarray(sig)
