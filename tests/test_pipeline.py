"""BasecallPipeline acceptance: chunk/stitch correctness, backend parity,
streaming equivalence, the phased trainer, and the base-calling engine."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ctc as ctc_lib
from repro.core import voting as voting_lib
from repro.core.quant import QuantConfig
from repro.data import genome
from repro.kernels.registry import Backend
from repro.models import basecaller as bc
from repro.pipeline import (BasecallPipeline, ChunkConfig, TrainPolicy,
                            chunk_signal)
from repro.serve import BasecallRequest, Server
from repro.serve.basecall_engine import BasecallEngine, ReadRequest

jax.config.update("jax_platform_name", "cpu")

QUANT = QuantConfig(enabled=True, bits_w=5, bits_a=5)


def _pipe(backend="ref", **kw):
    pipe = BasecallPipeline.from_preset("guppy", scale="tiny", quant=QUANT,
                                        backend=backend, beam_width=3, **kw)
    pipe.init_params(jax.random.PRNGKey(0))
    return pipe


def _long_signal(n_samples, seed=0):
    return np.random.default_rng(seed).standard_normal(
        n_samples).astype(np.float32)


# ---------------------------------------------------------------------------
# (a) chunked + stitched basecall == the windowed reference path
# ---------------------------------------------------------------------------

def test_basecall_matches_windowed_reference():
    pipe = _pipe()
    sig = _long_signal(3 * pipe.mcfg.input_len + 17)
    got = pipe.basecall(sig)

    # reference: window by hand, run model + beam decode + vote directly
    windows = chunk_signal(sig, pipe.chunk)
    lps = bc.apply_basecaller(pipe.params, jnp.asarray(windows), pipe.mcfg,
                              backend=Backend("ref"))
    frames = pipe.window_logit_lengths(sig.shape[0])
    reads, lens, _ = ctc_lib.ctc_beam_search_hash_batch(
        lps, beam_width=pipe.beam_width, max_len=pipe.max_read_len,
        logit_lengths=jnp.asarray(frames), backend="ref")
    reads, lens = reads[:, 0], lens[:, 0]
    span = pipe.max_read_len * windows.shape[0]
    cons, clen = voting_lib.vote(reads, lens, span=span)

    np.testing.assert_array_equal(got.window_reads, np.asarray(reads))
    np.testing.assert_array_equal(got.window_lengths, np.asarray(lens))
    assert got.length == int(clen)
    np.testing.assert_array_equal(got.read[: got.length],
                                  np.asarray(cons[: clen]))


def test_tail_window_padding_not_decoded():
    """Regression (PR 2 bugfix): a zero-padded tail window must decode the
    same read as the unpadded signal slice — padded frames previously
    entered the beam search and emitted garbage bases."""
    pipe = _pipe()
    win = pipe.mcfg.input_len
    sig = _long_signal(win + 17, seed=8)          # final window mostly padding
    got = pipe.basecall(sig)
    frames = pipe.window_logit_lengths(sig.shape[0])
    n_frames = int(frames[-1])
    assert n_frames < pipe.mcfg.output_len        # tail really is partial

    # decode the tail window's valid prefix only, no padding involved
    windows = chunk_signal(sig, pipe.chunk)
    lps = bc.apply_basecaller(pipe.params, jnp.asarray(windows), pipe.mcfg,
                              backend=Backend("ref"))
    reads, lens, _ = ctc_lib.ctc_beam_search_hash_batch(
        lps[-1:, :n_frames], beam_width=pipe.beam_width,
        max_len=pipe.max_read_len, backend="ref")
    want = np.asarray(reads[0, 0])
    want_len = int(lens[0, 0])

    assert int(got.window_lengths[-1]) == want_len
    np.testing.assert_array_equal(got.window_reads[-1][:want_len],
                                  want[:want_len])
    # and the garbage regime is real: decoding WITH the padded frames
    # must not be what the pipeline reports (the window is mostly padding)
    full, flens, _ = ctc_lib.ctc_beam_search_hash_batch(
        lps[-1:], beam_width=pipe.beam_width, max_len=pipe.max_read_len,
        backend="ref")
    assert int(flens[0, 0]) != want_len or not np.array_equal(
        np.asarray(full[0, 0])[:want_len], want[:want_len])


def test_basecall_single_window_read():
    pipe = _pipe()
    sig = _long_signal(pipe.mcfg.input_len - 9, seed=3)  # shorter than window
    res = pipe.basecall(sig)
    assert res.window_reads.shape[0] == 1
    assert res.length == int(res.window_lengths[0])


def test_basecall_short_and_empty_signals():
    """Regression: signals shorter than one chunk hop (or empty) must
    produce an empty/short-read BasecallResult, not a ``ValueError`` out
    of ``np.concatenate([])``."""
    pipe = _pipe()
    hop = pipe.chunk.hop

    short = pipe.basecall(_long_signal(hop - 1, seed=4))  # < one hop
    assert short.window_reads.shape[0] == 1               # one padded window
    assert short.length == int(short.window_lengths[0])

    empty = pipe.basecall(np.zeros((0,), np.float32))     # zero windows
    assert empty.length == 0
    assert empty.sequence() == ""
    assert empty.window_reads.shape == (0, pipe.max_read_len)
    assert empty.window_lengths.shape == (0,)
    assert list(pipe.basecall_iter(np.zeros((0,), np.float32))) == []


def test_engine_handles_empty_signal():
    """Engine-level regression: an empty signal submitted STRAIGHT to the
    scheduler (below the server's admission validation) still retires at
    admit() with an empty result instead of wedging a lane."""
    pipe = _pipe()
    eng = BasecallEngine(pipe, batch_slots=2)
    eng.sched.submit(ReadRequest(rid=0, signal=np.zeros((0,), np.float32)))
    eng.admit()
    done = eng.sched.drain_finished()
    assert done[0].result.length == 0
    assert not any(eng.active_mask())
    # and the pool still serves a real read through the API afterwards
    srv = Server(eng)
    res = srv.submit(BasecallRequest(
        signal=_long_signal(130, seed=5))).result()
    want = pipe.basecall(_long_signal(130, seed=5))
    assert res.value.length == want.length


# ---------------------------------------------------------------------------
# (b) backend="ref" and backend="interpret" pipelines agree
# ---------------------------------------------------------------------------

def test_ref_and_interpret_backends_agree():
    sig = _long_signal(2 * 120 + 31, seed=1)
    ref = _pipe("ref")
    interp = BasecallPipeline(ref.mcfg, backend="interpret",
                              scfg=ref.scfg, chunk=ref.chunk,
                              beam_width=ref.beam_width, params=ref.params)
    a = ref.basecall(sig)
    b = interp.basecall(sig)
    np.testing.assert_array_equal(a.window_lengths, b.window_lengths)
    np.testing.assert_array_equal(a.window_reads, b.window_reads)
    assert a.length == b.length
    np.testing.assert_array_equal(a.read[: a.length], b.read[: b.length])


def test_fused_window_path_backend_parity():
    ref = _pipe("ref")
    interp = BasecallPipeline(ref.mcfg, backend="interpret", scfg=ref.scfg,
                              beam_width=ref.beam_width, params=ref.params)
    dcfg = ref.data_config(max_label_len=24)
    batch = genome.batch_for_step(0, 3, dcfg)
    Ca, La, ra, la, sa = ref.basecall_windows(batch["signal"])
    Cb, Lb, rb, lb, sb = interp.basecall_windows(batch["signal"])
    np.testing.assert_array_equal(np.asarray(Ca), np.asarray(Cb))
    np.testing.assert_array_equal(np.asarray(La), np.asarray(Lb))
    np.testing.assert_array_equal(np.asarray(ra), np.asarray(rb))
    np.testing.assert_allclose(np.asarray(sa), np.asarray(sb), rtol=1e-5)


# ---------------------------------------------------------------------------
# streaming + chunking mechanics
# ---------------------------------------------------------------------------

def test_basecall_iter_streams_same_reads_in_bounded_batches():
    pipe = _pipe(chunk=ChunkConfig(window=120, hop=60, batch_windows=2))
    sig = _long_signal(5 * 120, seed=2)
    got = pipe.basecall(sig)
    batches = list(pipe.basecall_iter(sig))
    assert all(r.shape[0] <= 2 for r, _ in batches)
    streamed = np.concatenate([r for r, _ in batches])
    np.testing.assert_array_equal(streamed, got.window_reads)


def test_chunk_signal_covers_and_overlaps():
    cfg = ChunkConfig(window=100, hop=40)
    sig = np.arange(250, dtype=np.float32)
    w = chunk_signal(sig, cfg)
    assert w.shape == (5, 100, 1)
    np.testing.assert_array_equal(w[0, :, 0], sig[:100])
    np.testing.assert_array_equal(w[1, :60, 0], w[0, 40:, 0])  # overlap
    np.testing.assert_array_equal(w[4, :90, 0], sig[160:])     # tail window
    assert np.all(w[4, 90:] == 0)                              # tail pad


def test_chunk_config_validates_hop():
    with pytest.raises(ValueError):
        ChunkConfig(window=100, hop=0)
    with pytest.raises(ValueError):
        ChunkConfig(window=100, hop=101)


# ---------------------------------------------------------------------------
# construction + training policy
# ---------------------------------------------------------------------------

def test_from_preset_validates_names():
    with pytest.raises(KeyError):
        BasecallPipeline.from_preset("bonito")
    with pytest.raises(KeyError):
        BasecallPipeline.from_preset("guppy", scale="huge")


def test_train_policy_phases_and_step():
    policy = TrainPolicy(warmup_steps=2, seat_steps=2, lr=1e-3)
    assert policy.phase(0) == "warmup" and policy.phase(2) == "seat"
    pipe = _pipe()
    trainer = pipe.trainer(policy)
    dcfg = pipe.data_config(max_label_len=24)
    batch = genome.batch_for_step(0, 2, dcfg)
    params, state = pipe.params, trainer.init(pipe.params)
    losses = []
    for step in range(policy.total_steps):
        params, state, loss, m = pipe.train_step(params, state, batch, step)
        losses.append(float(loss))
        assert np.isfinite(losses[-1])
    # SEAT phase adds the consensus term: metrics grow the gap entry
    assert float(m["consensus_gap"]) >= 0.0


# ---------------------------------------------------------------------------
# continuous-batching engine
# ---------------------------------------------------------------------------

def test_engine_matches_pipeline_per_read():
    pipe = _pipe()
    sigs = [_long_signal(n, seed=10 + i)
            for i, n in enumerate((130, 470, 120))]
    srv = Server(BasecallEngine(pipe, batch_slots=2))
    for s in sigs:
        srv.submit(BasecallRequest(signal=s))
    done = srv.run_until_idle()
    assert sorted(done) == [0, 1, 2]
    for i, s in enumerate(sigs):
        want = pipe.basecall(s)
        got = done[i].value
        assert got.length == want.length, f"read {i}"
        np.testing.assert_array_equal(got.read[: got.length],
                                      want.read[: want.length])


def test_engine_retires_short_reads_early():
    pipe = _pipe()
    eng = BasecallEngine(pipe, batch_slots=1)
    srv = Server(eng)
    srv.submit(BasecallRequest(signal=_long_signal(120)))      # 1 window
    srv.submit(BasecallRequest(signal=_long_signal(60 * 7)))   # many
    done = srv.run_until_idle()
    n0 = done[0].value.window_reads.shape[0]
    n1 = done[1].value.window_reads.shape[0]
    assert n0 == 1 and n1 > 1
    assert eng.steps == n0 + n1   # one slot: pure sequential window count


def test_engine_handles_multichannel_signals():
    """Idle-lane filler must match the model's channel count."""
    mcfg = dataclasses.replace(BasecallPipeline.from_preset(
        "guppy", scale="tiny").mcfg, in_channels=2, quant=QUANT)
    pipe = BasecallPipeline(mcfg, backend="ref", beam_width=2)
    pipe.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    srv = Server(BasecallEngine(pipe, batch_slots=2))  # 1 request: one idle
    sig = rng.standard_normal((200, 2)).astype(np.float32)
    res = srv.submit(BasecallRequest(signal=sig)).result()
    assert res.ok and res.value.length >= 0


def test_lstm_backend_warns_partial_acceleration_once_per_process():
    from repro.pipeline import pipeline as pipeline_mod
    pipeline_mod._reset_lstm_warning()
    # first LSTM pipeline of the process warns...
    with pytest.warns(UserWarning, match="LSTM"):
        BasecallPipeline.from_preset("chiron", scale="tiny",
                                     backend="interpret")
    # ...every later construction is silent (deduped, not dropped)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        BasecallPipeline.from_preset("chiron", scale="tiny",
                                     backend="interpret")
        BasecallPipeline.from_preset("chiron", scale="tiny", backend="auto")
