"""Serving layer: ``api.Server`` request lifecycle over the
``EngineProtocol`` step-executors (LM tokens / base-calling windows /
live chunk streams), all driving one ``scheduler.SlotScheduler``.

Engines import the heavy model stacks, so they live in their own
modules — ``serve.engine`` (token LM), ``serve.basecall_engine`` (whole
reads), ``serve.streaming`` (incremental ReadUntil streams with adaptive
ejection) — and are imported directly, not re-exported here."""
from repro.serve.api import (BasecallRequest, EngineProtocol, LMRequest,
                             QueueFull, ServeEvent, ServeFuture, ServeResult,
                             Server, ServerMetrics)
from repro.serve.scheduler import SlotScheduler

__all__ = ["Server", "ServeFuture", "ServeResult", "ServeEvent",
           "ServerMetrics", "BasecallRequest", "LMRequest", "QueueFull",
           "EngineProtocol", "SlotScheduler"]
