"""Serving layer: ``api.Server`` request lifecycle over the
``EngineProtocol`` step-executors (LM tokens / base-calling windows /
live chunk streams), all driving one ``scheduler.SlotScheduler``.

Multi-tenant serving: ``registry.ModelRegistry`` holds many packed
artifacts (LRU under a byte budget, evict -> re-pack bitwise identical)
and ``multitenant.MultiModelBasecallEngine`` multiplexes hosted models
over per-model slot groups in one scheduler, routed by the requests'
``model=`` field.

Engines import the heavy model stacks, so they live in their own
modules — ``serve.engine`` (token LM), ``serve.basecall_engine`` (whole
reads), ``serve.streaming`` (incremental ReadUntil streams with adaptive
ejection), ``serve.multitenant`` (multi-model fleets) — and are imported
directly, not re-exported here.  The dependency-light ``ModelRegistry``
is re-exported."""
from repro.serve.api import (BasecallRequest, EngineProtocol, LMRequest,
                             ModelMetrics, QueueFull, ServeEvent,
                             ServeFuture, ServeResult, Server, ServerMetrics)
from repro.serve.registry import ModelRegistry, RegistryStats
from repro.serve.scheduler import SlotScheduler

__all__ = ["Server", "ServeFuture", "ServeResult", "ServeEvent",
           "ServerMetrics", "ModelMetrics", "BasecallRequest", "LMRequest",
           "QueueFull", "EngineProtocol", "SlotScheduler", "ModelRegistry",
           "RegistryStats"]
