"""Serving layer: ``api.Server`` request lifecycle over the two
``EngineProtocol`` step-executors (LM tokens / base-calling windows),
all driving one ``scheduler.SlotScheduler``."""
from repro.serve.api import (BasecallRequest, EngineProtocol, LMRequest,
                             QueueFull, ServeEvent, ServeFuture, ServeResult,
                             Server, ServerMetrics)
from repro.serve.scheduler import SlotScheduler

__all__ = ["Server", "ServeFuture", "ServeResult", "ServeEvent",
           "ServerMetrics", "BasecallRequest", "LMRequest", "QueueFull",
           "EngineProtocol", "SlotScheduler"]
