"""Continuous-batching base-calling engine: long reads in, consensus out.

The LM engine's slot scheduler, reused for signals: a request is one
arbitrarily long raw-signal read, chunked into overlapping windows at
admission (``pipeline.chunking``).  Each engine step assembles one
(B, window, C) batch from every occupied lane's next window, runs the
pipeline's jitted quantized-DNN + CTC-decode stage ONCE for the whole
pool, and appends each lane's decoded window read.  A read whose windows
are exhausted retires immediately — its consensus is voted from the
accumulated window reads and the slot admits the next queued read, so
short reads never wait for long ones (iteration-level scheduling, same
policy as serve/engine.py).

The engine is a pure step-executor implementing ``serve.api.
EngineProtocol``; the request lifecycle (queueing, backpressure,
deadlines, cancellation, per-window streaming, the driver loop) lives in
``serve.api.Server``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import sharding as shd
from repro.pipeline import chunking
from repro.pipeline.pipeline import BasecallPipeline, BasecallResult
from repro.serve.scheduler import SlotScheduler


@dataclasses.dataclass
class ReadRequest:
    rid: int
    signal: np.ndarray                   # (T,) or (T, C) raw samples
    windows: Optional[np.ndarray] = None  # (N, window, C), set at admission
    frame_lengths: Optional[np.ndarray] = None  # (N,) decoder logit_lengths
    cursor: int = 0
    reads: List[np.ndarray] = dataclasses.field(default_factory=list)
    lengths: List[int] = dataclasses.field(default_factory=list)
    result: Optional[BasecallResult] = None

    @property
    def done(self) -> bool:
        return self.result is not None


class _WindowView:
    """Constant-time sequence view over one request's decoded windows."""
    __slots__ = ("_req",)

    def __init__(self, req: ReadRequest):
        self._req = req

    def __len__(self) -> int:
        return len(self._req.reads)

    def __getitem__(self, i: int) -> Tuple[np.ndarray, int]:
        return (self._req.reads[i], self._req.lengths[i])


class BasecallEngine:
    """Continuous-batching step-executor for long signal reads.

    Args:
        pipeline: the :class:`BasecallPipeline` whose jitted decode stage
            (and serving artifact) every step consumes.
        params: optional checkpoint override (defaults to the pipeline's).
        batch_slots: device lanes **per dp device**.  Under an ambient
            ``dist.sharding.use_mesh`` mesh at construction the pool is
            ``batch_slots * dp_size`` lanes and each step's window batch
            is split over the mesh's data-parallel devices; without a
            mesh this is the total lane count (dp = 1).
        model_id: optional hosted-model name.  When set, requests naming a
            DIFFERENT ``model=`` resolve with a clear ``"error"`` result
            at submit instead of silently running the wrong weights, and
            the Server's per-model metrics key on it.  (Multi-model
            hosting lives in ``serve.multitenant.MultiModelBasecallEngine``;
            this keeps single-model fleets honestly routable.)

    Example::

        eng = BasecallEngine(pipe, batch_slots=8)
        srv = Server(eng)
        res = srv.submit(BasecallRequest(signal=sig)).result()
    """

    def __init__(self, pipeline: BasecallPipeline, params=None,
                 batch_slots: int = 8, model_id: Optional[str] = None):
        self.pipe = pipeline
        self.model_id = model_id
        if params is None and pipeline.params is None:
            raise ValueError("BasecallEngine needs initialized params")
        # slot capacity scales with the ambient mesh: batch_slots lanes
        # per dp device, one (B, window, C) batch split over all of them
        self.mesh = shd.get_mesh()
        self.dp = shd.dp_size(self.mesh)
        self.B = batch_slots * self.dp
        # the engine holds the quantize-once serving artifact, not float
        # weights: every step consumes the same PackedParams the pipeline
        # serves, which is what keeps engine ≡ pipeline bit for bit
        self.params = pipeline.serving_params(params)
        if self.mesh is not None:
            self.params = pipeline._place_params(self.params, self.mesh)
        self.sched: SlotScheduler[ReadRequest] = SlotScheduler(self.B)
        ck = pipeline.chunk
        self._zero = np.zeros((ck.window, pipeline.mcfg.in_channels),
                              np.float32)
        self.steps = 0

    @classmethod
    def from_registry(cls, registry, model_id: str,
                      **kw) -> "BasecallEngine":
        """A single-model engine serving a ``ModelRegistry`` tenant: the
        registry's cached packed artifact (quantize-once, re-packed
        bitwise-identically after eviction) plus its pipeline, with
        ``model_id`` routing installed."""
        pipe = registry.pipeline(model_id)
        return cls(pipe, params=registry.artifact(model_id),
                   model_id=model_id, **kw)

    def _mesh_ctx(self):
        """The construction-time mesh, re-installed around device calls so
        the jitted decode traces with its sharding constraints no matter
        what mesh (if any) is ambient when the server drives us
        (``use_mesh(None)`` masks an ambient mesh for a no-mesh engine)."""
        return shd.use_mesh(self.mesh)

    # -- EngineProtocol request adapters -----------------------------------
    event_kind = "window"

    def model_of(self, r) -> Optional[str]:
        """The model id serving ``r`` (its ``model=``, or this engine's)."""
        return getattr(r, "model", None) or self.model_id

    def validate(self, r) -> Optional[str]:
        """Requests routed to a model this engine does not host get a
        clear ``"error"`` result at submit."""
        m = getattr(r, "model", None)
        if m is not None and m != self.model_id:
            hosts = (f"[{self.model_id!r}]" if self.model_id is not None
                     else "one anonymous model (no model= routing)")
            return f"unknown model {m!r}: this server hosts {hosts}"
        return None

    def make_request(self, rid: int, r) -> ReadRequest:
        return ReadRequest(rid=rid, signal=np.asarray(r.signal))

    def degenerate(self, r) -> bool:
        """A zero-length signal chunks to zero windows: nothing to decode
        (misrouted models are never degenerate: ``validate`` errors them)."""
        if self.validate(r) is not None:
            return False
        return np.asarray(r.signal).shape[0] == 0

    def empty_result(self, r) -> BasecallResult:
        return BasecallResult.empty(self.pipe.max_read_len)

    def progress(self, native: ReadRequest) -> "_WindowView":
        # a lazy (read, length) view — the server polls progress() every
        # step, so materializing the zipped list each time would be
        # O(windows²) per read
        return _WindowView(native)

    def result_of(self, native: ReadRequest) -> BasecallResult:
        assert native.result is not None
        return native.result

    # -- admission ---------------------------------------------------------
    def submit(self, req: ReadRequest):
        self.sched.submit(req)

    def _admit_one(self, slot: int, req: ReadRequest):
        req.windows = chunking.chunk_signal(req.signal, self.pipe.chunk)
        req.frame_lengths = self.pipe.window_logit_lengths(
            np.asarray(req.signal).shape[0])
        req.cursor = 0

    def admit(self) -> List[int]:
        admitted = self.sched.admit(self._admit_one)
        # an empty signal chunks to zero windows: retire it immediately
        # with an empty read instead of feeding step() an empty lane
        for slot in admitted:
            req = self.sched.slots[slot]
            if req is not None and req.windows.shape[0] == 0:
                self._finalize(req)
                self.sched.retire(slot, req.rid)
        return admitted

    # -- stepping ----------------------------------------------------------
    def active_mask(self) -> np.ndarray:
        return self.sched.active_mask()

    def step(self):
        """Decode one window for every occupied lane in a single batch."""
        batch = np.stack([
            r.windows[r.cursor] if r is not None else self._zero
            for r in self.sched.slots])
        frames = np.asarray([
            r.frame_lengths[r.cursor] if r is not None else 0
            for r in self.sched.slots], np.int32)
        batch, frames = jnp.asarray(batch), jnp.asarray(frames)
        if self.mesh is not None:
            # B = batch_slots * dp by construction, so dim 0 always divides
            batch = jax.device_put(
                batch, shd.batch_sharding(self.mesh, batch.ndim))
            frames = jax.device_put(
                frames, shd.batch_sharding(self.mesh, frames.ndim))
        with self._mesh_ctx():
            reads, lens, _scores = self.pipe._decode_windows(self.params,
                                                             batch, frames)
        reads, lens = np.asarray(reads), np.asarray(lens)
        self.steps += 1
        for slot, req in enumerate(self.sched.slots):
            if req is None:
                continue
            req.reads.append(reads[slot])
            req.lengths.append(int(lens[slot]))
            req.cursor += 1
            if req.cursor >= req.windows.shape[0]:
                self._finalize(req)
                self.sched.retire(slot, req.rid)

    def _finalize(self, req: ReadRequest):
        if not req.reads:                      # zero-window (empty) signal
            req.result = BasecallResult.empty(self.pipe.max_read_len)
            return
        # the pipeline's own finalization — engine ≡ pipeline by sharing it
        req.result = BasecallResult.from_window_reads(
            np.stack(req.reads), np.asarray(req.lengths, np.int32),
            max_read_len=self.pipe.max_read_len)
