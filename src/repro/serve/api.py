"""Transport-agnostic serving front-end: the request lifecycle over both
engines.

The paper's headline number is end-to-end base-calling *throughput*, so
serving needs a real request lifecycle — submit -> queue -> stream ->
retire — not a per-call driver loop.  This module owns that lifecycle:

    eng = BasecallEngine(pipe, batch_slots=8)          # pure step-executor
    srv = Server(eng, max_queue=64, backpressure="block")
    fut = srv.submit(BasecallRequest(signal=sig))      # -> ServeFuture
    res = fut.result()                                 # drives the loop
    for ev in srv.stream(BasecallRequest(signal=sig)): # per-window events
        ...
    srv.metrics()        # requests/s, occupancy, queue depth, p50/p99

``Server`` wraps any ``EngineProtocol`` implementation
(``serve.engine.ServingEngine`` for token LMs, ``serve.basecall_engine.
BasecallEngine`` for signal reads) as a pure step-executor: the engines
own what one unit of work means (a decoded token, a signal window); the
server owns admission (bounded queue + explicit backpressure policy),
priorities, deadlines, cancellation, event fan-out, and metrics.

The server is a cooperative single-thread event loop: ``step()`` advances
the engine one scheduler tick, and ``ServeFuture.result()`` / ``stream()``
drive ``step()`` until their request completes.  A transport (HTTP,
asyncio, RPC) pumps ``step()`` from its own executor — nothing here
depends on threads, which is what makes the front-end transport-agnostic.

Backpressure policies when the admission queue is full at ``submit()``:

    reject      raise ``QueueFull`` (caller sheds load)
    block       drive engine steps until a queue slot frees (cooperative)
    shed-oldest drop the oldest queued request (its future resolves with
                status "shed") and admit the newcomer
"""
from __future__ import annotations

import dataclasses
import time
from typing import (Any, Callable, Dict, Iterator, List, Optional, Protocol,
                    Sequence, runtime_checkable)

import numpy as np

from repro.serve.scheduler import SlotScheduler

BACKPRESSURE_POLICIES = ("reject", "block", "shed-oldest")

#: terminal request statuses
STATUS_OK = "ok"
STATUS_CANCELLED = "cancelled"
STATUS_EXPIRED = "expired"
STATUS_SHED = "shed"
STATUS_ERROR = "error"
STATUS_EJECTED = "ejected"


class QueueFull(RuntimeError):
    """Admission queue at capacity under the ``reject`` policy."""


# ---------------------------------------------------------------------------
# requests / results / events
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BasecallRequest:
    """One raw-signal read to base-call (served by ``BasecallEngine`` or,
    with ``model=``, a hosted tenant of ``MultiModelBasecallEngine``)."""
    signal: np.ndarray                 # (T,) or (T, C) raw samples
    priority: int = 0                  # higher admits first
    deadline: Optional[float] = None   # seconds after submit (server clock)
    #: hosted-model routing: which of the server's packed artifacts serves
    #: this read (None -> the engine's default).  A model the engine does
    #: not host resolves with a clear ``"error"`` result at submit.
    model: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class LMRequest:
    """One token-LM generation (served by ``ServingEngine``)."""
    prompt: np.ndarray                 # (P,) int token ids
    max_tokens: int = 32
    eos_id: Optional[int] = None
    priority: int = 0
    deadline: Optional[float] = None
    #: hosted-model routing, as on :class:`BasecallRequest`
    model: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class ServeEvent:
    """One incremental output: a decoded token / a decoded signal window.

    ``kind`` is the engine's ``event_kind`` ("token" | "window") or
    "final"; ``index`` counts events of that kind per request."""
    rid: int
    kind: str
    index: int
    payload: Any


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """Terminal state of one request.

    ``value`` is engine-shaped: a ``pipeline.BasecallResult`` for signal
    reads, the generated token list for LM requests — and None when the
    request did not complete (cancelled / expired / shed / error).  An
    ``"error"`` status carries the rejection reason in ``error`` (e.g. a
    request whose prompt + max_tokens exceeds the engine's KV capacity —
    resolved at submit, before it could wedge a lane)."""
    rid: int
    status: str
    value: Any
    submitted_at: float
    finished_at: float
    n_events: int = 0
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.status == STATUS_OK

    @property
    def latency(self) -> float:
        return self.finished_at - self.submitted_at


@dataclasses.dataclass(frozen=True)
class ModelMetrics:
    """Per hosted-model slice of one ``Server.metrics()`` snapshot
    (multi-tenant serving: one row set per model id, so a cold tenant or
    an error-prone client shows up per model, not diluted pool-wide)."""
    submitted: int = 0
    completed: int = 0
    errors: int = 0
    ejected: int = 0
    #: time-averaged occupancy of THIS model's slot group (engines
    #: exposing ``model_occupancy``; 0.0 for single-group engines)
    occupancy: float = 0.0
    requests_per_s: float = 0.0
    latency_p50_s: float = 0.0
    latency_p99_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class ServerMetrics:
    """One ``Server.metrics()`` snapshot — the serving counterpart of the
    fig9 latency breakdown (requests/s + occupancy + queue + tails)."""
    steps: int
    submitted: int
    completed: int
    cancelled: int
    expired: int
    shed: int
    rejected: int
    queue_depth: int
    active: int
    occupancy: float            # time-averaged over engine steps
    elapsed_s: float
    requests_per_s: float
    latency_p50_s: float
    latency_p99_s: float
    #: dp device count behind the engine (1 without a mesh) and the
    #: time-averaged occupancy of each device's lane group — the scale-out
    #: utilization axis: one cold device shows up here, not diluted into
    #: the pool-wide mean
    devices: int = 1
    occupancy_per_device: tuple = (0.0,)
    #: requests rejected at submit by engine validation (e.g. prompt +
    #: max_tokens over the KV capacity) — resolved with status "error"
    errors: int = 0
    #: streaming lanes abandoned early by their eject policy (ReadUntil)
    #: — resolved with status "ejected" and the provisional read
    ejected: int = 0
    #: submit -> FIRST incremental event latency tails — the streaming
    #: responsiveness axis (how quickly a pore sees provisional bases),
    #: distinct from the full-request latency percentiles above
    ttfe_p50_s: float = 0.0
    ttfe_p99_s: float = 0.0
    #: per hosted-model metric slices, keyed by model id (empty for
    #: engines/requests without ``model=`` routing)
    per_model: Dict[str, ModelMetrics] = dataclasses.field(
        default_factory=dict)

    def rows(self, prefix: str = "serve") -> List[tuple]:
        """``benchmarks._util.emit``-shaped CSV rows (pool-wide rows, then
        one row set per hosted model id)."""
        per_dev = " ".join(f"{o:.3f}" for o in self.occupancy_per_device)
        out = [
            (f"{prefix}/requests_per_s", f"{self.requests_per_s:.2f}",
             f"{self.completed} completed in {self.elapsed_s:.2f}s"),
            (f"{prefix}/occupancy", f"{self.occupancy:.3f}",
             f"{self.steps} engine steps; per-device [{per_dev}] "
             f"over {self.devices} dp device(s)"),
            (f"{prefix}/queue_depth", str(self.queue_depth),
             f"shed={self.shed} rejected={self.rejected} "
             f"expired={self.expired}"),
            (f"{prefix}/latency_p50_s", f"{self.latency_p50_s:.4f}", ""),
            (f"{prefix}/latency_p99_s", f"{self.latency_p99_s:.4f}", ""),
            (f"{prefix}/ttfe_p50_s", f"{self.ttfe_p50_s:.4f}",
             f"ejected={self.ejected}"),
            (f"{prefix}/ttfe_p99_s", f"{self.ttfe_p99_s:.4f}", ""),
        ]
        for mid in sorted(self.per_model):
            m = self.per_model[mid]
            p = f"{prefix}/model/{mid}"
            out += [
                (f"{p}/requests_per_s", f"{m.requests_per_s:.2f}",
                 f"{m.completed} completed of {m.submitted} submitted"),
                (f"{p}/occupancy", f"{m.occupancy:.3f}", ""),
                (f"{p}/latency_p50_s", f"{m.latency_p50_s:.4f}", ""),
                (f"{p}/latency_p99_s", f"{m.latency_p99_s:.4f}", ""),
                (f"{p}/errors", str(m.errors), f"ejected={m.ejected}"),
            ]
        return out


# ---------------------------------------------------------------------------
# the engine contract
# ---------------------------------------------------------------------------

@runtime_checkable
class EngineProtocol(Protocol):
    """What ``Server`` needs from an engine: a pure step-executor.

    Engines own slot bookkeeping via one ``SlotScheduler`` and define one
    unit of work (``step``); the server owns the request lifecycle.  The
    driver loop the engines used to hand-roll (``run()``) lives in
    ``Server`` now — engines must not grow one back.

    Optional extensions (duck-typed via ``getattr``, not required by the
    protocol):

    * ``validate(request) -> Optional[str]`` — a non-None return is an
      error message and the server resolves the request with status
      ``"error"`` at submit instead of queueing it (``ServingEngine``
      uses this to reject requests that would overflow its KV cache).
    * ``final_status(native) -> str`` — the terminal status for a
      retired request (default ``"ok"``; ``StreamingBasecallEngine``
      returns ``"ejected"`` for lanes its eject policy abandoned).
    * ``model_of(request) -> Optional[str]`` — the hosted-model id
      serving a request (its ``model=`` resolved against the engine's
      default); the server keys per-model ``metrics()`` slices on it.
    * ``model_occupancy() -> Dict[str, float]`` — instantaneous per-model
      slot-group occupancy, accumulated into per-model metrics
      (``MultiModelBasecallEngine``).
    * ``device_occupancy() -> np.ndarray`` — instantaneous (dp,)
      per-device occupancy for engines whose lane -> device layout is not
      one contiguous pool-wide fold (multi-tenant groups are each
      lane-major over dp on their own).
    """
    sched: SlotScheduler
    steps: int
    event_kind: str

    def make_request(self, rid: int, request: Any) -> Any:
        """API request -> the engine-native slot record."""

    def degenerate(self, request: Any) -> bool:
        """True when the request is valid but empty (zero-length signal,
        ``max_tokens <= 0`` / empty prompt): completes at admission with
        ``empty_result`` instead of occupying a slot."""

    def empty_result(self, request: Any) -> Any:
        """The ``ServeResult.value`` for a degenerate request."""

    def admit(self) -> List[int]:
        """Fill free slots from ``sched.queue``; returns admitted slots."""

    def step(self) -> None:
        """Advance every occupied lane one unit of work; retire finished
        requests into ``sched.finished``."""

    def progress(self, native: Any) -> Sequence:
        """Monotone per-request outputs so far (tokens / window reads);
        the server turns new entries into ``ServeEvent``s."""

    def result_of(self, native: Any) -> Any:
        """Final payload of a retired native request."""


# ---------------------------------------------------------------------------
# futures
# ---------------------------------------------------------------------------

class ServeFuture:
    """Handle to one submitted request.

    ``result()`` cooperatively drives the server loop until this request
    reaches a terminal state — the single-thread analogue of awaiting."""

    def __init__(self, server: "Server", rid: int):
        self._server = server
        self.rid = rid

    def done(self) -> bool:
        """True once this request reached a terminal state."""
        rec = self._server._records.get(self.rid)
        # a missing record means the request reached a terminal state and
        # its record aged out of retain_results — done, result unreadable
        return rec is None or rec.result is not None

    def result(self, max_steps: int = 1_000_000) -> ServeResult:
        """Drive the server loop until this request is terminal, then
        return its ``ServeResult`` (raises ``TimeoutError`` past the
        ``max_steps`` budget)."""
        rec = self._server._record(self.rid)
        while rec.result is None and max_steps > 0:
            self._server.step()
            max_steps -= 1
        if rec.result is None:
            raise TimeoutError(f"request {self.rid} not done "
                               f"within the step budget")
        return rec.result

    def cancel(self) -> bool:
        """Cancel this request (queued or in-flight); False once terminal."""
        return self._server.cancel(self.rid)

    def events(self) -> List[ServeEvent]:
        """Events observed so far (grows as the server steps)."""
        return list(self._server._record(self.rid).events)


@dataclasses.dataclass
class _Record:
    rid: int
    request: Any
    native: Any                       # engine-native request (None if degen)
    priority: int
    submitted_at: float
    expires_at: Optional[float]
    model: Optional[str] = None       # per-model metrics key (or None)
    events: List[ServeEvent] = dataclasses.field(default_factory=list)
    emitted: int = 0
    result: Optional[ServeResult] = None


# ---------------------------------------------------------------------------
# the server
# ---------------------------------------------------------------------------

class Server:
    """Request lifecycle over one engine: bounded admission queue,
    priority ordering, deadlines, cancellation, streaming, metrics."""

    def __init__(self, engine: EngineProtocol, *, max_queue: int = 64,
                 backpressure: str = "reject",
                 retain_results: int = 4096,
                 clock: Callable[[], float] = time.monotonic):
        if backpressure not in BACKPRESSURE_POLICIES:
            raise ValueError(f"unknown backpressure {backpressure!r}; "
                             f"one of {BACKPRESSURE_POLICIES}")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if retain_results < 1:
            raise ValueError(
                f"retain_results must be >= 1, got {retain_results}")
        self.engine = engine
        self.max_queue = max_queue
        self.backpressure = backpressure
        # terminal records are kept for late future.result()/events()
        # reads, but only the most recent `retain_results` of them — a
        # long-running server must not grow memory with requests served
        self.retain_results = retain_results
        self.clock = clock
        self.results: Dict[int, ServeResult] = {}
        self._records: Dict[int, _Record] = {}
        self._live: Dict[int, _Record] = {}      # not yet terminal
        self._terminal_order: List[int] = []     # FIFO for eviction
        self._next_rid = 0
        self._latencies: List[float] = []
        self._occ_sum = 0.0
        # per-dp-device occupancy accumulator (lazily sized from the
        # engine's dp attribute; engines without one count as 1 device)
        self._occ_dev_sum: Optional[np.ndarray] = None
        self._counts = {STATUS_OK: 0, STATUS_CANCELLED: 0,
                        STATUS_EXPIRED: 0, STATUS_SHED: 0, STATUS_ERROR: 0,
                        STATUS_EJECTED: 0, "rejected": 0, "submitted": 0}
        # per hosted-model metric state, keyed by the engine's model_of()
        # (requests without model routing never create a slice)
        self._per_model: Dict[str, dict] = {}
        self._ttfe: List[float] = []             # submit -> first event
        self._started_at: Optional[float] = None

    def _model_id_of(self, request: Any) -> Optional[str]:
        fn = getattr(self.engine, "model_of", None)
        if fn is not None:
            return fn(request)
        return getattr(request, "model", None)

    def _mstats(self, mid: str) -> dict:
        ms = self._per_model.get(mid)
        if ms is None:
            ms = dict(self._counts, latencies=[], occ_sum=0.0)
            for k in ms:
                if k not in ("latencies", "occ_sum"):
                    ms[k] = 0
            self._per_model[mid] = ms
        return ms

    # -- submission ---------------------------------------------------------

    def submit(self, request: Any) -> ServeFuture:
        """Enqueue one request; returns immediately with a future.

        Degenerate requests (``engine.degenerate``) resolve here with an
        empty ok result — they never occupy a queue entry or a slot.
        Requests the engine's (optional) ``validate`` hook rejects — e.g.
        ``prompt + max_tokens`` over the KV capacity, which would wedge a
        lane — resolve here with status ``"error"`` and the reason in
        ``ServeResult.error``.  A full queue applies the backpressure
        policy (see module doc).

        Args:
            request: a :class:`BasecallRequest` / :class:`LMRequest` (or
                anything the engine's ``make_request`` understands), with
                optional ``priority`` and ``deadline`` attributes.

        Returns:
            A :class:`ServeFuture`; ``future.result()`` cooperatively
            drives the loop until this request is terminal.

        Raises:
            QueueFull: queue at capacity under the ``reject`` policy (or
                ``shed-oldest`` with nothing of ours to shed).

        Example::

            fut = srv.submit(BasecallRequest(signal=sig, priority=1))
            res = fut.result()          # ServeResult; res.value stitched
        """
        now = self.clock()
        if self._started_at is None:
            self._started_at = now
        rid = self._next_rid
        self._next_rid += 1
        self._counts["submitted"] += 1
        prio = getattr(request, "priority", 0)
        ddl = getattr(request, "deadline", None)
        mid = self._model_id_of(request)
        rec = _Record(rid=rid, request=request, native=None, priority=prio,
                      submitted_at=now, model=mid,
                      expires_at=None if ddl is None else now + ddl)
        if mid is not None:
            self._mstats(mid)["submitted"] += 1
        self._records[rid] = rec
        if self.engine.degenerate(request):
            self._resolve(rec, STATUS_OK, self.engine.empty_result(request))
            return ServeFuture(self, rid)
        # engines may veto requests their cache cannot serve (duck-typed:
        # ``validate`` is an optional EngineProtocol extension) — resolve
        # with a clear error result instead of wedging a lane later
        err = getattr(self.engine, "validate", lambda r: None)(request)
        if err is not None:
            # counted ONCE, as an error: validation rejections (unknown
            # model, over-capacity request) resolve before the queue is
            # consulted, so they can never also count as a backpressure
            # rejection — pool-wide and per-model alike
            self._resolve(rec, STATUS_ERROR, None, error=err)
            return ServeFuture(self, rid)

        queue = self.engine.sched.queue
        while len(queue) >= self.max_queue:
            if self.backpressure == "reject":
                self._counts["rejected"] += 1
                del self._records[rid]
                raise QueueFull(
                    f"admission queue at capacity ({self.max_queue}); "
                    f"policy=reject")
            if self.backpressure == "block":
                self.step()
                continue
            # shed-oldest: drop the longest-queued entry WE own to make
            # room (entries submitted straight to the engine are not ours
            # to shed; with none of our own queued, behave like reject)
            owned = [r for q in queue
                     if (r := self._owner_of(q)) is not None]
            if not owned:
                self._counts["rejected"] += 1
                del self._records[rid]
                raise QueueFull(
                    "admission queue full of requests not owned by this "
                    "server; cannot shed")
            oldest = min(owned, key=lambda r: r.submitted_at)
            self.engine.sched.cancel_queued(oldest.native)
            self._resolve(oldest, STATUS_SHED, None)

        rec.native = self.engine.make_request(rid, request)
        self._live[rid] = rec
        # priority insertion: higher priority first, FIFO within a class
        # (entries we don't own rank as priority 0)
        pos = len(queue)
        while pos > 0 and prio > self._priority_of(queue[pos - 1]):
            pos -= 1
        queue.insert(pos, rec.native)
        return ServeFuture(self, rid)

    def _owner_of(self, native: Any) -> Optional[_Record]:
        """This server's live record for a queued native, or None when the
        entry was submitted straight to the engine (a colliding rid does
        not fool the identity check)."""
        rec = self._live.get(getattr(native, "rid", None))
        return rec if rec is not None and rec.native is native else None

    def _priority_of(self, native: Any) -> int:
        rec = self._owner_of(native)
        return rec.priority if rec is not None else 0

    def stream(self, request: Any,
               max_steps: int = 1_000_000) -> Iterator[ServeEvent]:
        """Submit and yield incremental events as the request decodes.

        Args:
            request: as for :meth:`submit`.
            max_steps: server-step budget before ``TimeoutError``.

        Returns:
            An iterator of :class:`ServeEvent` — one per decoded token /
            signal window, ending with a ``"final"`` event whose payload
            is the :class:`ServeResult`.

        Example::

            for ev in srv.stream(BasecallRequest(signal=sig)):
                print(ev.kind, ev.index)
        """
        fut = self.submit(request)
        rec = self._record(fut.rid)
        seen = 0
        while True:
            while seen < len(rec.events):
                yield rec.events[seen]
                seen += 1
            if rec.result is not None:
                return
            if max_steps <= 0:
                raise TimeoutError(f"request {fut.rid} not done "
                                   f"within the step budget")
            self.step()
            max_steps -= 1

    def cancel(self, rid: int) -> bool:
        """Cancel a queued or in-flight request.  False once terminal."""
        rec = self._records.get(rid)
        if rec is None or rec.result is not None:
            return False
        if self.engine.sched.cancel_queued(rec.native):
            self._resolve(rec, STATUS_CANCELLED, None)
            return True
        slot = self.engine.sched.slot_of(rec.native)
        if slot is not None:
            self.engine.sched.release(slot)
            self._resolve(rec, STATUS_CANCELLED, None)
            return True
        return False

    # -- the loop -----------------------------------------------------------

    def pending(self) -> bool:
        """True while any submitted request is not yet terminal."""
        return bool(self._live)

    def step(self) -> None:
        """One scheduler tick: expire -> admit -> engine step -> deliver."""
        self._expire()
        self.engine.admit()
        sched = self.engine.sched
        if sched.any_active():
            # occupancy is averaged over ENGINE steps (device launches),
            # not idle server ticks — it answers "how full were the lanes
            # we actually paid for", the paper's utilization axis
            self._occ_sum += sched.occupancy()
            dp = getattr(self.engine, "dp", 1)
            if self._occ_dev_sum is None or len(self._occ_dev_sum) != dp:
                self._occ_dev_sum = np.zeros((dp,))
            # engines whose lane -> device layout is not one pool-wide
            # contiguous fold (multi-tenant slot groups) expose their own
            # per-device view; everyone else folds the pool over dp
            dev_fn = getattr(self.engine, "device_occupancy", None)
            self._occ_dev_sum += (dev_fn() if dev_fn is not None
                                  else sched.group_occupancy(dp))
            mo_fn = getattr(self.engine, "model_occupancy", None)
            if mo_fn is not None:
                for mid, occ in mo_fn().items():
                    self._mstats(mid)["occ_sum"] += occ
            self.engine.step()
        self._pump_events()
        for rid, native in sched.drain_finished().items():
            rec = self._records.get(rid)
            if rec is None or rec.native is not native:
                # not ours: submitted straight to the engine (possibly
                # with a colliding rid — identity disambiguates)
                continue
            if rec.result is not None:
                continue                        # already terminal
            # engines may retire a request in a non-ok terminal state
            # (duck-typed ``final_status``, e.g. a streaming lane the
            # eject policy abandoned resolves as "ejected" — with the
            # provisional read as its value)
            status = getattr(self.engine, "final_status",
                             lambda n: STATUS_OK)(native)
            self._resolve(rec, status, self.engine.result_of(native))

    def run_until_idle(self, max_steps: int = 1_000_000
                       ) -> Dict[int, ServeResult]:
        """Drive until every submitted request is terminal; returns all
        results delivered so far (rid -> ServeResult)."""
        while self.pending() and max_steps > 0:
            self.step()
            max_steps -= 1
        if self.pending():
            raise TimeoutError("requests still pending after step budget")
        return dict(self.results)

    # -- internals ----------------------------------------------------------

    def _record(self, rid: int) -> _Record:
        rec = self._records.get(rid)
        if rec is None:
            raise KeyError(
                f"unknown request id {rid} (never submitted, or its "
                f"terminal record aged out of retain_results="
                f"{self.retain_results})")
        return rec

    def _expire(self) -> None:
        now = self.clock()
        for rec in [r for r in self._live.values()
                    if r.expires_at is not None and now >= r.expires_at]:
            if not self.engine.sched.cancel_queued(rec.native):
                slot = self.engine.sched.slot_of(rec.native)
                if slot is None:
                    continue                     # retiring this very step
                self.engine.sched.release(slot)
            self._resolve(rec, STATUS_EXPIRED, None)

    def _pump_events(self) -> None:
        kind = self.engine.event_kind
        now = self.clock()
        for rec in list(self._live.values()):
            if rec.native is None:
                continue
            out = self.engine.progress(rec.native)
            if rec.emitted == 0 and len(out) > 0:
                # time-to-first-event: the streaming responsiveness tail
                # (submit -> first provisional output, not the final)
                self._ttfe.append(now - rec.submitted_at)
            while rec.emitted < len(out):
                rec.events.append(ServeEvent(rid=rec.rid, kind=kind,
                                             index=rec.emitted,
                                             payload=out[rec.emitted]))
                rec.emitted += 1

    def _resolve(self, rec: _Record, status: str, value: Any,
                 error: Optional[str] = None) -> None:
        assert rec.result is None, f"request {rec.rid} resolved twice"
        res = ServeResult(rid=rec.rid, status=status, value=value,
                          submitted_at=rec.submitted_at,
                          finished_at=self.clock(), n_events=rec.emitted,
                          error=error)
        rec.result = res
        rec.events.append(ServeEvent(rid=rec.rid, kind="final",
                                     index=rec.emitted, payload=res))
        self.results[rec.rid] = res
        self._live.pop(rec.rid, None)
        self._counts[status] += 1
        if status == STATUS_OK:
            self._latencies.append(res.latency)
        if rec.model is not None:
            ms = self._mstats(rec.model)
            ms[status] += 1
            if status == STATUS_OK:
                ms["latencies"].append(res.latency)
        # bound terminal-record retention: a server that lives for
        # millions of requests must not pin every signal/result forever
        self._terminal_order.append(rec.rid)
        while len(self._terminal_order) > self.retain_results:
            old = self._terminal_order.pop(0)
            self._records.pop(old, None)
            self.results.pop(old, None)

    # -- observability ------------------------------------------------------

    def reset_metrics(self) -> None:
        """Zero the observability state (benchmarks call this after their
        warmup request so compile time stays out of the tails): delivered
        results, latencies, occupancy/step accounting, counters — and
        every per-model slice, in the same call, so pool-wide and
        per-model counters can never disagree about the epoch.
        In-flight requests are unaffected and still deliver."""
        for rid in self._terminal_order:
            self._records.pop(rid, None)
        self._terminal_order.clear()
        self.results.clear()
        self._latencies.clear()
        self._ttfe.clear()
        self._occ_sum = 0.0
        self._occ_dev_sum = None
        self.engine.steps = 0
        for k in self._counts:
            self._counts[k] = 0
        self._per_model.clear()
        self._started_at = None

    def metrics(self) -> ServerMetrics:
        """Snapshot the serving observability state.

        Returns:
            A :class:`ServerMetrics` with requests/s, time-averaged slot
            occupancy (pool-wide and per dp device), queue depth,
            shed/rejected/expired counters, and p50/p99 latency.  Under a
            sharded engine ``devices`` is the mesh's dp size and
            ``occupancy_per_device`` has one entry per device's lane
            group.

        Example::

            m = srv.metrics()
            print(m.requests_per_s, m.occupancy_per_device)
        """
        steps = self.engine.steps
        now = self.clock()
        elapsed = (now - self._started_at
                   if self._started_at is not None else 0.0)
        lat = np.asarray(self._latencies) if self._latencies else None
        dp = getattr(self.engine, "dp", 1)
        if self._occ_dev_sum is not None and steps:
            occ_dev = tuple(float(o) for o in self._occ_dev_sum / steps)
        else:
            occ_dev = (0.0,) * dp
        per_model = {}
        for mid, ms in self._per_model.items():
            mlat = np.asarray(ms["latencies"]) if ms["latencies"] else None
            per_model[mid] = ModelMetrics(
                submitted=ms["submitted"],
                completed=ms[STATUS_OK],
                errors=ms[STATUS_ERROR],
                ejected=ms[STATUS_EJECTED],
                occupancy=ms["occ_sum"] / steps if steps else 0.0,
                requests_per_s=(ms[STATUS_OK] / elapsed
                                if elapsed > 0 else 0.0),
                latency_p50_s=(float(np.percentile(mlat, 50))
                               if mlat is not None else 0.0),
                latency_p99_s=(float(np.percentile(mlat, 99))
                               if mlat is not None else 0.0))
        return ServerMetrics(
            steps=steps,
            submitted=self._counts["submitted"],
            completed=self._counts[STATUS_OK],
            cancelled=self._counts[STATUS_CANCELLED],
            expired=self._counts[STATUS_EXPIRED],
            shed=self._counts[STATUS_SHED],
            rejected=self._counts["rejected"],
            errors=self._counts[STATUS_ERROR],
            queue_depth=len(self.engine.sched.queue),
            active=int(self.engine.sched.active_mask().sum()),
            occupancy=self._occ_sum / steps if steps else 0.0,
            elapsed_s=elapsed,
            requests_per_s=(self._counts[STATUS_OK] / elapsed
                            if elapsed > 0 else 0.0),
            latency_p50_s=float(np.percentile(lat, 50)) if lat is not None
            else 0.0,
            latency_p99_s=float(np.percentile(lat, 99)) if lat is not None
            else 0.0,
            devices=dp,
            occupancy_per_device=occ_dev,
            ejected=self._counts[STATUS_EJECTED],
            ttfe_p50_s=(float(np.percentile(self._ttfe, 50))
                        if self._ttfe else 0.0),
            ttfe_p99_s=(float(np.percentile(self._ttfe, 99))
                        if self._ttfe else 0.0),
            per_model=per_model,
        )


__all__ = ["BasecallRequest", "LMRequest", "ServeEvent", "ServeResult",
           "ServeFuture", "ServerMetrics", "ModelMetrics", "Server",
           "EngineProtocol", "QueueFull", "BACKPRESSURE_POLICIES"]
