"""Slot-based continuous-batching core, shared by every serving engine.

Iteration-level (Orca-style) scheduling over a fixed pool of B device
lanes: requests are admitted into free slots, every engine step advances
all occupied lanes by one unit of work (a decoded token, a signal window),
and finished requests retire immediately so their slot is reusable — the
batch never drains to refill.

This module owns only the BOOKKEEPING (queue, slot table, retirement);
what a "step of work" means belongs to the engine built on top:
``serve.engine.ServingEngine`` (LM tokens) and
``serve.basecall_engine.BasecallEngine`` (signal windows) both drive one
``SlotScheduler``.
"""
from __future__ import annotations

from typing import Callable, Dict, Generic, List, Optional, TypeVar

import numpy as np

R = TypeVar("R")


class SlotScheduler(Generic[R]):
    """Queue + slot table + retirement for one engine's lane pool.

    Args:
        n_slots: total device lanes (under a mesh, engines size this as
            slots-per-device x dp device count).
    """

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.slots: List[Optional[R]] = [None] * n_slots
        self.queue: List[R] = []
        self.finished: Dict[int, R] = {}

    # -- admission ---------------------------------------------------------
    def submit(self, req: R) -> None:
        """Append ``req`` to the admission queue (FIFO; the server layers
        priority ordering on top)."""
        self.queue.append(req)

    def admit(self, admit_fn: Callable[[int, R], None]) -> List[int]:
        """Fill free slots from the queue; ``admit_fn(slot, req)`` does the
        engine-specific lane setup.  Returns the slots admitted into."""
        admitted = []
        for slot in range(self.n_slots):
            if self.slots[slot] is None and self.queue:
                req = self.queue.pop(0)
                admit_fn(slot, req)
                self.slots[slot] = req
                admitted.append(slot)
        return admitted

    # -- state -------------------------------------------------------------
    def active_mask(self) -> np.ndarray:
        """(n_slots,) bool — which lanes hold an admitted request."""
        return np.asarray([r is not None for r in self.slots])

    def any_active(self) -> bool:
        """True when at least one lane is occupied."""
        return any(r is not None for r in self.slots)

    def pending(self) -> bool:
        """True while anything is queued or in flight."""
        return bool(self.queue) or self.any_active()

    def occupancy(self) -> float:
        """Fraction of lanes occupied right now (0.0 - 1.0)."""
        return float(self.active_mask().mean())

    def group_occupancy(self, groups: int) -> np.ndarray:
        """(groups,) mean occupancy per contiguous lane group.

        Engines batch lane-major and shard dim 0 over dp devices, so lanes
        ``[d*B/groups, (d+1)*B/groups)`` live on device ``d`` — this is the
        per-device occupancy ``Server.metrics()`` reports under a mesh.
        ``groups`` must divide ``n_slots`` (engines guarantee
        ``B = slots_per_device * dp``).
        """
        return self.active_mask().reshape(groups, -1).mean(axis=1)

    # -- retirement --------------------------------------------------------
    def retire(self, slot: int, rid: int) -> R:
        """Free ``slot`` and move its request to ``finished[rid]``."""
        req = self.slots[slot]
        assert req is not None, f"retiring empty slot {slot}"
        self.finished[rid] = req
        self.slots[slot] = None
        return req

    def drain_finished(self) -> Dict[int, R]:
        """Hand retired requests to the caller and forget them (the server
        polls this every step, so ``finished`` never grows unboundedly)."""
        done, self.finished = self.finished, {}
        return done

    # -- cancellation ------------------------------------------------------
    def release(self, slot: int) -> R:
        """Free ``slot`` WITHOUT retiring (cancel/expiry: the request is
        dropped, not finished).  Both engines' lanes are masked/reassembled
        from host state each step, so an abandoned lane needs no device
        cleanup — the next admission resets it."""
        req = self.slots[slot]
        assert req is not None, f"releasing empty slot {slot}"
        self.slots[slot] = None
        return req

    def cancel_queued(self, req: R) -> bool:
        """Remove a not-yet-admitted request from the queue (by identity)."""
        for i, q in enumerate(self.queue):
            if q is req:
                del self.queue[i]
                return True
        return False

    def slot_of(self, req: R) -> Optional[int]:
        """The slot ``req`` currently occupies, or None (by identity)."""
        for slot, q in enumerate(self.slots):
            if q is req:
                return slot
        return None
