"""Slot-based continuous-batching core, shared by every serving engine.

Iteration-level (Orca-style) scheduling over a fixed pool of B device
lanes: requests are admitted into free slots, every engine step advances
all occupied lanes by one unit of work (a decoded token, a signal window),
and finished requests retire immediately so their slot is reusable — the
batch never drains to refill.

This module owns only the BOOKKEEPING (queue, slot table, retirement);
what a "step of work" means belongs to the engine built on top:
``serve.engine.ServingEngine`` (LM tokens) and
``serve.basecall_engine.BasecallEngine`` (signal windows) both drive one
``SlotScheduler``.
"""
from __future__ import annotations

from typing import (Callable, Dict, Generic, Hashable, List, Mapping,
                    Optional, TypeVar)

import numpy as np

R = TypeVar("R")


class SlotScheduler(Generic[R]):
    """Queue + slot table + retirement for one engine's lane pool.

    Args:
        n_slots: total device lanes (under a mesh, engines size this as
            slots-per-device x dp device count).
        kv_blocks: optional pooled KV-arena size in blocks.  When > 0 the
            scheduler also owns the FREE-BLOCK ALLOCATOR for a paged KV
            cache: blocks are handed out at admission (``admit`` with a
            ``need_fn``), grown one at a time mid-flight
            (:meth:`grow_block`), and reclaimed automatically on
            ``retire``/``release``.  ``kv_blocks == 0`` (the default, and
            what the basecall engine uses) leaves all block machinery
            inert.
        kv_groups: number of contiguous arena partitions (engines pass
            their dp device count).  Slot ``s`` allocates only from
            partition ``s * kv_groups // n_slots`` so that, with the arena
            dim sharded over dp devices, every lane's block-table gather
            stays device-local.  Must divide both ``kv_blocks`` and
            ``n_slots``.
        slot_groups: optional ordered mapping of group id -> lane count,
            carving the pool into contiguous, named SLOT GROUPS (multi-
            tenant engines pass one group per hosted model).  Lane counts
            must sum to ``n_slots``.  Admission never crosses a group
            boundary (see :meth:`admit`'s ``group_fn``), per-group
            occupancy is first-class (:meth:`occupancy` with ``group=``),
            and with a paged arena every group must cover a whole number
            of KV partitions so blocks never cross group boundaries
            either.  ``None`` (the default) keeps the pool a single
            anonymous group and changes nothing for existing engines.
    """

    def __init__(self, n_slots: int, kv_blocks: int = 0, kv_groups: int = 1,
                 slot_groups: Optional[Mapping[Hashable, int]] = None):
        self.n_slots = n_slots
        self.slots: List[Optional[R]] = [None] * n_slots
        self.queue: List[R] = []
        self.finished: Dict[int, R] = {}
        self.kv_blocks = kv_blocks
        self.kv_groups = kv_groups
        self.slot_blocks: List[List[int]] = [[] for _ in range(n_slots)]
        if kv_blocks:
            if kv_blocks % kv_groups or n_slots % kv_groups:
                raise ValueError(
                    f"kv_groups={kv_groups} must divide both "
                    f"kv_blocks={kv_blocks} and n_slots={n_slots}")
            per = kv_blocks // kv_groups
            self._free = [list(range(g * per, (g + 1) * per))
                          for g in range(kv_groups)]
        else:
            self._free = []
        self.slot_groups: Dict[Hashable, int] = (
            dict(slot_groups) if slot_groups else {None: n_slots})
        if sum(self.slot_groups.values()) != n_slots:
            raise ValueError(
                f"slot_groups lane counts {dict(self.slot_groups)} must sum "
                f"to n_slots={n_slots}")
        self._group_lo: Dict[Hashable, int] = {}
        self._slot_group: List[Hashable] = []
        lo = 0
        for gid, n in self.slot_groups.items():
            if n < 1:
                raise ValueError(f"slot group {gid!r} needs >= 1 lane")
            self._group_lo[gid] = lo
            self._slot_group.extend([gid] * n)
            lo += n
        if kv_blocks and len(self.slot_groups) > 1:
            spp = n_slots // kv_groups  # slots per arena partition
            for gid, n in self.slot_groups.items():
                if self._group_lo[gid] % spp or n % spp:
                    raise ValueError(
                        f"slot group {gid!r} (lanes "
                        f"[{self._group_lo[gid]}, {self._group_lo[gid] + n})) "
                        f"does not cover whole KV partitions of {spp} slots "
                        "- blocks would cross a group boundary")

    # -- the free-block allocator (paged KV arenas) ------------------------
    def group_of(self, slot: int) -> int:
        """The arena partition lane ``slot`` allocates from."""
        return slot * self.kv_groups // self.n_slots

    def free_blocks(self, group: Optional[int] = None) -> int:
        """Free blocks in ``group`` (or arena-wide when ``group`` is None)."""
        if not self.kv_blocks:
            return 0
        if group is None:
            return sum(len(f) for f in self._free)
        return len(self._free[group])

    def can_alloc(self, slot: int, n: int) -> bool:
        """True when ``slot``'s partition has ``n`` free blocks."""
        return self.free_blocks(self.group_of(slot)) >= n

    def alloc_blocks(self, slot: int, n: int) -> List[int]:
        """Assign ``n`` blocks from ``slot``'s partition to ``slot``."""
        free = self._free[self.group_of(slot)]
        if len(free) < n:
            raise RuntimeError(
                f"slot {slot}: need {n} KV blocks, partition has "
                f"{len(free)} free (check can_alloc first)")
        taken, self._free[self.group_of(slot)] = free[:n], free[n:]
        self.slot_blocks[slot].extend(taken)
        return taken

    def grow_block(self, slot: int) -> Optional[int]:
        """Extend ``slot`` by one block; None when its partition is dry
        (the engine preempts the lane and requeues its request)."""
        if not self.can_alloc(slot, 1):
            return None
        return self.alloc_blocks(slot, 1)[0]

    def reclaim_blocks(self, slot: int) -> None:
        """Return every block held by ``slot`` to its partition free list
        (sorted so allocation order is deterministic)."""
        if self.slot_blocks[slot]:
            g = self.group_of(slot)
            self._free[g] = sorted(self._free[g] + self.slot_blocks[slot])
            self.slot_blocks[slot] = []

    # -- slot groups (multi-tenant lane partitioning) ----------------------
    def group_ids(self) -> tuple:
        """The group ids, in declaration (= lane) order."""
        return tuple(self.slot_groups)

    def group_range(self, gid: Hashable) -> range:
        """The contiguous lane range owned by group ``gid``."""
        lo = self._group_lo[gid]
        return range(lo, lo + self.slot_groups[gid])

    def group_of_slot(self, slot: int) -> Hashable:
        """The group id lane ``slot`` belongs to."""
        return self._slot_group[slot]

    def group_of_partition(self, partition: int) -> Hashable:
        """The slot group KV arena ``partition`` serves (partitions are
        validated at construction to never straddle a group boundary)."""
        return self._slot_group[partition * (self.n_slots // self.kv_groups)]

    # -- admission ---------------------------------------------------------
    def submit(self, req: R) -> None:
        """Append ``req`` to the admission queue (FIFO; the server layers
        priority ordering on top)."""
        self.queue.append(req)

    def admit(self, admit_fn: Callable[[int, R], None],
              need_fn: Optional[Callable[[R], int]] = None,
              group_fn: Optional[Callable[[R], Hashable]] = None) -> List[int]:
        """Fill free slots from the queue; ``admit_fn(slot, req)`` does the
        engine-specific lane setup.  Returns the slots admitted into.

        With a ``need_fn`` (paged engines: request -> KV blocks required
        at admission) a request is only placed into a slot whose arena
        partition can cover it, and the blocks are allocated BEFORE
        ``admit_fn`` runs so the engine can build the lane's block table.

        With a ``group_fn`` (multi-tenant engines: request -> slot group
        id) a request is only placed into a lane of ITS OWN group, and
        head-of-line blocking is per group: a request whose group has no
        eligible free lane blocks everything queued BEHIND IT FOR THAT
        GROUP, while other groups keep admitting past it.  Without
        ``group_fn`` every request targets the sole (anonymous) group,
        which degenerates to the classic global-FIFO behaviour: when no
        free slot can host the queue head, admission stops rather than
        starving it behind smaller requests.  ``group_fn`` is required
        when more than one group was declared.
        """
        if group_fn is None and len(self.slot_groups) > 1:
            raise ValueError(
                "SlotScheduler has multiple slot groups "
                f"{list(self.slot_groups)}; admit() needs a group_fn to "
                "route requests")
        default_gid = next(iter(self.slot_groups))
        free: Dict[Hashable, List[int]] = {
            gid: [s for s in self.group_range(gid) if self.slots[s] is None]
            for gid in self.slot_groups}
        admitted: List[int] = []
        blocked: set = set()
        i = 0
        while i < len(self.queue):
            req = self.queue[i]
            gid = group_fn(req) if group_fn is not None else default_gid
            if gid in blocked:
                i += 1
                continue
            cand = free.get(gid)
            if cand is None:
                raise KeyError(
                    f"request routed to unknown slot group {gid!r} "
                    f"(groups: {list(self.slot_groups)})")
            if not cand:
                blocked.add(gid)
                i += 1
                continue
            if need_fn is None:
                slot = cand[0]
            else:
                need = need_fn(req)
                slot = next((s for s in cand if self.can_alloc(s, need)),
                            None)
                if slot is None:
                    blocked.add(gid)
                    i += 1
                    continue
                self.alloc_blocks(slot, need)
            self.queue.pop(i)
            admit_fn(slot, req)
            self.slots[slot] = req
            admitted.append(slot)
            cand.remove(slot)
        return admitted

    # -- state -------------------------------------------------------------
    def active_mask(self) -> np.ndarray:
        """(n_slots,) bool — which lanes hold an admitted request."""
        return np.asarray([r is not None for r in self.slots])

    def any_active(self) -> bool:
        """True when at least one lane is occupied."""
        return any(r is not None for r in self.slots)

    def pending(self) -> bool:
        """True while anything is queued or in flight."""
        return bool(self.queue) or self.any_active()

    def occupancy(self, group: Hashable = None) -> float:
        """Fraction of lanes occupied right now (0.0 - 1.0), pool-wide or
        for one slot ``group``'s lanes."""
        mask = self.active_mask()
        if group is not None:
            rng = self.group_range(group)
            mask = mask[rng.start:rng.stop]
        return float(mask.mean())

    def group_occupancy(self, groups: int) -> np.ndarray:
        """(groups,) mean occupancy per contiguous lane group.

        Engines batch lane-major and shard dim 0 over dp devices, so lanes
        ``[d*B/groups, (d+1)*B/groups)`` live on device ``d`` — this is the
        per-device occupancy ``Server.metrics()`` reports under a mesh.
        ``groups`` must divide ``n_slots`` (engines guarantee
        ``B = slots_per_device * dp``).
        """
        return self.active_mask().reshape(groups, -1).mean(axis=1)

    # -- retirement --------------------------------------------------------
    def retire(self, slot: int, rid: int) -> R:
        """Free ``slot`` (reclaiming its KV blocks) and move its request
        to ``finished[rid]``."""
        req = self.slots[slot]
        assert req is not None, f"retiring empty slot {slot}"
        self.finished[rid] = req
        self.slots[slot] = None
        self.reclaim_blocks(slot)
        return req

    def drain_finished(self) -> Dict[int, R]:
        """Hand retired requests to the caller and forget them (the server
        polls this every step, so ``finished`` never grows unboundedly)."""
        done, self.finished = self.finished, {}
        return done

    # -- cancellation ------------------------------------------------------
    def release(self, slot: int) -> R:
        """Free ``slot`` WITHOUT retiring (cancel/expiry: the request is
        dropped, not finished).  Both engines' lanes are masked/reassembled
        from host state each step, so an abandoned lane needs no device
        cleanup — the next admission resets it.  Held KV blocks are
        reclaimed (cancel/expiry/preemption must not leak arena)."""
        req = self.slots[slot]
        assert req is not None, f"releasing empty slot {slot}"
        self.slots[slot] = None
        self.reclaim_blocks(slot)
        return req

    def cancel_queued(self, req: R) -> bool:
        """Remove a not-yet-admitted request from the queue (by identity)."""
        for i, q in enumerate(self.queue):
            if q is req:
                del self.queue[i]
                return True
        return False

    def slot_of(self, req: R) -> Optional[int]:
        """The slot ``req`` currently occupies, or None (by identity)."""
        for slot, q in enumerate(self.slots):
            if q is req:
                return slot
        return None
