"""Real-time streaming basecalling with adaptive read ejection (ReadUntil).

Every other entry point in this repo wants the complete raw signal up
front; a sequencer gives you neither that nor the time to wait for it —
thousands of pores emit signal CHUNKS concurrently, each wanting
provisional bases and an eject/continue verdict within a few chunks
(the UNCALLED / ReadUntil scenario).  This module is that scenario as a
first-class serving subsystem:

  * :class:`StreamingSession` — one pore's incremental decode.  Chunks go
    in (``feed``); each overlap window decodes EXACTLY ONCE the moment its
    samples are complete (``pipeline.chunking.WindowBuffer``), through the
    same jitted quantized-DNN + hash-beam stage batch serving uses — the
    ``gru_seq`` persistent kernel threads hidden state across every
    timestep of the walk and ``beam_merge_multiframe`` keeps beam state
    resident across decode strips, so within a lane no sample is ever
    re-run.  ``finalize()`` is bitwise identical to
    ``BasecallPipeline.basecall`` on the concatenated signal: chunk
    boundaries never change the result.
  * an incremental stitcher — the batch path's ``align_offsets`` chaining
    is a scan, so it replays exactly one window at a time; bases whose
    overlap horizon has closed are emitted early as
    :class:`ProvisionalBases` patches (the final patch reconciles, so
    applying all patches reconstructs the exact final consensus).
  * :class:`EjectPolicy` — the ReadUntil verdict surface: after the first
    N chunks the policy sees a :class:`StreamProgress` (provisional read +
    per-base beam-score posteriors) and answers ``continue`` / ``accept``
    / ``eject``; an eject cancels the lane, reclaims its
    ``SlotScheduler`` slot, and resolves the request with status
    ``"ejected"``.
  * :class:`StreamingBasecallEngine` — an ``EngineProtocol``
    step-executor, so streams get the same admission queue, priorities,
    deadlines, dp-sharded batching, and ``Server.metrics()`` as batch
    serving: one (B, window, C) device batch per step over every lane's
    next ready window.

The model's own chunk-boundary state contract
(``models.basecaller.apply_basecaller(..., rnn_state=..., return_state=
True)``) is exact for forward-only stacks; the paper presets run
alternating-direction layers, whose reversed walks integrate FUTURE
samples — so the streaming quantum here is the overlap WINDOW (bitwise
parity with the batch path, by construction), not the sub-window sample.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import voting as voting_lib
from repro.dist import sharding as shd
from repro.pipeline import chunking
from repro.pipeline.pipeline import BasecallPipeline, BasecallResult
from repro.serve.api import STATUS_EJECTED, STATUS_OK
from repro.serve.scheduler import SlotScheduler

#: eject-policy verdicts
CONTINUE = "continue"   # undecided: consult again next step
ACCEPT = "accept"       # keep the read; stop consulting the policy
EJECT = "eject"         # abandon the read, free the lane NOW

#: EjectPolicy: ``StreamProgress -> CONTINUE | ACCEPT | EJECT``
EjectPolicy = Callable[["StreamProgress"], str]


@functools.cache
def _pairwise_offset():
    """Jitted ``voting.pairwise_offset`` (integer DP — exact), shared by
    every session so the per-window alignment compiles once per shape."""
    return jax.jit(voting_lib.pairwise_offset)


# ---------------------------------------------------------------------------
# provisional output events
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ProvisionalBases:
    """One streamed consensus patch: ``read[start : start+len(bases)] =
    bases``.

    Mid-stream patches are append-only (``start`` == bases emitted so
    far); the finalize patch may rewind ``start`` to revise — after
    applying it the read ENDS at ``start + len(bases)``, so folding every
    patch of a stream reconstructs the exact final consensus
    (:func:`apply_patches`)."""
    start: int
    bases: np.ndarray            # (k,) int32 base ids

    def __len__(self) -> int:
        return len(self.bases)


def apply_patches(patches) -> np.ndarray:
    """Fold :class:`ProvisionalBases` patches into the read they spell.

    The consumer-side contract: after a session's final patch this equals
    ``result.read[:result.length]`` exactly."""
    buf = np.zeros((0,), np.int32)
    for p in patches:
        buf = np.concatenate([buf[: p.start],
                              np.asarray(p.bases, np.int32)])
    return buf


@dataclasses.dataclass(frozen=True)
class StreamProgress:
    """What an :data:`EjectPolicy` sees after each engine step.

    ``read`` is the provisional consensus emitted so far (bases whose
    overlap horizon closed); ``base_logprobs`` its per-base confidence —
    the mean top-beam log-probability per base of the windows that voted
    at each position (beam-score posteriors at window granularity)."""
    read: np.ndarray             # (length,) int32 provisional consensus
    length: int
    base_logprobs: np.ndarray    # (length,) float32
    window_scores: np.ndarray    # (n_windows,) top-beam score per window
    window_lengths: np.ndarray   # (n_windows,)
    n_windows: int               # windows decoded so far
    n_chunks: int                # raw chunks consumed so far
    n_samples: int               # raw samples consumed so far

    def score_per_base(self) -> float:
        """Pool-level confidence: summed window scores per decoded base."""
        total = int(self.window_lengths.sum())
        return float(self.window_scores.sum()) / max(total, 1)


class ScoreEjectPolicy:
    """Reference :data:`EjectPolicy`: eject low-confidence reads early.

    Ejects once the mean per-base top-beam log-probability over at least
    ``min_bases`` decoded bases falls below ``threshold``; accepts once it
    holds above.  Stays ``CONTINUE`` until enough evidence arrives.
    """

    def __init__(self, threshold: float, min_bases: int = 8):
        self.threshold = threshold
        self.min_bases = min_bases

    def __call__(self, progress: StreamProgress) -> str:
        if int(progress.window_lengths.sum()) < self.min_bases:
            return CONTINUE
        return (EJECT if progress.score_per_base() < self.threshold
                else ACCEPT)


# ---------------------------------------------------------------------------
# the incremental stitcher
# ---------------------------------------------------------------------------

class _IncrementalStitcher:
    """``core.voting`` replayed one window at a time.

    ``align_offsets`` is a scan whose carry is (previous read, previous
    offset) — so offsets are computed incrementally with the SAME integer
    DP (exact).  Votes accumulate on a growing host-side counts grid;
    once ``depth`` newer windows have opened past a grid position, its
    overlap horizon has closed and its majority base is emitted as a
    provisional patch.  Horizon closure is a heuristic (a pathological
    later window may still align backwards); the finalize patch
    reconciles against the authoritative batch vote, so the patch stream
    always folds to the exact final consensus.
    """

    def __init__(self, max_read_len: int, depth: int, n_symbols: int = 4):
        self.L = max_read_len
        self.depth = max(depth, 1)
        self.n_symbols = n_symbols
        self._counts = np.zeros((0, n_symbols), np.int64)
        self._qual = np.zeros((0,), np.float64)     # summed score/base votes
        self._offs: List[int] = []                  # last `depth` offsets
        self._prev: Optional[Tuple[np.ndarray, int, int]] = None
        self._cursor = 0                            # grid scan position
        self._emitted_vals = np.zeros((0,), np.int32)
        self._emitted_pos = np.zeros((0,), np.int64)

    def _grow(self, upto: int) -> None:
        if upto > self._counts.shape[0]:
            extra = upto - self._counts.shape[0]
            self._counts = np.concatenate(
                [self._counts, np.zeros((extra, self.n_symbols), np.int64)])
            self._qual = np.concatenate(
                [self._qual, np.zeros((extra,), np.float64)])

    def push(self, read: np.ndarray, length: int,
             score: float) -> List[ProvisionalBases]:
        """Vote one window read onto the grid; emit newly closed bases."""
        read = np.asarray(read, np.int32)
        length = int(length)
        if self._prev is None:
            off = 0
        else:
            p_read, p_len, p_off = self._prev
            rel, _ = _pairwise_offset()(p_read, p_len, read, length)
            off = max(p_off + int(rel), 0)
        self._prev = (read, length, off)
        self._offs.append(off)
        del self._offs[: -self.depth]
        if length > 0:
            self._grow(off + length)
            pos = off + np.arange(length)
            sym = np.clip(read[:length], 0, self.n_symbols - 1)
            np.add.at(self._counts, (pos, sym), 1)
            self._qual[pos] += float(score) / max(length, 1)
        frontier = max(self._cursor, min(self._offs))
        frontier = min(frontier, self._counts.shape[0])
        if frontier <= self._cursor:
            return []
        rows = self._counts[self._cursor: frontier]
        covered = rows.sum(axis=1) > 0
        vals = rows.argmax(axis=1).astype(np.int32)[covered]
        poss = np.arange(self._cursor, frontier)[covered]
        self._cursor = frontier
        if vals.size == 0:
            return []
        patch = ProvisionalBases(start=int(self._emitted_vals.size),
                                 bases=vals)
        self._emitted_vals = np.concatenate([self._emitted_vals, vals])
        self._emitted_pos = np.concatenate([self._emitted_pos, poss])
        return [patch]

    def emitted(self) -> Tuple[np.ndarray, np.ndarray]:
        """(provisional read, per-base mean vote score) emitted so far."""
        pos = self._emitted_pos
        if pos.size == 0:
            return self._emitted_vals, np.zeros((0,), np.float32)
        votes = self._counts[pos].sum(axis=1)
        lp = (self._qual[pos] / np.maximum(votes, 1)).astype(np.float32)
        return self._emitted_vals, lp

    def flush(self, final_read: np.ndarray,
              final_length: int) -> ProvisionalBases:
        """The reconciling terminal patch against the batch-voted read."""
        want = np.asarray(final_read[:final_length], np.int32)
        have = self._emitted_vals
        m = min(have.size, want.size)
        diff = np.nonzero(have[:m] != want[:m])[0]
        k = int(diff[0]) if diff.size else m
        if k == have.size == want.size:
            k = want.size            # clean append of nothing: a no-op tail
        return ProvisionalBases(start=k, bases=want[k:].copy())


# ---------------------------------------------------------------------------
# the per-pore session
# ---------------------------------------------------------------------------

class StreamingSession:
    """One pore's incremental basecall: chunks in, provisional bases out.

    Two driving modes share all geometry/stitching state:

      * **bound** (default, ``pipe.stream()``): ``feed`` decodes windows
        the moment they complete, through the pipeline's own jitted
        decode stage — batched ``chunk.batch_windows`` at a time and
        dp-sharded under the mesh ambient at session creation, exactly
        like ``basecall_iter``.
      * **engine-driven** (``auto=False``): ``StreamingBasecallEngine``
        pulls ready windows from many sessions into ONE device batch per
        step (``ready``/``next_window``/``push_decoded``) — the session
        never touches the device itself.

    Either way ``finalize()`` runs the batch path's own
    ``BasecallResult.from_window_reads`` over the identical window reads,
    so the result is bitwise what ``pipe.basecall`` returns for the
    concatenated signal.

    Args:
        pipeline: the :class:`~repro.pipeline.BasecallPipeline` whose
            chunk geometry and jitted decode stage this stream uses.
        params: optional checkpoint override (bound mode only).
        auto: decode on ``feed`` (bound mode) vs. engine-driven.

    Example::

        sess = pipe.stream()
        for chunk in pore_chunks:
            for patch in sess.feed(chunk):
                ...                      # provisional bases, early
        result = sess.finalize()         # == pipe.basecall(full_signal)
    """

    def __init__(self, pipeline: BasecallPipeline, params=None, *,
                 auto: bool = True):
        self.pipe = pipeline
        self.auto = auto
        self.buffer = chunking.WindowBuffer(pipeline.chunk)
        self.stitcher = _IncrementalStitcher(
            pipeline.max_read_len, chunking.overlap_depth(pipeline.chunk))
        #: every ProvisionalBases patch emitted, in order (monotone — the
        #: serving layer streams new entries as ServeEvents)
        self.events: List[ProvisionalBases] = []
        self.n_chunks = 0
        self._reads: List[np.ndarray] = []
        self._lengths: List[int] = []
        self._scores: List[float] = []
        self._result: Optional[BasecallResult] = None
        if auto:
            # mirror basecall_iter: params packed once, mesh pinned at
            # session creation, batches padded to batch_windows (rounded
            # up to the dp device count)
            self._params = pipeline.serving_params(params)
            self._mesh = shd.get_mesh()
            dp = shd.dp_size(self._mesh)
            if self._mesh is not None:
                self._params = pipeline._place_params(self._params,
                                                      self._mesh)
            B = pipeline.chunk.batch_windows
            if B % dp:
                B += dp - B % dp
            self._B = B

    # -- feeding ------------------------------------------------------------
    def feed(self, chunk) -> List[ProvisionalBases]:
        """Append one raw-signal chunk ((t,) or (t, C), any size).

        Returns the provisional patches this chunk unlocked (bound mode;
        engine-driven sessions always return [] here — the engine decodes
        on its own step cadence)."""
        if self._result is not None:
            raise RuntimeError("session already finalized")
        self.buffer.feed(chunk)
        self.n_chunks += 1
        return self._drain() if self.auto else []

    def end(self) -> None:
        """Mark the pore's stream complete (tail windows become ready)."""
        if not self.buffer.ended:
            self.buffer.end()

    # -- the engine-facing decode surface -----------------------------------
    def ready(self) -> int:
        """Windows whose samples are complete and not yet handed out."""
        return self.buffer.ready()

    def next_window(self) -> Tuple[np.ndarray, int]:
        """Pop the next ready window: ((window, C), decoder logit_length)."""
        win, valid = self.buffer.next_window()
        return win, int(self.pipe.mcfg.output_frames(valid))

    def push_decoded(self, read, length: int,
                     score: float) -> List[ProvisionalBases]:
        """Record one window's decode; emit newly closed consensus bases."""
        read = np.asarray(read, np.int32)
        self._reads.append(read)
        self._lengths.append(int(length))
        self._scores.append(float(score))
        patches = self.stitcher.push(read, int(length), float(score))
        self.events.extend(patches)
        return patches

    @property
    def done(self) -> bool:
        """True once the stream ended and every window is decoded."""
        return (self.buffer.ended and self.buffer.ready() == 0
                and len(self._reads) == self.buffer.emitted)

    # -- progress + results --------------------------------------------------
    def progress(self) -> StreamProgress:
        """Snapshot for eject policies / dashboards (cheap, host-side)."""
        read, lp = self.stitcher.emitted()
        return StreamProgress(
            read=read, length=int(read.size), base_logprobs=lp,
            window_scores=np.asarray(self._scores, np.float32),
            window_lengths=np.asarray(self._lengths, np.int32),
            n_windows=len(self._reads), n_chunks=self.n_chunks,
            n_samples=self.buffer.n_fed)

    def _settle(self) -> BasecallResult:
        """Vote what's decoded into a result + the reconciling patch."""
        if not self._reads:
            res = BasecallResult.empty(self.pipe.max_read_len)
        else:
            res = BasecallResult.from_window_reads(
                np.stack(self._reads),
                np.asarray(self._lengths, np.int32),
                max_read_len=self.pipe.max_read_len)
        self.events.append(self.stitcher.flush(res.read, res.length))
        self._result = res
        return res

    def finalize(self) -> BasecallResult:
        """End the stream, decode the tail, and vote the final consensus.

        Bitwise identical to ``pipe.basecall`` on the concatenated
        signal: same windows, same decode trace, same
        ``from_window_reads`` finalization.  Appends the reconciling
        terminal patch to ``events`` (so folding every patch with
        :func:`apply_patches` reproduces ``result.read[:length]``)."""
        if self._result is not None:
            return self._result
        self.end()
        if self.auto:
            self._drain()
        elif not self.done:
            raise RuntimeError("engine-driven session not fully decoded; "
                               "the engine finalizes it")
        return self._settle()

    def eject(self) -> BasecallResult:
        """Abandon the stream NOW: settle the windows decoded so far into
        a provisional result (what an ejected request resolves with)."""
        if self._result is None:
            self._settle()
        return self._result

    # -- bound-mode decoding -------------------------------------------------
    def _drain(self) -> List[ProvisionalBases]:
        patches: List[ProvisionalBases] = []
        while self.buffer.ready() > 0:
            take = min(self.buffer.ready(), self._B)
            wins, frames = [], []
            for _ in range(take):
                w, f = self.next_window()
                wins.append(w)
                frames.append(f)
            pad = self._B - take
            if pad:
                wins += [np.zeros_like(wins[0])] * pad
                frames += [0] * pad
            grp = jnp.asarray(np.stack(wins))
            fl = jnp.asarray(np.asarray(frames, np.int32))
            if self._mesh is not None:
                grp = jax.device_put(
                    grp, shd.batch_sharding(self._mesh, grp.ndim))
                fl = jax.device_put(
                    fl, shd.batch_sharding(self._mesh, fl.ndim))
            with shd.use_mesh(self._mesh):
                reads, lens, scores = self.pipe._decode_windows(
                    self._params, grp, fl)
            reads, lens = np.asarray(reads), np.asarray(lens)
            scores = np.asarray(scores)
            for i in range(take):
                patches += self.push_decoded(reads[i], int(lens[i]),
                                             float(scores[i]))
        return patches


# ---------------------------------------------------------------------------
# the streaming engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StreamRequest:
    """One pore's chunk stream to base-call incrementally.

    ``chunks`` is any iterable of raw-signal arrays ((t,) or (t, C), any
    sizes — a list, a generator, a live feed).  The engine pulls lazily:
    by default just enough each step to complete the lane's next window
    (work-conserving); ``chunks_per_step`` caps the pull to model a pore's
    fixed arrival cadence (latency benchmarks).  ``eject`` is consulted
    from the ``eject_after_chunks``-th chunk on, each step, until it
    answers ``accept`` or ``eject``."""
    chunks: Any
    eject: Optional[EjectPolicy] = None
    eject_after_chunks: int = 4
    chunks_per_step: Optional[int] = None
    priority: int = 0
    deadline: Optional[float] = None
    #: hosted-model routing (multi-tenant fleets): a request naming a
    #: model its engine does not serve resolves with a clear ``"error"``
    model: Optional[str] = None


@dataclasses.dataclass
class _StreamLane:
    rid: int
    request: StreamRequest
    session: Optional[StreamingSession] = None
    it: Optional[Iterator] = None
    exhausted: bool = False
    n_chunks: int = 0
    verdict: Optional[str] = None            # None | ACCEPT | EJECT
    status: str = STATUS_OK
    result: Optional[BasecallResult] = None


#: livelock guard: max chunk pulls per lane per step under the
#: work-conserving default (an adversarial stream of empty chunks must
#: not wedge the engine loop)
_MAX_PULLS_PER_STEP = 4096


class StreamingBasecallEngine:
    """Continuous-batching step-executor for live chunk streams.

    The ReadUntil counterpart of ``BasecallEngine``: one request is one
    pore's chunk iterable, admitted into a lane whose
    :class:`StreamingSession` turns chunks into ready windows.  Each
    engine step pulls every lane's chunks, assembles ONE (B, window, C)
    batch from the lanes' next ready windows (idle lanes contribute an
    inert zero window with ``logit_length 0``), decodes it through the
    pipeline's jitted stage — dp-sharded under the construction-time mesh
    exactly like batch serving — then streams newly closed consensus
    bases and consults each lane's eject policy.  An ``eject`` verdict
    retires the lane immediately: the slot readmits from the queue and
    the server resolves the request with status ``"ejected"`` (and the
    provisional read as its value).

    Args:
        pipeline: the :class:`BasecallPipeline` whose jitted decode stage
            (and serving artifact) every step consumes.
        params: optional checkpoint override (defaults to the pipeline's).
        batch_slots: device lanes **per dp device** (pool is
            ``batch_slots * dp`` under an ambient mesh at construction).

    Example::

        eng = StreamingBasecallEngine(pipe, batch_slots=8)
        srv = Server(eng)
        for ev in srv.stream(StreamRequest(chunks=pore_chunks)):
            ...                        # ProvisionalBases patches, then final
    """

    event_kind = "bases"

    def __init__(self, pipeline: BasecallPipeline, params=None,
                 batch_slots: int = 8, model_id: Optional[str] = None):
        self.pipe = pipeline
        self.model_id = model_id
        if params is None and pipeline.params is None:
            raise ValueError("StreamingBasecallEngine needs initialized "
                             "params")
        self.mesh = shd.get_mesh()
        self.dp = shd.dp_size(self.mesh)
        self.B = batch_slots * self.dp
        self.params = pipeline.serving_params(params)
        if self.mesh is not None:
            self.params = pipeline._place_params(self.params, self.mesh)
        self.sched: SlotScheduler[_StreamLane] = SlotScheduler(self.B)
        self._zero = np.zeros((pipeline.chunk.window,
                               pipeline.mcfg.in_channels), np.float32)
        self.steps = 0
        self.ejected = 0

    def _mesh_ctx(self):
        return shd.use_mesh(self.mesh)

    # -- EngineProtocol request adapters -----------------------------------
    def make_request(self, rid: int, r: StreamRequest) -> _StreamLane:
        return _StreamLane(rid=rid, request=r)

    def degenerate(self, r: StreamRequest) -> bool:
        """A sized, empty chunk container has nothing to stream."""
        try:
            return len(r.chunks) == 0
        except TypeError:
            return False                     # unsized iterators stream on

    def empty_result(self, r: StreamRequest) -> BasecallResult:
        return BasecallResult.empty(self.pipe.max_read_len)

    def model_of(self, r) -> Optional[str]:
        """The model id serving ``r`` (its ``model=``, or this engine's)."""
        return getattr(r, "model", None) or self.model_id

    def validate(self, r: StreamRequest) -> Optional[str]:
        """Reject malformed stream requests — and streams routed to a
        model this engine does not host — at submit, not mid-lane."""
        m = getattr(r, "model", None)
        if m is not None and m != self.model_id:
            hosts = (f"[{self.model_id!r}]" if self.model_id is not None
                     else "one anonymous model (no model= routing)")
            return f"unknown model {m!r}: this server hosts {hosts}"
        if not hasattr(r.chunks, "__iter__"):
            return f"chunks must be iterable, got {type(r.chunks).__name__}"
        if r.chunks_per_step is not None and r.chunks_per_step < 1:
            return f"chunks_per_step must be >= 1, got {r.chunks_per_step}"
        if r.eject is not None and r.eject_after_chunks < 1:
            return (f"eject_after_chunks must be >= 1, "
                    f"got {r.eject_after_chunks}")
        return None

    def progress(self, native: _StreamLane) -> List[ProvisionalBases]:
        return native.session.events if native.session is not None else []

    def result_of(self, native: _StreamLane) -> BasecallResult:
        assert native.result is not None
        return native.result

    def final_status(self, native: _StreamLane) -> str:
        """``"ejected"`` for lanes the eject policy abandoned, else ok —
        the ``Server.step`` resolution hook."""
        return native.status

    # -- admission ---------------------------------------------------------
    def submit(self, lane: _StreamLane):
        self.sched.submit(lane)

    def _admit_one(self, slot: int, lane: _StreamLane):
        lane.session = StreamingSession(self.pipe, auto=False)
        lane.it = iter(lane.request.chunks)

    def admit(self) -> List[int]:
        return self.sched.admit(self._admit_one)

    # -- stepping ----------------------------------------------------------
    def active_mask(self) -> np.ndarray:
        return self.sched.active_mask()

    def _pull(self, lane: _StreamLane) -> None:
        """Advance one lane's chunk intake for this step.

        Work-conserving by default: pull until the session has a ready
        window (or the stream ends); with ``chunks_per_step`` set, pull
        exactly that many — the fixed-cadence pore model."""
        limit = lane.request.chunks_per_step
        pulled = 0
        while not lane.exhausted:
            if limit is None:
                if (lane.session.ready() > 0
                        or pulled >= _MAX_PULLS_PER_STEP):
                    break
            elif pulled >= limit:
                break
            try:
                chunk = next(lane.it)
            except StopIteration:
                lane.exhausted = True
                lane.session.end()
                break
            lane.session.feed(chunk)
            lane.n_chunks += 1
            pulled += 1

    def _maybe_eject(self, slot: int, lane: _StreamLane) -> bool:
        """Consult the lane's eject policy; True when the lane was
        ejected (slot freed, request retiring as ``"ejected"``)."""
        r = lane.request
        if (r.eject is None or lane.verdict is not None
                or lane.n_chunks < r.eject_after_chunks):
            return False
        verdict = r.eject(lane.session.progress())
        if verdict == ACCEPT:
            lane.verdict = ACCEPT
            return False
        if verdict != EJECT:
            return False                     # CONTINUE: ask again next step
        lane.verdict = EJECT
        lane.status = STATUS_EJECTED
        lane.result = lane.session.eject()
        self.ejected += 1
        self.sched.retire(slot, lane.rid)
        return True

    def step(self):
        """Pull chunks, decode every lane's next ready window in one
        batch, stream closed bases, rule on ejects, retire done lanes."""
        self.steps += 1
        lanes = list(enumerate(self.sched.slots))
        for _, lane in lanes:
            if lane is not None:
                self._pull(lane)
        wins, frames, live = [], [], []
        for slot, lane in lanes:
            if lane is not None and lane.session.ready() > 0:
                w, f = lane.session.next_window()
                wins.append(w)
                frames.append(f)
                live.append(slot)
            else:
                wins.append(self._zero)
                frames.append(0)
        if live:
            batch = jnp.asarray(np.stack(wins))
            fl = jnp.asarray(np.asarray(frames, np.int32))
            if self.mesh is not None:
                batch = jax.device_put(
                    batch, shd.batch_sharding(self.mesh, batch.ndim))
                fl = jax.device_put(
                    fl, shd.batch_sharding(self.mesh, fl.ndim))
            with self._mesh_ctx():
                reads, lens, scores = self.pipe._decode_windows(
                    self.params, batch, fl)
            reads, lens = np.asarray(reads), np.asarray(lens)
            scores = np.asarray(scores)
            for slot in live:
                lane = self.sched.slots[slot]
                lane.session.push_decoded(reads[slot], int(lens[slot]),
                                          float(scores[slot]))
        for slot, lane in enumerate(self.sched.slots):
            if lane is None:
                continue
            if self._maybe_eject(slot, lane):
                continue
            if lane.session.done:
                lane.result = lane.session.finalize()
                self.sched.retire(slot, lane.rid)


__all__ = ["CONTINUE", "ACCEPT", "EJECT", "EjectPolicy", "ScoreEjectPolicy",
           "ProvisionalBases", "apply_patches", "StreamProgress",
           "StreamingSession", "StreamRequest", "StreamingBasecallEngine"]
