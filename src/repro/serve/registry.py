"""Multi-tenant model registry: packed serving artifacts behind one budget.

A :class:`ModelRegistry` maps model ids to quantize-once serving
artifacts — basecaller :class:`~repro.models.basecaller.PackedParams` and
LM ``pack_lm_serving`` bundles alike.  Registration stores only the
*recipe* (the retained float source plus a deterministic pack closure);
the packed artifact itself is built lazily, cached under an LRU policy
with an explicit byte budget, evicted cold, and re-packed on demand.
Because every pack closure is jitted and deterministic, a re-packed
artifact is bitwise identical to the one evicted — recall never changes
serving results.

Eviction never yanks an artifact out from under a live request: an entry
is IN USE while it is pinned (:meth:`ModelRegistry.pin` /
:meth:`ModelRegistry.pinned`) or while any registered use hook —
multi-tenant engines install one reporting "this model has active
lanes" — says so.  Evicting an in-use model is *deferred*, not dropped:
the entry is flagged and reclaimed at the next registry operation after
it falls idle.
"""
from __future__ import annotations

import contextlib
import dataclasses
from collections import OrderedDict
from typing import Any, Callable, Iterator, List, Optional, Tuple

import jax
import numpy as np


def tree_nbytes(tree: Any) -> int:
    """Total bytes of every array leaf in ``tree`` (non-array leaves —
    configs, Python scalars — are free)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        dt = getattr(leaf, "dtype", None)
        size = getattr(leaf, "size", None)
        if dt is not None and size is not None:
            total += int(size) * np.dtype(dt).itemsize
    return total


@dataclasses.dataclass(frozen=True)
class RegistryStats:
    """One snapshot of :meth:`ModelRegistry.stats`.

    ``builds`` counts every artifact pack (first build + re-packs);
    ``rebuilds`` counts only the re-packs after an eviction; ``deferred``
    is how many resident entries currently carry a deferred-eviction
    flag (in use when eviction was requested)."""
    models: int
    resident: int
    resident_bytes: int
    budget_bytes: Optional[int]
    hits: int
    builds: int
    rebuilds: int
    evictions: int
    deferred: int

    def rows(self, prefix: str = "registry") -> List[Tuple[str, float]]:
        """Flat ``(name, value)`` rows for benchmark CSV emission."""
        out = [(f"{prefix}/models", float(self.models)),
               (f"{prefix}/resident", float(self.resident)),
               (f"{prefix}/resident_bytes", float(self.resident_bytes)),
               (f"{prefix}/hits", float(self.hits)),
               (f"{prefix}/builds", float(self.builds)),
               (f"{prefix}/rebuilds", float(self.rebuilds)),
               (f"{prefix}/evictions", float(self.evictions))]
        if self.budget_bytes is not None:
            out.append((f"{prefix}/budget_bytes", float(self.budget_bytes)))
        return out


@dataclasses.dataclass
class _Entry:
    model_id: str
    kind: str
    pack: Callable[[], Any]
    meta: Any = None
    artifact: Any = None
    nbytes: int = 0
    pins: int = 0
    ever_built: bool = False
    evict_deferred: bool = False    # budget pressure hit an in-use entry
    evict_requested: bool = False   # explicit evict() hit an in-use entry


class ModelRegistry:
    """Model ids -> packed serving artifacts, under an LRU byte budget.

    Args:
        budget_bytes: resident-artifact budget.  ``None`` (default) means
            unbounded.  The budget bounds COLD artifacts: entries that are
            in use (pinned, or reported active by a use hook) are never
            evicted, so a burst of simultaneously-live models may
            transiently exceed it — each carries a deferred-eviction flag
            and is reclaimed once idle.

    Example::

        reg = ModelRegistry(budget_bytes=64 << 20)
        reg.register_basecaller("small", small_pipe)
        reg.register_basecaller("large", large_pipe)
        art = reg.artifact("small")        # packs on first touch
        reg.evict("small")                 # cold -> dropped
        assert reg.artifact("small") ...   # re-packed, bitwise identical
    """

    def __init__(self, budget_bytes: Optional[int] = None):
        if budget_bytes is not None and budget_bytes <= 0:
            raise ValueError(f"budget_bytes must be positive, got "
                             f"{budget_bytes}")
        self.budget_bytes = budget_bytes
        self._entries: "OrderedDict[str, _Entry]" = OrderedDict()
        self._lru: "OrderedDict[str, None]" = OrderedDict()  # oldest first
        self._use_hooks: List[Callable[[str], bool]] = []
        self.hits = 0
        self.builds = 0
        self.rebuilds = 0
        self.evictions = 0

    # -- registration ------------------------------------------------------
    def register(self, model_id: str, pack: Callable[[], Any], *,
                 kind: str = "custom", meta: Any = None,
                 replace: bool = False) -> None:
        """Bind ``model_id`` to a deterministic ``pack()`` closure.

        ``pack`` must rebuild the artifact bitwise-identically on every
        call (jitted quantize-once packers qualify) — that is what makes
        evict -> re-pack transparent to serving results.  ``meta`` rides
        along for engine construction (the pipeline for basecallers, the
        config for LMs)."""
        if not isinstance(model_id, str) or not model_id:
            raise ValueError(f"model_id must be a non-empty str, got "
                             f"{model_id!r}")
        if model_id in self._entries and not replace:
            raise ValueError(f"model {model_id!r} already registered "
                             "(pass replace=True to rebind)")
        if model_id in self._lru:
            self._drop(model_id)
        self._entries[model_id] = _Entry(model_id=model_id, kind=kind,
                                         pack=pack, meta=meta)

    def register_basecaller(self, model_id: str, pipeline: Any,
                            params: Any = None, *,
                            replace: bool = False) -> None:
        """Register a :class:`~repro.pipeline.BasecallPipeline` tenant.

        Retains the float ``params`` (``pipeline.params`` by default) as
        the re-pack source; the artifact is
        ``pipeline.pack_artifact(params)`` — the same quantize-once
        ``PackedParams`` the standalone pipeline serves from, so routing
        through the registry is bitwise-identical to ``pipeline.basecall``.
        """
        p = params if params is not None else pipeline.params
        if p is None:
            raise ValueError(
                f"model {model_id!r}: pipeline holds no params - call "
                "init_params()/load first or pass params=")
        self.register(model_id, lambda: pipeline.pack_artifact(p),
                      kind="basecaller", meta=pipeline, replace=replace)

    def register_lm(self, model_id: str, params: Any, cfg: Any, *,
                    replace: bool = False) -> None:
        """Register an LM tenant; the artifact is the
        ``(packed params, serving config)`` pair from
        :func:`repro.models.lm.pack_lm_serving` (consumed by
        ``ServingEngine.from_registry``)."""
        from repro.models import lm as lm_lib
        self.register(model_id, lambda: lm_lib.pack_lm_serving(params, cfg),
                      kind="lm", meta=cfg, replace=replace)

    # -- lookup ------------------------------------------------------------
    def __contains__(self, model_id: object) -> bool:
        return model_id in self._entries

    def ids(self) -> Tuple[str, ...]:
        """Registered model ids, in registration order."""
        return tuple(self._entries)

    def kind(self, model_id: str) -> str:
        """The registered kind of ``model_id`` (``"basecaller"``/``"lm"``/
        custom)."""
        return self._entry(model_id).kind

    def meta(self, model_id: str) -> Any:
        """The metadata object registered with ``model_id``."""
        return self._entry(model_id).meta

    def pipeline(self, model_id: str) -> Any:
        """The ``BasecallPipeline`` behind a basecaller tenant."""
        e = self._entry(model_id)
        if e.kind != "basecaller":
            raise TypeError(f"model {model_id!r} is kind {e.kind!r}, not a "
                            "basecaller")
        return e.meta

    def _entry(self, model_id: str) -> _Entry:
        try:
            return self._entries[model_id]
        except KeyError:
            raise KeyError(f"unknown model {model_id!r}: registered ids are "
                           f"{list(self._entries)}") from None

    # -- the artifact cache ------------------------------------------------
    def artifact(self, model_id: str) -> Any:
        """The packed artifact for ``model_id`` — cache hit, or pack (and
        count a rebuild if this entry was evicted before).  Touching an
        entry makes it most-recently-used and clears any deferred-eviction
        flag (it is hot again); colder entries are then evicted down to
        the byte budget."""
        self._sweep_deferred()
        e = self._entry(model_id)
        if e.artifact is None:
            e.artifact = e.pack()
            e.nbytes = tree_nbytes(e.artifact)
            self.builds += 1
            if e.ever_built:
                self.rebuilds += 1
            e.ever_built = True
        else:
            self.hits += 1
        e.evict_deferred = False      # hot again: deferred evictions lapse
        e.evict_requested = False
        self._lru[model_id] = None
        self._lru.move_to_end(model_id)
        self._evict_to_budget(keep=model_id)
        return e.artifact

    @property
    def resident_bytes(self) -> int:
        """Bytes held by resident artifacts right now."""
        return sum(self._entries[mid].nbytes for mid in self._lru)

    def resident(self) -> Tuple[str, ...]:
        """Resident model ids, least-recently-used first."""
        return tuple(self._lru)

    def evict(self, model_id: str, force: bool = False) -> bool:
        """Drop ``model_id``'s resident artifact (the recipe stays; the
        next :meth:`artifact` re-packs bitwise-identically).  Returns True
        when dropped now.  An IN-USE entry is not dropped: the eviction is
        deferred (flagged, reclaimed once idle) unless ``force=True``."""
        e = self._entry(model_id)
        if e.artifact is None:
            return False
        if not force and self._in_use(model_id):
            e.evict_requested = True
            return False
        self._drop(model_id)
        return True

    def sweep(self) -> None:
        """Reclaim deferred evictions whose entries have fallen idle and
        re-enforce the byte budget (engines trigger this implicitly via
        :meth:`artifact`; callers between bursts may call it directly)."""
        self._sweep_deferred()
        self._evict_to_budget()

    # -- in-use protection -------------------------------------------------
    def pin(self, model_id: str) -> None:
        """Refcount ``model_id`` as in use (never evicted while pinned)."""
        self._entry(model_id).pins += 1

    def unpin(self, model_id: str) -> None:
        """Drop one pin; reclaims any deferred eviction once idle."""
        e = self._entry(model_id)
        if e.pins <= 0:
            raise RuntimeError(f"unpin of unpinned model {model_id!r}")
        e.pins -= 1
        self._sweep_deferred()

    @contextlib.contextmanager
    def pinned(self, model_id: str) -> Iterator[None]:
        """``with reg.pinned(mid):`` — pin for the duration of a step."""
        self.pin(model_id)
        try:
            yield
        finally:
            self.unpin(model_id)

    def add_use_hook(self, hook: Callable[[str], bool]) -> None:
        """Register ``hook(model_id) -> bool`` consulted before eviction;
        engines report "this model has active lanes" so in-flight models
        are never evicted (deferred instead) without any per-lane pin
        bookkeeping to leak."""
        self._use_hooks.append(hook)

    def _in_use(self, model_id: str) -> bool:
        if self._entries[model_id].pins > 0:
            return True
        return any(hook(model_id) for hook in self._use_hooks)

    # -- internals ---------------------------------------------------------
    def _drop(self, model_id: str) -> None:
        e = self._entries[model_id]
        e.artifact = None
        e.nbytes = 0
        e.evict_deferred = False
        e.evict_requested = False
        del self._lru[model_id]
        self.evictions += 1

    def _sweep_deferred(self) -> None:
        # oldest first; explicit evict() requests always land once idle,
        # budget-pressure deferrals only while the budget is still blown
        # (they lapse when residency recovered some other way)
        for mid in list(self._lru):
            e = self._entries[mid]
            if self._in_use(mid):
                continue
            if e.evict_requested:
                self._drop(mid)
            elif e.evict_deferred:
                if (self.budget_bytes is not None
                        and self.resident_bytes > self.budget_bytes):
                    self._drop(mid)
                else:
                    e.evict_deferred = False

    def _evict_to_budget(self, keep: Optional[str] = None) -> None:
        if self.budget_bytes is None:
            return
        for mid in list(self._lru):  # oldest first
            if self.resident_bytes <= self.budget_bytes:
                return
            if mid == keep or self._in_use(mid):
                self._entries[mid].evict_deferred = True
                continue
            self._drop(mid)

    def stats(self) -> RegistryStats:
        """Cache counters + residency snapshot (see :class:`RegistryStats`)."""
        deferred = sum(1 for mid in self._lru
                       if self._entries[mid].evict_deferred
                       or self._entries[mid].evict_requested)
        return RegistryStats(models=len(self._entries),
                             resident=len(self._lru),
                             resident_bytes=self.resident_bytes,
                             budget_bytes=self.budget_bytes,
                             hits=self.hits, builds=self.builds,
                             rebuilds=self.rebuilds,
                             evictions=self.evictions, deferred=deferred)
