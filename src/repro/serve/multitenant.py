"""Multi-tenant base-calling engine: one Server, a fleet of models.

``MultiModelBasecallEngine`` hosts several packed basecaller artifacts
behind ONE :class:`~repro.serve.api.Server`: each hosted model owns a
contiguous SLOT GROUP in a single shared
:class:`~repro.serve.scheduler.SlotScheduler` (admission, occupancy and —
for paged engines — KV partitions never cross a group boundary), requests
carry a ``model=`` id that routes them to their model's lanes, and every
engine step runs each active model's own jitted decode on its group's
fixed-size sub-batch.  Batch-invariant numerics make that sub-batch
decode bitwise-identical to the model's standalone
``pipeline.basecall`` — multiplexing is free of accuracy drift by
construction, and the tests pin it.

Artifacts come from a :class:`~repro.serve.registry.ModelRegistry`
(quantize-once, LRU under a byte budget): the engine pins a model's
artifact only around its decode call and registers a use hook reporting
"this model has active lanes", so a cold tenant can be evicted and
re-packed on demand without a live one ever losing its weights mid-read.

This is the RUBICON deployment scenario — a *framework* over many
basecaller architectures — and the substrate for speed/accuracy tiering
(small model for ReadUntil triage, large model for final calls; see
docs/serving.md).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import sharding as shd
from repro.pipeline import chunking
from repro.pipeline.pipeline import BasecallResult
from repro.serve.basecall_engine import ReadRequest, _WindowView
from repro.serve.registry import ModelRegistry
from repro.serve.scheduler import SlotScheduler


@dataclasses.dataclass
class TenantReadRequest(ReadRequest):
    """A :class:`~repro.serve.basecall_engine.ReadRequest` stamped with
    the hosted model id that owns its lane."""
    model: str = ""


class MultiModelBasecallEngine:
    """Continuous-batching step-executor multiplexing several basecallers.

    Args:
        registry: the :class:`ModelRegistry` holding every tenant
            (``register_basecaller`` must have bound each hosted id).
        models: the hosted model ids — a sequence (every model gets
            ``batch_slots`` lanes per dp device) or an ordered mapping
            ``id -> lanes per device`` for asymmetric tiers (many small-
            model lanes for triage, a few large-model lanes for final
            calls).
        batch_slots: default lanes **per dp device** per model; under an
            ambient ``dist.sharding.use_mesh`` mesh each model's group is
            ``lanes * dp_size`` wide and its sub-batch is split over the
            mesh, exactly like the single-model ``BasecallEngine``.
        default_model: where requests without a ``model=`` go (first
            hosted id by default).

    Requests naming a model this engine does not host resolve with a
    clear ``"error"`` result at submit (``validate``); they never occupy
    a lane or touch another tenant's group.

    Example::

        reg = ModelRegistry()
        reg.register_basecaller("small", small_pipe)
        reg.register_basecaller("large", large_pipe)
        srv = Server(MultiModelBasecallEngine(reg, ["small", "large"]))
        fut = srv.submit(BasecallRequest(signal=sig, model="large"))
    """

    event_kind = "window"

    def __init__(self, registry: ModelRegistry,
                 models: Union[Sequence[str], Mapping[str, int]],
                 batch_slots: int = 4, default_model: Optional[str] = None):
        spec: Dict[str, int] = (
            dict(models) if isinstance(models, Mapping)
            else {m: batch_slots for m in models})
        if not spec:
            raise ValueError("MultiModelBasecallEngine hosts >= 1 model")
        self.registry = registry
        self.mesh = shd.get_mesh()
        self.dp = shd.dp_size(self.mesh)
        self.models: Tuple[str, ...] = tuple(spec)
        self.default_model = default_model or self.models[0]
        if self.default_model not in spec:
            raise ValueError(f"default_model {self.default_model!r} is not "
                             f"hosted ({list(spec)})")
        self._pipes = {}
        groups: Dict[str, int] = {}
        for mid, lanes in spec.items():
            pipe = registry.pipeline(mid)    # raises for unknown/non-basecall
            self._pipes[mid] = pipe
            groups[mid] = lanes * self.dp
        self.B = sum(groups.values())
        self.sched: SlotScheduler[TenantReadRequest] = SlotScheduler(
            self.B, slot_groups=groups)
        self._zero = {
            mid: np.zeros((p.chunk.window, p.mcfg.in_channels), np.float32)
            for mid, p in self._pipes.items()}
        self.steps = 0
        # in-flight tenants are never evicted from the registry: lanes are
        # the ground truth, so there is no per-lane pin to leak on cancel
        registry.add_use_hook(self._model_in_flight)

    def _model_in_flight(self, mid: str) -> bool:
        if mid not in self._pipes:
            return False
        rng = self.sched.group_range(mid)
        return any(self.sched.slots[s] is not None for s in rng)

    def _mesh_ctx(self):
        return shd.use_mesh(self.mesh)

    # -- EngineProtocol request adapters -----------------------------------
    def model_of(self, r) -> str:
        """The hosted id serving request ``r`` (its ``model=``, or the
        engine default) — also the Server's per-model metrics key."""
        return getattr(r, "model", None) or self.default_model

    def validate(self, r):
        """Unknown model ids resolve as a clear ``"error"`` at submit."""
        mid = self.model_of(r)
        if mid not in self._pipes:
            return (f"unknown model {mid!r}: this server hosts "
                    f"{sorted(self._pipes)}")
        return None

    def make_request(self, rid: int, r) -> TenantReadRequest:
        return TenantReadRequest(rid=rid, signal=np.asarray(r.signal),
                                 model=self.model_of(r))

    def degenerate(self, r) -> bool:
        """Zero-length signals of a HOSTED model decode to nothing; an
        unknown model is never degenerate (``validate`` must error it)."""
        if self.model_of(r) not in self._pipes:
            return False
        return np.asarray(r.signal).shape[0] == 0

    def empty_result(self, r) -> BasecallResult:
        pipe = self._pipes.get(self.model_of(r),
                               self._pipes[self.default_model])
        return BasecallResult.empty(pipe.max_read_len)

    def progress(self, native: TenantReadRequest) -> "_WindowView":
        return _WindowView(native)

    def result_of(self, native: TenantReadRequest) -> BasecallResult:
        assert native.result is not None
        return native.result

    # -- admission ---------------------------------------------------------
    def submit(self, req: TenantReadRequest):
        """Queue ``req`` (engine-direct callers get the same unknown-model
        guard the Server applies via ``validate``)."""
        err = self.validate(req)
        if err is not None:
            raise ValueError(err)
        self.sched.submit(req)

    def _admit_one(self, slot: int, req: TenantReadRequest):
        pipe = self._pipes[req.model]
        req.windows = chunking.chunk_signal(req.signal, pipe.chunk)
        req.frame_lengths = pipe.window_logit_lengths(
            np.asarray(req.signal).shape[0])
        req.cursor = 0

    def admit(self) -> List[int]:
        """Admit queued reads into their OWN model's lanes (per-group FIFO
        with per-group head-of-line blocking — a full tenant never stalls
        another tenant's admissions)."""
        admitted = self.sched.admit(self._admit_one,
                                    group_fn=lambda r: r.model)
        for slot in admitted:
            req = self.sched.slots[slot]
            if req is not None and req.windows.shape[0] == 0:
                self._finalize(req)
                self.sched.retire(slot, req.rid)
        return admitted

    # -- stepping ----------------------------------------------------------
    def active_mask(self) -> np.ndarray:
        return self.sched.active_mask()

    def model_occupancy(self) -> Dict[str, float]:
        """Per hosted model: fraction of ITS lanes occupied right now
        (the Server accumulates this into per-model ``metrics()`` rows)."""
        return {mid: self.sched.occupancy(group=mid) for mid in self.models}

    def device_occupancy(self) -> np.ndarray:
        """(dp,) per-device occupancy.  Every model's group is lane-major
        over the dp devices independently, so the per-device load is the
        mean of each group's own dp-fold — not one pool-wide reshape."""
        mask = self.sched.active_mask()
        occ = np.zeros((self.dp,))
        for mid in self.models:
            rng = self.sched.group_range(mid)
            occ += mask[rng.start:rng.stop].reshape(self.dp, -1).mean(axis=1)
        return occ / len(self.models)

    def _artifact(self, mid: str):
        pipe = self._pipes[mid]
        art = self.registry.artifact(mid)
        if self.mesh is not None:
            art = pipe._place_params(art, self.mesh)
        return art

    def step(self):
        """One window of decode for every occupied lane, model by model:
        each active tenant's group sub-batch (idle lanes zero-filled, so
        the batch shape — and the jit trace — is fixed per model) runs
        through that tenant's OWN jitted decode with its own artifact,
        pinned in the registry for the duration of the call."""
        for mid in self.models:
            rng = self.sched.group_range(mid)
            lanes = [self.sched.slots[s] for s in rng]
            if not any(r is not None for r in lanes):
                continue
            pipe = self._pipes[mid]
            zero = self._zero[mid]
            batch = np.stack([
                r.windows[r.cursor] if r is not None else zero
                for r in lanes])
            frames = np.asarray([
                r.frame_lengths[r.cursor] if r is not None else 0
                for r in lanes], np.int32)
            with self.registry.pinned(mid):
                art = self._artifact(mid)
                batch, frames = jnp.asarray(batch), jnp.asarray(frames)
                if self.mesh is not None:
                    batch = jax.device_put(
                        batch, shd.batch_sharding(self.mesh, batch.ndim))
                    frames = jax.device_put(
                        frames, shd.batch_sharding(self.mesh, frames.ndim))
                with self._mesh_ctx():
                    reads, lens, _scores = pipe._decode_windows(
                        art, batch, frames)
            reads, lens = np.asarray(reads), np.asarray(lens)
            for i, slot in enumerate(rng):
                req = self.sched.slots[slot]
                if req is None:
                    continue
                req.reads.append(reads[i])
                req.lengths.append(int(lens[i]))
                req.cursor += 1
                if req.cursor >= req.windows.shape[0]:
                    self._finalize(req)
                    self.sched.retire(slot, req.rid)
        self.steps += 1

    def _finalize(self, req: TenantReadRequest):
        pipe = self._pipes[req.model]
        if not req.reads:                      # zero-window (empty) signal
            req.result = BasecallResult.empty(pipe.max_read_len)
            return
        req.result = BasecallResult.from_window_reads(
            np.stack(req.reads), np.asarray(req.lengths, np.int32),
            max_read_len=pipe.max_read_len)
