"""Batched LM serving engine with continuous batching.

A fixed pool of B slots shares one jitted ``decode_step``; requests are
admitted into free slots and their prompt is folded in with a per-lane
``active`` mask (all other lanes are frozen: no KV write, no position
advance — see models/decode.py), every ``step()`` decodes one token for all
active slots, and finished requests (EOS / max_tokens) retire immediately
so their slot is reusable — the batch never drains to refill.

Slot/queue bookkeeping lives in ``serve.scheduler.SlotScheduler`` (shared
with the base-calling engine); this module owns what a step of work means
for token LMs.  Prompt folding runs as ONE jitted ``lax.scan`` over a
padded prompt bucket — one device call per admission instead of one per
prompt token (prompts are padded to the next power of two to bound
retraces; padded steps carry an all-False active mask, i.e. are no-ops).

Two KV-cache layouts (``kv_layout``):

  dense   one (B, L, Kv, hd) ring per layer, L = max_len (or the SWA
          window) — every lane reserves max-context memory up front.
  paged   one pooled (n_kv_blocks, block_size, Kv, hd) arena per layer
          (``models.decode.init_paged_cache``); lanes own arbitrary
          arena blocks via host-side BLOCK TABLES and the free-block
          allocator in ``SlotScheduler`` hands blocks out at admission,
          grows lanes one block at a time mid-flight, and reclaims on
          retire/release.  Lane count decouples from max context: memory
          follows actual sequence lengths, not the worst case.  When the
          arena partition runs dry mid-flight the lane is PREEMPTED —
          released and requeued at the queue front; greedy decode is
          deterministic, so refolding prompt + generated-so-far resumes
          bitwise identically.

Under an ambient ``dist.sharding.use_mesh`` at construction the engine
dp-shards its step like ``BasecallEngine``: params replicate across
devices, the (B,) step batch and the KV cache (lane dim dense / arena dim
paged) split over the logical "dp" axis, and the construction mesh is
re-installed around every device call.  The allocator's per-group
partitions align with the arena sharding, so each lane's block-table
gather stays device-local.

This is iteration-level scheduling (Orca-style) on a cache whose per-slot
positions make lanes fully independent; launch/specs.py's ``decode`` cells
lower exactly one engine step on the production mesh.

The engine is a pure step-executor implementing ``serve.api.
EngineProtocol`` (admit / step / retire + the request adapters); the
request lifecycle — queueing, backpressure, deadlines, cancellation,
streaming, the driver loop — lives in ``serve.api.Server``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import sharding as shd
from repro.models import decode as decode_lib
from repro.models import lm as lm_lib
from repro.serve.scheduler import SlotScheduler

KV_LAYOUTS = ("dense", "paged")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (P,) int32
    max_tokens: int = 32
    eos_id: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Continuous-batching step-executor for token LMs.

    Args:
        params: LM checkpoint pytree.
        cfg: its ``lm.LMConfig`` (must embed token inputs).
        batch_slots: device lanes **per dp device** — under an ambient
            ``dist.sharding.use_mesh`` mesh at construction the pool is
            ``batch_slots * dp_size`` lanes (dp = 1 without a mesh) and
            each step's (B,) batch + KV cache shard over the mesh's
            data-parallel devices.
        max_len: maximum context (prompt + generated) per lane.  Dense
            mode allocates this much KV per lane; paged mode only caps
            per-lane block-table width.
        pack: serve the quantize-once packed artifact (False keeps the
            float tree + per-call quantization as the oracle).
        kv_layout: "dense" (per-lane KV ring) or "paged" (pooled block
            arena + block tables; attention-decoder, no-SWA configs only).
        kv_block: paged mode: tokens per KV block.
        kv_blocks: paged mode: total arena size in blocks (rounded up to
            a dp multiple).  Defaults to dense-equivalent capacity,
            ``B * ceil(max_len / kv_block)``; smaller values trade
            worst-case capacity for more lanes per byte (preemption
            keeps overflow correct).
        model_id: optional hosted-model name; requests naming a different
            ``model=`` resolve with a clear ``"error"`` result at submit,
            and the Server's per-model metrics key on it.  Set by
            :meth:`from_registry` for registry-backed fleets.
    """

    def __init__(self, params, cfg: lm_lib.LMConfig, batch_slots: int = 8,
                 max_len: int = 256, pack: bool = True,
                 kv_layout: str = "dense", kv_block: int = 16,
                 kv_blocks: Optional[int] = None,
                 model_id: Optional[str] = None):
        assert cfg.embed_inputs, "engine serves token models"
        self.model_id = model_id
        if kv_layout not in KV_LAYOUTS:
            raise ValueError(f"unknown kv_layout {kv_layout!r}; "
                             f"one of {KV_LAYOUTS}")
        if pack:
            # the engine holds the quantize-once serving artifact: every
            # qdense weight pre-snapped to the b-bit grid, so the jitted
            # decode/fold traces carry no weight-quantization ops (a no-op
            # when cfg.quant is disabled).  pack=False keeps the float
            # tree + per-call quantization as the differential oracle.
            params, cfg = lm_lib.pack_lm_serving(params, cfg)
        self.cfg = cfg
        # slot capacity AND the step batch scale with the ambient mesh's
        # data-parallel size (batch_slots lanes per dp device; dp = 1
        # single-device) — the mesh is captured here and re-installed
        # around every device call, exactly like BasecallEngine
        self.mesh = shd.get_mesh()
        self.dp = shd.dp_size(self.mesh)
        self.B = batch_slots * self.dp
        self.max_len = max_len
        self.kv_layout = kv_layout
        self.params = params
        if self.mesh is not None:
            self.params = jax.device_put(
                self.params,
                shd.replicated_sharding_tree(self.params, self.mesh))

        if kv_layout == "paged":
            self.kv_block = kv_block
            #: per-lane block-table width (caps context at max_len)
            self.max_blocks = -(-max_len // kv_block)
            n = kv_blocks if kv_blocks is not None else \
                self.B * self.max_blocks
            n = -(-n // self.dp) * self.dp       # partitions must divide
            self.n_kv_blocks = n
            self.cache = decode_lib.init_paged_cache(cfg, self.B, n,
                                                     kv_block)
            self.sched: SlotScheduler[Request] = SlotScheduler(
                self.B, kv_blocks=n, kv_groups=self.dp)
            # host-side block tables: -1 = unallocated (clipped to 0 when
            # shipped; those gathers are masked by n_valid = pos + 1)
            self.block_tables = np.full((self.B, self.max_blocks), -1,
                                        np.int32)
            # host mirror of each lane's next write position, so growth
            # checks never read device state
            self.lane_pos = np.zeros((self.B,), np.int64)
            self.preemptions = 0
        else:
            self.cache = decode_lib.init_cache(cfg, self.B, max_len)
            self.sched = SlotScheduler(self.B)
        self.cache = self._place_cache(self.cache)
        self.last_token = np.zeros((self.B,), np.int32)
        self.steps = 0

        paged = kv_layout == "paged"
        B = self.B

        if paged:
            def one_step(params, cache, tokens, active, block_tables):
                logits, cache = decode_lib.decode_step(
                    params, cfg, cache, tokens=tokens, active=active,
                    block_tables=block_tables)
                nxt = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)
                return nxt.astype(jnp.int32), cache
        else:
            def one_step(params, cache, tokens, active):
                logits, cache = decode_lib.decode_step(params, cfg, cache,
                                                       tokens=tokens,
                                                       active=active)
                nxt = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)
                return nxt.astype(jnp.int32), cache

        self._decode = jax.jit(one_step, donate_argnums=(1,))

        def reset_slot(cache, slot):
            """Zero one lane's position.  The previous tenant's K/V stays
            in place but is UNREACHABLE: attention validity is the prefix
            ``arange < pos + 1`` (dense; min'd with L) or ``pos + 1`` over
            the lane's own block table (paged), and pos restarts at 0 —
            see the cross-request isolation tests in
            tests/test_paged_serve.py."""
            return {"blocks": cache["blocks"],
                    "pos": cache["pos"].at[slot].set(0)}

        self._reset_slot = jax.jit(reset_slot, donate_argnums=(0,))

        if paged:
            def fold_prompt(params, cache, tokens, valid, slot,
                            block_tables):
                lane = jnp.zeros((B,), bool).at[slot].set(True)

                def body(c, tv):
                    tok, v = tv
                    toks = jnp.zeros((B,), jnp.int32).at[slot].set(tok)
                    _, c = decode_lib.decode_step(
                        params, cfg, c, tokens=toks, active=lane & v,
                        block_tables=block_tables)
                    return c, None

                cache, _ = jax.lax.scan(body, cache, (tokens, valid))
                return cache
        else:
            def fold_prompt(params, cache, tokens, valid, slot):
                """Fold a padded prompt into one lane as a single scan.

                tokens (P,) int32 prompt body; valid (P,) bool marks real
                entries — padded steps mask the whole batch inactive,
                which decode_step turns into a pure no-op (no write, no
                advance)."""
                lane = jnp.zeros((B,), bool).at[slot].set(True)

                def body(c, tv):
                    tok, v = tv
                    toks = jnp.zeros((B,), jnp.int32).at[slot].set(tok)
                    _, c = decode_lib.decode_step(params, cfg, c,
                                                  tokens=toks,
                                                  active=lane & v)
                    return c, None

                cache, _ = jax.lax.scan(body, cache, (tokens, valid))
                return cache

        self._fold = jax.jit(fold_prompt, donate_argnums=(1,))

    @classmethod
    def from_registry(cls, registry, model_id: str, **kw) -> "ServingEngine":
        """Serve a ``ModelRegistry`` LM tenant: consumes the registry's
        cached ``(packed params, serving config)`` artifact (built by
        ``register_lm`` via ``pack_lm_serving`` — quantize-once, so this
        is bitwise-identical to constructing with ``pack=True`` from the
        float checkpoint) and installs ``model_id`` routing."""
        if registry.kind(model_id) != "lm":
            raise TypeError(f"model {model_id!r} is kind "
                            f"{registry.kind(model_id)!r}, not an lm")
        packed, scfg = registry.artifact(model_id)
        return cls(packed, scfg, pack=False, model_id=model_id, **kw)

    # -- device placement --------------------------------------------------
    def _mesh_ctx(self):
        """The construction-time mesh, re-installed around device calls so
        the jitted decode traces with its sharding constraints no matter
        what mesh (if any) is ambient when the server drives us
        (``use_mesh(None)`` masks an ambient mesh for a no-mesh engine)."""
        return shd.use_mesh(self.mesh)

    def _place_cache(self, cache):
        """Shard the cache over dp at construction: the lane dim (dense)
        or the pooled arena dim (paged — allocator partitions align, so
        every lane's blocks live on its own device)."""
        if self.mesh is None:
            return cache
        from jax.sharding import NamedSharding, PartitionSpec as P

        def f(x):
            spec = [None] * x.ndim
            if x.ndim >= 2:                 # (layers, B-or-N, ...)
                spec[1] = shd.logical_spec(("dp",), self.mesh)[0]
            return jax.device_put(x, NamedSharding(self.mesh, P(*spec)))

        blocks = jax.tree_util.tree_map(f, cache["blocks"])
        pos = jax.device_put(cache["pos"],
                             shd.batch_sharding(self.mesh, 1))
        return {"blocks": blocks, "pos": pos}

    def _put_batch(self, *arrays):
        """device_put per-lane step inputs with dim 0 split over dp."""
        if self.mesh is None:
            return arrays
        return tuple(
            jax.device_put(a, shd.batch_sharding(self.mesh, a.ndim))
            for a in arrays)

    # -- compatibility views over the scheduler ---------------------------
    @property
    def queue(self) -> List[Request]:
        return self.sched.queue

    @property
    def finished(self) -> Dict[int, Request]:
        return self.sched.finished

    @property
    def slot_req(self) -> List[Optional[Request]]:
        return self.sched.slots

    # -- EngineProtocol request adapters -----------------------------------
    event_kind = "token"

    def make_request(self, rid: int, r) -> Request:
        return Request(rid=rid, prompt=np.asarray(r.prompt, np.int32),
                       max_tokens=r.max_tokens, eos_id=r.eos_id)

    def model_of(self, r) -> Optional[str]:
        """The model id serving ``r`` (its ``model=``, or this engine's)."""
        return getattr(r, "model", None) or self.model_id

    def degenerate(self, r) -> bool:
        """Nothing to decode: a zero/negative token budget or an empty
        prompt (no last token to feed the first step) — admitted lanes
        would wedge or crash, so the server completes these inline.
        Misrouted models are never degenerate: ``validate`` errors them."""
        m = getattr(r, "model", None)
        if m is not None and m != self.model_id:
            return False
        return r.max_tokens <= 0 or np.asarray(r.prompt).shape[0] == 0

    def empty_result(self, r) -> List[int]:
        return []

    def validate(self, r) -> Optional[str]:
        """Reject requests the cache cannot hold BEFORE they wedge a lane.

        A request with ``len(prompt) + max_tokens > max_len`` would wrap
        the dense KV ring (``slot = pos % L``) and silently attend over
        clobbered history.  Sliding-window configs are exempt: there the
        ring IS the window (``cache_len = min(window, max_len)``) and
        wrapping is the intended layout.  Paged mode additionally rejects
        requests larger than one arena partition (they could never admit,
        deadlocking the FIFO queue head).

        Requests naming a model this engine does not host are rejected
        the same way (clear ``"error"`` result, never a wrong-weights
        decode).  Returns an error message, or None when servable.
        """
        m = getattr(r, "model", None)
        if m is not None and m != self.model_id:
            hosts = (f"[{self.model_id!r}]" if self.model_id is not None
                     else "one anonymous model (no model= routing)")
            return f"unknown model {m!r}: this server hosts {hosts}"
        P = int(np.asarray(r.prompt).shape[0])
        total = P + int(r.max_tokens)
        if self.cfg.window:
            return None                 # ring wrap is the SWA design
        if total > self.max_len:
            return (f"prompt ({P} tokens) + max_tokens ({r.max_tokens}) "
                    f"= {total} exceeds max_len={self.max_len}: the KV "
                    "cache would wrap and corrupt attention history. "
                    "Shorten the request or raise max_len")
        if self.kv_layout == "paged":
            need = -(-total // self.kv_block)
            per_group = self.n_kv_blocks // self.dp
            if need > per_group:
                return (f"request needs {need} KV blocks but an arena "
                        f"partition holds {per_group} "
                        f"({self.n_kv_blocks} blocks / {self.dp} dp "
                        "device(s)): it could never be admitted. Raise "
                        "kv_blocks or shorten the request")
        return None

    def progress(self, native: Request) -> List[int]:
        return native.out_tokens

    def result_of(self, native: Request) -> List[int]:
        return list(native.out_tokens)

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request):
        err = self.validate(req)
        if err is not None:
            raise ValueError(err)
        self.sched.submit(req)

    def _blocks_needed(self, req: Request) -> int:
        """KV blocks a (re-)admission must hold up front: enough to cover
        every fold write (positions 0 .. len-2), at least one so the
        first step's write has a home.  Growth covers the rest."""
        n = int(np.asarray(req.prompt).shape[0]) + len(req.out_tokens) - 1
        return max(1, -(-n // self.kv_block)) if n > 0 else 1

    def _admit_one(self, slot: int, req: Request):
        """Fold the prompt into `slot` while other lanes stay frozen.

        After a preemption ``req.out_tokens`` is non-empty: the fold
        replays prompt + generated-so-far, which greedy (argmax) decoding
        makes bitwise identical to the uninterrupted run."""
        seq = np.concatenate([np.asarray(req.prompt, np.int32),
                              np.asarray(req.out_tokens, np.int32)])
        if self.kv_layout == "paged":
            # sched.admit(need_fn) pre-allocated this lane's blocks; the
            # top-up only fires when tests drive _admit_one directly
            need = self._blocks_needed(req)
            have = len(self.sched.slot_blocks[slot])
            if have < need:
                self.sched.alloc_blocks(slot, need - have)
            row = self.block_tables[slot]
            row[:] = -1
            blocks = self.sched.slot_blocks[slot]
            row[: len(blocks)] = blocks
            self.lane_pos[slot] = seq.size - 1
        with self._mesh_ctx():
            self.cache = self._reset_slot(self.cache, slot)
            body = seq[:-1]
            if body.size:
                P = 1 << max(int(body.size) - 1, 0).bit_length()
                toks = np.zeros((P,), np.int32)
                toks[: body.size] = body
                valid = np.zeros((P,), bool)
                valid[: body.size] = True
                args = [self.params, self.cache, jnp.asarray(toks),
                        jnp.asarray(valid), jnp.asarray(slot)]
                if self.kv_layout == "paged":
                    args.append(self._ship_tables())
                self.cache = self._fold(*args)
        self.last_token[slot] = int(seq[-1])

    def _admit_one_unfolded(self, slot: int, req: Request):
        """Reference admission: one decode_step per prompt token.  Kept as
        the oracle the folded path is asserted against (tests/test_serve)."""
        seq = np.concatenate([np.asarray(req.prompt, np.int32),
                              np.asarray(req.out_tokens, np.int32)])
        if self.kv_layout == "paged":
            need = self._blocks_needed(req)
            have = len(self.sched.slot_blocks[slot])
            if have < need:
                self.sched.alloc_blocks(slot, need - have)
            row = self.block_tables[slot]
            row[:] = -1
            blocks = self.sched.slot_blocks[slot]
            row[: len(blocks)] = blocks
            self.lane_pos[slot] = seq.size - 1
        with self._mesh_ctx():
            self.cache = self._reset_slot(self.cache, slot)
            active = np.zeros((self.B,), bool)
            active[slot] = True
            for t in seq[:-1]:
                toks = np.array(self.last_token)
                toks[slot] = int(t)
                args = [self.params, self.cache, jnp.asarray(toks),
                        jnp.asarray(active)]
                if self.kv_layout == "paged":
                    args.append(self._ship_tables())
                _, self.cache = self._decode(*args)
        self.last_token[slot] = int(seq[-1])

    def admit(self) -> List[int]:
        if self.kv_layout == "paged":
            return self.sched.admit(self._admit_one,
                                    need_fn=self._blocks_needed)
        return self.sched.admit(self._admit_one)

    # -- decoding -----------------------------------------------------------
    def active_mask(self) -> np.ndarray:
        return self.sched.active_mask()

    def _ship_tables(self) -> jnp.ndarray:
        """Block tables as shipped into the trace: fixed (B, max_blocks)
        shape (no retraces as lanes grow), -1 clipped to 0 (those entries
        gather garbage that n_valid masks)."""
        bt = jnp.asarray(np.maximum(self.block_tables, 0))
        if self.mesh is not None:
            bt = jax.device_put(bt, shd.batch_sharding(self.mesh, 2))
        return bt

    def _ensure_capacity(self):
        """Grow every active lane whose next write crosses a block
        boundary; preempt (release + requeue at the queue FRONT, keeping
        generated tokens) when its arena partition is dry.  Preempted
        lanes free their blocks immediately, so later lanes in the same
        partition may still grow this very step."""
        for slot in range(self.B):
            req = self.sched.slots[slot]
            if req is None:
                continue
            have = len(self.sched.slot_blocks[slot])
            if int(self.lane_pos[slot]) < have * self.kv_block:
                continue
            blk = self.sched.grow_block(slot)
            if blk is not None:
                self.block_tables[slot, have] = blk
            else:
                self.sched.release(slot)         # reclaims its blocks
                self.block_tables[slot, :] = -1
                self.sched.queue.insert(0, req)  # FIFO: retry first
                self.preemptions += 1

    def step(self):
        if self.kv_layout == "paged":
            self._ensure_capacity()
        active = self.active_mask()
        if not active.any():
            return                  # every lane preempted this tick
        args = [jnp.asarray(self.last_token), jnp.asarray(active)]
        args = list(self._put_batch(*args))
        if self.kv_layout == "paged":
            args.append(self._ship_tables())
        with self._mesh_ctx():
            nxt, self.cache = self._decode(self.params, self.cache, *args)
        nxt = np.asarray(nxt)
        self.steps += 1
        for slot, req in enumerate(self.sched.slots):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.out_tokens.append(tok)
            self.last_token[slot] = tok
            if self.kv_layout == "paged":
                self.lane_pos[slot] += 1
            if ((req.eos_id is not None and tok == req.eos_id)
                    or len(req.out_tokens) >= req.max_tokens):
                req.done = True
                self.sched.retire(slot, req.rid)
                if self.kv_layout == "paged":
                    self.block_tables[slot, :] = -1
