"""Batched LM serving engine with continuous batching.

A fixed pool of B slots shares one jitted ``decode_step``; requests are
admitted into free slots and their prompt is folded in with a per-lane
``active`` mask (all other lanes are frozen: no KV write, no position
advance — see models/decode.py), every ``step()`` decodes one token for all
active slots, and finished requests (EOS / max_tokens) retire immediately
so their slot is reusable — the batch never drains to refill.

This is iteration-level scheduling (Orca-style) on a cache whose per-slot
positions make lanes fully independent; launch/specs.py's ``decode`` cells
lower exactly one engine step on the production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode as decode_lib
from repro.models import lm as lm_lib


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (P,) int32
    max_tokens: int = 32
    eos_id: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, params, cfg: lm_lib.LMConfig, batch_slots: int = 8,
                 max_len: int = 256):
        assert cfg.embed_inputs, "engine serves token models"
        self.params = params
        self.cfg = cfg
        self.B = batch_slots
        self.max_len = max_len
        self.cache = decode_lib.init_cache(cfg, batch_slots, max_len)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self.last_token = np.zeros((batch_slots,), np.int32)
        self.steps = 0

        def one_step(params, cache, tokens, active):
            logits, cache = decode_lib.decode_step(params, cfg, cache,
                                                   tokens=tokens,
                                                   active=active)
            nxt = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)
            return nxt.astype(jnp.int32), cache

        self._decode = jax.jit(one_step, donate_argnums=(1,))

        def reset_slot(cache, slot):
            """Zero one lane's position (its stale KV is masked by pos)."""
            return {"blocks": cache["blocks"],
                    "pos": cache["pos"].at[slot].set(0)}

        self._reset_slot = jax.jit(reset_slot, donate_argnums=(0,))

    # -- admission -------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit_one(self, slot: int, req: Request):
        """Fold the prompt into `slot` while other lanes stay frozen."""
        self.cache = self._reset_slot(self.cache, slot)
        active = np.zeros((self.B,), bool)
        active[slot] = True
        for t in req.prompt[:-1]:
            toks = np.array(self.last_token)
            toks[slot] = int(t)
            _, self.cache = self._decode(self.params, self.cache,
                                         jnp.asarray(toks),
                                         jnp.asarray(active))
        self.last_token[slot] = int(req.prompt[-1])
        self.slot_req[slot] = req

    def _admit(self):
        for slot in range(self.B):
            if self.slot_req[slot] is None and self.queue:
                self._admit_one(slot, self.queue.pop(0))

    # -- decoding --------------------------------------------------------------
    def active_mask(self) -> np.ndarray:
        return np.asarray([r is not None for r in self.slot_req])

    def step(self):
        active = self.active_mask()
        nxt, self.cache = self._decode(self.params, self.cache,
                                       jnp.asarray(self.last_token),
                                       jnp.asarray(active))
        nxt = np.asarray(nxt)
        self.steps += 1
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.out_tokens.append(tok)
            self.last_token[slot] = tok
            if ((req.eos_id is not None and tok == req.eos_id)
                    or len(req.out_tokens) >= req.max_tokens):
                req.done = True
                self.finished[req.rid] = req
                self.slot_req[slot] = None

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        while (self.queue or any(self.active_mask())) and max_steps > 0:
            self._admit()
            if any(self.active_mask()):
                self.step()
            max_steps -= 1
        return self.finished
