"""Batched LM serving engine with continuous batching.

A fixed pool of B slots shares one jitted ``decode_step``; requests are
admitted into free slots and their prompt is folded in with a per-lane
``active`` mask (all other lanes are frozen: no KV write, no position
advance — see models/decode.py), every ``step()`` decodes one token for all
active slots, and finished requests (EOS / max_tokens) retire immediately
so their slot is reusable — the batch never drains to refill.

Slot/queue bookkeeping lives in ``serve.scheduler.SlotScheduler`` (shared
with the base-calling engine); this module owns what a step of work means
for token LMs.  Prompt folding runs as ONE jitted ``lax.scan`` over a
padded prompt bucket — one device call per admission instead of one per
prompt token (prompts are padded to the next power of two to bound
retraces; padded steps carry an all-False active mask, i.e. are no-ops).

This is iteration-level scheduling (Orca-style) on a cache whose per-slot
positions make lanes fully independent; launch/specs.py's ``decode`` cells
lower exactly one engine step on the production mesh.

The engine is a pure step-executor implementing ``serve.api.
EngineProtocol`` (admit / step / retire + the request adapters); the
request lifecycle — queueing, backpressure, deadlines, cancellation,
streaming, the driver loop — lives in ``serve.api.Server``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import sharding as shd
from repro.models import decode as decode_lib
from repro.models import lm as lm_lib
from repro.serve.scheduler import SlotScheduler


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (P,) int32
    max_tokens: int = 32
    eos_id: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Continuous-batching step-executor for token LMs.

    Args:
        params: LM checkpoint pytree.
        cfg: its ``lm.LMConfig`` (must embed token inputs).
        batch_slots: device lanes **per dp device** — under an ambient
            ``dist.sharding.use_mesh`` mesh at construction the pool is
            ``batch_slots * dp_size`` lanes (dp = 1 without a mesh).
            Capacity scaling only: unlike ``BasecallEngine``, the LM
            decode batch itself still runs unsharded (dp-sharding the
            KV cache is an open item).
        max_len: KV-cache length per lane.
        pack: serve the quantize-once packed artifact (False keeps the
            float tree + per-call quantization as the oracle).
    """

    def __init__(self, params, cfg: lm_lib.LMConfig, batch_slots: int = 8,
                 max_len: int = 256, pack: bool = True):
        assert cfg.embed_inputs, "engine serves token models"
        if pack:
            # the engine holds the quantize-once serving artifact: every
            # qdense weight pre-snapped to the b-bit grid, so the jitted
            # decode/fold traces carry no weight-quantization ops (a no-op
            # when cfg.quant is disabled).  pack=False keeps the float
            # tree + per-call quantization as the differential oracle.
            params, cfg = lm_lib.pack_lm_serving(params, cfg)
        self.params = params
        self.cfg = cfg
        # slot capacity scales with the ambient mesh's data-parallel size
        # (batch_slots lanes per dp device; dp = 1 single-device)
        self.dp = shd.dp_size()
        self.B = batch_slots * self.dp
        self.max_len = max_len
        self.cache = decode_lib.init_cache(cfg, self.B, max_len)
        self.sched: SlotScheduler[Request] = SlotScheduler(self.B)
        self.last_token = np.zeros((self.B,), np.int32)
        self.steps = 0

        def one_step(params, cache, tokens, active):
            logits, cache = decode_lib.decode_step(params, cfg, cache,
                                                   tokens=tokens,
                                                   active=active)
            nxt = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)
            return nxt.astype(jnp.int32), cache

        self._decode = jax.jit(one_step, donate_argnums=(1,))

        def reset_slot(cache, slot):
            """Zero one lane's position (its stale KV is masked by pos)."""
            return {"blocks": cache["blocks"],
                    "pos": cache["pos"].at[slot].set(0)}

        self._reset_slot = jax.jit(reset_slot, donate_argnums=(0,))

        B = self.B

        def fold_prompt(params, cache, tokens, valid, slot):
            """Fold a padded prompt into one lane as a single scan.

            tokens (P,) int32 prompt body; valid (P,) bool marks real
            entries — padded steps mask the whole batch inactive, which
            decode_step turns into a pure no-op (no write, no advance).
            """
            lane = jnp.zeros((B,), bool).at[slot].set(True)

            def body(c, tv):
                tok, v = tv
                toks = jnp.zeros((B,), jnp.int32).at[slot].set(tok)
                _, c = decode_lib.decode_step(params, cfg, c, tokens=toks,
                                              active=lane & v)
                return c, None

            cache, _ = jax.lax.scan(body, cache, (tokens, valid))
            return cache

        self._fold = jax.jit(fold_prompt, donate_argnums=(1,))

    # -- compatibility views over the scheduler ---------------------------
    @property
    def queue(self) -> List[Request]:
        return self.sched.queue

    @property
    def finished(self) -> Dict[int, Request]:
        return self.sched.finished

    @property
    def slot_req(self) -> List[Optional[Request]]:
        return self.sched.slots

    # -- EngineProtocol request adapters -----------------------------------
    event_kind = "token"

    def make_request(self, rid: int, r) -> Request:
        return Request(rid=rid, prompt=np.asarray(r.prompt, np.int32),
                       max_tokens=r.max_tokens, eos_id=r.eos_id)

    def degenerate(self, r) -> bool:
        """Nothing to decode: a zero/negative token budget or an empty
        prompt (no last token to feed the first step) — admitted lanes
        would wedge or crash, so the server completes these inline."""
        return r.max_tokens <= 0 or np.asarray(r.prompt).shape[0] == 0

    def empty_result(self, r) -> List[int]:
        return []

    def progress(self, native: Request) -> List[int]:
        return native.out_tokens

    def result_of(self, native: Request) -> List[int]:
        return list(native.out_tokens)

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request):
        self.sched.submit(req)

    def _admit_one(self, slot: int, req: Request):
        """Fold the prompt into `slot` while other lanes stay frozen."""
        self.cache = self._reset_slot(self.cache, slot)
        body = np.asarray(req.prompt[:-1], np.int32)
        if body.size:
            P = 1 << max(int(body.size) - 1, 0).bit_length()
            toks = np.zeros((P,), np.int32)
            toks[: body.size] = body
            valid = np.zeros((P,), bool)
            valid[: body.size] = True
            self.cache = self._fold(self.params, self.cache,
                                    jnp.asarray(toks), jnp.asarray(valid),
                                    jnp.asarray(slot))
        self.last_token[slot] = int(req.prompt[-1])

    def _admit_one_unfolded(self, slot: int, req: Request):
        """Reference admission: one decode_step per prompt token.  Kept as
        the oracle the folded path is asserted against (tests/test_serve)."""
        self.cache = self._reset_slot(self.cache, slot)
        active = np.zeros((self.B,), bool)
        active[slot] = True
        for t in req.prompt[:-1]:
            toks = np.array(self.last_token)
            toks[slot] = int(t)
            _, self.cache = self._decode(self.params, self.cache,
                                         jnp.asarray(toks),
                                         jnp.asarray(active))
        self.last_token[slot] = int(req.prompt[-1])

    def admit(self) -> List[int]:
        return self.sched.admit(self._admit_one)

    # -- decoding -----------------------------------------------------------
    def active_mask(self) -> np.ndarray:
        return self.sched.active_mask()

    def step(self):
        active = self.active_mask()
        nxt, self.cache = self._decode(self.params, self.cache,
                                       jnp.asarray(self.last_token),
                                       jnp.asarray(active))
        nxt = np.asarray(nxt)
        self.steps += 1
        for slot, req in enumerate(self.sched.slots):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.out_tokens.append(tok)
            self.last_token[slot] = tok
            if ((req.eos_id is not None and tok == req.eos_id)
                    or len(req.out_tokens) >= req.max_tokens):
                req.done = True
                self.sched.retire(slot, req.rid)
