"""Central kernel backend registry — ONE dispatch point for all Pallas ops.

Every accelerated op in the repo is registered here under a name with two
implementations:

  ref     — pure-jnp oracle, identical public signature (differentiable,
            runs anywhere; also the numerics ground truth in tests)
  pallas  — the tiled Pallas TPU kernel behind its padding wrapper; takes
            an ``interpret`` keyword so the same body executes on CPU

and callers resolve a concrete callable with::

    op = registry.get_op("quant_matmul", backend="auto")

Backends:
  auto       pallas on TPU, interpret elsewhere (the old per-op
             ``_auto_interpret`` heuristic, now in exactly one place)
  pallas     compiled Pallas kernel (TPU)
  interpret  Pallas kernel body on the interpreter (CPU-testable)
  ref        the jnp oracle

``Backend`` is the value models/pipeline code threads around: a frozen,
hashable switch (safe as a jit static argument) whose ``op(name)`` resolves
through this registry.  ``set_default_backend`` rebinds what "auto" means
process-wide (benchmarks ``--backend``, CI).

Ops register themselves at import of their ``ops.py``; ``get_op`` lazily
imports the owning module so callers never need kernel-package imports.
"""
from __future__ import annotations

import dataclasses
import difflib
import functools
import importlib
import os
from typing import Callable, Dict, Optional

import jax

BACKENDS = ("auto", "pallas", "interpret", "ref")

# op name -> module that registers it (lazy import on first get_op)
_OP_MODULES = {
    "quant_matmul": "repro.kernels.quant_matmul.ops",
    "gru_cell": "repro.kernels.gru_cell.ops",
    "gru_seq": "repro.kernels.gru_seq.ops",
    "beam_merge_multiframe": "repro.kernels.beam_strip.ops",
    "masked_logsumexp": "repro.kernels.ctc_merge.ops",
    "beam_merge_topk": "repro.kernels.ctc_merge.ops",
    "decode_attn": "repro.kernels.decode_attn.ops",
    "paged_decode_attn": "repro.kernels.decode_attn.ops",
    "mismatch_bits": "repro.kernels.vote_cmp.ops",
}


@dataclasses.dataclass(frozen=True)
class OpEntry:
    name: str
    ref: Callable
    pallas: Callable      # must accept an ``interpret: bool`` keyword
    # zero-argument factory returning ``(args, kwargs)`` exercising the op
    # on representative (deliberately ragged) shapes; used by
    # ``repro.analysis.kernel_checks`` to trace the kernel statically
    example: Optional[Callable] = None


_REGISTRY: Dict[str, OpEntry] = {}

# ``REPRO_DEFAULT_BACKEND`` seeds what "auto" means for the process (the CI
# backend matrix sets it); validated lazily at FIRST USE so a bad value
# produces one clear ValueError from the resolving call site instead of an
# opaque import-time failure in whatever module touched the registry first.
_default_backend: Optional[str] = None


def register_op(name: str, *, ref: Callable, pallas: Callable,
                example: Optional[Callable] = None) -> None:
    """Register (or re-register) an op's reference + Pallas implementations.

    Called at import time by each kernel package's ``ops.py`` (see
    ``docs/kernels.md`` for the add-an-op walkthrough).

    Args:
        name: the registry key callers resolve with :func:`get_op`.
        ref: pure-jnp oracle — identical public signature, runs anywhere,
            and is the numerics ground truth in tests.
        pallas: the Pallas kernel wrapper; must accept an
            ``interpret: bool`` keyword (the registry supplies it for the
            "interpret" backend).
        example: zero-argument factory returning ``(args, kwargs)`` on
            representative shapes — lets ``repro.analysis`` (and other
            tooling) trace the op without knowing its signature.

    Returns:
        None.

    Example::

        register_op("my_op", ref=my_op_ref, pallas=my_op_pallas,
                    example=lambda: ((jnp.zeros((3, 5)),), {}))
    """
    prev = _REGISTRY.get(name)
    if example is None and prev is not None:
        example = prev.example   # re-registration (tests) keeps the example
    _REGISTRY[name] = OpEntry(name=name, ref=ref, pallas=pallas,
                              example=example)


def list_ops() -> tuple:
    """All registered op names (forces registration of the known set)."""
    for name in _OP_MODULES:
        _ensure(name)
    return tuple(sorted(_REGISTRY))


def set_default_backend(backend: str) -> None:
    """Process-wide backend used when callers pass backend=None/"auto"."""
    global _default_backend
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    _default_backend = backend


def get_default_backend() -> str:
    """The process default backend, seeding ``REPRO_DEFAULT_BACKEND``.

    The env value is validated HERE, on first use: a typo like
    ``REPRO_DEFAULT_BACKEND=cuda`` raises one actionable ValueError from
    the call that first resolves a backend, not an import-time crash and
    not a shape error deep in kernel dispatch.
    """
    global _default_backend
    if _default_backend is None:
        env = os.environ.get("REPRO_DEFAULT_BACKEND", "auto")
        if env not in BACKENDS:
            raise ValueError(
                f"REPRO_DEFAULT_BACKEND={env!r} is not a known backend; "
                f"expected one of {BACKENDS}")
        _default_backend = env
    return _default_backend


def resolve_backend(backend: Optional[str] = None) -> str:
    """None/"auto" -> the concrete backend for this process/host."""
    b = backend or get_default_backend()
    if b == "auto":
        b = get_default_backend()
    if b == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "interpret"
    if b not in BACKENDS:
        raise ValueError(f"unknown backend {b!r}; one of {BACKENDS}")
    return b


def _ensure(name: str) -> OpEntry:
    if name not in _REGISTRY:
        mod = _OP_MODULES.get(name)
        if mod is not None:
            importlib.import_module(mod)
    if name not in _REGISTRY:
        close = difflib.get_close_matches(name, list(_OP_MODULES), n=1)
        hint = f"; did you mean {close[0]!r}?" if close else ""
        raise KeyError(f"unknown op {name!r}{hint} "
                       f"(known: {sorted(set(_REGISTRY) | set(_OP_MODULES))})")
    return _REGISTRY[name]


def get_op(name: str, backend: Optional[str] = None) -> Callable:
    """Resolve an op to a concrete callable for ``backend``.

    Args:
        name: a registered op name (``list_ops()`` enumerates them; the
            owning kernel module is imported lazily on first use).
        backend: "auto" | "pallas" | "interpret" | "ref", or None for the
            process default (``set_default_backend`` /
            ``REPRO_DEFAULT_BACKEND``).

    Returns:
        The op's concrete callable: the jnp oracle for "ref", otherwise
        the Pallas wrapper with ``interpret`` pre-bound.

    Raises:
        KeyError: unknown op name (with a did-you-mean hint).

    Example::

        qmm = get_op("quant_matmul", backend="interpret")
    """
    entry = _ensure(name)
    b = resolve_backend(backend)
    if b == "ref":
        return entry.ref
    return functools.partial(entry.pallas, interpret=(b == "interpret"))


@dataclasses.dataclass(frozen=True)
class Backend:
    """The single compute-backend switch threaded through models/pipeline.

    Frozen + hashable so it can ride through jit static arguments.  ``mode``
    is a registry backend name; ``op(name)`` resolves through the registry
    at trace time.
    """
    mode: str = "auto"

    def __post_init__(self):
        if self.mode not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.mode!r}; one of {BACKENDS}")

    def op(self, name: str) -> Callable:
        """Resolve op ``name`` through the registry on this backend."""
        return get_op(name, self.mode)

    @property
    def resolved(self) -> str:
        return resolve_backend(self.mode)
