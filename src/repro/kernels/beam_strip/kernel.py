"""Pallas TPU kernel: persistent multi-frame CTC beam merge.

The serving decoder launches ``beam_merge_topk`` once per frame — beam
state (hashes, log-masses, last symbol, lengths) round-trips through HBM
between every launch.  This kernel is the decode-side analogue of the
persistent GRU walk (kernels/gru_seq): ONE ``pallas_call`` per strip of
F frames, grid (B, F) with semantics ("parallel", "arbitrary"), where

  * the six beam-state arrays live in the OUTPUT refs, whose BlockSpec
    index maps ignore the frame coordinate — Pallas keeps those blocks
    resident in VMEM across the whole strip and writes them back once,
  * state is seeded from the input refs under ``@pl.when(f == 0)``,
  * only the (1, A) log-prob row streams in and the (1, W) winner-index
    row streams out per frame.

Per-frame math is the per-frame decoder's candidate assembly verbatim
(stays ``[0, W)``, extends ``W + w*nsym + j``) followed by the SHARED
``merge_rank_select`` body from kernels/ctc_merge — one merge
implementation, so per-frame and multi-frame stay bitwise
interchangeable by construction.  Candidates are padded to the 128 lane
tile in-kernel with the same inert scheme as the per-frame wrapper:
unique lane-index keys + MASK-level scores, which contribute exactly 0.0
to every pooled mass and rank strictly after every real lane.

VMEM per grid step: the (Cp x Cp) merge planes dominate — W = 10, A = 5
gives Cp = 128, i.e. a few hundred KiB; W up to ~45 (Cp = 256) stays far
inside the 16 MiB budget (``repro.analysis`` pass 2 checks the
registered example).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels._compat import CompilerParams
from repro.kernels.beam_strip.ref import _MUL_I32, NEG
from repro.kernels.ctc_merge.kernel import merge_rank_select
from repro.kernels.ctc_merge.ref import MASK


def _strip_kernel(lp_ref, act_ref, keys_in, pb_in, pnb_in, last_in, len_in,
                  idx_ref, keys_ref, pb_ref, pnb_ref, last_ref, len_ref,
                  *, blank: int, L: int, A: int, W: int):
    f = pl.program_id(1)

    @pl.when(f == 0)
    def _init():
        keys_ref[...] = keys_in[...]
        pb_ref[...] = pb_in[...]
        pnb_ref[...] = pnb_in[...]
        last_ref[...] = last_in[...]
        len_ref[...] = len_in[...]

    nsym = A - 1
    C = W * A                      # stays + extends
    Cp = -(-C // 128) * 128        # lane-tile padded candidate count

    lp = lp_ref[0]                 # (1, A) — this frame's log-probs
    keys = keys_ref[...]           # (1, W) int32 — persistent across f
    pb = pb_ref[...]
    pnb = pnb_ref[...]
    last = last_ref[...]
    lens = len_ref[...]

    tot = jnp.logaddexp(pb, pnb)   # (1, W)

    # --- stay candidates (prefix unchanged) ------------------------------
    stay_pb = tot + lp[:, blank:blank + 1]
    # gather lp at each beam's last symbol via one-hot (exact: single
    # nonzero per row, exact zeros elsewhere)
    last_c = jnp.reshape(last, (W, 1))
    oh = (jax.lax.broadcasted_iota(jnp.int32, (W, A), 1)
          == jnp.maximum(last_c, 0))
    lp_last = jnp.sum(jnp.where(oh, jnp.broadcast_to(lp, (W, A)), 0.0),
                      axis=1, keepdims=True)                   # (W, 1)
    lens_c = jnp.reshape(lens, (W, 1))
    stay_pnb = jnp.where(lens_c > 0,
                         jnp.reshape(pnb, (W, 1)) + lp_last, NEG)

    # --- extend candidates (append symbol c) -----------------------------
    # static gather of the non-blank columns, in sym_ids order
    lp_sym = jnp.concatenate(
        [lp[:, c:c + 1] for c in range(A) if c != blank], axis=1)  # (1,nsym)
    jj = jax.lax.broadcasted_iota(jnp.int32, (W, nsym), 1)
    sym2 = jj + (jj >= blank).astype(jnp.int32)    # sym_ids[j], sorted ids
    is_rep = last_c == sym2
    pb_c = jnp.reshape(pb, (W, 1))
    tot_c = jnp.reshape(tot, (W, 1))
    ext_pnb = jnp.where(is_rep, pb_c, tot_c) + lp_sym          # (W, nsym)
    ext_pnb = jnp.where(lens_c < L, ext_pnb, NEG)
    keys_c = jnp.reshape(keys, (W, 1))
    ext_key = keys_c * _MUL_I32 + sym2 + 1         # wrapping i32 ≡ u32 hash
    ext_len = jnp.broadcast_to(jnp.minimum(lens_c + 1, L), (W, nsym))

    # --- candidates: stays first, then extends (row-major) ---------------
    cand_key = jnp.concatenate(
        [keys, jnp.reshape(ext_key, (1, W * nsym))], axis=1)
    cand_pb = jnp.concatenate(
        [stay_pb, jnp.full((1, W * nsym), NEG, jnp.float32)], axis=1)
    cand_pnb = jnp.concatenate(
        [jnp.reshape(stay_pnb, (1, W)),
         jnp.reshape(ext_pnb, (1, W * nsym))], axis=1)
    cand_last = jnp.concatenate(
        [last, jnp.reshape(sym2, (1, W * nsym))], axis=1)
    cand_len = jnp.concatenate(
        [lens, jnp.reshape(ext_len, (1, W * nsym))], axis=1)

    # --- pad to the lane tile with inert lanes (cf. ctc_merge.ops) -------
    if Cp != C:
        lane = jax.lax.broadcasted_iota(jnp.int32, (1, Cp - C), 1) + C
        fill = jnp.full((1, Cp - C), MASK, jnp.float32)
        cand_key = jnp.concatenate([cand_key, lane], axis=1)
        cand_pb = jnp.concatenate([cand_pb, fill], axis=1)
        cand_pnb = jnp.concatenate([cand_pnb, fill], axis=1)

    # --- shared fused merge + rank ---------------------------------------
    idx_row, mpb, mpnb = merge_rank_select(cand_key, cand_pb, cand_pnb)
    top = idx_row[:, :W]                                       # (1, W)
    new_pb = mpb[:, :W]
    new_pnb = mpnb[:, :W]

    # gather key/last/len at the winning candidates (one-hot, exact; the
    # top W ranks are always real lanes — pad lanes rank strictly last)
    sel = (jax.lax.broadcasted_iota(jnp.int32, (W, C), 1)
           == jnp.reshape(top, (W, 1)))

    def take(row):
        picked = jnp.where(sel, jnp.broadcast_to(row[:, :C], (W, C)),
                           jnp.zeros((), row.dtype))
        return jnp.reshape(jnp.sum(picked, axis=1, keepdims=True), (1, W))

    new_key = take(cand_key)
    new_last = take(cand_last)
    new_len = take(cand_len)

    # padded frames are no-ops: identity idx, state untouched
    live = act_ref[0, 0] > 0
    iw = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)
    idx_ref[0] = jnp.where(live, top, iw)
    keys_ref[...] = jnp.where(live, new_key, keys)
    pb_ref[...] = jnp.where(live, new_pb, pb)
    pnb_ref[...] = jnp.where(live, new_pnb, pnb)
    last_ref[...] = jnp.where(live, new_last, last)
    len_ref[...] = jnp.where(live, new_len, lens)


def beam_merge_multiframe_pallas(lp, active, keys, pb, pnb, last, lengths,
                                 *, blank: int, L: int,
                                 interpret: bool = False):
    """lp (B, F, A) f32, active (B, F) i32, state (B, W) each ->
    (idx (B, F, W) i32, keys, pb, pnb, last, lengths) post-strip."""
    B, F, A = lp.shape
    W = keys.shape[1]
    assert keys.dtype == jnp.int32

    state_spec = pl.BlockSpec((1, W), lambda b, f: (b, 0))
    kernel = functools.partial(_strip_kernel, blank=blank, L=L, A=A, W=W)
    return pl.pallas_call(
        kernel,
        grid=(B, F),
        in_specs=[
            pl.BlockSpec((1, 1, A), lambda b, f: (b, f, 0)),
            pl.BlockSpec((1, 1), lambda b, f: (b, f)),
            state_spec, state_spec, state_spec, state_spec, state_spec,
        ],
        out_specs=(
            pl.BlockSpec((1, 1, W), lambda b, f: (b, f, 0)),
            state_spec, state_spec, state_spec, state_spec, state_spec,
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, F, W), jnp.int32),
            jax.ShapeDtypeStruct((B, W), jnp.int32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.int32),
            jax.ShapeDtypeStruct((B, W), jnp.int32),
        ),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lp, active, keys, pb, pnb, last, lengths)
