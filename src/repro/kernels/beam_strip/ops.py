"""Persistent multi-frame beam merge — dispatch via the registry.

``beam_merge_multiframe`` advances the hash beam decoder's state through
a strip of F frames in one launch instead of F ``beam_merge_topk``
launches.  The state the op carries (hashes, log-masses, last symbol,
lengths) is everything EXCEPT prefix content — callers replay the
returned per-frame winner indices to rebuild prefixes (see
``core.ctc.ctc_beam_search_hash_batch``'s ``strip_frames`` path).

No padding is needed at this layer: the grid is (B, F) with unit blocks
on both axes, and the in-kernel candidate row handles its own lane-tile
padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.beam_strip.kernel import beam_merge_multiframe_pallas
from repro.kernels.beam_strip.ref import beam_merge_multiframe_ref


def _impl_pallas(lp, active, keys, pb, pnb, last, lengths, *, blank: int,
                 L: int, interpret: bool = False):
    return beam_merge_multiframe_pallas(
        lp.astype(jnp.float32), active.astype(jnp.int32),
        keys.astype(jnp.int32), pb.astype(jnp.float32),
        pnb.astype(jnp.float32), last.astype(jnp.int32),
        lengths.astype(jnp.int32), blank=blank, L=L, interpret=interpret)


def _impl_ref(lp, active, keys, pb, pnb, last, lengths, *, blank: int,
              L: int, **_tiles):
    return beam_merge_multiframe_ref(
        lp.astype(jnp.float32), active.astype(jnp.int32),
        keys.astype(jnp.int32), pb.astype(jnp.float32),
        pnb.astype(jnp.float32), last.astype(jnp.int32),
        lengths.astype(jnp.int32), blank=blank, L=L)


def _example():
    """Ragged strip (one padded frame) at the paper's A=5 alphabet."""
    B, F, A, W, L = 2, 3, 5, 4, 11
    NEG = -1.0e9
    lp = jnp.zeros((B, F, A), jnp.float32) - jnp.log(float(A))
    active = jnp.array([[1, 1, 1], [1, 1, 0]], jnp.int32)
    keys = jnp.zeros((B, W), jnp.int32)
    pb = jnp.full((B, W), NEG, jnp.float32).at[:, 0].set(0.0)
    pnb = jnp.full((B, W), NEG, jnp.float32)
    last = jnp.full((B, W), -1, jnp.int32)
    lengths = jnp.zeros((B, W), jnp.int32)
    return ((lp, active, keys, pb, pnb, last, lengths),
            {"blank": A - 1, "L": L})


registry.register_op("beam_merge_multiframe", ref=_impl_ref,
                     pallas=_impl_pallas, example=_example)


@functools.partial(jax.jit, static_argnames=("blank", "L", "backend"))
def _dispatch(lp, active, keys, pb, pnb, last, lengths, *, blank, L,
              backend):
    return registry.get_op("beam_merge_multiframe", backend)(
        lp, active, keys, pb, pnb, last, lengths, blank=blank, L=L)


def beam_merge_multiframe(lp, active, keys, pb, pnb, last, lengths, *,
                          blank: int, L: int, backend: str | None = None):
    """Advance hash beam state through F frames in one persistent launch.

    lp (B, F, A), active (B, F), state arrays (B, W) -> (idx (B, F, W),
    keys, pb, pnb, last, lengths).  ``idx`` uses the per-frame decoder's
    candidate layout (stays [0, W), extends W + w*nsym + j); padded
    frames (active == 0) emit the identity and leave state untouched.
    Backend resolves before the jit boundary (see quant_matmul.ops)."""
    return _dispatch(lp, active, keys, pb, pnb, last, lengths, blank=blank,
                     L=L, backend=registry.resolve_backend(backend))


__all__ = ["beam_merge_multiframe", "beam_merge_multiframe_ref"]
