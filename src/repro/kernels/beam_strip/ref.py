"""Pure-jnp oracle for the persistent multi-frame beam-merge kernel.

One strip = F consecutive CTC frames advanced through the hash beam
update.  The oracle is literally the per-frame serving decoder's inner
loop (``core.ctc.ctc_beam_search_hash_batch``) restricted to the state
the kernel carries — hashes, blank/non-blank log-masses, last symbol,
prefix length — scanned over the strip with ``beam_merge_topk_ref`` as
the per-frame merge.  Prefix CONTENT is not part of the op: the caller
replays the emitted ``idx`` trace to reconstruct prefixes (see
``core.ctc``), which keeps the kernel state narrow enough to stay
resident in VMEM.

Key identity: hashes live as int32 here (bitcast from the decoder's
uint32).  Two's-complement wrapping multiply-add is bit-identical to the
uint32 rolling hash ``h' = h * 2654435761 + (sym + 1) (mod 2^32)``, so
merges (pure equality tests) agree bitwise with the per-frame path.
"""
import jax
import jax.numpy as jnp

from repro.kernels.ctc_merge.ref import beam_merge_topk_ref

NEG = -1.0e9
# 2654435761 (Knuth's odd multiplicative constant, cf. core.ctc._HASH_MUL)
# viewed as a two's-complement int32 — wrapping i32 arithmetic with this
# constant is bitwise the uint32 rolling hash.  A plain Python int (weakly
# typed) so the Pallas kernel body can close over it without capturing a
# traced constant.
_MUL_I32 = -1640531535


def beam_merge_multiframe_ref(lp, active, keys, pb, pnb, last, lengths,
                              *, blank: int, L: int):
    """Advance the hash beam state through a strip of F frames.

    Args:
      lp: (B, F, A) f32 per-frame log-probabilities.
      active: (B, F) int32; 0 marks a padded frame (state untouched,
        identity ``idx`` emitted).
      keys: (B, W) int32 rolling prefix hashes (uint32 bit patterns).
      pb/pnb: (B, W) f32 blank / non-blank log-mass per beam.
      last: (B, W) int32 last symbol per beam (-1 = empty prefix).
      lengths: (B, W) int32 prefix lengths.
      blank: blank symbol id (static, non-negative).
      L: max prefix length (static).

    Returns ``(idx, keys, pb, pnb, last, lengths)`` where ``idx`` is
    (B, F, W) int32 — per frame, the winning candidate index in the
    per-frame decoder's candidate layout (stays ``[0, W)``, then extends
    ``W + w*nsym + j``) — and the rest is the post-strip state.
    """
    B, F, A = lp.shape
    W = keys.shape[1]
    nsym = A - 1
    sym_ids = jnp.array([c for c in range(A) if c != blank], jnp.int32)

    def step(state, inp):
        keys, pb, pnb, last, lens = state
        lp_f, act_f = inp                              # (B, A), (B,)
        tot = jnp.logaddexp(pb, pnb)

        # --- stay candidates (prefix unchanged) --------------------------
        stay_pb = tot + lp_f[:, blank][:, None]
        stay_pnb = jnp.where(
            lens > 0,
            pnb + jnp.take_along_axis(lp_f, jnp.maximum(last, 0), axis=1),
            NEG)

        # --- extend candidates (append symbol c) -------------------------
        lp_sym = lp_f[:, sym_ids]                      # (B, nsym)
        is_rep = last[:, :, None] == sym_ids[None, None, :]
        ext_pnb = (jnp.where(is_rep, pb[:, :, None], tot[:, :, None])
                   + lp_sym[:, None, :])               # (B, W, nsym)
        ext_pnb = jnp.where((lens < L)[:, :, None], ext_pnb, NEG)
        ext_key = keys[:, :, None] * _MUL_I32 + (sym_ids[None, None, :] + 1)
        ext_last = jnp.broadcast_to(sym_ids[None, None, :], (B, W, nsym))
        ext_len = jnp.broadcast_to(
            jnp.minimum(lens + 1, L)[:, :, None], (B, W, nsym))

        # --- candidates: stays first, then extends (row-major) -----------
        cand_key = jnp.concatenate(
            [keys, ext_key.reshape(B, W * nsym)], axis=1)
        cand_pb = jnp.concatenate(
            [stay_pb, jnp.full((B, W * nsym), NEG)], axis=1)
        cand_pnb = jnp.concatenate(
            [stay_pnb, ext_pnb.reshape(B, W * nsym)], axis=1)
        cand_last = jnp.concatenate(
            [last, ext_last.reshape(B, W * nsym)], axis=1)
        cand_len = jnp.concatenate(
            [lens, ext_len.reshape(B, W * nsym)], axis=1)

        idx, mpb, mpnb = beam_merge_topk_ref(cand_key, cand_pb, cand_pnb,
                                             W=W)
        new = (jnp.take_along_axis(cand_key, idx, axis=1),
               mpb, mpnb,
               jnp.take_along_axis(cand_last, idx, axis=1),
               jnp.take_along_axis(cand_len, idx, axis=1))
        act = (act_f > 0)[:, None]
        idx_out = jnp.where(act, idx,
                            jnp.arange(W, dtype=jnp.int32)[None, :])
        new = jax.tree_util.tree_map(lambda n, o: jnp.where(act, n, o),
                                     new, state)
        return new, idx_out

    state0 = (keys, pb, pnb, last, lengths)
    state, idx_seq = jax.lax.scan(
        step, state0, (jnp.moveaxis(lp, 1, 0), jnp.moveaxis(active, 1, 0)))
    keys, pb, pnb, last, lengths = state
    return (jnp.moveaxis(idx_seq, 0, 1), keys, pb, pnb, last, lengths)
