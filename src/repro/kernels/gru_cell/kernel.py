"""Pallas TPU kernel: fused GRU cell (the base-caller's compute hot-spot).

Guppy/Scrappie spend >90 % of DNN FLOPs in the GRU stack (Table 3); the
recurrent h·U product is the part that cannot be hoisted out of the time
loop.  This kernel fuses, per time step:

    gates = h @ U + x_proj + b          (MXU)
    z, r  = σ(gates[:, :H]), σ(gates[:, H:2H])
    n     = tanh(x_projₙ + bₙ + (r ⊙ h) @ Uₙ)   (second MXU product)
    h'    = z ⊙ h + (1-z) ⊙ n

so h, U, and the gate intermediates stay in VMEM for the whole step —
on the PIM this is "weights stationary in the crossbar"; on TPU it is
U resident in VMEM across the batch grid (BlockSpec index ignores the
batch coordinate).

Grid: (B/bb,). U is (H, 3H): with H≤512 that is ≤3 MiB fp32 — well within
a v5e core's 16 MiB VMEM next to the (bb, 3H) activation tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels._compat import CompilerParams


def _gru_kernel(xp_ref, h_ref, u_ref, b_ref, o_ref):
    h = h_ref[...]                      # (bb, H)
    u = u_ref[...]                      # (H, 3H)
    xp = xp_ref[...]                    # (bb, 3H)
    b = b_ref[...]                      # (1, 3H)
    H = h.shape[-1]

    gates = jnp.dot(h, u, preferred_element_type=jnp.float32) + xp + b
    z = jax.nn.sigmoid(gates[:, :H])
    r = jax.nn.sigmoid(gates[:, H:2 * H])
    n_in = xp[:, 2 * H:] + b[:, 2 * H:]
    n_h = jnp.dot(r * h, u[:, 2 * H:], preferred_element_type=jnp.float32)
    n = jnp.tanh(n_in + n_h)
    o_ref[...] = z * h + (1.0 - z) * n


def gru_cell_pallas(x_proj: jnp.ndarray, h: jnp.ndarray, u: jnp.ndarray,
                    b: jnp.ndarray, *, bb: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """x_proj (B, 3H), h (B, H), u (H, 3H), b (1, 3H) -> h' (B, H)."""
    B, H = h.shape
    assert x_proj.shape == (B, 3 * H)
    assert B % bb == 0

    grid = (B // bb,)
    return pl.pallas_call(
        _gru_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, 3 * H), lambda i: (i, 0)),
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
            pl.BlockSpec((H, 3 * H), lambda i: (0, 0)),   # stationary
            pl.BlockSpec((1, 3 * H), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, H), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x_proj, h, u, b)
