"""Fused GRU cell public wrapper — dispatch via ``repro.kernels.registry``."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.gru_cell.kernel import gru_cell_pallas
from repro.kernels.gru_cell.ref import gru_cell_ref


def _impl_pallas(x_proj, h, u, b, *, bb: int = 128,
                 interpret: bool = False) -> jnp.ndarray:
    """Pad batch to the tile size and run the fused kernel."""
    B = h.shape[0]
    pad = (-B) % bb
    if pad:
        x_proj = jnp.pad(x_proj, ((0, pad), (0, 0)))
        h = jnp.pad(h, ((0, pad), (0, 0)))
    out = gru_cell_pallas(x_proj, h, u, b.reshape(1, -1), bb=bb,
                          interpret=interpret)
    return out[:B]


def _impl_ref(x_proj, h, u, b, **_tiles) -> jnp.ndarray:
    return gru_cell_ref(x_proj, h, u, b.reshape(1, -1))


def _example():
    """Ragged batch vs bb=128 (cf. tests/test_registry.py)."""
    B, H = 23, 48
    return ((jnp.zeros((B, 3 * H), jnp.float32),
             jnp.zeros((B, H), jnp.float32),
             jnp.zeros((H, 3 * H), jnp.float32),
             jnp.zeros((3 * H,), jnp.float32)), {})


registry.register_op("gru_cell", ref=_impl_ref, pallas=_impl_pallas,
                     example=_example)


@functools.partial(jax.jit, static_argnames=("bb", "backend"))
def _dispatch(x_proj, h, u, b, *, bb, backend):
    return registry.get_op("gru_cell", backend)(x_proj, h, u, b, bb=bb)


def gru_cell(x_proj: jnp.ndarray, h: jnp.ndarray, u: jnp.ndarray,
             b: jnp.ndarray, *, bb: int = 128,
             backend: str | None = None) -> jnp.ndarray:
    """Fused GRU step (x_proj (B, 3H), h (B, H), u (H, 3H), b (3H,)).

    Backend resolves before the jit boundary (see quant_matmul.ops)."""
    return _dispatch(x_proj, h, u, b, bb=bb,
                     backend=registry.resolve_backend(backend))


__all__ = ["gru_cell", "gru_cell_ref"]
