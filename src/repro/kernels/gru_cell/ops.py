"""jit'd wrapper for the fused GRU cell (padding + auto-interpret)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gru_cell.kernel import gru_cell_pallas
from repro.kernels.gru_cell.ref import gru_cell_ref


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def gru_cell(x_proj: jnp.ndarray, h: jnp.ndarray, u: jnp.ndarray,
             b: jnp.ndarray, *, bb: int = 128,
             interpret: bool | None = None) -> jnp.ndarray:
    """Fused GRU step; pads batch to the tile size."""
    if interpret is None:
        interpret = _auto_interpret()
    B = h.shape[0]
    pad = (-B) % bb
    if pad:
        x_proj = jnp.pad(x_proj, ((0, pad), (0, 0)))
        h = jnp.pad(h, ((0, pad), (0, 0)))
    out = gru_cell_pallas(x_proj, h, u, b.reshape(1, -1), bb=bb,
                          interpret=interpret)
    return out[:B]


__all__ = ["gru_cell", "gru_cell_ref"]
