"""Pure-jnp oracle for the fused GRU cell — mirrors models.basecaller."""
import jax
import jax.numpy as jnp


def gru_cell_ref(x_proj, h, u, b):
    H = h.shape[-1]
    gates = h @ u + x_proj + b
    z = jax.nn.sigmoid(gates[..., :H])
    r = jax.nn.sigmoid(gates[..., H:2 * H])
    n_in = x_proj[..., 2 * H:] + b[..., 2 * H:]
    n_h = (r * h) @ u[:, 2 * H:]
    n = jnp.tanh(n_in + n_h)
    return z * h + (1.0 - z) * n
