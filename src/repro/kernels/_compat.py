"""jax version compatibility for the Pallas TPU surface.

The kernels target the current Pallas API name ``pltpu.CompilerParams``;
older jax releases ship the identical class as ``TPUCompilerParams``.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
