"""Pallas TPU kernel: CTC beam-merge (paper §4.3, Fig. 18).

Helix writes the beam's per-base probabilities onto the diagonal of an NVM
dot-product array and closes bit-line transistors to MERGE the probabilities
of candidate sequences that collapse to the same read
(p(A) = p(A₀A₁)+p(A₀-₁)+p(-₀A₁)+p(-₀-₁)).

The digital equivalent of "closing transistors between bit-lines" is a
masked reduction over an equality matrix: given candidate scores s (log
domain) and eq[i,j] = 1 iff candidates i and j collapse to the same prefix,

    merged[i] = log Σ_j eq[i,j] · exp(s[j])

computed per row with max-subtraction for stability.  The (C×C) masked
sum-product is the same crossbar-shaped operation, on the VPU.

Tiling: grid (B, C/bi); each step holds an (bi, C) eq tile and the full
(1, C) score row in VMEM — C is the candidate count (beam·alphabet, ≤ a few
hundred), so a full row fits comfortably.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels._compat import CompilerParams
from repro.kernels.ctc_merge.ref import MASK  # oracle fill, bitwise-shared

NEG = -1.0e9


def _merge_kernel(eq_ref, s_ref, o_ref):
    eq = eq_ref[0]                       # (bi, C) int8
    s = s_ref[0]                         # (1, C) f32
    masked = jnp.where(eq > 0, s, NEG)   # broadcast row scores
    m = jnp.max(masked, axis=1, keepdims=True)
    ssum = jnp.sum(jnp.exp(masked - m), axis=1, keepdims=True)
    o_ref[0, :] = (m + jnp.log(ssum))[:, 0]


def ctc_merge_pallas(eq: jnp.ndarray, scores: jnp.ndarray,
                     *, bi: int = 128, interpret: bool = False
                     ) -> jnp.ndarray:
    """eq (B, C, C) int8, scores (B, C) f32 -> merged (B, C) f32."""
    B, C, C2 = eq.shape
    assert C == C2 and C % bi == 0

    grid = (B, C // bi)
    return pl.pallas_call(
        _merge_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bi, C), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, C), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, bi), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(eq, scores)


# ---------------------------------------------------------------------------
# fused hash-merge + top-k (the whole per-frame beam update in one kernel)
# ---------------------------------------------------------------------------

def merge_rank_select(keys_row, pb_row, pnb_row):
    """One batch row's fused beam update: merge duplicate candidates by
    key, rank by merged score, emit the full descending order.

    Shared in-kernel body of the per-frame ``beam_merge_topk`` kernel AND
    the persistent multi-frame ``beam_merge_multiframe`` kernel
    (kernels/beam_strip) — one implementation so the two stay bitwise
    interchangeable by construction.

    Everything is dense (C x C) vector work — equality plane, two masked
    logsumexp reductions, a comparison-count ranking, and a one-hot
    selection — the digital rendition of Helix's crossbar merge, with the
    top-k sort ALSO expressed as crossbar-shaped ops so a frame's whole
    beam update is one kernel launch:

      rank[i] = #{j : score[j] > score[i] or (score[j]==score[i] and j<i)}

    is a permutation of 0..C-1 (ties are broken by index, matching
    ``lax.top_k``), so emitting ``out[rank[i]] = i`` is a masked
    column-reduction instead of a sort network.

    Args: (1, C) rows — int32 keys, f32 blank / non-blank log-masses.
    Returns (idx, merged_pb, merged_pnb), each (1, C), in rank order.
    """
    C = keys_row.shape[1]
    keys_col = jnp.reshape(keys_row, (C, 1))
    eq = keys_col == keys_row                      # (C, C): [i, j]
    ii = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)

    # canonical = first occurrence of each key
    dup_earlier = jnp.sum((eq & (jj < ii)).astype(jnp.int32), axis=1,
                          keepdims=True)           # (C, 1)
    canon = dup_earlier == 0

    def masked_lse(vals_row):
        masked = jnp.where(eq, vals_row, MASK)     # (C, C)
        m = jnp.max(masked, axis=1, keepdims=True)
        return m + jnp.log(jnp.sum(jnp.exp(masked - m), axis=1,
                                   keepdims=True))  # (C, 1)

    # duplicate (non-canonical) lanes are stripped of their pooled mass,
    # matching the dense oracle — only the first occurrence carries it
    mpb = jnp.where(canon, masked_lse(pb_row), NEG)
    mpnb = jnp.where(canon, masked_lse(pnb_row), NEG)
    score_col = jnp.where(canon, jnp.logaddexp(mpb, mpnb), NEG)  # (C, 1)
    score_row = jnp.reshape(score_col, (1, C))

    beats = (score_row > score_col) | ((score_row == score_col) & (jj < ii))
    rank_col = jnp.sum(beats.astype(jnp.int32), axis=1, keepdims=True)

    # out[0, r] = sum_i [rank[i] == r] * val[i]   (rank is a permutation)
    sel = rank_col == jj                           # (C, C): [i, r]
    idx = jnp.sum(jnp.where(sel, ii, 0), axis=0, keepdims=True)
    opb = jnp.sum(jnp.where(sel, mpb, 0.0), axis=0, keepdims=True)
    opnb = jnp.sum(jnp.where(sel, mpnb, 0.0), axis=0, keepdims=True)
    return idx, opb, opnb


def _merge_topk_kernel(keys_ref, pb_ref, pnb_ref, idx_ref, opb_ref, opnb_ref):
    """One batch row through ``merge_rank_select`` (see its docstring)."""
    idx, opb, opnb = merge_rank_select(keys_ref[...], pb_ref[...],
                                       pnb_ref[...])
    idx_ref[...] = idx
    opb_ref[...] = opb
    opnb_ref[...] = opnb


def beam_merge_topk_pallas(keys: jnp.ndarray, pb: jnp.ndarray,
                           pnb: jnp.ndarray, *, interpret: bool = False):
    """keys (B, C) int32, pb/pnb (B, C) f32, C a lane multiple ->
    (idx (B, C) int32, pb (B, C) f32, pnb (B, C) f32) in rank order."""
    B, C = keys.shape
    assert C % 128 == 0, "pad C to the lane tile before calling"
    spec = pl.BlockSpec((1, C), lambda b: (b, 0))
    return pl.pallas_call(
        _merge_topk_kernel,
        grid=(B,),
        in_specs=[spec, spec, spec],
        out_specs=(spec, spec, spec),
        out_shape=(jax.ShapeDtypeStruct((B, C), jnp.int32),
                   jax.ShapeDtypeStruct((B, C), jnp.float32),
                   jax.ShapeDtypeStruct((B, C), jnp.float32)),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(keys, pb, pnb)
