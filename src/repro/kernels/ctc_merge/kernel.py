"""Pallas TPU kernel: CTC beam-merge (paper §4.3, Fig. 18).

Helix writes the beam's per-base probabilities onto the diagonal of an NVM
dot-product array and closes bit-line transistors to MERGE the probabilities
of candidate sequences that collapse to the same read
(p(A) = p(A₀A₁)+p(A₀-₁)+p(-₀A₁)+p(-₀-₁)).

The digital equivalent of "closing transistors between bit-lines" is a
masked reduction over an equality matrix: given candidate scores s (log
domain) and eq[i,j] = 1 iff candidates i and j collapse to the same prefix,

    merged[i] = log Σ_j eq[i,j] · exp(s[j])

computed per row with max-subtraction for stability.  The (C×C) masked
sum-product is the same crossbar-shaped operation, on the VPU.

Tiling: grid (B, C/bi); each step holds an (bi, C) eq tile and the full
(1, C) score row in VMEM — C is the candidate count (beam·alphabet, ≤ a few
hundred), so a full row fits comfortably.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels._compat import CompilerParams

NEG = -1.0e9


def _merge_kernel(eq_ref, s_ref, o_ref):
    eq = eq_ref[0]                       # (bi, C) int8
    s = s_ref[0]                         # (1, C) f32
    masked = jnp.where(eq > 0, s, NEG)   # broadcast row scores
    m = jnp.max(masked, axis=1, keepdims=True)
    ssum = jnp.sum(jnp.exp(masked - m), axis=1, keepdims=True)
    o_ref[0, :] = (m + jnp.log(ssum))[:, 0]


def ctc_merge_pallas(eq: jnp.ndarray, scores: jnp.ndarray,
                     *, bi: int = 128, interpret: bool = False
                     ) -> jnp.ndarray:
    """eq (B, C, C) int8, scores (B, C) f32 -> merged (B, C) f32."""
    B, C, C2 = eq.shape
    assert C == C2 and C % bi == 0

    grid = (B, C // bi)
    return pl.pallas_call(
        _merge_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bi, C), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, C), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, bi), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(eq, scores)
