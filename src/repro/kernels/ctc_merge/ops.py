"""jit'd wrapper for the CTC beam-merge kernel (padding + auto-interpret)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ctc_merge.kernel import ctc_merge_pallas
from repro.kernels.ctc_merge.ref import ctc_merge_ref

NEG = -1.0e9


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bi", "interpret"))
def masked_logsumexp(eq: jnp.ndarray, scores: jnp.ndarray, *, bi: int = 128,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Batched masked logsumexp: (B, C, C) mask x (B, C) scores -> (B, C).

    Rows must be self-connected (eq[b,i,i]=1) so no row is empty.
    Pads C to the tile size with inert (self-connected, NEG-score) lanes.
    """
    if interpret is None:
        interpret = _auto_interpret()
    B, C, _ = eq.shape
    pad = (-C) % bi
    if pad:
        Cp = C + pad
        eye = jnp.eye(Cp, dtype=eq.dtype)
        eq_p = jnp.zeros((B, Cp, Cp), eq.dtype).at[:, :C, :C].set(eq)
        eq_p = jnp.maximum(eq_p, eye[None])
        s_p = jnp.full((B, Cp), NEG, scores.dtype).at[:, :C].set(scores)
    else:
        eq_p, s_p = eq, scores
    out = ctc_merge_pallas(eq_p.astype(jnp.int8), s_p.astype(jnp.float32),
                           bi=bi, interpret=interpret)
    return out[:, :C]


__all__ = ["masked_logsumexp", "ctc_merge_ref"]
