"""CTC beam-merge public wrappers — dispatch via ``repro.kernels.registry``.

Two ops live here:

  masked_logsumexp  — the dense-equality merge (the PR-1 kernel; now the
                      oracle path's accelerated tail)
  beam_merge_topk   — the fused hash-merge + top-W selection that the
                      vectorized hash beam decoder (``core.ctc``) runs
                      every frame: candidate identity is an int32 rolling
                      prefix hash, so duplicate detection is single-word
                      compares instead of length-L prefix compares
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.ctc_merge.kernel import (beam_merge_topk_pallas,
                                            ctc_merge_pallas)
from repro.kernels.ctc_merge.ref import (MASK, beam_merge_topk_ref,
                                         ctc_merge_ref)

NEG = -1.0e9


def _impl_pallas(eq, scores, *, bi: int = 128,
                 interpret: bool = False) -> jnp.ndarray:
    """Pad C to the tile size with inert (self-connected, NEG) lanes."""
    B, C, _ = eq.shape
    pad = (-C) % bi
    if pad:
        Cp = C + pad
        eye = jnp.eye(Cp, dtype=eq.dtype)
        eq_p = jnp.zeros((B, Cp, Cp), eq.dtype).at[:, :C, :C].set(eq)
        eq_p = jnp.maximum(eq_p, eye[None])
        s_p = jnp.full((B, Cp), NEG, scores.dtype).at[:, :C].set(scores)
    else:
        eq_p, s_p = eq, scores
    out = ctc_merge_pallas(eq_p.astype(jnp.int8), s_p.astype(jnp.float32),
                           bi=bi, interpret=interpret)
    return out[:, :C]


def _impl_ref(eq, scores, **_tiles) -> jnp.ndarray:
    return ctc_merge_ref(eq, scores.astype(jnp.float32))


def _example():
    """Ragged candidate count vs bi=128 (cf. tests/test_registry.py)."""
    B, C = 3, 45
    eq = jnp.maximum(jnp.zeros((B, C, C), jnp.int8),
                     jnp.eye(C, dtype=jnp.int8)[None])  # self-connected
    return ((eq, jnp.zeros((B, C), jnp.float32)), {})


registry.register_op("masked_logsumexp", ref=_impl_ref, pallas=_impl_pallas,
                     example=_example)


@functools.partial(jax.jit, static_argnames=("bi", "backend"))
def _dispatch(eq, scores, *, bi, backend):
    return registry.get_op("masked_logsumexp", backend)(eq, scores, bi=bi)


def masked_logsumexp(eq: jnp.ndarray, scores: jnp.ndarray, *, bi: int = 128,
                     backend: str | None = None) -> jnp.ndarray:
    """Batched masked logsumexp: (B, C, C) mask x (B, C) scores -> (B, C).

    Rows must be self-connected (eq[b,i,i]=1) so no row is empty.
    Backend resolves before the jit boundary (see quant_matmul.ops)."""
    return _dispatch(eq, scores, bi=bi,
                     backend=registry.resolve_backend(backend))


# ---------------------------------------------------------------------------
# fused hash-merge + top-k
# ---------------------------------------------------------------------------

def _topk_impl_pallas(keys, pb, pnb, *, W: int, interpret: bool = False):
    """Pad C to the lane tile with inert rank-last lanes, run the fused
    kernel, trim back to (B, W).

    Padding invariants (see tests): pad lanes get UNIQUE keys (so each is
    canonical — a shared sentinel would create non-canonical pad lanes at
    NEG, which could outrank deeply-dead real candidates) and MASK-level
    scores, so every real lane strictly outranks every pad lane and the
    first C output ranks are bitwise what the oracle computes unpadded.
    """
    B, C = keys.shape
    keys = jax.lax.bitcast_convert_type(keys.astype(jnp.uint32), jnp.int32) \
        if keys.dtype == jnp.uint32 else keys.astype(jnp.int32)
    Cp = -(-max(C, W) // 128) * 128
    if Cp != C:
        lane = jnp.arange(Cp, dtype=jnp.int32)
        keys = jnp.concatenate(
            [keys, jnp.broadcast_to(lane[C:], (B, Cp - C))], axis=1)
        fill = jnp.full((B, Cp - C), MASK, jnp.float32)
        pb = jnp.concatenate([pb.astype(jnp.float32), fill], axis=1)
        pnb = jnp.concatenate([pnb.astype(jnp.float32), fill], axis=1)
    idx, opb, opnb = beam_merge_topk_pallas(
        keys, pb.astype(jnp.float32), pnb.astype(jnp.float32),
        interpret=interpret)
    idx, opb, opnb = idx[:, :W], opb[:, :W], opnb[:, :W]
    if W > C:   # ranks >= C are padding by construction
        is_pad = jnp.arange(W) >= C
        idx = jnp.where(is_pad[None], C - 1, idx)
        opb = jnp.where(is_pad[None], NEG, opb)
        opnb = jnp.where(is_pad[None], NEG, opnb)
    return jnp.clip(idx, 0, C - 1), opb, opnb


def _topk_impl_ref(keys, pb, pnb, *, W: int, **_tiles):
    if keys.dtype == jnp.uint32:
        keys = jax.lax.bitcast_convert_type(keys, jnp.int32)
    return beam_merge_topk_ref(keys.astype(jnp.int32),
                               pb.astype(jnp.float32),
                               pnb.astype(jnp.float32), W=W)


def _topk_example():
    """Ragged candidate count vs the 128 lane tile."""
    B, C = 2, 45
    keys = jnp.arange(B * C, dtype=jnp.int32).reshape(B, C) % 12
    return ((keys, jnp.zeros((B, C), jnp.float32),
             jnp.zeros((B, C), jnp.float32)), {"W": 7})


registry.register_op("beam_merge_topk", ref=_topk_impl_ref,
                     pallas=_topk_impl_pallas, example=_topk_example)


@functools.partial(jax.jit, static_argnames=("W", "backend"))
def _topk_dispatch(keys, pb, pnb, *, W, backend):
    return registry.get_op("beam_merge_topk", backend)(keys, pb, pnb, W=W)


def beam_merge_topk(keys: jnp.ndarray, pb: jnp.ndarray, pnb: jnp.ndarray,
                    W: int, *, backend: str | None = None):
    """Merge duplicate beam candidates by integer key and keep the top W.

    (B, C) keys/pb/pnb -> (idx (B, W) int32, pb (B, W), pnb (B, W)):
    per-key pooled log-masses on the first (canonical) occurrence, ranked
    by total score descending with ties broken by lower index.  W > C pads
    with (C-1, NEG, NEG) lanes.  Backend resolves before the jit boundary
    (see quant_matmul.ops)."""
    return _topk_dispatch(keys, pb, pnb, W=W,
                          backend=registry.resolve_backend(backend))


__all__ = ["masked_logsumexp", "ctc_merge_ref", "beam_merge_topk",
           "beam_merge_topk_ref"]
