"""CTC beam-merge public wrapper — dispatch via ``repro.kernels.registry``."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.ctc_merge.kernel import ctc_merge_pallas
from repro.kernels.ctc_merge.ref import ctc_merge_ref

NEG = -1.0e9


def _impl_pallas(eq, scores, *, bi: int = 128,
                 interpret: bool = False) -> jnp.ndarray:
    """Pad C to the tile size with inert (self-connected, NEG) lanes."""
    B, C, _ = eq.shape
    pad = (-C) % bi
    if pad:
        Cp = C + pad
        eye = jnp.eye(Cp, dtype=eq.dtype)
        eq_p = jnp.zeros((B, Cp, Cp), eq.dtype).at[:, :C, :C].set(eq)
        eq_p = jnp.maximum(eq_p, eye[None])
        s_p = jnp.full((B, Cp), NEG, scores.dtype).at[:, :C].set(scores)
    else:
        eq_p, s_p = eq, scores
    out = ctc_merge_pallas(eq_p.astype(jnp.int8), s_p.astype(jnp.float32),
                           bi=bi, interpret=interpret)
    return out[:, :C]


def _impl_ref(eq, scores, **_tiles) -> jnp.ndarray:
    return ctc_merge_ref(eq, scores.astype(jnp.float32))


registry.register_op("masked_logsumexp", ref=_impl_ref, pallas=_impl_pallas)


@functools.partial(jax.jit, static_argnames=("bi", "backend"))
def _dispatch(eq, scores, *, bi, backend):
    return registry.get_op("masked_logsumexp", backend)(eq, scores, bi=bi)


def masked_logsumexp(eq: jnp.ndarray, scores: jnp.ndarray, *, bi: int = 128,
                     interpret: bool | None = None,
                     backend: str | None = None) -> jnp.ndarray:
    """Batched masked logsumexp: (B, C, C) mask x (B, C) scores -> (B, C).

    Rows must be self-connected (eq[b,i,i]=1) so no row is empty.
    Backend resolves before the jit boundary (see quant_matmul.ops)."""
    if interpret is not None:
        backend = "interpret" if interpret else "pallas"
    return _dispatch(eq, scores, bi=bi,
                     backend=registry.resolve_backend(backend))


__all__ = ["masked_logsumexp", "ctc_merge_ref"]
