"""Pure-jnp oracles for the CTC beam-merge kernels."""
import jax
import jax.numpy as jnp

NEG = -1.0e9
# internal mask fill for the fused merge: low enough that exp(MASK - m)
# underflows to exactly 0.0 for every reachable row max m, so masked-out
# (and tile-padding) lanes contribute nothing — bitwise — to the reduction
MASK = -2.0e9


def ctc_merge_ref(eq: jnp.ndarray, scores: jnp.ndarray) -> jnp.ndarray:
    """eq (B, C, C), scores (B, C) -> (B, C) masked logsumexp per row."""
    masked = jnp.where(eq > 0, scores[:, None, :], NEG)
    m = jnp.max(masked, axis=-1, keepdims=True)
    return (m + jnp.log(jnp.sum(jnp.exp(masked - m), axis=-1,
                                keepdims=True)))[..., 0]


def _masked_lse_rows(eq: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    """(B, C, C) bool x (B, C) -> (B, C); same max-subtract formula (and
    the same MASK fill) as the Pallas kernel body so interpret/ref agree
    bitwise."""
    masked = jnp.where(eq, vals[:, None, :], MASK)
    m = jnp.max(masked, axis=-1)
    return m + jnp.log(jnp.sum(jnp.exp(masked - m[..., None]), axis=-1))


def beam_merge_topk_ref(keys: jnp.ndarray, pb: jnp.ndarray, pnb: jnp.ndarray,
                        *, W: int):
    """Fused hash-merge + top-W over beam-search candidates.

    Candidates i and j are the same prefix iff ``keys[b, i] == keys[b, j]``
    (keys are rolling prefix hashes — small integers instead of full
    prefixes).  Duplicate mass is pooled by masked logsumexp onto the
    FIRST (canonical) occurrence; non-canonical lanes score ``NEG``; the
    top-W lanes by merged total score win (ties broken by lower index,
    matching ``lax.top_k``).

    Args:
      keys: (B, C) int32 candidate identity hashes.
      pb/pnb: (B, C) f32 blank / non-blank log-mass per candidate.
      W: beams to keep.  When W > C the tail is padded with
         (idx=C-1, pb=pnb=NEG) lanes.

    Returns (idx (B, W) int32, pb (B, W) f32, pnb (B, W) f32): the indices
    of the winning candidates and their merged log-masses.
    """
    B, C = keys.shape
    eq = keys[:, :, None] == keys[:, None, :]               # (B, C, C)
    ar = jnp.arange(C)
    canon = ~jnp.any(eq & (ar[None, :] < ar[:, None])[None], axis=2)
    # pooled mass lands on the canonical lane ONLY — duplicate lanes are
    # neutralized to NEG so a duplicate that sneaks into the top-W (beam
    # wider than the distinct-candidate count) carries no mass twice
    mpb = jnp.where(canon, _masked_lse_rows(eq, pb), NEG)
    mpnb = jnp.where(canon, _masked_lse_rows(eq, pnb), NEG)
    score = jnp.where(canon, jnp.logaddexp(mpb, mpnb), NEG)
    k = min(W, C)
    _, idx = jax.lax.top_k(score, k)                        # (B, k)
    out_pb = jnp.take_along_axis(mpb, idx, axis=1)
    out_pnb = jnp.take_along_axis(mpnb, idx, axis=1)
    if W > C:
        pad = W - C
        idx = jnp.concatenate(
            [idx, jnp.full((B, pad), C - 1, idx.dtype)], axis=1)
        fill = jnp.full((B, pad), NEG, out_pb.dtype)
        out_pb = jnp.concatenate([out_pb, fill], axis=1)
        out_pnb = jnp.concatenate([out_pnb, fill], axis=1)
    return idx.astype(jnp.int32), out_pb, out_pnb
