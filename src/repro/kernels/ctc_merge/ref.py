"""Pure-jnp oracle for the CTC beam-merge kernel."""
import jax.numpy as jnp

NEG = -1.0e9


def ctc_merge_ref(eq: jnp.ndarray, scores: jnp.ndarray) -> jnp.ndarray:
    """eq (B, C, C), scores (B, C) -> (B, C) masked logsumexp per row."""
    masked = jnp.where(eq > 0, scores[:, None, :], NEG)
    m = jnp.max(masked, axis=-1, keepdims=True)
    return (m + jnp.log(jnp.sum(jnp.exp(masked - m), axis=-1,
                                keepdims=True)))[..., 0]
