"""Pallas TPU kernel: binary comparator array (paper §4.3, Fig. 20).

Helix stores every length-K substring of read R1 in the rows of a SOT-MRAM
array (each base as a 3-bit, 2-cell-per-bit code) and drives the bit-lines
with a substring of R2; a source-line current flags any mismatching bit.

Digital identity: with bit-planes a, b ∈ {0,1},
    xor(a, b) = a + b - 2ab
so the mismatch-bit count between substring i of R1 and substring j of R2 is

    C[i, j] = rowsum_a[i] + rowsum_b[j] - 2 * (A_bits @ B_bitsᵀ)[i, j]

i.e. ONE int8 MXU matmul plus a rank-1 epilogue — the comparator array *is*
a dot-product engine, which is exactly the paper's point.  C[i,j]==0 marks
an exact window match (zero source-line current).

Tiling: grid (N1/bm, N2/bn, D/bk) over the bit dimension D = K*3; the int32
accumulator lives in VMEM scratch; rowsums arrive as (bm,1)/(1,bn) tiles and
fuse in the last-k epilogue.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels._compat import CompilerParams


def _cmp_kernel(a_ref, b_ref, ra_ref, rb_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # A (bm, bk) @ B^T (bk, bn): B arrives pre-transposed as (bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        o_ref[...] = ra_ref[...] + rb_ref[...] - 2 * acc_ref[...]


def vote_cmp_pallas(a_bits: jnp.ndarray, bT_bits: jnp.ndarray,
                    rowsum_a: jnp.ndarray, rowsum_b: jnp.ndarray,
                    *, bm: int = 128, bn: int = 128, bk: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """a_bits (N1, D) int8, bT_bits (D, N2) int8, rowsums (N1,1)/(1,N2) int32
    -> mismatch-bit counts (N1, N2) int32."""
    N1, D = a_bits.shape
    D2, N2 = bT_bits.shape
    assert D == D2
    assert N1 % bm == 0 and N2 % bn == 0 and D % bk == 0

    grid = (N1 // bm, N2 // bn, D // bk)
    return pl.pallas_call(
        _cmp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((bm, 1), lambda m, n, k: (m, 0)),
            pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((N1, N2), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_bits, bT_bits, rowsum_a, rowsum_b)
