"""Pure-jnp oracle for the binary comparator kernel."""
import jax.numpy as jnp

from repro.core.voting import encode_3bit


def substring_bits(read: jnp.ndarray, K: int) -> jnp.ndarray:
    """(L,) symbols -> (L-K+1, K*3) int8 bit-planes of all K-substrings."""
    L = read.shape[0]
    n = L - K + 1
    idx = jnp.arange(n)[:, None] + jnp.arange(K)[None, :]
    bits = encode_3bit(read[idx])                  # (n, K, 3)
    return bits.reshape(n, K * 3).astype(jnp.int8)


def vote_cmp_ref(a_bits: jnp.ndarray, b_bits: jnp.ndarray) -> jnp.ndarray:
    """Mismatch-bit counts: direct XOR-popcount (no matmul trick)."""
    x = a_bits[:, None, :].astype(jnp.int32) ^ b_bits[None, :, :].astype(jnp.int32)
    return x.sum(-1)


def mismatch_matrix_ref(r1: jnp.ndarray, r2: jnp.ndarray, K: int) -> jnp.ndarray:
    """Symbol-level window compare: M[i,j] = #positions where windows differ."""
    n1 = r1.shape[0] - K + 1
    n2 = r2.shape[0] - K + 1
    i = jnp.arange(n1)[:, None, None] + jnp.arange(K)[None, None, :]
    j = jnp.arange(n2)[None, :, None] + jnp.arange(K)[None, None, :]
    return (r1[i] != r2[j]).sum(-1)
