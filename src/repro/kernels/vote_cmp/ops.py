"""Vote comparator public wrapper — dispatch via ``repro.kernels.registry``.

Substring extraction + bit encoding happen outside the kernel; both
backends consume the same (n, K*3) bit-plane tensors.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.vote_cmp.kernel import vote_cmp_pallas
from repro.kernels.vote_cmp.ref import substring_bits, vote_cmp_ref


def _pad_axis(x, mult, axis, value=0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _impl_pallas(r1, r2, *, K: int, bm: int = 128, bn: int = 128,
                 bk: int = 128, interpret: bool = False) -> jnp.ndarray:
    a = substring_bits(r1, K)                  # (n1, K*3)
    b = substring_bits(r2, K)                  # (n2, K*3)
    n1, _ = a.shape
    n2 = b.shape[0]
    ra = a.sum(-1, dtype=jnp.int32)[:, None]
    rb = b.sum(-1, dtype=jnp.int32)[None, :]
    a_p = _pad_axis(_pad_axis(a, bm, 0), bk, 1)
    bT_p = _pad_axis(_pad_axis(b.T, bk, 0), bn, 1)
    ra_p = _pad_axis(ra, bm, 0)
    rb_p = _pad_axis(rb, bn, 1)
    out = vote_cmp_pallas(a_p, bT_p, ra_p, rb_p, bm=bm, bn=bn, bk=bk,
                          interpret=interpret)
    return out[:n1, :n2]


def _impl_ref(r1, r2, *, K: int, **_tiles) -> jnp.ndarray:
    return vote_cmp_ref(substring_bits(r1, K), substring_bits(r2, K))


def _example():
    """Ragged read lengths vs 128 tiles (cf. tests/test_registry.py)."""
    return ((jnp.zeros((41,), jnp.int32), jnp.zeros((29,), jnp.int32)),
            {"K": 5})


registry.register_op("mismatch_bits", ref=_impl_ref, pallas=_impl_pallas,
                     example=_example)


@functools.partial(jax.jit,
                   static_argnames=("K", "bm", "bn", "bk", "backend"))
def _dispatch(r1, r2, *, K, bm, bn, bk, backend):
    return registry.get_op("mismatch_bits", backend)(
        r1, r2, K=K, bm=bm, bn=bn, bk=bk)


def mismatch_bits(r1: jnp.ndarray, r2: jnp.ndarray, K: int,
                  *, bm: int = 128, bn: int = 128, bk: int = 128,
                  backend: str | None = None) -> jnp.ndarray:
    """All-substring comparator: (L1-K+1, L2-K+1) XOR-bit counts.

    Zero entries mark exact K-window matches (paper: no SL current).
    Backend resolves before the jit boundary (see quant_matmul.ops)."""
    return _dispatch(r1, r2, K=K, bm=bm, bn=bn, bk=bk,
                     backend=registry.resolve_backend(backend))


def find_matches(r1: jnp.ndarray, r2: jnp.ndarray, K: int,
                 backend: str | None = None) -> jnp.ndarray:
    """Boolean (n1, n2): exact K-length window matches between two reads."""
    return mismatch_bits(r1, r2, K, backend=backend) == 0


def best_match(r1: jnp.ndarray, r2: jnp.ndarray, K: int,
               backend: str | None = None):
    """(i, j, found): positions of the first exact K-window match."""
    m = mismatch_bits(r1, r2, K, backend=backend)
    flat = jnp.argmin(m.reshape(-1))
    found = m.reshape(-1)[flat] == 0
    n2 = m.shape[1]
    return flat // n2, flat % n2, found


__all__ = ["mismatch_bits", "find_matches", "best_match", "vote_cmp_ref",
           "substring_bits"]
