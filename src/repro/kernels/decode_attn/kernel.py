"""Pallas TPU kernel: single-token decode attention over a KV cache.

The serving hot-spot: one query row per sequence against a (L, Kv, D)
cache. Bandwidth-bound (roofline §Perf: decode cells are memory-dominant),
so the kernel's job is to stream the cache HBM->VMEM exactly once with an
online softmax — no (L,) score round-trip to HBM, no f32 cache copy.

Grid (B, L/bl): for a fixed batch row the L-blocks arrive sequentially and
the running (m, l, acc) online-softmax state lives in VMEM scratch; the
output block writes once at the last L-block.  Per grid step:

  q     (1, Kv*G, D)   bf16/f32   VMEM (stationary across the L loop)
  k, v  (1, bl, Kv, D)            VMEM (streamed)
  state m,l (Kv*G,1), acc (Kv*G, D) f32 scratch
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels._compat import CompilerParams

M_INIT = -0.5e9
MASK_NEG = -1.0e9


def _decode_kernel(nv_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, bl: int, kv_heads: int, groups: int):
    li = pl.program_id(1)

    @pl.when(li == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, M_INIT)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                   # (Kv*G, D)
    k = k_ref[0].astype(jnp.float32)                   # (bl, Kv, D)
    v = v_ref[0].astype(jnp.float32)
    D = q.shape[-1]
    qh = q.reshape(kv_heads, groups, D) * (D ** -0.5)

    s = jnp.einsum("hgd,lhd->hgl", qh, k)              # (Kv, G, bl)
    pos = li * bl + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bl), 2)
    s = jnp.where(pos < nv_ref[0, 0], s, MASK_NEG)
    s = s.reshape(kv_heads * groups, bl)

    m_old = m_ref[...]                                 # (Kv*G, 1)
    m_new = jnp.maximum(m_old, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)                             # (Kv*G, bl)
    corr = jnp.exp(m_old - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    pv = jnp.einsum("hgl,lhd->hgd", p.reshape(kv_heads, groups, bl),
                    v).reshape(kv_heads * groups, D)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(li == pl.num_programs(1) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


def _paged_decode_kernel(bt_ref, nv_ref, q_ref, k_ref, v_ref, o_ref, m_ref,
                         l_ref, acc_ref, *, bs: int, kv_heads: int,
                         groups: int):
    """Same online softmax as ``_decode_kernel``, but the (1, bs, Kv, D)
    K/V block arriving each grid step was fetched THROUGH the block table
    (scalar-prefetch index map, see ``paged_decode_attn_pallas``) — the
    kernel body only re-derives which token positions the block covers
    (``j * bs + iota``) for the validity mask."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, M_INIT)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                   # (Kv*G, D)
    k = k_ref[0].astype(jnp.float32)                   # (bs, Kv, D)
    v = v_ref[0].astype(jnp.float32)
    D = q.shape[-1]
    qh = q.reshape(kv_heads, groups, D) * (D ** -0.5)

    s = jnp.einsum("hgd,lhd->hgl", qh, k)              # (Kv, G, bs)
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    s = jnp.where(pos < nv_ref[b, 0], s, MASK_NEG)
    s = s.reshape(kv_heads * groups, bs)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_old - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    pv = jnp.einsum("hgl,lhd->hgd", p.reshape(kv_heads, groups, bs),
                    v).reshape(kv_heads * groups, D)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


def paged_decode_attn_pallas(q: jnp.ndarray, k_arena: jnp.ndarray,
                             v_arena: jnp.ndarray,
                             block_tables: jnp.ndarray,
                             n_valid: jnp.ndarray, *, groups: int,
                             interpret: bool = False) -> jnp.ndarray:
    """q (B, Kv*G, D); arenas (N, bs, Kv, D) pooled KV blocks;
    block_tables (B, nb) int32; n_valid (B, 1) int32.

    Returns (B, Kv*G, D).  Grid (B, nb): the block table rides in as a
    SCALAR-PREFETCH operand so the K/V BlockSpec index map can address
    arena row ``block_tables[b, j]`` at grid step (b, j) — the kernel
    streams exactly the blocks each lane owns, never materializing the
    (B, nb*bs, Kv, D) gather the jnp reference builds.
    """
    import functools

    B, H, D = q.shape
    N, bs, Kv, _ = k_arena.shape
    nb = block_tables.shape[1]
    assert H == Kv * groups

    kern = functools.partial(_paged_decode_kernel, bs=bs, kv_heads=Kv,
                             groups=groups)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, j, bt, nv: (b, 0, 0)),
            pl.BlockSpec((1, bs, Kv, D),
                         lambda b, j, bt, nv: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, Kv, D),
                         lambda b, j, bt, nv: (bt[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, j, bt, nv: (b, 0, 0)),
        scratch_shapes=[pltpu.VMEM((H, 1), jnp.float32),
                        pltpu.VMEM((H, 1), jnp.float32),
                        pltpu.VMEM((H, D), jnp.float32)],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, n_valid, q, k_arena, v_arena)


def decode_attn_pallas(q: jnp.ndarray, k_cache: jnp.ndarray,
                       v_cache: jnp.ndarray, n_valid: jnp.ndarray,
                       *, groups: int, bl: int = 256,
                       interpret: bool = False) -> jnp.ndarray:
    """q (B, Kv*G, D); caches (B, L, Kv, D); n_valid (B, 1) int32.

    Returns (B, Kv*G, D). L must be a multiple of bl.
    """
    B, H, D = q.shape
    L, Kv = k_cache.shape[1], k_cache.shape[2]
    assert H == Kv * groups and L % bl == 0

    import functools
    kern = functools.partial(_decode_kernel, bl=bl, kv_heads=Kv,
                             groups=groups)
    grid = (B, L // bl)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, l: (b, 0)),
            pl.BlockSpec((1, H, D), lambda b, l: (b, 0, 0)),
            pl.BlockSpec((1, bl, Kv, D), lambda b, l: (b, l, 0, 0)),
            pl.BlockSpec((1, bl, Kv, D), lambda b, l: (b, l, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, l: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((H, 1), jnp.float32),
                        pltpu.VMEM((H, 1), jnp.float32),
                        pltpu.VMEM((H, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(n_valid, q, k_cache, v_cache)
