"""Pure-jnp oracle: models/layers.decode_attention reshaped to kernel I/O."""
import jax.numpy as jnp

from repro.models.layers import decode_attention, paged_decode_attention


def decode_attn_ref(q, k_cache, v_cache, n_valid, groups):
    """q (B, H, D); caches (B, L, Kv, D); n_valid (B, 1) -> (B, H, D)."""
    B, H, D = q.shape
    L = k_cache.shape[1]
    valid = jnp.arange(L)[None, :] < n_valid
    out = decode_attention(q[:, None], k_cache, v_cache, valid)
    return out[:, 0]


def paged_decode_attn_ref(q, k_arena, v_arena, block_tables, n_valid,
                          groups):
    """q (B, H, D); arenas (N, bs, Kv, D); block_tables (B, nb);
    n_valid (B, 1) -> (B, H, D).  Materializes the per-lane gather the
    Pallas kernel streams through its block-table index map."""
    out = paged_decode_attention(q[:, None], k_arena, v_arena,
                                 block_tables, n_valid[:, 0])
    return out[:, 0]
