"""Pure-jnp oracle: models/layers.decode_attention reshaped to kernel I/O."""
import jax.numpy as jnp

from repro.models.layers import decode_attention


def decode_attn_ref(q, k_cache, v_cache, n_valid, groups):
    """q (B, H, D); caches (B, L, Kv, D); n_valid (B, 1) -> (B, H, D)."""
    B, H, D = q.shape
    L = k_cache.shape[1]
    valid = jnp.arange(L)[None, :] < n_valid
    out = decode_attention(q[:, None], k_cache, v_cache, valid)
    return out[:, 0]
