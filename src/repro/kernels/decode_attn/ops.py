"""jit'd wrapper for the decode-attention kernel (padding, auto-interpret)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn.kernel import decode_attn_pallas
from repro.kernels.decode_attn.ref import decode_attn_ref


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("groups", "bl", "interpret"))
def decode_attn(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                n_valid: jnp.ndarray, *, groups: int, bl: int = 256,
                interpret: bool | None = None) -> jnp.ndarray:
    """Single-token GQA attention over a ring/full cache.

    q (B, H, D); caches (B, L, Kv, D) with H = Kv*groups; n_valid (B,).
    Pads L to the block size (padded slots are masked by n_valid).
    """
    if interpret is None:
        interpret = _auto_interpret()
    L = k_cache.shape[1]
    bl = min(bl, max(L, 8))
    pad = (-L) % bl
    if pad:
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        k_cache = jnp.pad(k_cache, widths)
        v_cache = jnp.pad(v_cache, widths)
    return decode_attn_pallas(q, k_cache, v_cache,
                              n_valid.reshape(-1, 1).astype(jnp.int32),
                              groups=groups, bl=bl, interpret=interpret)


__all__ = ["decode_attn", "decode_attn_ref"]
