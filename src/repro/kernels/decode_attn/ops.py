"""Decode-attention public wrapper — dispatch via ``repro.kernels.registry``."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.decode_attn.kernel import (decode_attn_pallas,
                                              paged_decode_attn_pallas)
from repro.kernels.decode_attn.ref import (decode_attn_ref,
                                           paged_decode_attn_ref)


def _impl_pallas(q, k_cache, v_cache, n_valid, *, groups: int, bl: int = 256,
                 interpret: bool = False) -> jnp.ndarray:
    """Pad L to the block size (padded slots are masked by n_valid)."""
    L = k_cache.shape[1]
    bl = min(bl, max(L, 8))
    pad = (-L) % bl
    if pad:
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        k_cache = jnp.pad(k_cache, widths)
        v_cache = jnp.pad(v_cache, widths)
    return decode_attn_pallas(q, k_cache, v_cache,
                              n_valid.reshape(-1, 1).astype(jnp.int32),
                              groups=groups, bl=bl, interpret=interpret)


def _impl_ref(q, k_cache, v_cache, n_valid, *, groups: int,
              **_tiles) -> jnp.ndarray:
    return decode_attn_ref(q, k_cache, v_cache,
                           n_valid.reshape(-1, 1).astype(jnp.int32),
                           groups=groups)


def _example():
    """Ragged cache length vs bl=256 (cf. tests/test_registry.py)."""
    B, L, Kv, G, D = 2, 75, 2, 3, 16
    return ((jnp.zeros((B, Kv * G, D), jnp.float32),
             jnp.zeros((B, L, Kv, D), jnp.float32),
             jnp.zeros((B, L, Kv, D), jnp.float32),
             jnp.asarray([31, 75], jnp.int32)), {"groups": G})


registry.register_op("decode_attn", ref=_impl_ref, pallas=_impl_pallas,
                     example=_example)


def _impl_paged_pallas(q, k_arena, v_arena, block_tables, n_valid, *,
                       groups: int, interpret: bool = False) -> jnp.ndarray:
    """No padding wrapper needed: the arena is block-shaped by
    construction (every BlockSpec block divides it exactly)."""
    return paged_decode_attn_pallas(q, k_arena, v_arena,
                                    block_tables.astype(jnp.int32),
                                    n_valid.reshape(-1, 1).astype(jnp.int32),
                                    groups=groups, interpret=interpret)


def _impl_paged_ref(q, k_arena, v_arena, block_tables, n_valid, *,
                    groups: int) -> jnp.ndarray:
    return paged_decode_attn_ref(q, k_arena, v_arena,
                                 block_tables.astype(jnp.int32),
                                 n_valid.reshape(-1, 1).astype(jnp.int32),
                                 groups=groups)


def _paged_example():
    """Partially-filled lanes over a shared 16-block arena (block tables
    deliberately non-contiguous; lane validity ragged vs nb*bs)."""
    B, N, bs, Kv, G, D, nb = 2, 16, 8, 2, 3, 16, 3
    return ((jnp.zeros((B, Kv * G, D), jnp.float32),
             jnp.zeros((N, bs, Kv, D), jnp.float32),
             jnp.zeros((N, bs, Kv, D), jnp.float32),
             jnp.asarray([[3, 7, 1], [12, 0, 5]], jnp.int32),
             jnp.asarray([5, 20], jnp.int32)), {"groups": G})


registry.register_op("paged_decode_attn", ref=_impl_paged_ref,
                     pallas=_impl_paged_pallas, example=_paged_example)


@functools.partial(jax.jit, static_argnames=("groups", "bl", "backend"))
def _dispatch(q, k_cache, v_cache, n_valid, *, groups, bl, backend):
    return registry.get_op("decode_attn", backend)(
        q, k_cache, v_cache, n_valid, groups=groups, bl=bl)


def decode_attn(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                n_valid: jnp.ndarray, *, groups: int, bl: int = 256,
                backend: str | None = None) -> jnp.ndarray:
    """Single-token GQA attention over a ring/full cache.

    q (B, H, D); caches (B, L, Kv, D) with H = Kv*groups; n_valid (B,).
    Backend resolves before the jit boundary (see quant_matmul.ops)."""
    return _dispatch(q, k_cache, v_cache, n_valid, groups=groups, bl=bl,
                     backend=registry.resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("groups", "backend"))
def _dispatch_paged(q, k_arena, v_arena, block_tables, n_valid, *, groups,
                    backend):
    return registry.get_op("paged_decode_attn", backend)(
        q, k_arena, v_arena, block_tables, n_valid, groups=groups)


def paged_decode_attn(q: jnp.ndarray, k_arena: jnp.ndarray,
                      v_arena: jnp.ndarray, block_tables: jnp.ndarray,
                      n_valid: jnp.ndarray, *, groups: int,
                      backend: str | None = None) -> jnp.ndarray:
    """Single-token GQA attention over a PAGED block arena.

    q (B, H, D); arenas (N, bs, Kv, D) with H = Kv*groups; block_tables
    (B, nb) int32 arena rows per lane; n_valid (B,) tokens written.
    Backend resolves before the jit boundary (see quant_matmul.ops)."""
    return _dispatch_paged(q, k_arena, v_arena, block_tables, n_valid,
                           groups=groups,
                           backend=registry.resolve_backend(backend))


__all__ = ["decode_attn", "decode_attn_ref", "paged_decode_attn",
           "paged_decode_attn_ref"]
