"""Pallas TPU kernel: low-bit quantized matmul with fused dequant epilogue.

TPU rendition of the paper's NVM dot-product engine (§2.4/§4.2): the
128x128 crossbar holding 2-bit-cell weights maps onto a 128x128 MXU tile
holding int8-container codes (a 5-bit weight occupies the [-15,15] sub-grid,
see core/quant.py).  The bit-serial input DAC pipeline becomes the int8 MXU
datapath; the CMOS/SOT-MRAM ADC stage becomes the fp32 dequant epilogue
(per-channel weight scale x per-tensor activation scale), fused so the int32
accumulator never round-trips to HBM.

Memory plan per grid step (defaults bm=bn=bk=128):
  x tile  (bm, bk) int8   16 KiB   VMEM
  w tile  (bk, bn) int8   16 KiB   VMEM (stationary across m by grid order)
  acc     (bm, bn) int32  64 KiB   VMEM scratch, lives across the k loop
  out     (bm, bn) f32    64 KiB   written once at k == K-1
MXU dims are multiples of 128 by construction; ops.py pads ragged shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels._compat import CompilerParams


def _qmm_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * sx_ref[0, 0] * sw_ref[...])


def quant_matmul_pallas(xq: jnp.ndarray, wq: jnp.ndarray,
                        x_scale: jnp.ndarray, w_scale: jnp.ndarray,
                        *, bm: int = 128, bn: int = 128, bk: int = 128,
                        interpret: bool = False) -> jnp.ndarray:
    """(M,K) int8 @ (K,N) int8 -> (M,N) f32. Shapes must be block multiples.

    x_scale: (1, 1) f32 per-tensor; w_scale: (1, N) f32 per-channel.
    """
    M, K = xq.shape
    K2, N = wq.shape
    assert K == K2, (K, K2)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)

    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _qmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((1, 1), lambda m, n, k: (0, 0)),
            pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xq, wq, x_scale, w_scale)
