"""quant_matmul public wrapper — dispatch via ``repro.kernels.registry``.

The Pallas path pads ragged shapes to MXU tiles; the ref path is the
int32-accumulate oracle.  Backend selection (pallas / interpret / ref /
auto) lives in the registry, not here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quant as quant_lib
from repro.kernels import registry
from repro.kernels.quant_matmul.kernel import quant_matmul_pallas
from repro.kernels.quant_matmul.ref import quant_matmul_ref


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _impl_pallas(xq, wq, x_scale, w_scale, *, bm: int = 128, bn: int = 128,
                 bk: int = 128, interpret: bool = False) -> jnp.ndarray:
    """Pad ragged shapes to MXU tiles and run the Pallas kernel."""
    M, K = xq.shape
    N = wq.shape[1]
    xq_p = _pad_to(_pad_to(xq, bm, 0), bk, 1)
    wq_p = _pad_to(_pad_to(wq, bk, 0), bn, 1)
    sw_p = _pad_to(w_scale.reshape(1, -1), bn, 1)
    out = quant_matmul_pallas(xq_p, wq_p, x_scale.reshape(1, 1), sw_p,
                              bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:M, :N]


def _impl_ref(xq, wq, x_scale, w_scale, **_tiles) -> jnp.ndarray:
    return quant_matmul_ref(xq, wq, x_scale.reshape(1, 1),
                            w_scale.reshape(1, -1))


def _example():
    """Ragged-vs-MXU-tile shapes (cf. tests/test_registry.py)."""
    return ((jnp.zeros((37, 100), jnp.int8), jnp.zeros((100, 51), jnp.int8),
             jnp.ones((1, 1), jnp.float32), jnp.ones((1, 51), jnp.float32)),
            {})


registry.register_op("quant_matmul", ref=_impl_ref, pallas=_impl_pallas,
                     example=_example)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "backend"))
def _dispatch(xq, wq, x_scale, w_scale, *, bm, bn, bk, backend):
    return registry.get_op("quant_matmul", backend)(
        xq, wq, x_scale, w_scale, bm=bm, bn=bn, bk=bk)


def quant_matmul(xq: jnp.ndarray, wq: jnp.ndarray, x_scale: jnp.ndarray,
                 w_scale: jnp.ndarray, *, bm: int = 128, bn: int = 128,
                 bk: int = 128,
                 backend: str | None = None) -> jnp.ndarray:
    """Quantized matmul over int8 codes; pads ragged shapes to MXU tiles.

    The backend resolves BEFORE the jit boundary so
    ``registry.set_default_backend`` takes effect on the next call rather
    than being pinned by a stale trace.
    """
    return _dispatch(xq, wq, x_scale, w_scale, bm=bm, bn=bn, bk=bk,
                     backend=registry.resolve_backend(backend))


def qmm_from_float(x: jnp.ndarray, w: jnp.ndarray, bits: int = 5,
                   backend: str | None = None) -> jnp.ndarray:
    """Quantize fp inputs on the fly and run the integer kernel."""
    xq, sx = quant_lib.pack_act(x, bits)
    wq, sw = quant_lib.pack_weight(w, bits)
    return quant_matmul(xq, wq, sx.reshape(1, 1), sw.reshape(1, -1),
                        backend=backend)


def qmm_packed(x: jnp.ndarray, wq: jnp.ndarray, sw: jnp.ndarray,
               *, bits_a: int = 5,
               backend: str | None = None) -> jnp.ndarray:
    """Integer matmul against a PRE-PACKED weight — no float detour.

    ``(wq int8, sw fp32)`` is the quantize-once serving artifact
    (``core.quant.pack_weight`` at pack time); only the activation is
    quantized here, with per-row scales so the result is batch-composition
    invariant (see ``core.quant.pack_act_rows``).  The trace therefore
    contains zero weight-quantization ops.
    """
    lead, F = x.shape[:-1], x.shape[-1]
    xq, sx = quant_lib.pack_act_rows(x.reshape(-1, F), bits_a)
    one = jnp.ones((1, 1), jnp.float32)
    y = quant_matmul(xq, wq, one, sw.reshape(1, -1), backend=backend) * sx
    return y.reshape(lead + (wq.shape[-1],))


__all__ = ["quant_matmul", "qmm_from_float", "qmm_packed",
           "quant_matmul_ref"]
