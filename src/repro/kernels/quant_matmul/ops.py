"""jit'd public wrapper: padding, auto-interpret on CPU, fp fast-path."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quant as quant_lib
from repro.kernels.quant_matmul.kernel import quant_matmul_pallas
from repro.kernels.quant_matmul.ref import quant_matmul_ref


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def quant_matmul(xq: jnp.ndarray, wq: jnp.ndarray, x_scale: jnp.ndarray,
                 w_scale: jnp.ndarray, *, bm: int = 128, bn: int = 128,
                 bk: int = 128, interpret: bool | None = None) -> jnp.ndarray:
    """Quantized matmul over int8 codes; pads ragged shapes to MXU tiles."""
    if interpret is None:
        interpret = _auto_interpret()
    M, K = xq.shape
    N = wq.shape[1]
    xq_p = _pad_to(_pad_to(xq, bm, 0), bk, 1)
    wq_p = _pad_to(_pad_to(wq, bk, 0), bn, 1)
    sw_p = _pad_to(w_scale.reshape(1, -1), bn, 1)
    out = quant_matmul_pallas(xq_p, wq_p, x_scale.reshape(1, 1), sw_p,
                              bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:M, :N]


def qmm_from_float(x: jnp.ndarray, w: jnp.ndarray, bits: int = 5,
                   interpret: bool | None = None) -> jnp.ndarray:
    """Quantize fp inputs on the fly and run the integer kernel."""
    xq, sx = quant_lib.pack_act(x, bits)
    wq, sw = quant_lib.pack_weight(w, bits)
    return quant_matmul(xq, wq, sx.reshape(1, 1), sw.reshape(1, -1),
                        interpret=interpret)


__all__ = ["quant_matmul", "qmm_from_float", "quant_matmul_ref"]
