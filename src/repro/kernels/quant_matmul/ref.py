"""Pure-jnp oracle for the quantized matmul kernel."""
import jax
import jax.numpy as jnp

from repro.core.quant import DEQUANT_SCOPE


def quant_matmul_ref(xq, wq, x_scale, w_scale):
    """int32-accumulated integer matmul with fp32 dequant.

    xq: (M, K) int8; wq: (K, N) int8; x_scale (1,1); w_scale (1, N).
    """
    acc = jnp.dot(xq.astype(jnp.int32), wq.astype(jnp.int32))
    # declared dequant boundary (see repro.core.quant.DEQUANT_SCOPE)
    with jax.named_scope(DEQUANT_SCOPE):
        return acc.astype(jnp.float32) * x_scale * w_scale
