"""Pallas TPU kernels for the compute hot-spots Helix optimizes.

Each subpackage ships kernel.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), ops.py (jit'd wrapper: padding, auto-interpret off-TPU), and
ref.py (pure-jnp oracle; tests assert allclose across shape sweeps).

  quant_matmul — int8-container low-bit matmul + fused dequant epilogue
                 (the NVM dot-product engine on the MXU, §4.2)
  vote_cmp     — XOR-popcount substring comparator (the SOT-MRAM binary
                 comparator array as one int8 matmul, §4.3/Fig 20)
  ctc_merge    — CTC beam-merge masked logsumexp (the BL-merge transistors
                 of Fig 18 as a crossbar-shaped VPU reduction)
  gru_cell     — fused GRU step, U stationary in VMEM (the base-caller's
                 recurrent hot loop, Table 3)
  decode_attn  — online-softmax single-token attention over a KV cache
                 (the serving memory-roofline hot-spot, EXPERIMENTS §Perf)

Dispatch is centralized in ``repro.kernels.registry``: every op registers a
(ref, pallas) pair and callers resolve concrete callables with
``registry.get_op(name, backend)`` where backend is one of
auto | pallas | interpret | ref.  The ``registry.Backend`` dataclass is the
switch models and the pipeline thread through their call stacks.
"""
from repro.kernels import registry  # noqa: F401
from repro.kernels.registry import Backend, get_op, register_op  # noqa: F401

__all__ = ["registry", "Backend", "get_op", "register_op"]

