"""Pure-jnp oracle for the persistent GRU sequence kernel.

The oracle IS the per-step path: a ``lax.scan`` over the fused GRU cell's
jnp oracle.  ``models.basecaller._run_rnn(fused_rnn=False)`` runs exactly
this scan (through the ``gru_cell`` registry op), which is what makes the
fused/unfused differential tests meaningful — same math, one launch.
"""
import jax
import jax.numpy as jnp

from repro.kernels.gru_cell.ref import gru_cell_ref


def gru_seq_ref(x_proj, h0, u, b):
    """x_proj (T, B, 3H), h0 (B, H), u (H, 3H), b (3H,) -> ys (T, B, H).

    ``ys[t]`` is the hidden state after consuming ``x_proj[t]`` (forward
    time order; callers flip the sequence for reverse direction)."""
    b2 = b.reshape(1, -1)

    def step(h, xp):
        hn = gru_cell_ref(xp, h, u, b2)
        return hn, hn

    _, ys = jax.lax.scan(step, h0, x_proj)
    return ys
