"""Pallas TPU kernel: persistent GRU sequence (whole-layer recurrent scan).

The per-step ``gru_cell`` kernel already keeps U resident within one step,
but the scan around it still launches one kernel per timestep — hidden
state and the recurrent weights round-trip through HBM T times per layer.
This kernel is the jax_pallas analogue of Helix's in-situ PIM dataflow:
ONE ``pallas_call`` whose grid walks timesteps, with

  * U and b fetched once per batch tile (their BlockSpec index maps
    ignore the time coordinate, so Pallas keeps the blocks resident in
    VMEM across the whole walk — "weights stationary in the crossbar"),
  * the hidden state h living in a VMEM scratch buffer that persists
    across grid iterations (initialized from h0 at t == 0),
  * only x_proj streaming in and ys streaming out, one (bb, ·) tile per
    step.

Grid: (B/bb, T) with semantics ("parallel", "arbitrary") — batch tiles
are independent; the time axis is a sequential walk (t is the minor grid
dimension, so each batch tile sees t = 0..T-1 in order and re-initializes
its scratch at t == 0).

Per-step math is IDENTICAL to ``gru_cell.kernel._gru_kernel`` — the
differential tests pin the fused walk bitwise against the per-step scan.

VMEM residency per tile: U (H, 3H) + b + h scratch (bb, H) + one x_proj
tile (bb, 3H) + one output tile (bb, H).  At the paper's H = 96 and
bb = 128 that is ~0.4 MiB — far inside the 16 MiB per-core budget
(``repro.analysis`` pass 2 checks this estimate on the registered
example shapes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _gru_seq_kernel(xp_ref, h0_ref, u_ref, b_ref, o_ref, h_scratch):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        h_scratch[...] = h0_ref[...]

    h = h_scratch[...]                  # (bb, H) — persistent across t
    u = u_ref[...]                      # (H, 3H) — stationary
    xp = xp_ref[0]                      # (bb, 3H) — this step's tile
    b = b_ref[...]                      # (1, 3H)
    H = h.shape[-1]

    gates = jnp.dot(h, u, preferred_element_type=jnp.float32) + xp + b
    z = jax.nn.sigmoid(gates[:, :H])
    r = jax.nn.sigmoid(gates[:, H:2 * H])
    n_in = xp[:, 2 * H:] + b[:, 2 * H:]
    n_h = jnp.dot(r * h, u[:, 2 * H:], preferred_element_type=jnp.float32)
    n = jnp.tanh(n_in + n_h)
    hn = z * h + (1.0 - z) * n
    h_scratch[...] = hn
    o_ref[0] = hn


def gru_seq_pallas(x_proj: jnp.ndarray, h0: jnp.ndarray, u: jnp.ndarray,
                   b: jnp.ndarray, *, bb: int = 128,
                   interpret: bool = False) -> jnp.ndarray:
    """x_proj (T, B, 3H), h0 (B, H), u (H, 3H), b (1, 3H) -> ys (T, B, H)."""
    T, B, _ = x_proj.shape
    H = h0.shape[-1]
    assert x_proj.shape == (T, B, 3 * H)
    assert B % bb == 0

    grid = (B // bb, T)
    return pl.pallas_call(
        _gru_seq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bb, 3 * H), lambda i, t: (t, i, 0)),
            pl.BlockSpec((bb, H), lambda i, t: (i, 0)),
            pl.BlockSpec((H, 3 * H), lambda i, t: (0, 0)),   # stationary
            pl.BlockSpec((1, 3 * H), lambda i, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bb, H), lambda i, t: (t, i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, B, H), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, H), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x_proj, h0, u, b)
