"""Persistent GRU sequence public wrapper — dispatch via the registry.

One launch per layer/direction instead of one per timestep: the batch is
padded to the tile size ONCE and the whole recurrent walk runs inside a
single ``pallas_call`` (see kernel.py).  Zero-padded batch rows are inert
— every per-row op (the h·U matmul rows included) is independent of the
other rows, so the real rows are bitwise what the per-step path computes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.gru_seq.kernel import gru_seq_pallas
from repro.kernels.gru_seq.ref import gru_seq_ref


def _impl_pallas(x_proj, h0, u, b, *, bb: int = 128,
                 interpret: bool = False) -> jnp.ndarray:
    """Pad batch to the tile size and run the persistent kernel."""
    B = h0.shape[0]
    pad = (-B) % bb
    if pad:
        x_proj = jnp.pad(x_proj, ((0, 0), (0, pad), (0, 0)))
        h0 = jnp.pad(h0, ((0, pad), (0, 0)))
    out = gru_seq_pallas(x_proj, h0, u, b.reshape(1, -1), bb=bb,
                         interpret=interpret)
    return out[:, :B]


def _impl_ref(x_proj, h0, u, b, **_tiles) -> jnp.ndarray:
    return gru_seq_ref(x_proj, h0, u, b)


def _example():
    """Ragged batch vs bb=128, odd T (cf. tests/test_registry.py)."""
    T, B, H = 7, 23, 48
    return ((jnp.zeros((T, B, 3 * H), jnp.float32),
             jnp.zeros((B, H), jnp.float32),
             jnp.zeros((H, 3 * H), jnp.float32),
             jnp.zeros((3 * H,), jnp.float32)), {})


registry.register_op("gru_seq", ref=_impl_ref, pallas=_impl_pallas,
                     example=_example)


@functools.partial(jax.jit, static_argnames=("bb", "backend"))
def _dispatch(x_proj, h0, u, b, *, bb, backend):
    return registry.get_op("gru_seq", backend)(x_proj, h0, u, b, bb=bb)


def gru_seq(x_proj: jnp.ndarray, h0: jnp.ndarray, u: jnp.ndarray,
            b: jnp.ndarray, *, bb: int = 128,
            backend: str | None = None) -> jnp.ndarray:
    """Whole-layer GRU walk: x_proj (T, B, 3H), h0 (B, H) -> ys (T, B, H).

    Backend resolves before the jit boundary (see quant_matmul.ops)."""
    return _dispatch(x_proj, h0, u, b, bb=bb,
                     backend=registry.resolve_backend(backend))


__all__ = ["gru_seq", "gru_seq_ref"]
