"""Post-SPMD HLO analysis: collective inventory + roofline terms.

``collective_stats``: parse ``compiled.as_text()`` and sum, per collective
kind, the result-buffer bytes and the estimated per-device WIRE bytes:

    all-gather          out * (g-1)/g        (ring receive volume)
    all-reduce          2 * size * (g-1)/g   (reduce-scatter + all-gather)
    reduce-scatter      out * (g-1)           (receives (g-1)/g of input)
    all-to-all          size * (g-1)/g
    collective-permute  size                  (point-to-point)

g is parsed from replica_groups (both the explicit {{...}} and the iota
[n,g]<= forms).  cost_analysis()['flops'/'bytes accessed'] are already
per-device for an SPMD-partitioned module (validated empirically), so the
three roofline terms are directly comparable.

v5e constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>[^=]*?)\s*"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_EXPL = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_EXPL.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    return default


def collective_stats(hlo_text: str, n_devices: int) -> Dict:
    """Per-kind {count, result_bytes, wire_bytes} + totals (per device)."""
    out = {k: {"count": 0, "result_bytes": 0, "wire_bytes": 0.0}
           for k in ("all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute")}
    for line in hlo_text.splitlines():
        if "-done" in line and "fusion" not in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group("kind")
        rb = _shape_bytes(m.group("shape"))
        if rb == 0:
            continue
        g = max(_group_size(line, n_devices), 1)
        frac = (g - 1) / g
        wire = {"all-gather": rb * frac,
                "all-reduce": 2.0 * rb * frac,
                "reduce-scatter": rb * (g - 1),
                "all-to-all": rb * frac,
                "collective-permute": float(rb)}[kind]
        out[kind]["count"] += 1
        out[kind]["result_bytes"] += rb
        out[kind]["wire_bytes"] += wire
    out["total_wire_bytes"] = sum(
        v["wire_bytes"] for k, v in out.items() if isinstance(v, dict))
    out["total_count"] = sum(
        v["count"] for k, v in out.items() if isinstance(v, dict))
    return out


# ---------------------------------------------------------------------------
# loop-aware HLO cost reconstruction
# ---------------------------------------------------------------------------
# XLA's cost_analysis() counts a while-loop body ONCE regardless of trip
# count (verified empirically — see EXPERIMENTS.md §Roofline methodology),
# so scan-stacked models report per-iteration costs. This section rebuilds
# flops / bytes-accessed / collective-wire-bytes from the HLO text with
# while bodies multiplied by their parsed trip counts.

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s*([\w\-]+)\((.*)$")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_CALL_SINGLE = re.compile(
    r"(?:body|to_apply|calls|condition)=%?([\w.\-]+)")
_CALL_LIST = re.compile(r"branch_computations=\{([^}]*)\}")


def _callees(rest: str):
    out = [m.group(1) for m in _CALL_SINGLE.finditer(rest)]
    for m in _CALL_LIST.finditer(rest):
        out.extend(c.strip().lstrip("%") for c in m.group(1).split(","))
    return out
_DOT_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


class _Comp:
    def __init__(self, name):
        self.name = name
        self.ops = []          # (result_name, shape_text, opcode, rest)
        self.shapes = {}       # value name -> byte size


def _parse_computations(hlo_text: str):
    comps = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = _COMP_HDR.match(stripped.replace("ENTRY ", ""))
            name = stripped.split()[1 if stripped.startswith("ENTRY") else 0]
            name = name.lstrip("%").split("(")[0].split()[0]
            cur = comps.setdefault(name, _Comp(name))
            continue
        if stripped == "}" or cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            res, shape_text, opcode, rest = m.groups()
            cur.ops.append((res, shape_text, opcode, rest))
            cur.shapes[res] = _shape_bytes(shape_text)
    return comps


def _operand_names(rest: str):
    """Names inside the op's FIRST parenthesized group (already open)."""
    depth = 1
    out = []
    token = ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        token += ch
    return re.findall(r"%([\w.\-]+)", token)


def _trip_count(comp: _Comp, comps=None) -> int:
    """Trip count from a while CONDITION computation: the s32[] constant
    operand of its bound compare (direction=LT/LE), not just any constant
    (conditions can also hold clamp bounds like the vocab size)."""
    consts = {}
    for res, shape_text, opcode, rest in comp.ops:
        if opcode == "constant" and re.search(r"s32\[\]", shape_text):
            c = re.match(r"(\d+)\)", rest)
            if c:
                consts[res] = int(c.group(1))
    for res, shape_text, opcode, rest in comp.ops:
        ops = _operand_names(rest)
        if opcode == "compare":
            m = re.search(r"direction=(LT|LE|GT|GE)", rest)
            for o in ops:
                if o in consts:
                    t = consts[o]
                    return t + 1 if (m and m.group(1) == "LE") else t
        if opcode == "fusion" and comps is not None:
            for c in _callees(rest):
                sub = comps.get(c)
                if sub is not None:
                    t = _trip_count(sub, comps)
                    if t > 1:
                        return t
    # fallback: single constant in the condition
    if len(consts) == 1:
        return next(iter(consts.values()))
    return 1


def loop_aware_cost(hlo_text: str, n_devices: int):
    """(flops, bytes_accessed, collective_wire_bytes) with while bodies
    multiplied by trip counts. Per-device (post-SPMD module)."""
    comps = _parse_computations(hlo_text)
    # element sizes (not bytes) per value for dot contraction math
    elem_tbl = {}
    dt_bytes = _DTYPE_BYTES

    def shape_dims(shape_text):
        out = []
        for dt, dims in _SHAPE_RE.findall(shape_text):
            d = [int(x) for x in dims.split(",") if x]
            out.append((dt, d))
        return out

    # pre-index value -> (dtype, dims) for each computation
    comp_vals = {}
    for name, comp in comps.items():
        tbl = {}
        for res, shape_text, opcode, rest in comp.ops:
            ds = shape_dims(shape_text)
            if ds:
                tbl[res] = ds[0]
        comp_vals[name] = tbl

    memo = {}
    # ops that are views/metadata: no HBM traffic of their own
    _FREE = {"parameter", "tuple", "get-tuple-element", "bitcast",
             "constant", "after-all", "partition-id", "replica-id",
             "get-dimension-size", "opt-barrier", "optimization-barrier"}

    def cost(name):
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None:
            return (0.0, 0.0, 0.0)
        memo[name] = (0.0, 0.0, 0.0)   # cycle guard
        fl = by = wi = 0.0
        tbl = comp_vals[name]
        for res, shape_text, opcode, rest in comp.ops:
            rbytes = comp.shapes.get(res, 0)
            ops = _operand_names(rest)
            if opcode == "fusion":
                # fused dynamic-slice/gather reads only a slice of a big
                # operand (e.g. the layer-stacked weights inside a scan
                # body); cap per-operand traffic near the result size, the
                # upper bound on what a kLoop/kOutput fusion consumes
                obytes = sum(min(comp.shapes.get(o, 0),
                                 2 * rbytes + (1 << 20)) for o in ops)
            else:
                obytes = sum(comp.shapes.get(o, 0) for o in ops)
            callees = _callees(rest)
            if opcode in _FREE:
                continue
            if opcode == "dynamic-slice":
                by += 2.0 * rbytes          # read slice + write result
                continue
            if opcode == "dynamic-update-slice":
                upd = (comp.shapes.get(ops[1], 0) if len(ops) > 1 else 0)
                by += 2.0 * upd             # in-place slice write
                continue
            if opcode == "while":
                body_cost = [0.0, 0.0, 0.0]
                for c in callees:
                    sub = cost(c)
                    body_cost = [a + b for a, b in zip(body_cost, sub)]
                # trip count ONLY from the condition computation — the body
                # holds unrelated s32 constants (sequence lengths etc.)
                trips = 1
                mcond = re.search(r"condition=%?([\w.\-]+)", rest)
                if mcond:
                    trips = _trip_count(
                        comps.get(mcond.group(1), _Comp("")), comps)
                fl += body_cost[0] * trips
                by += body_cost[1] * trips
                wi += body_cost[2] * trips
                continue
            if opcode in ("call", "conditional", "custom-call", "fusion",
                          "map", "reduce", "reduce-window", "sort",
                          "scatter", "select-and-scatter", "async-start"):
                for c in callees:
                    sub = cost(c)
                    # fusion internals: count FLOPs; bytes = boundary only
                    fl += sub[0]
                    wi += sub[2]
            if opcode == "dot":
                dtype, out_dims = tbl.get(res, ("f32", []))
                out_elems = 1
                for d in out_dims:
                    out_elems *= d
                k = 1
                m = _DOT_CDIMS.search(rest)
                if m and ops and ops[0] in tbl:
                    _, lhs_dims = tbl[ops[0]]
                    for di in m.group(1).split(","):
                        if di and int(di) < len(lhs_dims):
                            k *= lhs_dims[int(di)]
                fl += 2.0 * out_elems * k
            cw = _COLL_RE.search(" = " + shape_text + " " + opcode + "(")
            if cw:
                g = max(_group_size(rest, n_devices), 1)
                frac = (g - 1) / g
                wire = {"all-gather": rbytes * frac,
                        "all-reduce": 2.0 * rbytes * frac,
                        "reduce-scatter": rbytes * (g - 1),
                        "all-to-all": rbytes * frac,
                        "collective-permute": float(rbytes)}[cw.group("kind")]
                wi += wire
            by += rbytes + obytes
        memo[name] = (fl, by, wi)
        return memo[name]

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            entry = line.split()[1].lstrip("%").split("(")[0]
            break
    if entry is None:
        return (0.0, 0.0, 0.0)
    return cost(entry)


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   wire_bytes_per_dev: float, ici_links: int = 4) -> Dict:
    """The three per-device roofline terms, in seconds."""
    t_compute = flops_per_dev / PEAK_FLOPS
    t_memory = bytes_per_dev / HBM_BW
    t_collective = wire_bytes_per_dev / (ICI_BW * ici_links)
    terms = {"t_compute_s": t_compute, "t_memory_s": t_memory,
             "t_collective_s": t_collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = {"t_compute_s": "compute", "t_memory_s": "memory",
                         "t_collective_s": "collective"}[dom]
    terms["t_bound_s"] = max(t_compute, t_memory, t_collective)
    return terms


def model_flops(cfg, shape, n_devices: int) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) per step, per device.

    decode shapes: D = batch tokens (one step); train: 6ND fwd+bwd;
    prefill: 2ND (fwd only).
    """
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        d_tokens = shape.batch * shape.seq
        f = 6.0 * n_active * d_tokens
    elif shape.kind == "prefill":
        d_tokens = shape.batch * shape.seq
        f = 2.0 * n_active * d_tokens
    else:
        f = 2.0 * n_active * shape.batch
    return f / n_devices
