"""Serving launcher: the ``repro.serve.Server`` lifecycle on a smoke config.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
        [--slots 4] [--requests 6] [--max-tokens 8] [--stream]

The production serve_step (one decode step against a seq_len KV cache on
the 16x16 / 2x16x16 meshes) is lowered+validated by repro.launch.dryrun;
this driver exercises the same decode path end to end through the unified
serving API: requests are submitted as ``LMRequest``s, the ``Server``
owns admission/backpressure/retirement, and the run ends with a
``metrics()`` snapshot (requests/s, occupancy, p50/p99).
"""
import argparse

import jax
import numpy as np

from repro import configs as cfg_reg
from repro.models import lm as lm_lib
from repro.serve import LMRequest, Server
from repro.serve.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b",
                    choices=list(cfg_reg.LM_IDS))
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-tokens", type=int, default=8)
    ap.add_argument("--stream", action="store_true",
                    help="stream the first request token by token")
    args = ap.parse_args()

    cfg = cfg_reg.get_smoke(args.arch)
    if not cfg.embed_inputs:
        raise SystemExit(f"{args.arch} is a stub-frontend arch; serve a "
                         "token model (e.g. qwen2.5-3b)")
    params = lm_lib.init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, batch_slots=args.slots, max_len=128)
    srv = Server(eng, max_queue=max(args.requests, 1), backpressure="block")

    rng = np.random.default_rng(0)
    reqs = [LMRequest(prompt=rng.integers(0, cfg.vocab_size,
                                          int(rng.integers(2, 8))),
                      max_tokens=args.max_tokens)
            for _ in range(args.requests)]

    if args.stream and reqs:
        print("streaming request 0:")
        for ev in srv.stream(reqs[0]):
            if ev.kind == "token":
                print(f"  token[{ev.index}] = {ev.payload}")
        reqs = reqs[1:]

    futs = [srv.submit(r) for r in reqs]
    for f in futs:
        f.result()
    # report over EVERYTHING this server completed, streamed included
    done = sorted(srv.results.values(), key=lambda r: r.rid)
    m = srv.metrics()
    total = sum(len(r.value) for r in done if r.ok)
    print(f"served {m.completed} requests / {total} tokens in "
          f"{m.elapsed_s:.2f}s ({m.steps} engine steps, {args.slots} slots, "
          f"occupancy {m.occupancy:.2f}, {m.requests_per_s:.2f} req/s, "
          f"p50 {m.latency_p50_s:.3f}s p99 {m.latency_p99_s:.3f}s)")
    for res in done:
        print(f"  req {res.rid}: {res.value}")


if __name__ == "__main__":
    main()
