"""Serving launcher: continuous-batching engine on a smoke config.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
        [--slots 4] [--requests 6] [--max-tokens 8]

The production serve_step (one decode step against a seq_len KV cache on
the 16x16 / 2x16x16 meshes) is lowered+validated by repro.launch.dryrun;
this driver exercises the same decode path end to end with the engine's
admission/retirement logic on local devices.
"""
import argparse
import time

import jax
import numpy as np

from repro import configs as cfg_reg
from repro.models import lm as lm_lib
from repro.serve.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b",
                    choices=list(cfg_reg.LM_IDS))
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = cfg_reg.get_smoke(args.arch)
    if not cfg.embed_inputs:
        raise SystemExit(f"{args.arch} is a stub-frontend arch; serve a "
                         "token model (e.g. qwen2.5-3b)")
    params = lm_lib.init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, batch_slots=args.slots, max_len=128)

    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        plen = int(rng.integers(2, 8))
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab_size, plen),
                           max_tokens=args.max_tokens))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    total = sum(len(r.out_tokens) for r in done.values())
    print(f"served {len(done)} requests / {total} tokens in {dt:.2f}s "
          f"({eng.steps} engine steps, {args.slots} slots)")
    for rid in sorted(done):
        print(f"  req {rid}: {done[rid].out_tokens}")


if __name__ == "__main__":
    main()
