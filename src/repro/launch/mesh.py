"""Production mesh factory.

Single pod: v5e 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2 pods    = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is pure data-parallel; its gradient all-reduce is the only
traffic that crosses the (slow) inter-pod DCI, and it is int8-compressible
(dist/collectives.py).

Defined as a FUNCTION so importing this module never touches jax device
state (device count is locked on first backend init — dryrun.py sets
XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def _axis_kw(n_axes: int) -> dict:
    """axis_types only exists on newer jax; omit it elsewhere (same default)."""
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_host_mesh(model: int = 1):
    """Degenerate mesh over the locally available devices (tests/examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"),
                         **_axis_kw(2))
