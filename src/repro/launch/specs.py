"""(arch x shape x mesh) cell definitions for the dry-run.

For every assigned architecture and its shape set this module builds:
  * abstract inputs (ShapeDtypeStruct + NamedSharding) — no allocation;
  * the step function to lower:   train_4k   -> train_step (fwd+bwd+AdamW)
                                  prefill_32k -> prefill (logits + cache)
                                  decode_32k / long_500k -> serve_step
                                    (one new token against a seq_len cache).

Applicability rules (DESIGN.md §5): long_500k only for sub-quadratic archs
(SSM / hybrid / SWA); encoder-only archs would skip decode (none assigned);
base-callers use their own driver and are exercised by examples/benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as cfg_reg
from repro.dist import sharding as shd
from repro.models import decode as decode_lib
from repro.models import lm as lm_lib
from repro.train.optimizer import AdamW

# ---------------------------------------------------------------------------
# shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

SHAPE_IDS = tuple(SHAPES)

# encoder memory length for enc-dec decode shapes (decoder cache = seq_len,
# cross-attention memory is a fixed-length encoded utterance)
ENC_LEN_DECODE = 4_096


def applicable(arch_id: str, shape_id: str) -> Tuple[bool, str]:
    cfg = cfg_reg.get_config(arch_id)
    if shape_id == "long_500k":
        sub_quadratic = (cfg.block_pattern in ("mamba", "hybrid")
                         or cfg.window is not None)
        if not sub_quadratic:
            return False, ("full-attention arch: 500k dense-KV decode "
                           "out of spec (DESIGN.md §5)")
    return True, ""


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def runtime_config(arch_id: str) -> lm_lib.LMConfig:
    """Full config tuned for the production run: bf16 + remat + SP.

    REPRO_PERF_* env knobs toggle the §Perf hillclimb changes so baseline
    and optimized lowerings of the same cell can be A/B-measured.
    """
    import os
    cfg = cfg_reg.get_config(arch_id)
    cfg = dataclasses.replace(cfg, dtype=jnp.bfloat16, remat=True,
                              act_shard=True)
    if os.environ.get("REPRO_PERF_ATTN_SKIP"):
        cfg = dataclasses.replace(cfg, attn_causal_skip=True)
    if os.environ.get("REPRO_PERF_UNROLL_DECODE"):
        cfg = dataclasses.replace(cfg, scan_layers=False)
    return cfg


def batch_specs(cfg: lm_lib.LMConfig, shape: ShapeSpec, mesh):
    """Training/prefill batch as sharded ShapeDtypeStructs."""
    dp = shd.logical_spec(("dp",), mesh)[0]
    B, S = shape.batch, shape.seq
    batch = {}
    if cfg.embed_inputs:
        batch["tokens"] = _sds((B, S), jnp.int32, mesh, P(dp, None))
    else:
        batch["embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16, mesh,
                               P(dp, None, None))
        batch["labels"] = _sds((B, S), jnp.int32, mesh, P(dp, None))
    if cfg.encoder is not None:
        enc_len = S if shape.kind != "decode" else ENC_LEN_DECODE
        batch["enc_embeds"] = _sds((B, enc_len, cfg.d_model), jnp.bfloat16,
                                   mesh, P(dp, None, None))
    return batch


def _kv_head_axis(cfg, mesh) -> Tuple[Optional[str], Optional[str]]:
    """Shard KV-cache heads over tp when divisible, else head_dim."""
    tp = shd.logical_spec(("tp",), mesh)[0]
    if tp is None:
        return None, None
    tp_size = mesh.shape["model"]
    if cfg.n_kv_heads % tp_size == 0:
        return tp, None
    return None, tp


def cache_specs(cfg: lm_lib.LMConfig, shape: ShapeSpec, mesh,
                as_sharding_only: bool = False):
    """Abstract decode cache with per-leaf shardings (by leaf path name)."""
    B, S = shape.batch, shape.seq
    enc_len = ENC_LEN_DECODE if cfg.encoder is not None else 0
    shapes = jax.eval_shape(
        lambda: decode_lib.init_cache(cfg, B, S, enc_len))
    dp_ok = B % mesh.shape["data"] == 0 and B > 1
    dp = shd.logical_spec(("dp",), mesh)[0] if dp_ok else None
    head_ax, hd_ax = _kv_head_axis(cfg, mesh)
    seq_ax = None
    if not dp_ok:
        seq_ax = "data"   # long_500k: shard cache length instead of batch

    def spec_for(path, leaf):
        name = shd.path_str(path).split("/")[-1]
        if name in ("k", "v", "a_k", "a_v", "b_k", "b_v", "xk", "xv"):
            return P(None, dp, seq_ax, head_ax, hd_ax)
        if name == "h":        # (layers, B, di, n)
            return P(None, dp, "model", None)
        if name == "conv":     # (layers, B, K-1, di)
            return P(None, dp, None, "model")
        return P()             # pos scalar

    specs = jax.tree_util.tree_map_with_path(spec_for, shapes)
    if as_sharding_only:
        return jax.tree_util.tree_map(
            lambda sp: NamedSharding(mesh, sp), specs)
    return jax.tree_util.tree_map(
        lambda l, sp: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, specs)


def param_specs(cfg: lm_lib.LMConfig, mesh):
    shapes = jax.eval_shape(
        lambda: lm_lib.init_lm(jax.random.PRNGKey(0), cfg))
    shardings = shd.param_sharding_tree(shapes, mesh,
                                        overrides=shd.arch_overrides(cfg))
    sds = jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        shapes, shardings)
    return sds, shardings


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    axes = axis if isinstance(axis, tuple) else (axis,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def quantize_mask(params_sds, shardings, mesh):
    """8-bit moments only where (a) the leaf is big enough to matter and
    (b) the per-block stat layout (last dim -> last//256 blocks) still
    divides the leaf's last-axis sharding — otherwise GSPMD replicates the
    blocked f32 intermediates and the 'compression' costs memory."""
    def f(l, sh):
        if l.size < 1e8:
            return False
        spec = sh.spec
        last_ax = spec[l.ndim - 1] if len(spec) >= l.ndim else None
        if last_ax is None:
            return True
        n = _axis_size(mesh, last_ax)
        return l.shape[-1] % 256 == 0 and (l.shape[-1] // 256) % n == 0

    return jax.tree_util.tree_map(f, params_sds, shardings)


def make_optimizer(cfg: lm_lib.LMConfig, params_sds=None, shardings=None,
                   mesh=None) -> AdamW:
    """8-bit Adam moments for >20B-param models (fits v5e HBM), fp32 else."""
    big = cfg.param_count() > 20e9
    mask = None
    if big and params_sds is not None:
        mask = quantize_mask(params_sds, shardings, mesh)
    return AdamW(lr=3e-4, weight_decay=0.1, clip_norm=1.0,
                 state_bits=8 if big else 32, quantize_mask=mask)


def train_grad_accum(cfg: lm_lib.LMConfig) -> int:
    """Microbatching for the activation-heavy families.

    SSM (8x): the selective-scan state (B, S, d_inner, n) is ~n/2 residual
    streams per layer. MoE (4x): the capacity-dispatch buffers (E, C, d)
    run in f32 (see lm._moe_apply) and scale with local tokens. Both blow
    the 16 GB/chip budget at per-device batch 16 without accumulation.
    """
    import os
    if os.environ.get("REPRO_ACCUM"):
        return int(os.environ["REPRO_ACCUM"])
    if cfg.block_pattern in ("mamba", "hybrid"):
        return 8
    if cfg.moe is not None and cfg.block_pattern == "moe":
        return 4     # olmoe: top-8 of 64 => large dispatch buffers
    return 1         # llama4: top-1 of 128 => tiny capacity, no accum needed


def opt_specs(opt: AdamW, params_sds, mesh, cfg=None):
    shapes = jax.eval_shape(opt.init, params_sds)
    overrides = shd.arch_overrides(cfg) if cfg is not None else ()

    def f(path, leaf):
        s = shd.path_str(path)
        logical = shd.param_logical(s, leaf.ndim, "blocks" in s, overrides)
        spec = shd.logical_spec(logical, mesh)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(f, shapes)


# ---------------------------------------------------------------------------
# step builders — return (fn, abstract_args, donate_argnums, out_shardings)
# ---------------------------------------------------------------------------

def _sh(sds_tree):
    """ShapeDtypeStruct tree -> its sharding tree (for out_shardings)."""
    return jax.tree_util.tree_map(lambda l: l.sharding, sds_tree)


def build_cell(arch_id: str, shape_id: str, mesh):
    cfg = runtime_config(arch_id)
    shape = SHAPES[shape_id]

    if shape.kind == "train":
        params_sds, param_shardings = param_specs(cfg, mesh)
        opt = make_optimizer(cfg, params_sds, param_shardings, mesh)
        opt_sds = opt_specs(opt, params_sds, mesh, cfg)
        batch_sds = batch_specs(cfg, shape, mesh)
        accum = train_grad_accum(cfg)

        def pin(grads):
            """Gradients always carry the parameter's sharding — otherwise
            GSPMD may leave the optimizer's f32 temporaries for the large
            embed/head tables nearly replicated (multi-GiB per device)."""
            return jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, grads, param_shardings)

        def train_step(params, opt_state, batch):
            if accum == 1:
                (loss, _), grads = jax.value_and_grad(
                    lambda p: lm_lib.lm_loss(p, cfg, batch),
                    has_aux=True)(params)
                grads = pin(grads)
            else:
                micro = jax.tree_util.tree_map(
                    lambda x: x.reshape((accum, x.shape[0] // accum)
                                        + x.shape[1:]), batch)

                def body(acc, mb):
                    (l, _), g = jax.value_and_grad(
                        lambda p: lm_lib.lm_loss(p, cfg, mb),
                        has_aux=True)(params)
                    return (jax.tree_util.tree_map(jnp.add, acc[0], pin(g)),
                            acc[1] + l), None

                zero = pin(jax.tree_util.tree_map(
                    lambda l: jnp.zeros(l.shape, jnp.float32), params))
                (grads, loss), _ = jax.lax.scan(
                    body, (zero, jnp.zeros((), jnp.float32)), micro)
                grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
                loss = loss / accum
            new_p, new_s = opt.update(grads, opt_state, params)
            return new_p, new_s, {"loss": loss}

        out_sh = (_sh(params_sds), _sh(opt_sds), None)
        return train_step, (params_sds, opt_sds, batch_sds), (0, 1), out_sh

    if shape.kind == "prefill":
        params_sds, _ = param_specs(cfg, mesh)
        batch_sds = batch_specs(cfg, shape, mesh)

        def prefill_step(params, batch):
            return decode_lib.prefill(params, cfg, batch, max_len=shape.seq)

        cache_sh = cache_specs(cfg, shape, mesh, as_sharding_only=True)
        dp = shd.logical_spec(("dp",), mesh)[0]
        tp = shd.logical_spec(("tp",), mesh)[0]
        logits_sh = NamedSharding(mesh, P(dp, None, tp))
        return (prefill_step, (params_sds, batch_sds), (),
                (logits_sh, cache_sh))

    # decode: one new token against a seq_len cache
    params_sds, _ = param_specs(cfg, mesh)
    cache_sds = cache_specs(cfg, shape, mesh)
    dp_ok = shape.batch % mesh.shape["data"] == 0 and shape.batch > 1
    dp = shd.logical_spec(("dp",), mesh)[0] if dp_ok else None
    B = shape.batch
    if cfg.embed_inputs:
        tok_sds = _sds((B,), jnp.int32, mesh, P(dp))

        def serve_step(params, cache, tokens):
            return decode_lib.decode_step(params, cfg, cache, tokens=tokens)

        cache_sh = cache_specs(cfg, shape, mesh, as_sharding_only=True)
        tp = shd.logical_spec(("tp",), mesh)[0]
        logits_sh = NamedSharding(mesh, P(dp, tp))
        return (serve_step, (params_sds, cache_sds, tok_sds), (1,),
                (logits_sh, cache_sh))

    emb_sds = _sds((B, 1, cfg.d_model), jnp.bfloat16, mesh, P(dp, None, None))

    def serve_step_e(params, cache, embeds):
        return decode_lib.decode_step(params, cfg, cache, embeds=embeds)

    cache_sh = cache_specs(cfg, shape, mesh, as_sharding_only=True)
    tp = shd.logical_spec(("tp",), mesh)[0]
    logits_sh = NamedSharding(mesh, P(dp, tp))
    return (serve_step_e, (params_sds, cache_sds, emb_sds), (1,),
            (logits_sh, cache_sh))
