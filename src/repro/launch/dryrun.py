import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(*abstract_inputs).compile()`` must succeed
on the single-pod (16x16) and the 2-pod (2x16x16) production meshes for all
40 (architecture x input-shape) cells; ``memory_analysis()`` proves the
per-chip footprint fits a 16 GB v5e and ``cost_analysis()`` + the HLO
collective inventory feed EXPERIMENTS.md §Roofline.

The device-count override above MUST precede any jax import (jax locks the
device count on first backend init) and is deliberately NOT set anywhere
else — tests and benchmarks see the single real CPU device.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k --mesh pod1
  python -m repro.launch.dryrun --all --mesh both --out benchmarks/artifacts
"""
import argparse
import json
import time
import traceback

import jax

from repro import configs as cfg_reg
from repro.launch import analysis, mesh as mesh_lib, specs

V5E_HBM_BYTES = 16 * 1024 ** 3


def run_cell(arch: str, shape_id: str, mesh_name: str,
             keep_hlo: bool = False) -> dict:
    """Lower+compile one cell; returns the JSON-able record."""
    ok, why = specs.applicable(arch, shape_id)
    rec = {"arch": arch, "shape": shape_id, "mesh": mesh_name}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_name == "pod2"))
    n_dev = mesh.size
    t0 = time.monotonic()
    try:
        from repro.dist import sharding as shd
        with shd.use_mesh(mesh):
            fn, args, donate, out_sh = specs.build_cell(arch, shape_id,
                                                        mesh)
            jitted = jax.jit(fn, donate_argnums=donate,
                             out_shardings=out_sh)
            lowered = jitted.lower(*args)
            t_lower = time.monotonic() - t0
            compiled = lowered.compile()
            t_compile = time.monotonic() - t0 - t_lower

            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):  # older jax: list of one dict
                cost = cost[0]
            mem = compiled.memory_analysis()
            hlo = compiled.as_text()
        colls = analysis.collective_stats(hlo, n_dev)
        cfg = specs.runtime_config(arch)
        shape = specs.SHAPES[shape_id]
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        # loop-aware reconstruction: XLA cost_analysis counts while bodies
        # once; these multiply by parsed trip counts (validated vs unrolled
        # lowerings — see tests/test_analysis.py)
        la_flops, la_bytes, la_wire = analysis.loop_aware_cost(hlo, n_dev)
        terms = analysis.roofline_terms(la_flops, la_bytes, la_wire)
        mf = analysis.model_flops(cfg, shape, n_dev)
        dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
        rec.update(
            status="ok",
            n_devices=n_dev,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            hlo_flops_per_dev=la_flops,
            hlo_bytes_per_dev=la_bytes,
            collective_wire_bytes_loop_aware=la_wire,
            xla_reported_flops=flops,
            xla_reported_bytes=bytes_acc,
            transcendentals=float(cost.get("transcendentals", 0.0)),
            collectives={k: v for k, v in colls.items()
                         if isinstance(v, dict) and v["count"]},
            collective_wire_bytes=colls["total_wire_bytes"],
            collective_count=colls["total_count"],
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "per_device_bytes": dev_bytes,
                "fits_v5e_16g": bool(dev_bytes < V5E_HBM_BYTES),
            },
            roofline=terms,
            model_flops_per_dev=mf,
            useful_flops_frac=(mf / la_flops if la_flops else 0.0),
            hlo_lines=hlo.count("\n"),
        )
        if keep_hlo:
            rec["hlo_text"] = hlo
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug report
        rec.update(status="failed", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    return rec


def cell_list(archs, shapes):
    return [(a, s) for a in archs for s in shapes]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None,
                    help="architecture id (see repro.configs.LM_IDS)")
    ap.add_argument("--shape", default=None, choices=specs.SHAPE_IDS)
    ap.add_argument("--mesh", default="pod1", choices=("pod1", "pod2",
                                                       "both"))
    ap.add_argument("--all", action="store_true",
                    help="sweep all (arch x shape) cells")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun",
                    help="artifact dir (one JSON per cell)")
    args = ap.parse_args()

    if args.all:
        cells = cell_list(cfg_reg.LM_IDS, specs.SHAPE_IDS)
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]
    meshes = ("pod1", "pod2") if args.mesh == "both" else (args.mesh,)

    os.makedirs(args.out, exist_ok=True)
    n_fail = 0
    for arch, shape_id in cells:
        for mesh_name in meshes:
            rec = run_cell(arch, shape_id, mesh_name)
            path = os.path.join(
                args.out, f"{arch}__{shape_id}__{mesh_name}.json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            status = rec["status"]
            extra = ""
            if status == "ok":
                extra = (f" compile={rec['compile_s']}s "
                         f"mem/dev={rec['memory']['per_device_bytes']/2**30:.2f}GiB "
                         f"dom={rec['roofline']['dominant']}")
            elif status == "failed":
                n_fail += 1
                extra = " " + rec["error"][:160]
            print(f"[{status:7s}] {arch} x {shape_id} x {mesh_name}{extra}",
                  flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
