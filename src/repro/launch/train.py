"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        [--steps 100] [--smoke] [--ckpt-dir DIR] [--resume]

On real hardware this process runs per host (jax.distributed initializes
from the TPU environment) and the production mesh spans the pod(s); in this
offline container use --smoke to run the reduced config on local devices.
The step function, shardings, optimizer and fault-tolerance plumbing are
identical to what launch/dryrun.py lowers for the 16x16 / 2x16x16 meshes.
"""
import argparse
import logging

import jax
import jax.numpy as jnp

from repro import configs as cfg_reg
from repro.launch import specs
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(cfg_reg.LM_IDS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices (CPU-friendly)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(message)s")

    if not args.smoke:
        # production path: init the distributed runtime + production mesh,
        # then reuse exactly the dry-run cell builder
        jax.distributed.initialize()
        from repro.dist import sharding as shd
        from repro.launch import mesh as mesh_lib
        mesh = mesh_lib.make_production_mesh(multi_pod=args.multi_pod)
        with shd.use_mesh(mesh):
            fn, sds, donate, out_sh = specs.build_cell(
                args.arch, "train_4k", mesh)
            raise SystemExit(
                "production launch requires TPU hosts; the compiled step "
                "for this config is validated by repro.launch.dryrun")

    from repro.models import lm as lm_lib
    cfg = cfg_reg.get_smoke(args.arch)
    params = lm_lib.init_lm(jax.random.PRNGKey(0), cfg)

    def data_fn(step):
        key = jax.random.fold_in(jax.random.PRNGKey(17), step)
        tokens = jax.random.randint(key, (args.batch, args.seq), 0,
                                    cfg.vocab_size)
        batch = {"tokens": tokens}
        if not cfg.embed_inputs:
            batch = {"embeds": jax.random.normal(
                key, (args.batch, args.seq, cfg.d_model)) * 0.1,
                "labels": tokens}
        if cfg.encoder is not None:
            batch["enc_embeds"] = jax.random.normal(
                key, (args.batch, args.seq, cfg.d_model)) * 0.1
        return batch

    def loss_fn(params, batch):
        return lm_lib.lm_loss(params, cfg, batch)

    opt = AdamW(lr=warmup_cosine(1e-3, 10, args.steps), weight_decay=0.01)
    trainer = Trainer(loss_fn, data_fn, params, opt,
                      TrainerConfig(steps=args.steps, log_every=10,
                                    ckpt_every=25, ckpt_dir=args.ckpt_dir))
    if args.resume:
        trainer.run()
    else:
        trainer.run_from(0)


if __name__ == "__main__":
    main()
