"""Optimizers (no optax offline): AdamW + schedules + 8-bit state option.

The 8-bit optimizer state is the paper's quantization theme applied to the
training substrate: Adam's m/v moments are stored as int8 codes with
per-block fp32 scales (bitsandbytes-style).  This is what lets
llama4-maverick's 400 B parameters fit a 16 GB/chip v5e pod in the dry-run
(fp32 moments would need 8 bytes/param; int8 blocks need ~2.03).

Functional API mirroring optax:  ``opt.init(params) -> state``;
``opt.update(grads, state, params) -> (new_params, new_state)``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1):
    """Linear warmup then cosine decay to ``floor * peak_lr``."""

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) /
                        jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)

    return f


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


# ---------------------------------------------------------------------------
# 8-bit block quantized moment storage
# ---------------------------------------------------------------------------

BLOCK = 256


def _to_blocks(x: jnp.ndarray):
    """(..., d) -> (..., nb, BLOCK) along the LAST axis (shape-preserving
    blocking: codes keep the parameter's layout so the same sharding rules
    apply to optimizer state — critical for the 400B dry-run)."""
    if x.ndim == 0:
        x = x.reshape(1)
    pad = (-x.shape[-1]) % BLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x.reshape(x.shape[:-1] + (x.shape[-1] // BLOCK, BLOCK))


def _from_blocks(blocks: jnp.ndarray, shape):
    last = shape[-1] if shape else 1
    flatlast = blocks.reshape(blocks.shape[:-2] + (-1,))[..., :last]
    return flatlast.reshape(shape)


def quantize_moment(x: jnp.ndarray):
    """First moment m: signed linear int8 codes, per-block absmax scales."""
    blocks = _to_blocks(x)
    amax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return (codes.reshape(codes.shape[:-2] + (-1,)),
            scale[..., 0].astype(jnp.float32))


def dequantize_moment(codes: jnp.ndarray, scale: jnp.ndarray, shape, size):
    blocks = codes.reshape(codes.shape[:-1] + (-1, BLOCK))
    return _from_blocks(blocks.astype(jnp.float32) * scale[..., None], shape)


_V_FLOOR = 1e-16


def quantize_v(x: jnp.ndarray):
    """Second moment v: LOG-domain affine uint8 codes.

    Linear absmax codes flush small v entries to 0 and m/(sqrt(v)+eps)
    explodes; log-domain storage bounds the RELATIVE error instead
    (the non-linear-quantile idea from 8-bit Adam, in closed form).
    """
    blocks = _to_blocks(jnp.log(jnp.maximum(x, _V_FLOOR)))
    lo = blocks.min(axis=-1, keepdims=True)
    hi = blocks.max(axis=-1, keepdims=True)
    step = jnp.maximum(hi - lo, 1e-12) / 255.0
    codes = jnp.clip(jnp.round((blocks - lo) / step), 0, 255).astype(jnp.uint8)
    return (codes.reshape(codes.shape[:-2] + (-1,)),
            lo[..., 0].astype(jnp.float32), step[..., 0].astype(jnp.float32))


def dequantize_v(codes, lo, step, shape, size):
    blocks = codes.reshape(codes.shape[:-1] + (-1, BLOCK))
    logv = blocks.astype(jnp.float32) * step[..., None] + lo[..., None]
    v = _from_blocks(jnp.exp(logv), shape)
    return jnp.where(v <= _V_FLOOR * 1.0001, 0.0, v)


class MomentQ(NamedTuple):
    codes: jnp.ndarray
    scale: jnp.ndarray


class VMomentQ(NamedTuple):
    codes: jnp.ndarray
    lo: jnp.ndarray
    step: jnp.ndarray


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: object   # pytree of arrays or MomentQ
    v: object


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0
    state_bits: int = 32          # 32 => fp32 moments; 8 => quantized blocks
    moment_dtype: jnp.dtype = jnp.float32
    # optional bool pytree: which leaves get 8-bit moments. Lets the launch
    # layer exclude leaves whose last-axis blocking would break their
    # sharding (and small leaves where fp32 is free). None => all leaves.
    quantize_mask: Any = dataclasses.field(default=None, compare=False)

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def _flat_mask(self, treedef, n):
        if self.quantize_mask is None or self.state_bits != 8:
            return [self.state_bits == 8] * n
        return treedef.flatten_up_to(self.quantize_mask)

    # -- moment (de)materialization ---------------------------------------
    def _store(self, x, q: bool):
        if q:
            return MomentQ(*quantize_moment(x))
        return x.astype(self.moment_dtype)

    def _load(self, s, like):
        if isinstance(s, MomentQ):
            return dequantize_moment(s.codes, s.scale, like.shape, like.size)
        return s.astype(jnp.float32)

    def _store_v(self, x, q: bool):
        if q:
            return VMomentQ(*quantize_v(x))
        return x.astype(self.moment_dtype)

    def _load_v(self, s, like):
        if isinstance(s, VMomentQ):
            return dequantize_v(s.codes, s.lo, s.step, like.shape, like.size)
        return s.astype(jnp.float32)

    # -- api ----------------------------------------------------------------
    def init(self, params) -> AdamState:
        flat_p, treedef = jax.tree_util.tree_flatten(params)
        qs = self._flat_mask(treedef, len(flat_p))
        z = treedef.unflatten(
            [self._store(jnp.zeros(p.shape, jnp.float32), q)
             for p, q in zip(flat_p, qs)])
        z2 = treedef.unflatten(
            [self._store_v(jnp.zeros(p.shape, jnp.float32), q)
             for p, q in zip(flat_p, qs)])
        return AdamState(jnp.zeros((), jnp.int32), z, z2)

    # leaves above this many elements update via a lax.scan over their
    # leading (layer-stack) axis: the whole-leaf f32 intermediate chain of a
    # 129B-param expert bank is ~8x 1.9 GiB/device live at once otherwise.
    # Only layer-stacked leaves qualify (small leading dim) — scanning a
    # (vocab, d) table row-by-row would be a 150k-trip loop.
    CHUNKED_UPDATE_MIN = 1 << 28
    CHUNK_LEAD_MAX = 256

    def update(self, grads, state: AdamState, params):
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            # the scale multiplies INSIDE the (chunked) per-leaf update:
            # a whole-tree `g * scale` materializes f32 copies of every
            # multi-GiB gradient leaf before the optimizer even starts
            gscale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-12))
        else:
            gscale = jnp.ones((), jnp.float32)

        def upd(p, g, m_s, v_s, q):
            g = g.astype(jnp.float32) * gscale
            m = self.b1 * self._load(m_s, p) + (1 - self.b1) * g
            v = self.b2 * self._load_v(v_s, p) + (1 - self.b2) * g * g
            mhat = m / (1 - self.b1 ** step.astype(jnp.float32))
            vhat = v / (1 - self.b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) -
                    self._lr(step) * delta).astype(p.dtype)
            return newp, self._store(m, q), self._store_v(v, q)

        def upd_leaf(p, g, m_s, v_s, q):
            if p.size < self.CHUNKED_UPDATE_MIN or p.ndim < 2 \
                    or not (1 < p.shape[0] <= self.CHUNK_LEAD_MAX):
                return upd(p, g, m_s, v_s, q)

            def body(_, xs):
                return None, upd(*xs, q)

            _, (np_, nm, nv) = jax.lax.scan(body, None, (p, g, m_s, v_s))
            return np_, nm, nv

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        qs = self._flat_mask(treedef, len(flat_p))
        out = [upd_leaf(p, g, m, v, q) for p, g, m, v, q
               in zip(flat_p, flat_g, flat_m, flat_v, qs)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, AdamState(step, new_m, new_v)


def global_norm(tree) -> jnp.ndarray:
    def sumsq(x):
        if x.size >= AdamW.CHUNKED_UPDATE_MIN and x.ndim >= 2 \
                and 1 < x.shape[0] <= AdamW.CHUNK_LEAD_MAX:
            # chunk over the layer-stack axis: a whole-leaf f32 convert of
            # a 100B+-param bank is GiB-scale if XLA fails to fuse it
            def body(acc, xi):
                return acc + jnp.sum(jnp.square(xi.astype(jnp.float32))), None
            tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), x)
            return tot
        return jnp.sum(jnp.square(x.astype(jnp.float32)))

    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(sumsq(x) for x in leaves))
