"""Fault tolerance & straggler mitigation for long-running training jobs.

Three cooperating pieces, all host-side (they wrap — never enter — the jitted
step, so they add zero compile-graph cost):

* ``Heartbeat``          — liveness registry. On a real cluster each host
                           posts a heartbeat per step to shared storage; the
                           coordinator declares a host dead after ``timeout``
                           and triggers an elastic restart (fewer pods) from
                           the last checkpoint.  Simulated in-process here,
                           with the same API.
* ``StragglerDetector``  — EWMA of step wall-times + z-score flagging.
                           On TPU pods stragglers are usually a host issue
                           (input starvation, ECC retries); mitigation =
                           recompile-free data re-balancing or host eviction.
* ``run_resilient``      — supervisor loop: run -> crash -> restore latest
                           checkpoint -> resume, up to ``max_restarts``.
                           Determinism contract: data is generated per global
                           step (``data.genome.batch_for_step``), so a
                           restarted run replays the identical batch stream
                           and loss curves are bit-reproducible.
"""
from __future__ import annotations

import collections
import math
import time
from typing import Callable, Dict, Optional


class Heartbeat:
    def __init__(self, timeout_s: float = 30.0, clock=time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        self._last: Dict[str, float] = {}

    def beat(self, worker: str, now: Optional[float] = None):
        self._last[worker] = self._clock() if now is None else now

    def alive(self, worker: str, now: Optional[float] = None) -> bool:
        if worker not in self._last:
            return False
        now = self._clock() if now is None else now
        return (now - self._last[worker]) <= self.timeout_s

    def dead_workers(self, now: Optional[float] = None):
        now = self._clock() if now is None else now
        return sorted(w for w, t in self._last.items()
                      if now - t > self.timeout_s)

    def quorum(self, expected: int, now: Optional[float] = None) -> bool:
        now = self._clock() if now is None else now
        live = sum(1 for t in self._last.values()
                   if now - t <= self.timeout_s)
        return live >= expected


class StragglerDetector:
    """Flags steps (or workers) whose duration is a z-score outlier."""

    def __init__(self, alpha: float = 0.1, z_threshold: float = 3.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.z = z_threshold
        self.warmup = warmup
        self.mean = 0.0
        self.var = 0.0
        self.n = 0

    def observe(self, duration_s: float) -> bool:
        """Returns True if this observation is a straggler."""
        self.n += 1
        if self.n <= self.warmup:
            # prime the EWMA
            d = duration_s - self.mean
            self.mean += d / self.n
            self.var += d * (duration_s - self.mean)
            return False
        std = math.sqrt(max(self.var / max(self.n - 1, 1), 1e-12))
        is_straggler = duration_s > self.mean + self.z * std
        if not is_straggler:  # don't poison stats with outliers
            self.mean = (1 - self.alpha) * self.mean + self.alpha * duration_s
            self.var = ((1 - self.alpha) * self.var +
                        self.alpha * (duration_s - self.mean) ** 2 *
                        max(self.n - 1, 1))
        return is_straggler


class FaultInjector:
    """Deterministic fault injection for tests: raise at given steps, once."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected fault at step {step}")


def run_resilient(
    run_from: Callable[[int], int],
    restore_step: Callable[[], int],
    max_restarts: int = 3,
    on_restart: Optional[Callable[[int, BaseException], None]] = None,
) -> int:
    """Supervise ``run_from(start_step) -> final_step`` with restarts.

    ``restore_step()`` re-loads the latest checkpoint into the caller's state
    and returns the step to resume from (0 if none).
    """
    restarts = 0
    while True:
        start = restore_step()
        try:
            return run_from(start)
        except Exception as e:  # noqa: BLE001 — supervisor must catch all
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart is not None:
                on_restart(restarts, e)


class StepTimer:
    """Context manager collecting step durations for the detector."""

    def __init__(self):
        self.durations = collections.deque(maxlen=1000)
        self._t0 = None

    def __enter__(self):
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        self.durations.append(time.monotonic() - self._t0)
        return False

    @property
    def last(self):
        return self.durations[-1] if self.durations else float("nan")
