"""Checkpointing: atomic sharded saves, restore, elastic resharding.

Layout:  <dir>/step_<N>/  arrays.npz  (leaf path -> host array)
                          META.json   (step, leaf paths, shapes, dtypes)
         <dir>/step_<N>.tmp.<pid>     staging dir, atomically renamed.

Fault-tolerance contract (used by ``train/fault.py`` and tested):
  * a save is either fully visible or absent (tmp dir + os.rename);
  * ``latest_step`` never returns a partially written checkpoint;
  * ``restore`` can re-lay the arrays onto a DIFFERENT mesh / sharding
    (elastic scaling: N pods -> M pods restarts), because arrays are stored
    as host-global numpy and re-placed with ``jax.device_put(x, sharding)``;
  * async mode snapshots to host (device_get) synchronously — cheap — and
    writes to disk on a daemon thread, overlapping I/O with the next steps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = leaf
    return out


def save(ckpt_dir: str, step: int, tree: Any, keep: int = 3) -> str:
    """Atomic synchronous save. Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp.{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    leaves = _flatten_with_paths(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in leaves.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **host)
    meta = {"step": step,
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host.items()}}
    with open(os.path.join(tmp, "META.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


_PENDING: list = []


def save_async(ckpt_dir: str, step: int, tree: Any, keep: int = 3):
    """Snapshot to host now; write to disk on a daemon thread."""
    leaves = _flatten_with_paths(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in leaves.items()}

    def _write():
        os.makedirs(ckpt_dir, exist_ok=True)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + f".tmp.{os.getpid()}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        meta = {"step": step,
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in host.items()}}
        with open(os.path.join(tmp, "META.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending():
    while _PENDING:
        _PENDING.pop().join()


def _gc(ckpt_dir: str, keep: int):
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and ".tmp" not in name:
            if os.path.exists(os.path.join(ckpt_dir, name, "META.json")):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, template: Any, step: Optional[int] = None,
            sharding_tree: Any = None):
    """Restore into ``template``'s structure.

    ``sharding_tree``: optional pytree (same structure or a single Sharding)
    used to re-place every leaf — this is the elastic-rescale path: the saved
    host-global array is valid for ANY mesh, so restoring onto more/fewer
    devices is just a different device_put.
    Returns (tree, step).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))

    flat = jax.tree_util.tree_flatten_with_path(template)
    paths_leaves, treedef = flat
    single_sharding = (sharding_tree is not None and
                       not isinstance(sharding_tree, (dict, list, tuple)))
    shard_leaves = (None if sharding_tree is None else
                    ([sharding_tree] * len(paths_leaves) if single_sharding
                     else [x for _, x in
                           jax.tree_util.tree_flatten_with_path(
                               sharding_tree)[0]]))

    new_leaves = []
    for i, (pth, leaf) in enumerate(paths_leaves):
        key = "/".join(str(p) for p in pth)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"template {leaf.shape}")
        if shard_leaves is not None:
            new_leaves.append(jax.device_put(arr, shard_leaves[i]))
        else:
            new_leaves.append(jax.device_put(arr.astype(leaf.dtype)))
    return treedef.unflatten(new_leaves), step
