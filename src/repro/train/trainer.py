"""Generic training loop: jitted step, grad accumulation, checkpoints, FT.

The trainer is model-agnostic: it owns (loss_fn, optimizer, data_fn) and
wires in the production concerns — deterministic per-step data (restart
replay), periodic async checkpoints, straggler detection, heartbeats, and a
resilient supervisor (``run_resilient``).  The same class drives the SEAT
base-caller reproduction (examples/train_seat.py) and the LM smoke drivers.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.train import checkpoint as ckpt_lib
from repro.train import fault as fault_lib
from repro.train.optimizer import AdamW

log = logging.getLogger("repro.trainer")

LossFn = Callable[[Any, Dict[str, jnp.ndarray]], Tuple[jnp.ndarray, Dict]]
DataFn = Callable[[int], Dict[str, jnp.ndarray]]


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0          # 0 => no checkpoints
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    ckpt_async: bool = True
    grad_accum: int = 1
    heartbeat_timeout_s: float = 60.0
    worker: str = "worker0"


def make_train_step(loss_fn: LossFn, opt: AdamW, grad_accum: int = 1,
                    donate: bool = True):
    """Build the jitted (params, opt_state, batch) -> (params, state, metrics).

    grad_accum > 1 splits the leading batch dim into microbatches and
    accumulates grads with a lax.scan — constant memory in #microbatches.
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return grads, metrics

    def step(params, opt_state, batch):
        if grad_accum == 1:
            grads, metrics = grads_of(params, batch)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)

            def body(acc, mb):
                g, m = grads_of(params, mb)
                acc_g = jax.tree_util.tree_map(jnp.add, acc, g)
                return acc_g, m

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, ms = jax.lax.scan(body, zero, micro)
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
            metrics = jax.tree_util.tree_map(lambda x: x.mean(), ms)
        new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, metrics

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


class Trainer:
    def __init__(self, loss_fn: LossFn, data_fn: DataFn, params,
                 opt: AdamW, cfg: TrainerConfig):
        self.cfg = cfg
        self.opt = opt
        self.data_fn = data_fn
        self.params = params
        self.opt_state = opt.init(params)
        self._step_fn = make_train_step(loss_fn, opt, cfg.grad_accum)
        self.heartbeat = fault_lib.Heartbeat(cfg.heartbeat_timeout_s)
        self.straggler = fault_lib.StragglerDetector()
        self.history: list = []
        self.fault_injector: Optional[fault_lib.FaultInjector] = None

    # -- checkpoint plumbing -------------------------------------------------
    def _state_tree(self):
        return {"params": self.params, "opt": self.opt_state}

    def save(self, step: int):
        tree = self._state_tree()
        if self.cfg.ckpt_async:
            ckpt_lib.save_async(self.cfg.ckpt_dir, step, tree,
                                keep=self.cfg.ckpt_keep)
        else:
            ckpt_lib.save(self.cfg.ckpt_dir, step, tree,
                          keep=self.cfg.ckpt_keep)

    def restore_latest(self) -> int:
        """Returns the step to resume from (0 when no checkpoint exists)."""
        latest = ckpt_lib.latest_step(self.cfg.ckpt_dir)
        if latest is None:
            return 0
        tree, step = ckpt_lib.restore(self.cfg.ckpt_dir, self._state_tree())
        self.params, self.opt_state = tree["params"], tree["opt"]
        log.info("restored checkpoint at step %d", step)
        return step + 1

    # -- main loop -------------------------------------------------------------
    def run_from(self, start_step: int) -> int:
        cfg = self.cfg
        for step in range(start_step, cfg.steps):
            if self.fault_injector is not None:
                self.fault_injector.maybe_fail(step)
            t0 = time.monotonic()
            batch = self.data_fn(step)
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch)
            dur = time.monotonic() - t0
            self.heartbeat.beat(cfg.worker)
            if self.straggler.observe(dur):
                log.warning("straggler step %d: %.3fs", step, dur)
            if cfg.log_every and step % cfg.log_every == 0:
                loss = float(metrics["loss"])
                self.history.append((step, loss))
                log.info("step %d loss %.4f (%.2fs)", step, loss, dur)
            if cfg.ckpt_every and (step + 1) % cfg.ckpt_every == 0:
                self.save(step)
        ckpt_lib.wait_pending()
        return cfg.steps

    def run(self, max_restarts: int = 3) -> int:
        """Resilient entry point: crash -> restore -> resume."""
        return fault_lib.run_resilient(
            self.run_from, self.restore_latest, max_restarts=max_restarts,
            on_restart=lambda n, e: log.warning("restart %d after %r", n, e))
