"""The one currency of ``repro.analysis``: a typed, printable finding.

Every pass (trace invariants, kernel checks, repo lint) returns a flat
``list[Finding]``; the CLI renders them and turns their presence into an
exit code.  Keeping the type jax-free lets the package ``__init__`` and
the CLI bootstrap import it before the host-device flags are set.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence

ERROR = "error"
WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation (or warning) discovered by an analysis pass.

    Attributes:
        rule: the rule identifier (e.g. ``"trace-weight-quant"``) —
            stable, documented in ``docs/analysis.md``, and what
            ``--disable`` / ``# repro: allow[...]`` suppressions name.
        subject: what was analyzed — a trace case name, an op name, or a
            ``path:line`` location for lint findings.
        message: the actionable description of the violation.
        severity: ``"error"`` (fails the build) or ``"warning"``
            (fails only under ``--strict``).
    """
    rule: str
    subject: str
    message: str
    severity: str = ERROR

    def __str__(self) -> str:
        return f"[{self.rule}] {self.subject}: {self.message}"


def errors(findings: Iterable[Finding], strict: bool = False
           ) -> List[Finding]:
    """The findings that should fail the run (warnings count when strict)."""
    return [f for f in findings
            if f.severity == ERROR or (strict and f.severity == WARNING)]


def drop_disabled(findings: Iterable[Finding],
                  disabled: Sequence[str]) -> List[Finding]:
    """Filter out findings whose rule the caller disabled."""
    return [f for f in findings if f.rule not in disabled]


def render(findings: Sequence[Finding], header: str = "") -> str:
    """Human-readable report block (one line per finding)."""
    lines = []
    if header:
        lines.append(header)
    for f in findings:
        lines.append(f"  {f}")
    return "\n".join(lines)
