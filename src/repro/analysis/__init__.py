"""repro.analysis — three-pass static analysis of the serving stack.

Passes (each a submodule with a ``run() -> list[Finding]``):

  trace_invariants  jaxpr-level rules over every jitted serving trace
  kernel_checks     per-op Pallas kernel validation via the registry
  repolint          AST lint of repo conventions (pure-ast, jax-free)

Shared walker library: ``repro.analysis.jaxpr_tools`` — the ONE jaxpr
analysis implementation in the repo (tests use it too; see
docs/analysis.md).  CLI: ``python -m repro.analysis --strict``.

This ``__init__`` stays jax-free so ``python -m repro.analysis`` can pin
the host device count before jax initializes; import the submodules
directly for the jax-backed machinery.
"""
from repro.analysis.findings import (ERROR, WARNING, Finding, drop_disabled,
                                     errors, render)

__all__ = ["ERROR", "WARNING", "Finding", "drop_disabled", "errors",
           "render"]
