"""Pass 3 — AST lint: repo conventions as machine-checked rules.

Pure ``ast`` + filesystem — no jax import — so the lint can run on any
tree (the negative-path tests point it at tmp dirs with planted
violations).  Scope is ``<root>/src/repro``; tests are exempt by
construction (they legitimately import kernel internals to oracle them).

Rule catalog (see docs/analysis.md):
  lint-pallas-call        ``pallas_call`` invoked outside src/repro/kernels/
  lint-kernel-import      importing an op's ``kernel``/``ref`` module
                          outside kernels/ (bypasses ``registry.get_op``)
  lint-interpret-kwarg    passing ``interpret=`` outside kernels/ (backend
                          choice belongs to the registry)
  lint-wrapper-interpret  a public op wrapper (in ``__all__`` of an op's
                          ops.py) exposing an ``interpret`` parameter
  lint-registry-complete  every op package ships ref.py + kernel.py +
                          ops.py with a ``register_op`` call, and every
                          registered op name appears in tests/ (parity
                          coverage)

Suppression: append ``# repro: allow[rule-name]`` on the flagged line.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import List, Sequence

from repro.analysis.findings import Finding

_KERNEL_MOD_RE = re.compile(r"^repro\.kernels\.\w+\.(kernel|ref)$")
_REGISTER_RE = re.compile(r"register_op\(\s*['\"]([A-Za-z0-9_]+)['\"]")


def _suppressed(lines: Sequence[str], lineno: int, rule: str) -> bool:
    if not (1 <= lineno <= len(lines)):
        return False
    line = lines[lineno - 1]
    return "repro:" in line and f"allow[{rule}]" in line


def _call_name(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _all_names(tree: ast.Module) -> List[str]:
    """The string entries of a module-level ``__all__`` assignment."""
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == "__all__":
                    if isinstance(node.value, (ast.List, ast.Tuple)):
                        return [e.value for e in node.value.elts
                                if isinstance(e, ast.Constant)
                                and isinstance(e.value, str)]
    return []


def _lint_tree(path: Path, rel: str, source: str,
               in_kernels: bool) -> List[Finding]:
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        return [Finding("lint-parse", f"{rel}:{e.lineno or 0}",
                        f"file does not parse: {e.msg}")]
    lines = source.splitlines()
    findings: List[Finding] = []

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            if not in_kernels and _call_name(node) == "pallas_call":
                if not _suppressed(lines, node.lineno, "lint-pallas-call"):
                    findings.append(Finding(
                        "lint-pallas-call", f"{rel}:{node.lineno}",
                        "pallas_call outside src/repro/kernels/; new "
                        "kernels live in a kernels/<op>/ package and "
                        "dispatch through registry.get_op"))
            if not in_kernels:
                for kw in node.keywords:
                    if kw.arg == "interpret" and not _suppressed(
                            lines, node.lineno, "lint-interpret-kwarg"):
                        findings.append(Finding(
                            "lint-interpret-kwarg", f"{rel}:{node.lineno}",
                            "passing interpret= outside kernels/; select "
                            "the backend via the registry ('interpret' "
                            "backend name) instead of per-call kwargs"))
        elif isinstance(node, (ast.Import, ast.ImportFrom)) \
                and not in_kernels:
            mods = []
            if isinstance(node, ast.Import):
                mods = [a.name for a in node.names]
            elif node.module:
                mods = [node.module]
                # ``from repro.kernels.foo import kernel/ref``
                if re.match(r"^repro\.kernels\.\w+$", node.module):
                    mods += [f"{node.module}.{a.name}" for a in node.names]
            for mod in mods:
                if _KERNEL_MOD_RE.match(mod) and not _suppressed(
                        lines, node.lineno, "lint-kernel-import"):
                    findings.append(Finding(
                        "lint-kernel-import", f"{rel}:{node.lineno}",
                        f"import of {mod} bypasses registry.get_op; "
                        "resolve kernel impls through the registry (ref "
                        "oracles for tests live under tests/, which is "
                        "exempt)"))
    return findings


def _lint_wrapper_interpret(path: Path, rel: str,
                            source: str) -> List[Finding]:
    """kernels/*/ops.py: public wrappers must not expose interpret."""
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError:
        return []
    public = set(_all_names(tree))
    lines = source.splitlines()
    findings: List[Finding] = []
    for node in tree.body:
        if not isinstance(node, ast.FunctionDef) or node.name not in public:
            continue
        a = node.args
        names = [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]
        if "interpret" in names and not _suppressed(
                lines, node.lineno, "lint-wrapper-interpret"):
            findings.append(Finding(
                "lint-wrapper-interpret", f"{rel}:{node.lineno}",
                f"public wrapper {node.name}() resurrects an interpret= "
                "parameter; backend choice (including interpret mode) "
                "belongs to the registry"))
    return findings


def _lint_registry_complete(root: Path) -> List[Finding]:
    """Every op package ships ref+kernel+ops and has test coverage."""
    kernels = root / "src" / "repro" / "kernels"
    if not kernels.is_dir():
        return []
    findings: List[Finding] = []
    tests_dir = root / "tests"
    test_text = ""
    if tests_dir.is_dir():
        test_text = "\n".join(
            p.read_text(encoding="utf-8", errors="replace")
            for p in sorted(tests_dir.glob("test_*.py")))
    for ops_py in sorted(kernels.glob("*/ops.py")):
        pkg = ops_py.parent
        rel = pkg.relative_to(root).as_posix()
        for required in ("ref.py", "kernel.py"):
            if not (pkg / required).is_file():
                findings.append(Finding(
                    "lint-registry-complete", rel,
                    f"op package is missing {required}; every op ships a "
                    "jnp oracle AND a Pallas kernel"))
        text = ops_py.read_text(encoding="utf-8", errors="replace")
        names = _REGISTER_RE.findall(text)
        if not names:
            findings.append(Finding(
                "lint-registry-complete", rel,
                "ops.py never calls registry.register_op; the op is "
                "unreachable through get_op"))
        for name in names:
            if test_text and name not in test_text:
                findings.append(Finding(
                    "lint-registry-complete", rel,
                    f"registered op {name!r} never appears in tests/; add "
                    "it to the ref==interpret parity sweep "
                    "(tests/test_registry.py)"))
    return findings


def run(root: Path = Path("."),
        disable: Sequence[str] = ()) -> List[Finding]:
    """Lint ``<root>/src/repro`` (plus registry completeness checks)."""
    root = Path(root)
    src = root / "src" / "repro"
    kernels = src / "kernels"
    findings: List[Finding] = []
    for path in sorted(src.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        source = path.read_text(encoding="utf-8", errors="replace")
        in_kernels = kernels in path.parents or path.parent == kernels
        findings += _lint_tree(path, rel, source, in_kernels)
        if in_kernels and path.name == "ops.py":
            findings += _lint_wrapper_interpret(path, rel, source)
    findings += _lint_registry_complete(root)
    return [f for f in findings if f.rule not in disable]
