"""Pass 2 — static validation of every registered Pallas kernel.

For each op in ``repro.kernels.registry`` the pass traces the Pallas
wrapper (interpret mode — tracing only, nothing executes) on the op's
declared ``example`` shapes and inspects the resulting ``pallas_call``
equations:

  kernel-signature   ref and pallas impls take the same positional args,
                     and pallas accepts the ``interpret`` keyword
  kernel-example     every op declares an ``example=`` factory (the
                     shapes this pass traces with)
  kernel-trace       the pallas impl actually lowers to >=1 pallas_call
  kernel-block-div   every BlockSpec block shape divides its (padded)
                     operand shape — the wrapper's padding contract
  kernel-grid        no degenerate (zero-sized) grid dimensions
  kernel-vmem        estimated VMEM residency (all operand blocks +
                     scratch) fits the per-core budget

The VMEM estimate is deliberately simple — one block per operand plus
declared scratch, no double-buffering factor — and errs permissive; its
job is catching order-of-magnitude mistakes (a whole-array block) at
review time, not replacing the Mosaic compiler's accounting.
"""
from __future__ import annotations

import functools
import inspect
import math
from typing import Any, List, Optional, Sequence

import jax

from repro.analysis import jaxpr_tools as jt
from repro.analysis.findings import Finding

#: Per-core VMEM budget the estimate is checked against (v4/v5 cores
#: carry 16 MiB; CPU interpret mode has no real limit but the kernels
#: must stay deployable).
VMEM_BUDGET_BYTES = 16 * 2 ** 20


def _positional_names(fn: Any) -> List[str]:
    sig = inspect.signature(fn)
    return [p.name for p in sig.parameters.values()
            if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]


def _keyword_names(fn: Any) -> List[str]:
    sig = inspect.signature(fn)
    return [p.name for p in sig.parameters.values()
            if p.kind == p.KEYWORD_ONLY]


def check_signature_parity(name: str, ref: Any, pallas: Any
                           ) -> List[Finding]:
    """ref/pallas public signatures must agree on the data arguments."""
    findings: List[Finding] = []
    ref_pos, pal_pos = _positional_names(ref), _positional_names(pallas)
    if ref_pos != pal_pos:
        findings.append(Finding(
            "kernel-signature", name,
            f"ref takes positional args {ref_pos} but pallas takes "
            f"{pal_pos}; the registry swaps backends blindly, so data "
            "signatures must match exactly"))
    if "interpret" not in _keyword_names(pallas):
        findings.append(Finding(
            "kernel-signature", name,
            "pallas impl lacks the keyword-only 'interpret' argument the "
            "registry binds for the interpret backend"))
    return findings


def pallas_call_eqns(closed: Any) -> List[Any]:
    return [e for e in jt.iter_eqns(closed, into_kernels=True)
            if e.primitive.name == "pallas_call"]


def trace_pallas(entry: Any) -> Any:
    """Trace the op's pallas impl on its example shapes (no execution)."""
    args, kwargs = entry.example()
    fn = functools.partial(entry.pallas, interpret=True,  # repro: allow[lint-interpret-kwarg]
                           **kwargs)
    return jax.make_jaxpr(fn)(*args)


def _block_dims(block_shape: Sequence[Any]) -> List[int]:
    """Block extents with Mapped/None dims (size-1 squeezed) as 1."""
    return [b if isinstance(b, int) else 1 for b in block_shape]


def check_pallas_eqn(eqn: Any, subject: str,
                     budget: int = VMEM_BUDGET_BYTES) -> List[Finding]:
    """Block divisibility, grid sanity, and the VMEM estimate for one
    ``pallas_call`` equation."""
    findings: List[Finding] = []
    gm = eqn.params["grid_mapping"]

    for gi, g in enumerate(gm.grid):
        if isinstance(g, int) and g <= 0:
            findings.append(Finding(
                "kernel-grid", subject,
                f"grid dim {gi} is {g}; every grid extent must be >= 1"))

    vmem = 0
    for bi, bm in enumerate(gm.block_mappings):
        shape = bm.array_shape_dtype.shape
        dtype = bm.array_shape_dtype.dtype
        blk = _block_dims(bm.block_shape)
        for d, (dim, b) in enumerate(zip(shape, blk)):
            if b <= 0 or dim % b != 0:
                findings.append(Finding(
                    "kernel-block-div", subject,
                    f"operand {bi}: block shape {tuple(blk)} does not "
                    f"divide operand shape {tuple(shape)} at dim {d} "
                    f"({dim} % {b} != 0); pad the operand to a tile "
                    "multiple in the wrapper before pallas_call"))
                break
        vmem += math.prod(blk) * dtype.itemsize

    # declared scratch lives in VMEM for the kernel's whole lifetime
    n_scratch = getattr(gm, "num_scratch_operands", 0)
    if n_scratch:
        kjaxpr = eqn.params.get("jaxpr")
        if kjaxpr is not None:
            for v in kjaxpr.invars[len(kjaxpr.invars) - n_scratch:]:
                aval = getattr(v, "aval", None)
                shape = getattr(aval, "shape", None)
                dtype = getattr(aval, "dtype", None)
                if shape is not None and dtype is not None:
                    vmem += math.prod(shape) * dtype.itemsize

    if vmem > budget:
        findings.append(Finding(
            "kernel-vmem", subject,
            f"estimated VMEM residency {vmem / 2**20:.1f} MiB exceeds the "
            f"{budget / 2**20:.0f} MiB per-core budget; shrink the block "
            "shapes or stage through scratch"))
    return findings


def run(ops: Optional[Sequence[str]] = None,
        budget: int = VMEM_BUDGET_BYTES,
        disable: Sequence[str] = ()) -> List[Finding]:
    """Run every kernel check over every (or the given) registered op."""
    from repro.kernels import registry

    findings: List[Finding] = []
    names = tuple(ops) if ops is not None else registry.list_ops()
    for name in names:
        entry = registry._ensure(name)
        findings += check_signature_parity(name, entry.ref, entry.pallas)
        if entry.example is None:
            findings.append(Finding(
                "kernel-example", name,
                "no example= factory registered; register_op(..., "
                "example=lambda: (args, kwargs)) so analysis can trace "
                "the kernel on representative shapes"))
            continue
        closed = trace_pallas(entry)
        eqns = pallas_call_eqns(closed)
        if not eqns:
            findings.append(Finding(
                "kernel-trace", name,
                "tracing the pallas impl produced no pallas_call "
                "equation; the 'pallas' backend for this op never runs "
                "a kernel"))
        for i, eqn in enumerate(eqns):
            subject = name if len(eqns) == 1 else f"{name}#{i}"
            findings += check_pallas_eqn(eqn, subject, budget)
    return [f for f in findings if f.rule not in disable]
