"""Shared jaxpr-walking library: THE one implementation in the repo.

Everything here operates on ``jax.core.ClosedJaxpr`` / ``jax.core.Jaxpr``
objects produced by ``jax.make_jaxpr``; nothing executes.  The walkers
recurse into higher-order primitives (``pjit``, ``scan``, ``cond``,
``while``) via the ``ClosedJaxpr`` values found in ``eqn.params``.  Raw
Pallas kernel jaxprs (``pallas_call``'s ``jaxpr`` param) operate on
*refs* whose invars do not align positionally with the call's operands,
so dataflow walkers deliberately stop at the ``pallas_call`` boundary —
kernel bodies get their own pass (``repro.analysis.kernel_checks``).

Two taint engines live here:

* **weight taint** (`weight_quant_eqns`): seeds taint from the packed
  serving-parameter invars and flags quantization arithmetic
  ({round, clamp, reduce_max}, or converts to int8/int16) reachable from
  them.  This is the "quantize-once" invariant from PR 3: packing is a
  host-side artifact step, so a serving trace re-deriving codes from
  weights is a regression.
* **code taint** (`unsanctioned_dequant_eqns`): seeds taint from int8 /
  int16 "code" values and flags integer→float converts fed by them that
  are not under a ``jax.named_scope`` whose name contains the declared
  dequant scope (``repro.core.quant.DEQUANT_SCOPE``).  This pins WHERE
  codes are allowed to materialize as floats: the two reference
  dequant-matmul epilogues, nowhere else.

Both engines use the same sub-jaxpr operand alignment: jax's
higher-order primitives pass operands to the sub-jaxpr as a suffix of
``eqn.invars`` (scan prepends consts/carry, pjit is 1:1), so sub-invar
``i`` maps to ``eqn.invars[i + (len(eqn.invars) - len(sub.invars))]``.
Taint flows out when the sub-jaxpr's outvars align 1:1 with the
equation's outvars (true for pjit/scan/cond on every traced path here).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Iterator, List, Optional, Sequence, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import DEQUANT_SCOPE

#: Primitives that implement fake-quant rounding/clipping/range-finding.
#: Identical to the set the PR-3 packed tests enforced.
QUANT_PRIMS = frozenset({"round", "clamp", "reduce_max"})

#: Integer dtypes that carry quantized codes in this codebase.
CODE_DTYPES = (jnp.int8.dtype, jnp.int16.dtype)

#: Primitives that move data across the host boundary or between
#: devices outside the partitioner's control.  None may appear inside a
#: serving trace.
HOST_TRANSFER_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call", "host_callback_call", "infeed", "outfeed",
    "device_put",
})

_STAGE_RE = re.compile(r"stage:([A-Za-z0-9_]+)")


# ---------------------------------------------------------------------------
# structural helpers
# ---------------------------------------------------------------------------

def as_jaxpr(obj: Any) -> "jax.core.Jaxpr":
    """Accept a ClosedJaxpr or Jaxpr and return the raw Jaxpr."""
    return obj.jaxpr if isinstance(obj, jax.core.ClosedJaxpr) else obj


def sub_closed_jaxprs(eqn: Any) -> List["jax.core.ClosedJaxpr"]:
    """Sub-jaxprs of a higher-order equation (pjit/scan/cond/while...).

    Only ``ClosedJaxpr`` params count: ``pallas_call`` stores a raw
    ``Jaxpr`` over refs whose invars do not align with the operands, so
    it is intentionally excluded from dataflow recursion.
    """
    subs: List[jax.core.ClosedJaxpr] = []
    for val in eqn.params.values():
        items = val if isinstance(val, (list, tuple)) else (val,)
        for item in items:
            if isinstance(item, jax.core.ClosedJaxpr):
                subs.append(item)
    return subs


def iter_eqns(jaxpr: Any, *, into_kernels: bool = False) -> Iterator[Any]:
    """Yield every equation, recursing through sub-jaxprs.

    With ``into_kernels=True`` also descends into raw Pallas kernel
    jaxprs — safe for per-equation predicates (dtype scans, primitive
    counts) though not for dataflow.
    """
    for eqn in as_jaxpr(jaxpr).eqns:
        yield eqn
        for sub in sub_closed_jaxprs(eqn):
            yield from iter_eqns(sub.jaxpr, into_kernels=into_kernels)
        if into_kernels:
            for val in eqn.params.values():
                if isinstance(val, jax.core.Jaxpr):
                    yield from iter_eqns(val, into_kernels=True)


def count_primitive(jaxpr: Any, name: str) -> int:
    """Number of equations (recursively) whose primitive is ``name``."""
    return sum(1 for e in iter_eqns(jaxpr) if e.primitive.name == name)


def primitive_counts(jaxpr: Any) -> Dict[str, int]:
    """Histogram of primitive names over the whole (recursive) trace."""
    counts: Dict[str, int] = {}
    for e in iter_eqns(jaxpr):
        counts[e.primitive.name] = counts.get(e.primitive.name, 0) + 1
    return counts


def name_stack_of(eqn: Any) -> str:
    """The ``jax.named_scope`` stack recorded on an equation ('' if none)."""
    si = getattr(eqn, "source_info", None)
    return str(getattr(si, "name_stack", "") or "")


def stage_boundary_names(jaxpr: Any) -> Dict[str, int]:
    """Declared stage boundaries realized in a trace.

    Returns ``{stage_name: count}`` over all ``sharding_constraint``
    equations whose name stack contains a ``stage:<name>`` scope — the
    mechanism model/pipeline code uses to declare where a sharding
    boundary is *intended* (see ``docs/analysis.md``).
    """
    names: Dict[str, int] = {}
    for e in iter_eqns(jaxpr):
        if e.primitive.name != "sharding_constraint":
            continue
        for m in _STAGE_RE.finditer(name_stack_of(e)):
            names[m.group(1)] = names.get(m.group(1), 0) + 1
    return names


def _var_dtype(v: Any) -> Optional[Any]:
    aval = getattr(v, "aval", None)
    return getattr(aval, "dtype", None)


def _nonliteral(vs: Sequence[Any]) -> List[Any]:
    return [v for v in vs if not isinstance(v, jax.core.Literal)]


# ---------------------------------------------------------------------------
# weight taint: no re-quantization reachable from packed params
# ---------------------------------------------------------------------------

def is_quant_eqn(eqn: Any) -> bool:
    """Quantization arithmetic: fake-quant rounding/clipping/range ops,
    or a convert to a code dtype (int8/int16)."""
    name = eqn.primitive.name
    if name in QUANT_PRIMS:
        return True
    if name == "convert_element_type":
        return eqn.params.get("new_dtype") in CODE_DTYPES
    return False


def _align_sub_taint(eqn: Any, sub: "jax.core.ClosedJaxpr",
                     tainted: Set[Any]) -> Set[Any]:
    """Map taint from eqn operands onto sub-jaxpr invars (suffix-aligned)."""
    sub_taint: Set[Any] = set()
    offset = len(eqn.invars) - len(sub.jaxpr.invars)
    for i, sv in enumerate(sub.jaxpr.invars):
        j = i + offset
        if 0 <= j < len(eqn.invars):
            ov = eqn.invars[j]
            if not isinstance(ov, jax.core.Literal) and ov in tainted:
                sub_taint.add(sv)
    return sub_taint


def _outvar_taint(jaxpr: "jax.core.Jaxpr",
                  tainted: Set[Any]) -> List[bool]:
    """One extra linear weight-only pass, then report outvar taint."""
    tainted = set(tainted)
    for eqn in jaxpr.eqns:
        invars = _nonliteral(eqn.invars)
        if invars and all(v in tainted for v in invars):
            for ov in eqn.outvars:
                tainted.add(ov)
    return [not isinstance(v, jax.core.Literal) and v in tainted
            for v in jaxpr.outvars]


def collect_weight_quant(jaxpr: "jax.core.Jaxpr",
                         tainted: Set[Any]) -> List[Any]:
    """Equations doing quantization arithmetic on *weight-only* values.

    A value is weight-only when every non-literal input deriving it is
    weight-only (mixing in an activation clears the taint — activation
    packing legitimately keeps its round/clamp ops).  Mutates
    ``tainted``; returns the offending equations (empty ⇒ the
    quantize-once invariant holds).
    """
    found: List[Any] = []
    for eqn in jaxpr.eqns:
        invars = _nonliteral(eqn.invars)
        all_w = bool(invars) and all(v in tainted for v in invars)
        for sub in sub_closed_jaxprs(eqn):
            sub_taint = _align_sub_taint(eqn, sub, tainted)
            found.extend(collect_weight_quant(sub.jaxpr, sub_taint))
            if len(sub.jaxpr.outvars) == len(eqn.outvars):
                for ov, t in zip(eqn.outvars,
                                 _outvar_taint(sub.jaxpr, sub_taint)):
                    if t:
                        tainted.add(ov)
        if all_w:
            if is_quant_eqn(eqn):
                found.append(eqn)
            for ov in eqn.outvars:
                tainted.add(ov)
    return found


def weight_quant_eqns(closed: "jax.core.ClosedJaxpr",
                      n_param_leaves: int) -> List[Any]:
    """Quantization equations reachable from the first ``n_param_leaves``
    invars of a trace — the flattened parameter pytree when parameters
    are the callable's first argument (the convention of every serving
    entry point here).  Empty ⇒ the quantize-once invariant holds."""
    tainted: Set[Any] = set(closed.jaxpr.invars[:n_param_leaves])
    return collect_weight_quant(closed.jaxpr, tainted)


# ---------------------------------------------------------------------------
# code taint: int8/int16 -> float only inside the declared dequant scope
# ---------------------------------------------------------------------------

def _dequant_walk(jaxpr: "jax.core.Jaxpr", tainted: Set[Any],
                  scope: str) -> List[Any]:
    found: List[Any] = []
    for v in jaxpr.invars:
        dt = _var_dtype(v)
        if dt is not None and dt in CODE_DTYPES:
            tainted.add(v)
    for eqn in jaxpr.eqns:
        in_tainted = any(v in tainted for v in _nonliteral(eqn.invars))
        for sub in sub_closed_jaxprs(eqn):
            sub_taint = _align_sub_taint(eqn, sub, tainted)
            found.extend(_dequant_walk(sub.jaxpr, sub_taint, scope))
            if len(sub.jaxpr.outvars) == len(eqn.outvars):
                for ov, t in zip(eqn.outvars,
                                 _outvar_taint(sub.jaxpr, sub_taint)):
                    if t:
                        tainted.add(ov)
        if eqn.primitive.name == "convert_element_type":
            out_dt = _var_dtype(eqn.outvars[0])
            if out_dt in CODE_DTYPES:
                # producing codes (activation packing) is fine and
                # taints the result
                tainted.add(eqn.outvars[0])
            elif in_tainted and out_dt is not None:
                if jnp.issubdtype(out_dt, jnp.floating):
                    if scope not in name_stack_of(eqn):
                        found.append(eqn)
                    # sanctioned or not, the float result exits taint
                elif jnp.issubdtype(out_dt, jnp.integer):
                    # int8 -> int32 widening keeps carrying codes
                    tainted.add(eqn.outvars[0])
        elif in_tainted:
            for ov in eqn.outvars:
                dt = _var_dtype(ov)
                if (dt is not None and jnp.issubdtype(dt, jnp.integer)
                        and not jnp.issubdtype(dt, jnp.bool_)):
                    tainted.add(ov)
    return found


def unsanctioned_dequant_eqns(closed: Any, *,
                              scope: str = DEQUANT_SCOPE) -> List[Any]:
    """Integer→float converts fed by int8/int16 code values that are NOT
    under a ``named_scope`` containing ``scope``.  Taint propagates only
    through integer-dtype results (comparisons etc. drop it), so the
    declared dequant epilogue is the taint's only sanctioned float exit.
    """
    return _dequant_walk(as_jaxpr(closed), set(), scope)


# ---------------------------------------------------------------------------
# simple per-equation scans
# ---------------------------------------------------------------------------

def f64_eqns(jaxpr: Any) -> List[Any]:
    """Equations producing float64 anywhere in the trace (kernels too)."""
    f64 = np.dtype("float64")
    found = []
    for e in iter_eqns(jaxpr, into_kernels=True):
        for v in e.outvars:
            dt = _var_dtype(v)
            if dt is not None and dt == f64:
                found.append(e)
                break
    return found


def host_transfer_eqns(jaxpr: Any) -> List[Any]:
    """Host-callback / transfer primitives anywhere in the trace."""
    return [e for e in iter_eqns(jaxpr, into_kernels=True)
            if e.primitive.name in HOST_TRANSFER_PRIMS]


def kernel_launch_count(jaxpr: Any) -> int:
    """Static count of Pallas kernel launches one execution performs.

    Walks the trace multiplying each ``pallas_call`` by the trip counts
    of the ``scan`` loops enclosing it (``eqn.params["length"]``) — the
    number the persistent kernels exist to shrink: a per-step op under a
    T-step scan counts T launches, the fused walk counts 1.  ``cond``
    branches count as the worst case (max over branches); ``while`` trip
    counts are unknowable statically and count as 1 iteration (none of
    the serving traces here put kernels under ``while``).
    """
    total = 0
    for eqn in as_jaxpr(jaxpr).eqns:
        if eqn.primitive.name == "pallas_call":
            total += 1
            continue
        mult = 1
        if eqn.primitive.name == "scan":
            mult = int(eqn.params.get("length", 1))
        subs = sub_closed_jaxprs(eqn)
        if not subs:
            continue
        inner = (max(kernel_launch_count(s) for s in subs)
                 if eqn.primitive.name == "cond"
                 else sum(kernel_launch_count(s) for s in subs))
        total += mult * inner
    return total


def describe_eqn(eqn: Any) -> str:
    """Short human string for findings: primitive + dtypes + scope."""
    outs = ", ".join(str(_var_dtype(v)) for v in eqn.outvars)
    stack = name_stack_of(eqn)
    loc = f" in scope '{stack}'" if stack else ""
    return f"{eqn.primitive.name} -> ({outs}){loc}"
