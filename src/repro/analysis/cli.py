"""CLI for the three analysis passes: ``python -m repro.analysis``.

Exit code 0 when no enforced findings remain, 1 otherwise (warnings
count under ``--strict``).  Pass order is cheapest-first so lint
feedback lands before any jax tracing starts.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path
from typing import List, Optional, Sequence

from repro.analysis import findings as F

PASSES = ("lint", "kernels", "trace")

_RULES = {
    "lint": ("lint-pallas-call", "lint-kernel-import",
             "lint-interpret-kwarg", "lint-wrapper-interpret",
             "lint-registry-complete", "lint-parse"),
    "kernels": ("kernel-signature", "kernel-example", "kernel-trace",
                "kernel-block-div", "kernel-grid", "kernel-vmem"),
    "trace": ("trace-weight-quant", "trace-dequant", "trace-f64",
              "trace-host-transfer", "trace-stage-coverage",
              "trace-mesh-bake", "trace-retrace"),
}


def _parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis: serving-trace invariants, Pallas "
                    "kernel validation, and repo lint (docs/analysis.md).")
    ap.add_argument("--strict", action="store_true",
                    help="treat warnings as errors")
    ap.add_argument("--passes", default=",".join(PASSES),
                    help=f"comma-separated subset of {PASSES}")
    ap.add_argument("--disable", action="append", default=[],
                    metavar="RULE", help="skip a rule (repeatable); "
                    "see --list-rules")
    ap.add_argument("--root", default=".",
                    help="repo root for the lint pass (default: cwd)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    return ap


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = _parser().parse_args(argv)
    if args.list_rules:
        for pass_name, rules in _RULES.items():
            print(f"{pass_name}:")
            for r in rules:
                print(f"  {r}")
        return 0

    selected = [p.strip() for p in args.passes.split(",") if p.strip()]
    unknown = sorted(set(selected) - set(PASSES))
    if unknown:
        print(f"unknown pass(es) {unknown}; choose from {list(PASSES)}",
              file=sys.stderr)
        return 2
    disable = tuple(args.disable)

    all_findings: List[F.Finding] = []
    for pass_name in PASSES:
        if pass_name not in selected:
            continue
        t0 = time.monotonic()
        if pass_name == "lint":
            from repro.analysis import repolint
            fs = repolint.run(Path(args.root), disable=disable)
        elif pass_name == "kernels":
            from repro.analysis import kernel_checks
            fs = kernel_checks.run(disable=disable)
        else:
            from repro.analysis import trace_invariants
            mesh = trace_invariants.default_mesh()
            fs = trace_invariants.run(mesh=mesh, disable=disable)
        dt = time.monotonic() - t0
        status = "ok" if not fs else f"{len(fs)} finding(s)"
        print(f"[{pass_name}] {status} ({dt:.1f}s)")
        if fs:
            print(F.render(fs))
        all_findings += fs

    enforced = F.errors(all_findings, strict=args.strict)
    if enforced:
        print(f"\nFAIL: {len(enforced)} enforced finding(s)")
        return 1
    print("\nOK: all analysis passes clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
