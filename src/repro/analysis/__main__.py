"""``python -m repro.analysis`` entry point.

Pins the fake host-device count BEFORE jax initializes (the trace pass
needs a multi-device mesh to exercise the sharding rules on CPU), then
hands off to the argparse CLI.  ``repro.analysis/__init__`` is jax-free
precisely so this ordering holds under ``python -m``.
"""
import sys

from repro.hostdev import force_host_devices

force_host_devices(4)

from repro.analysis.cli import main  # noqa: E402  (after device pin)

sys.exit(main())
