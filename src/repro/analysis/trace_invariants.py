"""Pass 1 — invariants over the jitted serving traces.

Builds the repo's serving traces (pipeline decode/fused, both engines'
step — each with and without an ambient mesh) via ``jax.make_jaxpr`` and
runs every trace rule on each.  Rules read *declared intent*:

* packed parameters are the trace's leading invars (``n_param_leaves``),
* sharding stage boundaries are declared by ``stage:<name>`` scopes
  (``BasecallPipeline.decode_stage_boundaries`` /
  ``models.basecaller.serving_stage_boundaries``),
* sanctioned dequant sites carry the ``repro.core.quant.DEQUANT_SCOPE``
  named scope.

All traces use the "ref" backend: the reference path exposes the full
dataflow to the walker, whereas interpret mode hides arithmetic inside
``pallas_call`` kernel bodies that dataflow analysis deliberately skips
(kernel bodies get their own pass).

Rule catalog (see docs/analysis.md):
  trace-weight-quant    no weight-quantization reachable from packed params
  trace-dequant         int8/int16 -> float only under the dequant scope
  trace-f64             no float64 anywhere in a serving trace
  trace-host-transfer   no host callbacks / device transfers in traces
  trace-stage-coverage  every declared boundary constrained under a mesh
  trace-mesh-bake       zero sharding constraints in a mesh-free trace
  trace-retrace         same-aval second call hits the jit cache
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import jaxpr_tools as jt
from repro.analysis.findings import Finding


@dataclasses.dataclass
class TraceCase:
    """One serving trace plus its declared intent."""
    name: str
    closed: "jax.core.ClosedJaxpr"
    n_param_leaves: int
    boundaries: Tuple[str, ...] = ()
    meshed: bool = False


def _mesh_ctx(mesh):
    from repro.dist import sharding as shd
    if mesh is None:
        return contextlib.nullcontext()
    return shd.use_mesh(mesh)


def default_mesh():
    """A 1-D data mesh over all local devices (None when single-device)."""
    devs = jax.devices()
    if len(devs) < 2:
        return None
    return jax.make_mesh((len(devs),), ("data",))


# ---------------------------------------------------------------------------
# trace-case builders
# ---------------------------------------------------------------------------

def _tiny_pipe(preset: str):
    from repro.core.quant import QuantConfig
    from repro.pipeline import BasecallPipeline

    pipe = BasecallPipeline.from_preset(
        preset, scale="tiny",
        quant=QuantConfig(enabled=True, bits_w=5, bits_a=5),
        backend="ref", beam_width=3, packed=True)
    pipe.init_params(jax.random.PRNGKey(0))
    return pipe


def _tag(preset: str, mesh) -> str:
    return f"[{preset}{'/mesh' if mesh is not None else ''}]"


def build_pipeline_cases(preset: str = "guppy",
                         mesh=None) -> List[TraceCase]:
    """The pipeline's two jitted serving surfaces (decode + fused)."""
    pipe = _tiny_pipe(preset)
    packed = pipe.serving_params()
    n = len(jax.tree_util.tree_leaves(packed))
    B = 4  # divisible by every host-device mesh CI uses
    windows = jnp.zeros((B, pipe.mcfg.input_len, 1), jnp.float32)
    lengths = jnp.full((B,), pipe.mcfg.input_len, jnp.int32)
    batch = jnp.zeros((B, pipe.mcfg.input_len + 2 * pipe.scfg.margin, 1),
                      jnp.float32)
    with _mesh_ctx(mesh):
        decode = jax.make_jaxpr(pipe._build_decode_windows())(
            packed, windows, lengths)
        fused = jax.make_jaxpr(pipe._build_windows_fused())(packed, batch)
    meshed = mesh is not None
    return [
        TraceCase(f"pipeline.decode_windows{_tag(preset, mesh)}", decode, n,
                  pipe.decode_stage_boundaries(), meshed),
        TraceCase(f"pipeline.windows_fused{_tag(preset, mesh)}", fused, n,
                  pipe.fused_stage_boundaries(), meshed),
    ]


def build_basecall_engine_case(mesh=None) -> TraceCase:
    """BasecallEngine.step's decode trace at engine capacity (B*dp)."""
    from repro.serve.basecall_engine import BasecallEngine

    pipe = _tiny_pipe("guppy")
    with _mesh_ctx(mesh):
        eng = BasecallEngine(pipe, batch_slots=2)
        packed = pipe.serving_params()
        windows = jnp.zeros((eng.B, pipe.mcfg.input_len, 1), jnp.float32)
        lengths = jnp.full((eng.B,), pipe.mcfg.input_len, jnp.int32)
        closed = jax.make_jaxpr(pipe._build_decode_windows())(
            packed, windows, lengths)
    n = len(jax.tree_util.tree_leaves(packed))
    return TraceCase(f"basecall_engine.step{_tag('guppy', mesh)}", closed, n,
                     pipe.decode_stage_boundaries(), mesh is not None)


def _tiny_lm_cfg():
    from repro.core.quant import QuantConfig
    from repro.models import lm as lm_lib

    return lm_lib.LMConfig(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
        vocab_size=64, quant=QuantConfig(enabled=True, bits_w=5, bits_a=5),
        remat=False)


def build_lm_engine_case(mesh=None) -> TraceCase:
    """ServingEngine's jitted decode step over the packed LM artifact.

    Under an ambient mesh the (B,) step batch dp-shards lane-major
    (``models.decode.lm_stage_boundaries`` declares the constrained
    stages), mirroring the basecall engine's step."""
    from repro.models import decode as decode_lib
    from repro.models import lm as lm_lib
    from repro.serve.engine import ServingEngine

    cfg = _tiny_lm_cfg()
    params = lm_lib.init_lm(jax.random.PRNGKey(0), cfg)
    with _mesh_ctx(mesh):
        eng = ServingEngine(params, cfg, batch_slots=2, max_len=16)
        tokens = jnp.zeros((eng.B,), jnp.int32)
        active = jnp.ones((eng.B,), bool)
        closed = jax.make_jaxpr(eng._decode)(
            eng.params, eng.cache, tokens, active)
    n = len(jax.tree_util.tree_leaves(eng.params))
    return TraceCase(f"serving_engine.step{_tag('lm', mesh)}", closed, n,
                     decode_lib.lm_stage_boundaries(), mesh is not None)


def build_paged_lm_engine_case(mesh=None) -> TraceCase:
    """ServingEngine's decode step on the PAGED KV layout (block-table
    gathers through the pooled arena; same declared stage boundaries as
    the dense step)."""
    from repro.models import decode as decode_lib
    from repro.models import lm as lm_lib
    from repro.serve.engine import ServingEngine

    cfg = _tiny_lm_cfg()
    params = lm_lib.init_lm(jax.random.PRNGKey(0), cfg)
    with _mesh_ctx(mesh):
        eng = ServingEngine(params, cfg, batch_slots=2, max_len=16,
                            kv_layout="paged", kv_block=4)
        tokens = jnp.zeros((eng.B,), jnp.int32)
        active = jnp.ones((eng.B,), bool)
        closed = jax.make_jaxpr(eng._decode)(
            eng.params, eng.cache, tokens, active, eng._ship_tables())
    n = len(jax.tree_util.tree_leaves(eng.params))
    return TraceCase(f"serving_engine.step{_tag('lm-paged', mesh)}", closed,
                     n, decode_lib.lm_stage_boundaries(), mesh is not None)


def build_cases(presets: Sequence[str] = ("guppy", "chiron"),
                mesh=None) -> List[TraceCase]:
    """Every serving trace the rules run on, unmeshed + meshed."""
    cases: List[TraceCase] = []
    for preset in presets:
        cases += build_pipeline_cases(preset, None)
    cases.append(build_basecall_engine_case(None))
    cases.append(build_lm_engine_case(None))
    cases.append(build_paged_lm_engine_case(None))
    if mesh is not None:
        cases += build_pipeline_cases(presets[0], mesh)
        cases.append(build_basecall_engine_case(mesh))
        cases.append(build_lm_engine_case(mesh))
        cases.append(build_paged_lm_engine_case(mesh))
    return cases


# ---------------------------------------------------------------------------
# trace rules
# ---------------------------------------------------------------------------

def rule_weight_quant(case: TraceCase) -> List[Finding]:
    eqns = jt.weight_quant_eqns(case.closed, case.n_param_leaves)
    if not eqns:
        return []
    return [Finding(
        "trace-weight-quant", case.name,
        f"{len(eqns)} weight-quantization op(s) reachable from the serving "
        f"params (first: {jt.describe_eqn(eqns[0])}); serve the "
        "quantize-once packed artifact instead of re-deriving codes "
        "in-trace (docs/analysis.md#trace-weight-quant)")]


def rule_dequant(case: TraceCase) -> List[Finding]:
    eqns = jt.unsanctioned_dequant_eqns(case.closed)
    if not eqns:
        return []
    return [Finding(
        "trace-dequant", case.name,
        f"{len(eqns)} int8/int16->float convert(s) outside the declared "
        f"dequant boundary (first: {jt.describe_eqn(eqns[0])}); wrap the "
        "sanctioned site in jax.named_scope(quant.DEQUANT_SCOPE) or stop "
        "dequantizing codes there (docs/analysis.md#trace-dequant)")]


def rule_f64(case: TraceCase) -> List[Finding]:
    eqns = jt.f64_eqns(case.closed)
    if not eqns:
        return []
    return [Finding(
        "trace-f64", case.name,
        f"{len(eqns)} float64-producing op(s) in a serving trace (first: "
        f"{jt.describe_eqn(eqns[0])}); serving numerics are fp32/int8 "
        "only")]


def rule_host_transfer(case: TraceCase) -> List[Finding]:
    eqns = jt.host_transfer_eqns(case.closed)
    if not eqns:
        return []
    return [Finding(
        "trace-host-transfer", case.name,
        f"host callback / device transfer inside the trace: "
        f"{sorted({e.primitive.name for e in eqns})}; serving steps must "
        "stay on-device end to end")]


def rule_sharding(case: TraceCase) -> List[Finding]:
    if not case.meshed:
        n = jt.count_primitive(case.closed, "sharding_constraint")
        if n:
            return [Finding(
                "trace-mesh-bake", case.name,
                f"{n} sharding_constraint op(s) in a MESH-FREE trace: an "
                "ambient mesh was baked at trace time and would outlive "
                "its use_mesh block (docs/analysis.md#trace-mesh-bake)")]
        return []
    realized = jt.stage_boundary_names(case.closed)
    missing = [b for b in case.boundaries if not realized.get(b)]
    if missing:
        return [Finding(
            "trace-stage-coverage", case.name,
            f"declared stage boundaries carry no sharding constraint "
            f"under the mesh: {missing}; add shd.constrain under "
            "jax.named_scope('stage:<name>') at each, or update the "
            "boundary declaration (docs/analysis.md#trace-stage-coverage)")]
    return []


TRACE_RULES: Dict[str, Callable[[TraceCase], List[Finding]]] = {
    "trace-weight-quant": rule_weight_quant,
    "trace-dequant": rule_dequant,
    "trace-f64": rule_f64,
    "trace-host-transfer": rule_host_transfer,
    "trace-sharding": rule_sharding,  # emits stage-coverage / mesh-bake
}


# ---------------------------------------------------------------------------
# retrace guard (the one rule that must EXECUTE the jitted fns)
# ---------------------------------------------------------------------------

def retrace_findings(mesh=None) -> List[Finding]:
    """Same-aval second calls must hit the jit cache (no silent retrace)."""
    found: List[Finding] = []

    pipe = _tiny_pipe("guppy")
    packed = pipe.serving_params()
    windows = jnp.zeros((4, pipe.mcfg.input_len, 1), jnp.float32)
    lengths = jnp.full((4,), pipe.mcfg.input_len, jnp.int32)
    fn = pipe._build_decode_windows()
    with _mesh_ctx(mesh):
        fn(packed, windows, lengths)
        fn(packed, windows, lengths)
    n = fn._cache_size()
    if n != 1:
        found.append(Finding(
            "trace-retrace", f"pipeline.decode_windows{_tag('guppy', mesh)}",
            f"two same-aval calls compiled {n} jit entries (expected 1): "
            "an unhashable/unstable static argument or weak-type flap is "
            "forcing retraces"))

    from repro.core.quant import QuantConfig
    from repro.models import lm as lm_lib
    from repro.serve.engine import ServingEngine

    cfg = lm_lib.LMConfig(
        n_layers=1, d_model=16, n_heads=2, n_kv_heads=2, d_ff=32,
        vocab_size=32, quant=QuantConfig(enabled=True, bits_w=5, bits_a=5),
        remat=False)
    params = lm_lib.init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, batch_slots=2, max_len=8)
    tokens = jnp.zeros((eng.B,), jnp.int32)
    active = jnp.ones((eng.B,), bool)
    # _decode donates the cache: thread the returned cache into call 2
    _, cache = eng._decode(eng.params, eng.cache, tokens, active)
    eng._decode(eng.params, cache, tokens, active)
    n = eng._decode._cache_size()
    if n != 1:
        found.append(Finding(
            "trace-retrace", "serving_engine.step[lm]",
            f"two same-aval calls compiled {n} jit entries (expected 1)"))

    # paged layout: block tables ship with a FIXED (B, max_blocks) shape
    # precisely so lane growth never retraces — guard that here
    eng_p = ServingEngine(params, cfg, batch_slots=2, max_len=8,
                          kv_layout="paged", kv_block=4)
    bt = eng_p._ship_tables()
    _, cache = eng_p._decode(eng_p.params, eng_p.cache, tokens, active, bt)
    eng_p._decode(eng_p.params, cache, tokens, active, bt)
    n = eng_p._decode._cache_size()
    if n != 1:
        found.append(Finding(
            "trace-retrace", "serving_engine.step[lm-paged]",
            f"two same-aval calls compiled {n} jit entries (expected 1)"))

    found += _multitenant_retrace(mesh)
    return found


def _multitenant_retrace(mesh=None) -> List[Finding]:
    """PER-MODEL retrace guard for the multi-tenant engine: every hosted
    tenant's group sub-batch has a fixed shape, so two engine steps must
    leave each model's decode with exactly one jit entry — a tenant whose
    lane batch flaps avals would retrace on every step of a fleet."""
    import numpy as np

    from repro.serve.api import BasecallRequest
    from repro.serve.multitenant import MultiModelBasecallEngine
    from repro.serve.registry import ModelRegistry

    found: List[Finding] = []
    reg = ModelRegistry()
    pipes = {}
    for mid, preset in (("small", "guppy"), ("large", "chiron")):
        pipes[mid] = _tiny_pipe(preset)
        reg.register_basecaller(mid, pipes[mid])
    with _mesh_ctx(mesh):
        eng = MultiModelBasecallEngine(reg, tuple(pipes), batch_slots=2)
    for rid, (mid, pipe) in enumerate(pipes.items()):
        sig = np.zeros((2 * pipe.mcfg.input_len,), np.float32)
        eng.submit(eng.make_request(rid, BasecallRequest(signal=sig,
                                                         model=mid)))
    eng.admit()
    eng.step()
    eng.step()
    for mid, pipe in pipes.items():
        fn = pipe._decode_windows.cache.get(eng.mesh)
        n = -1 if fn is None else fn._cache_size()
        if n != 1:
            where = "never ran" if fn is None else f"compiled {n} jit entries"
            found.append(Finding(
                "trace-retrace",
                f"multitenant.step[{mid}{'/mesh' if mesh else ''}]",
                f"two same-aval engine steps for hosted model {mid!r} "
                f"{where} (expected exactly 1): the tenant's group "
                "sub-batch must keep a fixed shape across steps"))
    return found


# ---------------------------------------------------------------------------
# pass entry point
# ---------------------------------------------------------------------------

def run(presets: Sequence[str] = ("guppy", "chiron"), mesh=None,
        disable: Sequence[str] = (),
        with_retrace: bool = True) -> List[Finding]:
    """Run every trace rule over every serving trace case."""
    findings: List[Finding] = []
    for case in build_cases(presets, mesh):
        for rule_name, rule in TRACE_RULES.items():
            if rule_name in disable:
                continue
            findings += rule(case)
    if with_retrace and "trace-retrace" not in disable:
        findings += retrace_findings(mesh)
    # rule fns may emit sub-rule names (stage-coverage/mesh-bake); apply
    # disable to those too
    return [f for f in findings if f.rule not in disable]
