"""Distribution substrate: logical-axis sharding rules + compressed collectives.

``sharding``    — logical ("dp"/"tp") -> physical mesh-axis mapping, the
                  ambient-mesh context used by models/launch, and the
                  path-name param partitioning rules.
``collectives`` — int8 block compression for the slow inter-pod gradient
                  all-reduce (error-feedback variant preserves the sum).
"""
from repro.dist import collectives, sharding  # noqa: F401

__all__ = ["collectives", "sharding"]
