"""Logical-axis sharding: one vocabulary ("dp", "tp") over many meshes.

Models and launch code never name physical mesh axes.  They speak two
logical axes:

  "dp" — the batch/data direction.  Maps to every pure-data axis present
         on the mesh: ("pod", "data") on the 2-pod mesh, ("data",) on a
         single pod, () on a host mesh with no data axis.
  "tp" — the model/tensor direction.  Maps to ("model",) when present.

``use_mesh``/``get_mesh`` carry the ambient mesh (a plain context stack —
importing this module never touches jax device state), ``constrain``
applies a with_sharding_constraint and degrades to a no-op when no mesh is
active (CPU tests, single-host examples), and ``param_sharding_tree``
implements the path-name partitioning rules for parameter pytrees
(FSDP-style: last axis -> tp, first large axis -> dp; MoE expert tables
EP-shard over the model axis — see models/layers.moe_ff).
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# data-parallel-ish physical axes in priority order; "pod" is the pure-DP
# inter-pod axis of the 512-chip mesh (launch/mesh.py)
_DP_AXES = ("pod", "data")
_TP_AXIS = "model"

_state = threading.local()


def _stack():
    if not hasattr(_state, "meshes"):
        _state.meshes = []
    return _state.meshes


def get_mesh() -> Optional[jax.sharding.Mesh]:
    """The innermost mesh installed by :func:`use_mesh`, or None."""
    stack = _stack()
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_mesh(mesh: jax.sharding.Mesh):
    """Install ``mesh`` as the ambient mesh for ``constrain``/``get_mesh``."""
    _stack().append(mesh)
    try:
        yield mesh
    finally:
        _stack().pop()


def _physical(logical_name, mesh):
    """One logical axis name -> physical axis (str | tuple | None)."""
    if logical_name is None:
        return None
    if logical_name == "dp":
        axes = tuple(a for a in _DP_AXES if a in mesh.axis_names)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]
    if logical_name == "tp":
        return _TP_AXIS if _TP_AXIS in mesh.axis_names else None
    # allow passing a physical axis name straight through
    return logical_name if logical_name in mesh.axis_names else None


def logical_spec(logical: Sequence, mesh) -> Tuple:
    """Map a tuple of logical axis names to physical mesh axes."""
    return tuple(_physical(a, mesh) for a in logical)


def constrain(x, logical: Sequence):
    """with_sharding_constraint under the ambient mesh; no-op without one."""
    mesh = get_mesh()
    if mesh is None:
        return x
    spec = logical_spec(logical, mesh)
    if all(a is None for a in spec):
        return x
    # only constrain when every sharded dim divides its axis group —
    # GSPMD handles padding, but uneven activation shards are never what
    # the rules here intend (smoke configs on production meshes).
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            continue
        n = _axis_size(mesh, ax)
        if dim % n != 0:
            return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def _axis_size(mesh, axis) -> int:
    axes = axis if isinstance(axis, tuple) else (axis,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# parameter partitioning by path name
# ---------------------------------------------------------------------------

def path_str(path) -> str:
    """jax key-path -> "a/0/b" style string (stable across jax versions)."""
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


# (regex, logical tuple) pairs; first match wins.  The logical tuple is
# right-aligned against the param's trailing dims (scanned layer dims keep
# their leading None).
_DEFAULT_RULES: Tuple[Tuple[str, Tuple], ...] = (
    # MoE expert tables: EP over the model axis, per-expert ff over data
    # (2-D expert sharding; see models/layers.moe_ff docstring)
    (r"(^|/)moe/(w1|w3)$", ("tp", None, "dp")),
    (r"(^|/)moe/w2$", ("tp", "dp", None)),
    (r"(^|/)moe/(sw1|sw3|sw2|router)$", (None, "tp")),
    # embedding / head tables: FSDP over vocab, tp over d
    (r"(^|/)(embed|head)$", ("dp", "tp")),
)


def arch_overrides(cfg) -> Tuple[Tuple[str, Tuple], ...]:
    """Per-architecture extra rules, matched before the defaults."""
    rules = []
    if getattr(cfg, "tie_embeddings", False):
        # tied table doubles as the CE head: keep the vocab layout so the
        # head matmul contracts over the replicated d axis
        rules.append((r"(^|/)embed$", ("tp", None)))
    return tuple(rules)


def param_logical(path: str, ndim: int, scanned: bool,
                  overrides: Tuple[Tuple[str, Tuple], ...] = ()) -> Tuple:
    """Logical axes for one parameter leaf.

    Default rule: biases/scalars/norm gains replicate; matrices shard the
    last axis over "tp" and the first non-scanned axis over "dp" (FSDP).
    """
    eff = ndim - (1 if scanned else 0)       # dims the rules describe
    for pat, logical in tuple(overrides) + _DEFAULT_RULES:
        if re.search(pat, path):
            if len(logical) != eff:
                continue
            return (None,) * (ndim - eff) + tuple(logical)
    if eff <= 1:
        return (None,) * ndim
    logical = [None] * eff
    logical[-1] = "tp"
    logical[0] = "dp"
    return (None,) * (ndim - eff) + tuple(logical)


def param_sharding_tree(shapes, mesh, overrides=()):
    """ShapeDtypeStruct tree -> NamedSharding tree by path-name rules.

    A sharded dim that does not divide its mesh-axis group falls back to
    replicated on that dim (smoke configs lowering on big meshes).
    """
    def f(path, leaf):
        s = path_str(path)
        logical = param_logical(s, leaf.ndim, "blocks" in s, overrides)
        spec = list(logical_spec(logical, mesh))
        for i, (dim, ax) in enumerate(zip(leaf.shape, spec)):
            if ax is not None and dim % _axis_size(mesh, ax) != 0:
                spec[i] = None
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(f, shapes)
