"""Logical-axis sharding: one vocabulary ("dp", "tp") over many meshes.

Models and launch code never name physical mesh axes.  They speak two
logical axes:

  "dp" — the batch/data direction.  Maps to every pure-data axis present
         on the mesh: ("pod", "data") on the 2-pod mesh, ("data",) on a
         single pod, () on a host mesh with no data axis.
  "tp" — the model/tensor direction.  Maps to ("model",) when present.

``use_mesh``/``get_mesh`` carry the ambient mesh (a plain context stack —
importing this module never touches jax device state), ``constrain``
applies a with_sharding_constraint and degrades to a no-op when no mesh is
active (CPU tests, single-host examples), and ``param_sharding_tree``
implements the path-name partitioning rules for parameter pytrees
(FSDP-style: last axis -> tp, first large axis -> dp; MoE expert tables
EP-shard over the model axis — see models/layers.moe_ff).
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# data-parallel-ish physical axes in priority order; "pod" is the pure-DP
# inter-pod axis of the 512-chip mesh (launch/mesh.py)
_DP_AXES = ("pod", "data")
_TP_AXIS = "model"

_state = threading.local()


def _stack():
    if not hasattr(_state, "meshes"):
        _state.meshes = []
    return _state.meshes


def get_mesh() -> Optional[jax.sharding.Mesh]:
    """The innermost mesh installed by :func:`use_mesh`, or None."""
    stack = _stack()
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_mesh(mesh: jax.sharding.Mesh):
    """Install ``mesh`` as the ambient mesh for ``constrain``/``get_mesh``.

    Everything downstream — model ``constrain`` calls, the pipeline's
    dp-sharded basecall path, engine slot scaling — keys off the ambient
    mesh, so a single ``with`` block turns the whole serving path
    multi-device without any API change at the call sites.

    Args:
        mesh: a ``jax.sharding.Mesh`` whose axis names the logical
            ``"dp"``/``"tp"`` vocabulary maps onto (``"pod"``/``"data"``
            are data-parallel, ``"model"`` is tensor-parallel) — or
            ``None`` to pin "no mesh", masking any outer ``use_mesh``
            (how the pipeline keeps a generator's device placement
            consistent with the mesh captured at its creation).

    Returns:
        A context manager yielding ``mesh``; on exit the previous ambient
        mesh (or none) is restored.  Nestable — the innermost mesh wins.

    Example::

        mesh = jax.make_mesh((4,), ("data",))
        with use_mesh(mesh):
            result = pipe.basecall(signal)   # windows shard over "dp"
    """
    _stack().append(mesh)
    try:
        yield mesh
    finally:
        _stack().pop()


def _physical(logical_name, mesh):
    """One logical axis name -> physical axis (str | tuple | None)."""
    if logical_name is None:
        return None
    if logical_name == "dp":
        axes = tuple(a for a in _DP_AXES if a in mesh.axis_names)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]
    if logical_name == "tp":
        return _TP_AXIS if _TP_AXIS in mesh.axis_names else None
    # allow passing a physical axis name straight through
    return logical_name if logical_name in mesh.axis_names else None


def logical_spec(logical: Sequence, mesh) -> Tuple:
    """Map a tuple of logical axis names to physical mesh axes."""
    return tuple(_physical(a, mesh) for a in logical)


def dp_size(mesh: Optional[jax.sharding.Mesh] = None) -> int:
    """Device count behind the logical ``"dp"`` axis.

    Args:
        mesh: the mesh to inspect; defaults to the ambient :func:`use_mesh`
            mesh.

    Returns:
        The product of the mesh's data-parallel axis sizes, or ``1`` when
        no mesh is active (single-device paths stay untouched).
    """
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None:
        return 1
    ax = _physical("dp", mesh)
    return 1 if ax is None else _axis_size(mesh, ax)


def constrain(x, logical: Sequence, *, strict: bool = False):
    """``with_sharding_constraint`` under the ambient mesh.

    The single sharding annotation the models/pipeline speak: callers name
    logical axes ("dp"/"tp"), this maps them onto whatever physical mesh is
    ambient and degrades gracefully everywhere else.

    Args:
        x: the array to annotate.
        logical: one logical axis name (or ``None``) per dim of ``x``,
            e.g. ``("dp", None, None)`` to shard dim 0 over data-parallel
            devices.
        strict: when True, a sharded dim that does not divide its mesh-axis
            group raises a clear ``ValueError`` instead of silently
            skipping the constraint (the pipeline uses this so an
            indivisible window batch fails with a readable message, not an
            XLA shape crash deep inside GSPMD).

    Returns:
        ``x`` annotated with the resolved ``NamedSharding`` — or ``x``
        unchanged when no mesh is active, no logical axis resolves on this
        mesh, or (non-strict) a dim is indivisible.

    Example::

        windows = constrain(windows, ("dp", None, None))
    """
    mesh = get_mesh()
    if mesh is None:
        return x
    spec = logical_spec(logical, mesh)
    if all(a is None for a in spec):
        return x
    # only constrain when every sharded dim divides its axis group —
    # GSPMD handles padding, but uneven activation shards are never what
    # the rules here intend (smoke configs on production meshes).
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            continue
        n = _axis_size(mesh, ax)
        if dim % n != 0:
            if strict:
                raise ValueError(
                    f"cannot shard dim of size {dim} over mesh axis "
                    f"{ax!r} ({n} devices): {dim} % {n} != 0. Pad the "
                    f"batch to a multiple of {n} or drop the mesh "
                    f"(shape={tuple(x.shape)}, logical={tuple(logical)})")
            return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def replicate(x):
    """All-gather ``x`` to fully-replicated under the ambient mesh.

    The pipeline applies this to per-window reads/lengths before the host
    stitch/vote, so every device (and the host) sees the complete window
    set.  No-op without an ambient mesh.
    """
    mesh = get_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P()))


def batch_sharding(mesh, ndim: int) -> NamedSharding:
    """``NamedSharding`` splitting dim 0 over logical "dp", rest replicated.

    What the pipeline/engines ``jax.device_put`` window batches with before
    a sharded decode step (dim 0 must divide :func:`dp_size` — the callers
    pad to a multiple first, or raise via strict :func:`constrain`).
    """
    spec = (_physical("dp", mesh),) + (None,) * (ndim - 1)
    return NamedSharding(mesh, P(*spec))


def _axis_size(mesh, axis) -> int:
    axes = axis if isinstance(axis, tuple) else (axis,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# parameter partitioning by path name
# ---------------------------------------------------------------------------

def path_str(path) -> str:
    """jax key-path -> "a/0/b" style string (stable across jax versions)."""
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


#: sentinel logical "tuple" for rules that replicate a leaf on every dim
#: regardless of rank (the basecall serving artifact uses this — dp shards
#: windows, never weights)
REPLICATE = "replicate"

# (regex, logical tuple) pairs; first match wins.  The logical tuple is
# right-aligned against the param's trailing dims (scanned layer dims keep
# their leading None).
_DEFAULT_RULES: Tuple[Tuple[str, Tuple], ...] = (
    # MoE expert tables: EP over the model axis, per-expert ff over data
    # (2-D expert sharding; see models/layers.moe_ff docstring)
    (r"(^|/)moe/(w1|w3)$", ("tp", None, "dp")),
    (r"(^|/)moe/w2$", ("tp", "dp", None)),
    (r"(^|/)moe/(sw1|sw3|sw2|router)$", (None, "tp")),
    # embedding / head tables: FSDP over vocab, tp over d
    (r"(^|/)(embed|head)$", ("dp", "tp")),
)


def arch_overrides(cfg) -> Tuple[Tuple[str, Tuple], ...]:
    """Per-architecture extra rules, matched before the defaults."""
    rules = []
    if getattr(cfg, "tie_embeddings", False):
        # tied table doubles as the CE head: keep the vocab layout so the
        # head matmul contracts over the replicated d axis
        rules.append((r"(^|/)embed$", ("tp", None)))
    return tuple(rules)


def param_logical(path: str, ndim: int, scanned: bool,
                  overrides: Tuple[Tuple[str, Tuple], ...] = ()) -> Tuple:
    """Logical axes for one parameter leaf.

    Default rule: biases/scalars/norm gains replicate; matrices shard the
    last axis over "tp" and the first non-scanned axis over "dp" (FSDP).
    """
    eff = ndim - (1 if scanned else 0)       # dims the rules describe
    for pat, logical in tuple(overrides) + _DEFAULT_RULES:
        if re.search(pat, path):
            if logical == REPLICATE:
                return (None,) * ndim
            if len(logical) != eff:
                continue
            return (None,) * (ndim - eff) + tuple(logical)
    if eff <= 1:
        return (None,) * ndim
    logical = [None] * eff
    logical[-1] = "tp"
    logical[0] = "dp"
    return (None,) * (ndim - eff) + tuple(logical)


def param_sharding_tree(shapes, mesh, overrides=()):
    """ShapeDtypeStruct tree -> NamedSharding tree by path-name rules.

    A sharded dim that does not divide its mesh-axis group falls back to
    replicated on that dim (smoke configs lowering on big meshes).
    """
    def f(path, leaf):
        s = path_str(path)
        logical = param_logical(s, leaf.ndim, "blocks" in s, overrides)
        spec = list(logical_spec(logical, mesh))
        for i, (dim, ax) in enumerate(zip(leaf.shape, spec)):
            if ax is not None and dim % _axis_size(mesh, ax) != 0:
                spec[i] = None
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(f, shapes)


def replicated_sharding_tree(tree, mesh):
    """Sharding tree that fully replicates every leaf of ``tree`` on ``mesh``.

    :func:`param_sharding_tree` under a match-everything :data:`REPLICATE`
    rule — how the dp-sharded basecall path places its ``PackedParams``
    serving artifact (every device holds the whole model; only the window
    batch is split).
    """
    return param_sharding_tree(tree, mesh, overrides=((r"", REPLICATE),))
