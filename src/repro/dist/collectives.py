"""int8 block compression for the inter-pod gradient all-reduce.

The 2-pod production mesh (launch/mesh.py) crosses a slow DCI on exactly one
collective: the pure-data-parallel gradient all-reduce over the "pod" axis.
Gradients tolerate aggressive quantization there, so the wire format is
1-byte codes + one f32 scale per 256-element block (~3.9x vs f32), and the
error-feedback variant (``ef_compress``) carries the rounding residual into
the next step so the *sum* of transmitted gradients stays exact — the
standard EF-SGD trick that keeps convergence intact.

All functions are jit-compatible and shape-static.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

BLOCK = 256      # elements per scale block
_QMAX = 127.0    # int8 symmetric grid


def _blocked(x: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    """Flatten + zero-pad to a whole number of blocks -> (nblk, BLOCK)."""
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(-1, BLOCK), pad


def compress(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (any shape, float) -> (codes int8 (n,), scales f32 (nblocks,)).

    Per-block symmetric absmax scaling; max abs error <= scale/2 per block.
    """
    blocks, _ = _blocked(x.astype(jnp.float32))
    amax = jnp.max(jnp.abs(blocks), axis=1)
    scale = jnp.maximum(amax, 1e-30) / _QMAX              # (nblk,)
    q = jnp.round(blocks / scale[:, None])
    codes = jnp.clip(q, -_QMAX, _QMAX).astype(jnp.int8)
    return codes.reshape(-1)[: x.size], scale.astype(jnp.float32)


def decompress(codes: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    """Inverse of :func:`compress` back to f32 of ``shape``."""
    blocks, _ = _blocked(codes)
    out = blocks.astype(jnp.float32) * scale[:, None]
    n = 1
    for d in shape:
        n *= d
    return out.reshape(-1)[:n].reshape(shape)


def roundtrip(x: jnp.ndarray) -> jnp.ndarray:
    """compress ∘ decompress — what the receiving pod reconstructs."""
    codes, scale = compress(x)
    return decompress(codes, scale, x.shape)


def ef_compress(grad: jnp.ndarray, residual: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback compression step.

    wire = Q(grad + residual); new_residual = (grad + residual) - wire, so
    Σ_t wire_t + residual_T == Σ_t grad_t exactly (up to fp addition).
    Returns (wire (decompressed f32, what the collective carries), residual).
    """
    acc = grad + residual
    wire = roundtrip(acc)
    return wire, acc - wire


def compression_ratio(shape) -> float:
    """f32 bytes / wire bytes for a tensor of ``shape`` (~3.94 at BLOCK=256)."""
    n = 1
    for d in shape:
        n *= d
    nblk = -(-n // BLOCK)
    return (4.0 * n) / (n + 4.0 * nblk)
