"""Network quantization: fake-quant QAT (STE) + integer packing for serving.

Paper §2.3/§3.1: Helix quantizes inputs, weights and activations of the
base-caller to b-bit fixed point (FQN-style uniform symmetric quantization).
On TPU the low-bit path executes as int8-container MXU matmuls
(``kernels/quant_matmul``); this module owns the *numerics*: scales, rounding,
straight-through gradients, and the packing used by the serving engine.

Quantization is simulated at arbitrary bit-widths (3..16) by clipping the
integer grid inside an int8/int16 container — the same trick the paper uses
in its 2-bit-cell crossbars (a 5-bit weight is bit-sliced over cells; here a
5-bit weight occupies the [-15, 15] sub-grid of an int8 lane).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

#: Name of the ``jax.named_scope`` that marks a *sanctioned* dequant site:
#: the only places an int8/int16 code may be converted to floating point
#: inside a serving trace.  ``repro.analysis`` flags any code->float
#: convert whose name stack lacks this scope.
DEQUANT_SCOPE = "dequant"


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Quantization policy for a model. ``enabled=False`` => pure fp path."""
    enabled: bool = False
    bits_w: int = 5           # paper's headline: 5-bit with SEAT == fp32
    bits_a: int = 5
    per_channel: bool = True  # per-output-channel weight scales
    # STE clipping range follows the observed absmax (no learned step size —
    # matches FQN [18] as used by the paper)
    weights_prequantized: bool = False
    # serving-artifact mode: every weight the model consumes is ALREADY on
    # the b-bit grid (snapped once at pack time), so ``fq_weight`` is the
    # identity and the jitted serving trace carries zero weight-quantization
    # ops.  Activation quantization is unaffected.

    def with_bits(self, bits: int) -> "QuantConfig":
        return dataclasses.replace(self, bits_w=bits, bits_a=bits, enabled=True)

    def as_prequantized(self) -> "QuantConfig":
        """The serving view of this policy (weights pre-snapped at pack time)."""
        return dataclasses.replace(self, weights_prequantized=True)


def qmax(bits: int) -> int:
    """Largest magnitude on a symmetric b-bit grid: 2^(b-1) - 1."""
    return (1 << (bits - 1)) - 1


def compute_scale(x: jnp.ndarray, bits: int, axis=None) -> jnp.ndarray:
    """absmax / qmax, with keepdims so the scale broadcasts against x."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(amax, 1e-8) / qmax(bits)


def quantize_int(x: jnp.ndarray, scale: jnp.ndarray, bits: int,
                 dtype=jnp.int8) -> jnp.ndarray:
    """Real -> integer grid (container dtype holds the sub-grid)."""
    q = jnp.round(x / scale)
    return jnp.clip(q, -qmax(bits), qmax(bits)).astype(dtype)


def fake_quant(x: jnp.ndarray, bits: int, axis=None) -> jnp.ndarray:
    """Quantize-dequantize with a straight-through gradient estimator.

    forward: round(clip(x)) * scale; backward: identity (STE).
    """
    scale = compute_scale(jax.lax.stop_gradient(x), bits, axis=axis)
    q = jnp.clip(jnp.round(x / scale), -qmax(bits), qmax(bits)) * scale
    return x + jax.lax.stop_gradient(q - x)


def fq_weight(w: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """Fake-quant a weight; per-output-channel scales on the LAST axis.

    Identity when ``cfg.weights_prequantized`` — the packed serving
    artifact already snapped every weight to the grid, and re-quantizing
    in-trace is exactly the per-call cost the artifact exists to remove.
    """
    if not cfg.enabled or cfg.weights_prequantized:
        return w
    axis = tuple(range(w.ndim - 1)) if (cfg.per_channel and w.ndim > 1) else None
    return fake_quant(w, cfg.bits_w, axis=axis)


def fq_act(x: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """Fake-quant an activation (per-tensor scale, as in FQN)."""
    if not cfg.enabled:
        return x
    return fake_quant(x, cfg.bits_a)


def qdense(x: jnp.ndarray, w: jnp.ndarray, cfg: QuantConfig,
           b: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Quantization-aware dense layer: fq(x) @ fq(w) + b."""
    y = fq_act(x, cfg) @ fq_weight(w, cfg)
    return y if b is None else y + b


# ---------------------------------------------------------------------------
# serving-side packing (real integer execution; consumed by kernels/quant_matmul)
# ---------------------------------------------------------------------------

def pack_weight(w: jnp.ndarray, bits: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (int8 codes, per-channel fp32 scales (1, ..., Cout))."""
    axis = tuple(range(w.ndim - 1))
    scale = compute_scale(w, bits, axis=axis).astype(jnp.float32)
    return quantize_int(w, scale, bits), scale


def pack_act(x: jnp.ndarray, bits: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (int8 codes, scalar fp32 scale)."""
    scale = compute_scale(x, bits).astype(jnp.float32)
    return quantize_int(x, scale, bits), scale


def pack_act_rows(x: jnp.ndarray, bits: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (int8 codes, per-row fp32 scales (M, 1)) for x (M, K).

    Per-row scales make the integer serving path batch-composition
    invariant: a window quantizes identically whether it shares the batch
    with 1 or 100 other windows (continuous batching ==
    fixed-batch pipeline, bit for bit)."""
    scale = compute_scale(x, bits, axis=(x.ndim - 1,)).astype(jnp.float32)
    return quantize_int(x, scale, bits), scale


def dequant_matmul_reference(xq, x_scale, wq, w_scale):
    """Oracle for the quantized matmul: int32 accumulate, fp dequant."""
    acc = xq.astype(jnp.int32) @ wq.astype(jnp.int32)
    # The declared dequant boundary: repro.analysis allows integer codes
    # to become floats ONLY under this scope.
    with jax.named_scope(DEQUANT_SCOPE):
        return acc.astype(jnp.float32) * x_scale * w_scale


def packed_dense_reference(x: jnp.ndarray, wq: jnp.ndarray, sw: jnp.ndarray,
                           bits_a: int) -> jnp.ndarray:
    """Oracle for the packed serving projection.

    Consumes a pre-packed ``(wq int8, sw fp32)`` weight — the serving
    artifact built once by ``pack_weight`` — and quantizes ONLY the
    activation (per-row scales, batch-composition invariant).  This is the
    numerics contract ``kernels.quant_matmul.qmm_packed`` and the packed
    base-caller apply path must match bit for bit.
    """
    lead, F = x.shape[:-1], x.shape[-1]
    xq, sx = pack_act_rows(x.reshape(-1, F), bits_a)
    y = dequant_matmul_reference(xq, sx, wq, sw.reshape(1, -1))
    return y.reshape(lead + (wq.shape[-1],))


def tree_fake_quant(params, cfg: QuantConfig, predicate=None):
    """Fake-quant every >=2-D leaf of a param tree (weights), leave biases."""
    if not cfg.enabled:
        return params

    def f(path, leaf):
        if leaf.ndim >= 2 and (predicate is None or predicate(path, leaf)):
            return fq_weight(leaf, cfg)
        return leaf

    return jax.tree_util.tree_map_with_path(f, params)
