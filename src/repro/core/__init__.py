"""Helix core: the paper's algorithmic contributions as composable JAX modules.

- ``ctc``    : CTC loss (forward algorithm), greedy + prefix beam-search decode
- ``voting`` : longest-match alignment + majority-vote consensus (read voting)
- ``quant``  : FQN-style fake-quant QAT + integer packing for serving
- ``seat``   : Systematic-Error-Aware Training loss (Eq. 4)
- ``pim``    : first-order analytical model of the ISAAC/Helix PIM hardware
"""
from repro.core.ctc import (
    ctc_loss, ctc_loss_batch, ctc_greedy_decode,
    ctc_beam_search, ctc_beam_search_batch,
)
from repro.core.voting import (
    encode_3bit, equality_matrix, longest_common_substring,
    align_offsets, consensus_grid, vote, vote_batch, vote_reference,
)
from repro.core.quant import (
    QuantConfig, fake_quant, fq_weight, fq_act, qdense,
    pack_weight, pack_act, pack_act_rows, dequant_matmul_reference,
    packed_dense_reference, tree_fake_quant,
)
from repro.core.seat import SEATConfig, seat_loss, consensus_reads, make_views
