"""SEAT — Systematic Error Aware Training (paper §4.1, Eq. 4).

Quantizing a base-caller inflates *systematic* errors: every read covering a
signal decodes to the same wrong base, so read voting cannot repair it.  SEAT
adds a consensus term to the CTC loss:

    loss₁ = Σ  [ −η·ln p(Gᵢ|Rᵢ)  +  ( ln p(Gᵢ|Rᵢ) − ln p(Cᵢ|Rᵢ) )² ]

where Cᵢ is the consensus read voted from the predicted reads of several
overlapping signal windows (R_{i-1}, R_i, R_{i+1}).  Making p(C|R) track
p(G|R) pushes the *ensemble* (not just each read) toward the ground truth —
exactly the error class voting cannot fix.

Everything here is jit-compatible: views are static slices, decoding is the
fixed-shape greedy/beam decoder from ``core.ctc``, voting is ``core.voting``.
The consensus is discrete (ints) so no gradient flows through it; side-view
logits are wrapped in stop_gradient (they only feed the decoder), which is
also why SEAT's overhead stays in the paper's reported 32–52 % band: the
extra view forwards have no backward pass.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import ctc as ctc_lib
from repro.core import voting as voting_lib


@dataclasses.dataclass(frozen=True)
class SEATConfig:
    enabled: bool = True
    eta: float = 1.0           # weight of the per-read CTC term (paper: (0,1])
    n_views: int = 3           # R_{i-1}, R_i, R_{i+1}
    view_stride: int = 16      # signal-sample offset between views (paper's T)
    beam_width: int = 0        # 0 => greedy decode of view reads (fast path)
    max_read_len: int = 96     # decode pad length
    consensus_span: int = 192  # voting grid length
    n_symbols: int = 4         # DNA alphabet for voting

    @property
    def margin(self) -> int:
        """Extra signal samples required on EACH side of the center window."""
        return (self.n_views // 2) * self.view_stride


def make_views(signal: jnp.ndarray, cfg: SEATConfig) -> Tuple[jnp.ndarray, int]:
    """Slice n_views overlapping windows out of a padded signal chunk.

    signal: (B, T_center + 2*margin, C).  Returns (views (V, B, T_center, C),
    center_index).  View k starts at k*stride; the center view is the one the
    ground-truth labels correspond to.
    """
    V, s = cfg.n_views, cfg.view_stride
    t_center = signal.shape[1] - 2 * cfg.margin
    views = jnp.stack([
        jax.lax.dynamic_slice_in_dim(signal, k * s, t_center, axis=1)
        for k in range(V)
    ])
    return views, V // 2


def _decode_views(log_probs: jnp.ndarray, cfg: SEATConfig):
    """(V*B, T, A) -> (V*B, max_read_len) reads + (V*B,) lengths."""
    if cfg.beam_width and cfg.beam_width > 1:
        pref, lens, _ = ctc_lib.ctc_beam_search_batch(
            log_probs, beam_width=cfg.beam_width, max_len=cfg.max_read_len)
        return pref[:, 0], lens[:, 0]
    reads, lens = jax.vmap(ctc_lib.ctc_greedy_decode)(log_probs)
    # clip/pad to max_read_len
    L = reads.shape[1]
    if L >= cfg.max_read_len:
        reads = reads[:, : cfg.max_read_len]
        lens = jnp.minimum(lens, cfg.max_read_len)
    else:
        reads = jnp.pad(reads, ((0, 0), (0, cfg.max_read_len - L)),
                        constant_values=-1)
    return reads, lens


def consensus_reads(view_log_probs: jnp.ndarray, center: int, cfg: SEATConfig
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Vote a consensus read aligned to the center view.

    view_log_probs: (V, B, T, A).  Returns (C (B, max_read_len) padded -1,
    C_len (B,)) — the consensus restricted to the span the center read covers,
    so that p(C|R_center) is well-defined.
    """
    V, B, T, A = view_log_probs.shape
    reads, lens = _decode_views(view_log_probs.reshape(V * B, T, A), cfg)
    reads = reads.reshape(V, B, -1).transpose(1, 0, 2)   # (B, V, L)
    lens = lens.reshape(V, B).T                          # (B, V)

    def one(reads_b, lens_b):
        offs = voting_lib.align_offsets(reads_b, lens_b)
        grid, covered = voting_lib.consensus_grid(
            reads_b, lens_b, offs, n_symbols=cfg.n_symbols,
            span=cfg.consensus_span)
        # slice the window belonging to the center read
        start = jnp.clip(offs[center], 0, cfg.consensus_span - 1)
        clen = jnp.minimum(lens_b[center], cfg.max_read_len)
        win = jax.lax.dynamic_slice_in_dim(grid, start, cfg.max_read_len)
        win = jnp.where(jnp.arange(cfg.max_read_len) < clen, win, -1)
        return win, clen

    return jax.vmap(one)(reads, lens)


def seat_loss(
    logits_fn: Callable[[jnp.ndarray], jnp.ndarray],
    signal: jnp.ndarray,
    labels: jnp.ndarray,
    label_lengths: jnp.ndarray,
    cfg: SEATConfig,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Eq. 4. ``logits_fn``: (B, T_win, C) -> (B, T_out, A) LOG-probs.

    ``signal`` must carry ``cfg.margin`` extra samples on each side of the
    window the ``labels`` describe.  Returns (scalar loss, metrics dict).
    """
    views, center = make_views(signal, cfg)                # (V, B, Tw, C)
    lp_center = logits_fn(views[center])                   # grads flow here

    if not cfg.enabled:
        loss_g = ctc_lib.ctc_loss_batch(lp_center, labels, label_lengths)
        loss = loss_g.mean()
        return loss, {"loss": loss, "ctc_g": loss,
                      "consensus_gap": jnp.zeros(())}

    # side views feed only the (discrete) decoder — no backward needed
    side_lps = [jax.lax.stop_gradient(logits_fn(views[k]))
                for k in range(cfg.n_views) if k != center]
    all_lps = side_lps[: center] + [jax.lax.stop_gradient(lp_center)] \
        + side_lps[center:]
    view_lps = jnp.stack(all_lps)                          # (V, B, T, A)

    C, C_len = consensus_reads(view_lps, center, cfg)      # ints: no grad path

    loss_g = ctc_lib.ctc_loss_batch(lp_center, labels, label_lengths)  # −ln p(G|R)
    loss_c = ctc_lib.ctc_loss_batch(lp_center, C, C_len)               # −ln p(C|R)
    gap = loss_g - loss_c                                   # ln p(C|R) − ln p(G|R)
    loss = (cfg.eta * loss_g + gap ** 2).mean()
    return loss, {
        "loss": loss,
        "ctc_g": loss_g.mean(),
        "ctc_c": loss_c.mean(),
        "consensus_gap": jnp.abs(gap).mean(),
    }
