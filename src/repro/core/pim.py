"""First-order analytical model of the ISAAC/Helix PIM hardware (§4.4-§6).

The paper's architecture results (Fig 24-26) come from a cycle-accurate
NVM-PIM simulator + NVSim + Cadence runs that need RTL/process kits we do
not have offline (DESIGN.md §8).  This module reproduces them as an
explicit, testable first-order model:

* POWER/AREA: component accounting straight from Table 2 (per-IMA crossbar
  arrays, DACs, IR/OR, S+A; CMOS 8-bit 1.28 GSps ADCs vs Helix's 32x32
  SOT-MRAM ADC arrays; 168 tiles x 12 IMAs; +1024 256x256 comparator
  arrays for Helix).
* THROUGHPUT: per-base-caller stage times
      T(scheme) = t_dnn(bits) + t_ctc + t_vote [+ t_xfer]
  with the DNN term from bit-serial crossbar arithmetic
  (ceil(w_bits/2) column slices x a_bits 1-bit-DAC cycles @10 MHz) and the
  CTC/vote/transfer stage constants CALIBRATED once against the paper's own
  measurements: Fig 9's 16.7 %/37 % CTC/vote split, the +6.25 % (16-bit),
  +11.1 % (SEAT), +67.8 % (CTC), 2.22x (vote) step speedups, and Chiron's
  7.16x ISAAC-over-GPU DNN ratio.  Note the paper's own steps compose to
  1.111 x 1.678 x 2.22 = 4.14x for a Guppy-like profile; the 6x headline is
  the AVERAGE over {Guppy, Scrappie, Chiron} and emerges here from Chiron's
  DNN-heavy profile — which is exactly what the tests assert.

Times are normalized to (t_ctc + t_vote) on the GPU == 1 for each caller.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

# ---------------------------------------------------------------------------
# Table 2 component power (mW) / area (mm^2)
# ---------------------------------------------------------------------------

TILE_SHARED_POWER = 40.9       # eDRAM+bus+router+activation+S&A+maxpool+OR
TILE_SHARED_AREA = 0.215

IMA_ARRAY_POWER = 2.4          # 8 arrays, 128x128, 2 bits/cell
IMA_SH_POWER = 0.001
IMA_SA_POWER = 0.2
IMA_IR_POWER = 1.24
IMA_OR_POWER = 0.23
IMA_DAC_POWER = 4.0            # 8x128 1-bit DACs
IMA_CMOS_ADC_POWER = 16.0      # 8x 8-bit 1.28 GSps
IMA_MISC_POWER = (IMA_ARRAY_POWER + IMA_SH_POWER + IMA_SA_POWER +
                  IMA_IR_POWER + IMA_OR_POWER + IMA_DAC_POWER)

IMA_ARRAY_AREA = 0.0002
IMA_MISC_AREA = 0.00004 + 0.00024 + 0.0021 + 0.00077 + 0.00017
IMA_CMOS_ADC_AREA = 0.0096

# Helix SOT-MRAM ADC block per IMA: 8x4 32x32 arrays @640 MHz + vref + enc
IMA_SOT_ADC_POWER = 0.6 + 0.02 + 0.001
IMA_SOT_ADC_AREA = 0.00005 + 0.00003 + 0.000002

N_TILES = 168
N_IMAS = 12
N_ARRAYS = 8
ROWS = COLS = 128
BITS_PER_CELL = 2
ENGINE_FREQ = 10e6

CMP_POWER_W = 1.3              # 1024 256x256 SOT-MRAM comparator arrays
CMP_AREA = 0.11
CMP_READS_PARALLEL = 256


def cmos_adc_power(bits: int) -> float:
    """Flash-ADC style scaling: energy/conversion ~2x per bit."""
    return IMA_CMOS_ADC_POWER * (2.0 ** (bits - 8))


def cmos_adc_area(bits: int) -> float:
    return IMA_CMOS_ADC_AREA * (0.5 + 0.5 * bits / 8)


def chip_power_area(adc: str = "cmos", adc_bits: int = 8,
                    comparators: bool = False):
    """Whole-chip (W, mm^2) from Table 2 components."""
    if adc == "cmos":
        adc_p, adc_a = cmos_adc_power(adc_bits), cmos_adc_area(adc_bits)
    else:
        adc_p, adc_a = IMA_SOT_ADC_POWER, IMA_SOT_ADC_AREA
    tile_p = TILE_SHARED_POWER + N_IMAS * (IMA_MISC_POWER + adc_p)
    tile_a = TILE_SHARED_AREA + N_IMAS * (IMA_ARRAY_AREA + IMA_MISC_AREA
                                          + adc_a)
    power_w = N_TILES * tile_p / 1000.0
    area = N_TILES * tile_a
    if comparators:
        power_w += CMP_POWER_W
        area += CMP_AREA
    return power_w, area


# ---------------------------------------------------------------------------
# calibrated stage-time constants (units: GPU t_ctc + t_vote == 1)
# ---------------------------------------------------------------------------
T_CTC_GPU = 16.7 / 53.7        # Fig 9
T_VOTE_GPU = 37.0 / 53.7
T_XFER = 0.212                 # GPU<->PIM transfer eliminated by CTC scheme
# fp32-DNN-on-ISAAC time per caller, relative to its (ctc+vote) GPU time.
# guppy/scrappie from the +6.25 %/+11.1 % quantization speedups; chiron from
# its 7.16x ISAAC-over-GPU ratio with a 95 % DNN GPU profile (§6.1).
ALPHA = {"guppy": 0.10, "scrappie": 0.13, "chiron": 1.79}
# PIM-side CTC beam-merge and comparator-vote stage times (solved from the
# +67.8 % and 2.22x step equations at beam width 10)
T_CTC_PIM = 0.0283
T_VOTE_PIM = 0.2929


def dnn_rel(w_bits: int, a_bits: int) -> float:
    """Crossbar DNN time relative to the fp32 configuration."""
    col_slices = math.ceil(w_bits / BITS_PER_CELL)
    cycles = max(a_bits, 1)
    return (col_slices * cycles) / (math.ceil(32 / BITS_PER_CELL) * 32)


@dataclasses.dataclass(frozen=True)
class SchemeMetrics:
    name: str
    time: float
    power_w: float
    area_mm2: float

    @property
    def throughput(self) -> float:
        return 1.0 / self.time

    def per_watt(self, base: "SchemeMetrics") -> float:
        return (self.throughput / base.throughput) / (self.power_w /
                                                      base.power_w)

    def per_mm2(self, base: "SchemeMetrics") -> float:
        return (self.throughput / base.throughput) / (self.area_mm2 /
                                                      base.area_mm2)


def scheme(name: str, caller: str = "guppy", beam_width: int = 10,
           adc_bits: int = 8) -> SchemeMetrics:
    """The §5.3 scheme ladder: ISAAC -> 16-bit -> SEAT -> ADC -> CTC -> Helix.

    ``cmosN`` variants (Fig 25) use an N-bit CMOS ADC with the full Helix
    pipeline otherwise.
    """
    a = ALPHA[caller]
    bs = beam_width / 10.0
    ctc_gpu = T_CTC_GPU * bs
    ctc_pim = T_CTC_PIM * bs

    if name == "ISAAC":
        t = a + ctc_gpu + T_VOTE_GPU + T_XFER
        p, ar = chip_power_area("cmos", 8)
    elif name == "16-bit":
        t = a * dnn_rel(16, 16) + ctc_gpu + T_VOTE_GPU + T_XFER
        p, ar = chip_power_area("cmos", 8)
    elif name == "SEAT":
        t = a * dnn_rel(5, 5) + ctc_gpu + T_VOTE_GPU + T_XFER
        p, ar = chip_power_area("cmos", 8)
    elif name == "ADC":
        t = a * dnn_rel(5, 5) + ctc_gpu + T_VOTE_GPU + T_XFER
        p, ar = chip_power_area("sot")
    elif name == "CTC":
        t = a * dnn_rel(5, 5) + ctc_pim + T_VOTE_GPU
        p, ar = chip_power_area("sot")
    elif name == "Helix":
        t = a * dnn_rel(5, 5) + ctc_pim + T_VOTE_PIM
        p, ar = chip_power_area("sot", comparators=True)
    elif name.startswith("cmos"):
        bits = int(name[4:])
        t = a * dnn_rel(min(bits, 5), 5) + ctc_pim + T_VOTE_PIM
        p, ar = chip_power_area("cmos", bits, comparators=True)
    else:
        raise ValueError(name)
    return SchemeMetrics(name, t, p, ar)


SCHEMES = ("ISAAC", "16-bit", "SEAT", "ADC", "CTC", "Helix")
CALLERS = ("guppy", "scrappie", "chiron")


def ladder(beam_width: int = 10) -> Dict[str, Dict[str, float]]:
    """Per-scheme metrics averaged over the three base-callers (Fig 24)."""
    out = {}
    for name in SCHEMES:
        thr = pw = pm = 0.0
        p = a = 0.0
        for caller in CALLERS:
            base = scheme("ISAAC", caller, beam_width)
            s = scheme(name, caller, beam_width)
            thr += s.throughput / base.throughput
            pw += s.per_watt(base)
            pm += s.per_mm2(base)
            p, a = s.power_w, s.area_mm2
        n = len(CALLERS)
        out[name] = {"throughput_x": thr / n, "per_watt_x": pw / n,
                     "per_mm2_x": pm / n, "power_w": p, "area_mm2": a}
    return out


PAPER_CLAIMS = {
    "helix_throughput_x": 6.0,
    "helix_per_watt_x": 11.9,
    "helix_per_mm2_x": 7.5,
    "16bit_speedup": 1.0625,
    "seat_speedup": 1.111,
    "ctc_over_adc": 1.678,
    "helix_over_ctc": 2.22,
    "isaac_power_w": 55.4,
    "isaac_area_mm2": 62.5,
    "helix_power_w": 25.7,
    "helix_area_mm2": 43.83,
    "adc_per_watt_over_seat": 2.27,   # "+127 %"
    "adc_per_mm2_over_seat": 1.429,   # "+42.9 %"
}
