"""Read voting: longest-match alignment + per-position majority consensus.

Paper §4.3 / Fig. 19: a vote (a) finds the longest match between consecutive
reads, (b) aligns them by that match, and (c) takes a per-position majority.
Helix runs step (a) on a SOT-MRAM binary-comparator array — every substring of
R1 is stored in a row and compared against a substring of R2 in one shot, a
mismatch current on the source line marking inequality.  The TPU-native
rendition is a dense equality matrix ``eq[i,j] = (r1[i] == r2[j])`` reduced
along diagonals (``kernels/vote_cmp`` provides the Pallas tile kernel; this
module is the algorithmic layer and pure-jnp fallback).

All functions are fixed-shape and jit/vmap-safe; reads are int arrays padded
with -1 past their length.  DNA symbols use the paper's 3-bit encoding ids
[A,C,G,T,-] = [0,1,2,3,4] (see ``encode_3bit``).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

# paper Fig. 19(c): A:001 C:010 T:000 G:100 -:101
SYM2BITS = jnp.array([
    [0, 0, 1],  # A
    [0, 1, 0],  # C
    [1, 0, 0],  # G
    [0, 0, 0],  # T
    [1, 0, 1],  # - (blank / gap)
], jnp.int32)


def encode_3bit(read: jnp.ndarray) -> jnp.ndarray:
    """(L,) symbol ids -> (L, 3) bit planes (paper's comparator encoding)."""
    safe = jnp.clip(read, 0, SYM2BITS.shape[0] - 1)
    return SYM2BITS[safe]


def equality_matrix(r1: jnp.ndarray, l1, r2: jnp.ndarray, l2) -> jnp.ndarray:
    """eq[i,j] = 1 if r1[i] == r2[j] and both positions are valid."""
    v1 = jnp.arange(r1.shape[0]) < l1
    v2 = jnp.arange(r2.shape[0]) < l2
    eq = (r1[:, None] == r2[None, :]) & v1[:, None] & v2[None, :]
    return eq


def longest_common_substring(r1: jnp.ndarray, l1, r2: jnp.ndarray, l2
                             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Longest common substring via the run-length DP on the equality matrix.

    M[i,j] = eq[i,j] * (M[i-1,j-1] + 1);  the maximum entry is the match length
    and its position gives the end indices in both reads.

    Returns (length, start1, start2) — all int32 scalars. length==0 when no
    character matches.
    """
    eq = equality_matrix(r1, l1, r2, l2).astype(jnp.int32)
    L1, L2 = eq.shape

    def row(prev, eq_row):
        shifted = jnp.concatenate([jnp.zeros((1,), jnp.int32), prev[:-1]])
        cur = eq_row * (shifted + 1)
        return cur, cur

    _, M = jax.lax.scan(row, jnp.zeros((L2,), jnp.int32), eq)
    flat = jnp.argmax(M.reshape(-1))
    best = M.reshape(-1)[flat]
    i_end, j_end = flat // L2, flat % L2
    start1 = i_end - best + 1
    start2 = j_end - best + 1
    return best, jnp.where(best > 0, start1, 0), jnp.where(best > 0, start2, 0)


def pairwise_offset(r1, l1, r2, l2) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Offset of r2 relative to r1 implied by their longest match.

    If r1[s1:s1+m] == r2[s2:s2+m], aligning those means r2 starts at
    ``s1 - s2`` in r1's coordinate frame.  Returns (offset, match_len).
    When no match exists, r2 is appended after r1 (offset = l1).
    """
    m, s1, s2 = longest_common_substring(r1, l1, r2, l2)
    off = jnp.where(m > 0, s1 - s2, l1)
    return off.astype(jnp.int32), m


def align_offsets(reads: jnp.ndarray, lengths: jnp.ndarray) -> jnp.ndarray:
    """Chain pairwise longest-match offsets into global read offsets (R,).

    Reads are in sequencing order (consecutive reads overlap — paper: "the
    order of these reads is already known"), so read k is aligned against
    read k-1 and offsets accumulate.
    """
    def align_next(carry, read_len):
        prev_read, prev_len, prev_off = carry
        read, length = read_len
        rel, _ = pairwise_offset(prev_read, prev_len, read, length)
        off = jnp.maximum(prev_off + rel, 0)  # clamp per step, then chain
        return (read, length, off), off

    (_, _, _), offs = jax.lax.scan(
        align_next, (reads[0], lengths[0], jnp.zeros((), jnp.int32)),
        (reads[1:], lengths[1:]))
    return jnp.concatenate([jnp.zeros((1,), jnp.int32), offs])  # (R,)


def consensus_grid(reads: jnp.ndarray, lengths: jnp.ndarray,
                   offsets: jnp.ndarray, n_symbols: int = 4,
                   span: int | None = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Majority vote on the aligned coordinate grid.

    Returns (consensus (span,), covered (span,) bool); uncovered positions
    hold -1.
    """
    R, L = reads.shape
    if span is None:
        span = 2 * L
    pos = offsets[:, None] + jnp.arange(L)[None, :]          # (R, L)
    valid = (jnp.arange(L)[None, :] < lengths[:, None]) & (pos < span)
    sym = jnp.clip(reads, 0, n_symbols - 1)
    counts = jnp.zeros((span, n_symbols), jnp.int32)
    counts = counts.at[jnp.where(valid, pos, span),
                       jnp.where(valid, sym, 0)].add(1, mode="drop")
    covered = counts.sum(axis=1) > 0
    consensus = jnp.where(covered,
                          jnp.argmax(counts, axis=1).astype(jnp.int32), -1)
    return consensus, covered


def vote(reads: jnp.ndarray, lengths: jnp.ndarray, n_symbols: int = 4,
         span: int | None = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Align consecutive reads by longest match and majority-vote a consensus.

    Args:
      reads: (R, L) int32, padded with -1.
      lengths: (R,) int32 true lengths.
      n_symbols: vote alphabet (4 DNA bases).
      span: length of the consensus coordinate grid (default 2*L).

    Returns (consensus (span,) padded -1, consensus_length).
    """
    R, L = reads.shape
    if span is None:
        span = 2 * L
    offsets = align_offsets(reads, lengths)
    consensus, covered = consensus_grid(reads, lengths, offsets, n_symbols, span)
    # compact: drop any interior uncovered holes (rare: disjoint reads)
    keep = covered
    wpos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    out = jnp.full((span,), -1, jnp.int32)
    out = out.at[jnp.where(keep, wpos, span)].set(
        jnp.where(covered, consensus, 0), mode="drop")
    return out, keep.sum().astype(jnp.int32)


def vote_batch(reads, lengths, n_symbols: int = 4, span: int | None = None):
    """(B, R, L) -> (B, span) consensus. vmap of :func:`vote`."""
    f = functools.partial(vote, n_symbols=n_symbols, span=span)
    return jax.vmap(f)(reads, lengths)


# ---------------------------------------------------------------------------
# host-side (numpy-flavoured) oracle for tests
# ---------------------------------------------------------------------------

def vote_reference(reads_list, n_symbols: int = 4):
    """Plain-Python consensus used as a test oracle. reads_list: list[list[int]]."""
    import numpy as np

    def lcs(a, b):
        best, s1, s2 = 0, 0, 0
        prev = [0] * (len(b) + 1)
        for i in range(1, len(a) + 1):
            cur = [0] * (len(b) + 1)
            for j in range(1, len(b) + 1):
                if a[i - 1] == b[j - 1]:
                    cur[j] = prev[j - 1] + 1
                    if cur[j] > best:
                        best, s1, s2 = cur[j], i - cur[j], j - cur[j]
            prev = cur
        return best, s1, s2

    offsets = [0]
    for k in range(1, len(reads_list)):
        m, s1, s2 = lcs(reads_list[k - 1], reads_list[k])
        rel = (s1 - s2) if m > 0 else len(reads_list[k - 1])
        offsets.append(max(offsets[-1] + rel, 0))
    span = max(off + len(r) for off, r in zip(offsets, reads_list))
    counts = np.zeros((span, n_symbols), np.int64)
    for off, r in zip(offsets, reads_list):
        for i, c in enumerate(r):
            if 0 <= c < n_symbols and off + i < span:
                counts[off + i, c] += 1
    out = [int(np.argmax(row)) for row in counts if row.sum() > 0]
    return out
