"""Connectionist Temporal Classification: loss, greedy decode, prefix beam search.

The paper's base-callers (Guppy/Scrappie/Chiron) emit per-frame log-probabilities
over [A, C, G, T, blank]; a CTC decoder maps frames to a read.  Helix's C3
restructures beam search into dense vector ops so it runs on the matrix engine —
here everything is expressed as fixed-shape jnp tensor ops under ``lax.scan`` so
XLA maps it onto the TPU VPU/MXU the same way.

Conventions
-----------
* alphabet indices ``0..A-2`` are symbols, ``blank`` defaults to the LAST index
  (the paper's [A,C,G,T,-] layout with A=5, blank=4).
* all decode outputs are fixed-shape, padded with ``-1`` beyond ``length``.
* ``NEG`` is used instead of ``-inf`` so logsumexp gradients stay NaN-free.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

NEG = -1.0e9  # "log zero" that keeps gradients finite


def _lse2(a, b):
    return jnp.logaddexp(a, b)


def _lse3(a, b, c):
    return jnp.logaddexp(jnp.logaddexp(a, b), c)


# ---------------------------------------------------------------------------
# CTC loss (log-domain forward algorithm)
# ---------------------------------------------------------------------------

def ctc_loss(
    log_probs: jnp.ndarray,
    labels: jnp.ndarray,
    label_length: jnp.ndarray | int | None = None,
    logit_length: jnp.ndarray | int | None = None,
    blank: int = -1,
) -> jnp.ndarray:
    """-ln p(labels | log_probs) for a single example.

    Args:
      log_probs: (T, A) per-frame log-probabilities (already log-softmaxed).
      labels: (L,) int32 label ids, padded arbitrarily beyond ``label_length``.
      label_length: true label length (<= L). Defaults to L.
      logit_length: true frame count (<= T). Defaults to T.
      blank: blank id; negative values index from the end (default: last).

    Returns: scalar loss = -log p(labels | inputs).
    """
    T, A = log_probs.shape
    L = labels.shape[0]
    if blank < 0:
        blank = A + blank
    label_length = jnp.asarray(L if label_length is None else label_length, jnp.int32)
    logit_length = jnp.asarray(T if logit_length is None else logit_length, jnp.int32)

    S = 2 * L + 1
    s_idx = jnp.arange(S)
    # extended label sequence: blank interleaved
    lab_safe = jnp.where(jnp.arange(L) < label_length, labels, 0)
    ext = jnp.where(s_idx % 2 == 0, blank, lab_safe[jnp.minimum((s_idx - 1) // 2, L - 1)])
    # skip transition s-2 -> s allowed for non-blank s whose label differs from s-2
    ext_m2 = jnp.concatenate([jnp.full((2,), -2, ext.dtype), ext[:-2]])
    allow_skip = (s_idx % 2 == 1) & (ext != ext_m2)

    lp0 = log_probs[0]
    alpha0 = jnp.full((S,), NEG)
    alpha0 = alpha0.at[0].set(lp0[blank])
    if L > 0:
        alpha0 = alpha0.at[1].set(jnp.where(label_length > 0, lp0[ext[1]], NEG))

    def step(alpha, lp):
        a1 = jnp.concatenate([jnp.array([NEG]), alpha[:-1]])
        a2 = jnp.concatenate([jnp.array([NEG, NEG]), alpha[:-2]])
        a2 = jnp.where(allow_skip, a2, NEG)
        new = lp[ext] + _lse3(alpha, a1, a2)
        return new, new

    _, alphas = jax.lax.scan(step, alpha0, log_probs[1:])
    alphas = jnp.concatenate([alpha0[None], alphas], axis=0)  # (T, S)
    alpha_final = alphas[jnp.maximum(logit_length - 1, 0)]

    s_end = 2 * label_length  # last blank
    ll_pos = alpha_final[jnp.minimum(s_end, S - 1)]
    ll_pre = jnp.where(label_length > 0,
                       alpha_final[jnp.clip(s_end - 1, 0, S - 1)], NEG)
    return -_lse2(ll_pos, ll_pre)


def ctc_loss_batch(log_probs, labels, label_lengths=None, logit_lengths=None,
                   blank: int = -1):
    """Batched CTC loss, per-example. Shapes: (B,T,A), (B,L), (B,), (B,)."""
    B, T, A = log_probs.shape
    L = labels.shape[1]
    if label_lengths is None:
        label_lengths = jnp.full((B,), L, jnp.int32)
    if logit_lengths is None:
        logit_lengths = jnp.full((B,), T, jnp.int32)
    f = jax.vmap(functools.partial(ctc_loss, blank=blank))
    return f(log_probs, labels, label_lengths, logit_lengths)


# ---------------------------------------------------------------------------
# Greedy (best-path) decode
# ---------------------------------------------------------------------------

def ctc_greedy_decode(log_probs: jnp.ndarray, blank: int = -1,
                      logit_length=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Best-path decode: argmax per frame, collapse repeats, drop blanks.

    Returns (read (T,), length). ``read`` padded with -1.
    """
    T, A = log_probs.shape
    if blank < 0:
        blank = A + blank
    if logit_length is None:
        logit_length = T
    logit_length = jnp.asarray(logit_length, jnp.int32)

    path = jnp.argmax(log_probs, axis=-1)  # (T,)
    prev = jnp.concatenate([jnp.array([-1], path.dtype), path[:-1]])
    valid_t = jnp.arange(T) < logit_length
    keep = (path != blank) & (path != prev) & valid_t
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1  # write index per kept frame
    out = jnp.full((T,), -1, jnp.int32)
    out = out.at[jnp.where(keep, pos, T)].set(path.astype(jnp.int32), mode="drop")
    return out, keep.sum().astype(jnp.int32)


# ---------------------------------------------------------------------------
# CTC prefix beam search (fixed-shape, vectorized; paper Fig. 4d / §4.3)
# ---------------------------------------------------------------------------

def ctc_beam_search(
    log_probs: jnp.ndarray,
    beam_width: int = 10,
    blank: int = -1,
    max_len: int | None = None,
    logit_length=None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Prefix beam search over (T, A) log-probs.

    Maintains per-beam (prefix, p_blank, p_nonblank) and at every frame expands
    each of the W beams with {stay} ∪ {append c : c != blank} — a dense
    (W × A) candidate tensor (the paper computes exactly this product on its
    dot-product array, merging equal prefixes on the bit-lines; we merge with a
    masked logsumexp over an equality matrix).

    Returns (prefixes (W, max_len) padded -1, lengths (W,), scores (W,)),
    sorted by score descending. scores = log p(prefix).
    """
    T, A = log_probs.shape
    if blank < 0:
        blank = A + blank
    if max_len is None:
        max_len = T
    if logit_length is None:
        logit_length = T
    logit_length = jnp.asarray(logit_length, jnp.int32)
    W = beam_width
    nsym = A - 1  # non-blank symbols; ids: all indices != blank
    sym_ids = jnp.array([c for c in range(A) if c != blank], jnp.int32)  # (nsym,)

    # beam state
    prefixes = jnp.full((W, max_len), -1, jnp.int32)
    lengths = jnp.zeros((W,), jnp.int32)
    p_b = jnp.full((W,), NEG).at[0].set(0.0)   # log p(prefix ends in blank)
    p_nb = jnp.full((W,), NEG)                 # log p(prefix ends in non-blank)

    C = W * (1 + nsym)  # candidates per step

    def step(state, inp):
        prefixes, lengths, p_b, p_nb = state
        lp, t = inp
        active = t < logit_length

        last = jnp.where(lengths > 0,
                         prefixes[jnp.arange(W), jnp.maximum(lengths - 1, 0)], -1)
        tot = _lse2(p_b, p_nb)

        # --- stay candidates (prefix unchanged) ------------------------------
        stay_pb = tot + lp[blank]
        stay_pnb = jnp.where(lengths > 0, p_nb + lp[jnp.maximum(last, 0)], NEG)

        # --- extend candidates (append symbol c) -----------------------------
        # (W, nsym): repeat-char extensions may only come through a blank
        lp_sym = lp[sym_ids]                                   # (nsym,)
        is_rep = last[:, None] == sym_ids[None, :]             # (W, nsym)
        ext_pnb = jnp.where(is_rep, p_b[:, None], tot[:, None]) + lp_sym[None, :]
        ext_pb = jnp.full((W, nsym), NEG)
        can_grow = lengths < max_len
        ext_pnb = jnp.where(can_grow[:, None], ext_pnb, NEG)

        # extended prefixes: append c at position `length`
        ext_prefix = jnp.broadcast_to(prefixes[:, None, :], (W, nsym, max_len))
        widx = jnp.minimum(lengths, max_len - 1)
        ext_prefix = ext_prefix.at[jnp.arange(W)[:, None],
                                   jnp.arange(nsym)[None, :],
                                   widx[:, None]].set(
            jnp.broadcast_to(sym_ids[None, :], (W, nsym)))
        ext_len = jnp.minimum(lengths + 1, max_len)

        # --- assemble candidate tensors --------------------------------------
        cand_prefix = jnp.concatenate(
            [prefixes, ext_prefix.reshape(W * nsym, max_len)], axis=0)  # (C, L)
        cand_len = jnp.concatenate([lengths, jnp.repeat(ext_len, nsym)], axis=0)
        cand_pb = jnp.concatenate([stay_pb, ext_pb.reshape(-1)], axis=0)
        cand_pnb = jnp.concatenate([stay_pnb, ext_pnb.reshape(-1)], axis=0)

        # --- merge identical prefixes (masked logsumexp) ----------------------
        eq = (cand_len[:, None] == cand_len[None, :]) & jnp.all(
            cand_prefix[:, None, :] == cand_prefix[None, :, :], axis=-1)  # (C, C)
        canon = ~jnp.any(eq & (jnp.arange(C)[None, :] < jnp.arange(C)[:, None]),
                         axis=1)  # first occurrence wins
        mrg_pb = jax.nn.logsumexp(jnp.where(eq, cand_pb[None, :], NEG), axis=1)
        mrg_pnb = jax.nn.logsumexp(jnp.where(eq, cand_pnb[None, :], NEG), axis=1)
        mrg_pb = jnp.where(canon, mrg_pb, NEG)
        mrg_pnb = jnp.where(canon, mrg_pnb, NEG)

        # --- select top-W -----------------------------------------------------
        score = _lse2(mrg_pb, mrg_pnb)
        _, top = jax.lax.top_k(score, W)
        new_state = (cand_prefix[top], cand_len[top], mrg_pb[top], mrg_pnb[top])
        # frames past logit_length are no-ops
        new_state = jax.tree_util.tree_map(
            lambda n, o: jnp.where(active, n, o), new_state, state)
        return new_state, None

    ts = jnp.arange(T)
    (prefixes, lengths, p_b, p_nb), _ = jax.lax.scan(
        step, (prefixes, lengths, p_b, p_nb), (log_probs, ts))

    score = _lse2(p_b, p_nb)
    order = jnp.argsort(-score)
    return prefixes[order], lengths[order], score[order]


def ctc_beam_search_batch(log_probs, beam_width=10, blank=-1, max_len=None,
                          logit_lengths=None):
    B, T, A = log_probs.shape
    if logit_lengths is None:
        logit_lengths = jnp.full((B,), T, jnp.int32)

    def one(lp, ll):
        return ctc_beam_search(lp, beam_width=beam_width, blank=blank,
                               max_len=max_len, logit_length=ll)

    return jax.vmap(one)(log_probs, logit_lengths)


# ---------------------------------------------------------------------------
# hash-merge CTC prefix beam search (the serving decoder)
# ---------------------------------------------------------------------------
#
# The dense decoder above materializes an O(C^2 * L) prefix-equality tensor
# per frame (C = W * A candidates) — beam width and read length blow up
# quadratically, and only the logsumexp tail is accelerated.  The serving
# decoder instead identifies every candidate by a 32-bit ROLLING PREFIX
# HASH:
#
#     h(empty) = 0;   h(prefix + c) = h(prefix) * M + (c + 1)   (mod 2^32)
#
# with M odd, so duplicate detection is single-word integer compares and
# the whole per-frame beam update — merge duplicate candidates, pool their
# log-mass, pick the top W — is ONE fused ``beam_merge_topk`` op from
# ``repro.kernels.registry`` (ref / interpret / Pallas backends).
#
# Invariants the hash state maintains (see ARCHITECTURE.md):
#   * after every frame the W live beams carry distinct prefixes, so the
#     only duplicates among the W*(1+nsym) candidates are structural:
#     extend(beam_i, c) colliding with stay(beam_j) where P_j = P_i + c —
#     exactly what the key-equality merge pools;
#   * hash identity == prefix identity up to 32-bit collisions
#     (probability ~ C^2 * T / 2^33 per read — negligible, and the dense
#     decoder stays available as the exact oracle);
#   * dead lanes (score ~ NEG) may carry stale prefixes; their mass
#     underflows to zero in every merge, so they never influence a live
#     beam.

_HASH_MUL = jnp.uint32(2654435761)  # Knuth's multiplicative constant (odd)


def prefix_hash_extend(h: jnp.ndarray, sym: jnp.ndarray) -> jnp.ndarray:
    """Rolling prefix hash update: h' = h * M + (sym + 1) (mod 2^32)."""
    return h * _HASH_MUL + (sym.astype(jnp.uint32) + jnp.uint32(1))


def ctc_beam_search_hash_batch(log_probs, beam_width: int = 10,
                               blank: int = -1, max_len: int | None = None,
                               logit_lengths=None, backend=None,
                               strip_frames: int | None = None
                               ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                          jnp.ndarray]:
    """Batched hash-merge prefix beam search over (B, T, A) log-probs.

    Natively batched (no vmap): the whole pool advances one frame per
    fused merge/top-k call, which is what the serving engine batches over
    slots.  ``logit_lengths`` (B,) masks padded tail frames per example —
    frames at/after an example's length leave its beam state untouched.

    ``backend`` is a registry backend name or ``repro.kernels.registry
    .Backend`` ("auto"/"pallas"/"interpret"/"ref") for the fused op.

    ``strip_frames`` > 1 switches the per-frame ``beam_merge_topk`` loop
    to the persistent ``beam_merge_multiframe`` kernel: beam state stays
    resident in VMEM across strips of that many frames (one launch per
    strip instead of one per frame), and prefixes are rebuilt from the
    kernel's per-frame winner indices by an index-only replay scan.  The
    result is bitwise identical to the per-frame path (``None``/``1``),
    which remains the differential oracle.

    Returns (prefixes (B, W, max_len) padded -1, lengths (B, W),
    scores (B, W)), each example sorted by score descending.
    """
    from repro.kernels import registry as _registry

    B, T, A = log_probs.shape
    if blank < 0:
        blank = A + blank
    if max_len is None:
        max_len = T
    if logit_lengths is None:
        logit_lengths = jnp.full((B,), T, jnp.int32)
    logit_lengths = jnp.asarray(logit_lengths, jnp.int32)
    W = beam_width
    nsym = A - 1
    sym_ids = jnp.array([c for c in range(A) if c != blank], jnp.int32)
    L = max_len

    mode = backend.mode if isinstance(backend, _registry.Backend) else backend
    if strip_frames is not None and strip_frames > 1:
        return _hash_beam_strips(log_probs, logit_lengths, mode,
                                 W=W, blank=blank, L=L,
                                 F=int(strip_frames))
    merge_topk = _registry.get_op("beam_merge_topk", mode)

    prefixes = jnp.full((B, W, L), -1, jnp.int32)
    lengths = jnp.zeros((B, W), jnp.int32)
    hashes = jnp.zeros((B, W), jnp.uint32)
    p_b = jnp.full((B, W), NEG).at[:, 0].set(0.0)
    p_nb = jnp.full((B, W), NEG)

    def step(state, inp):
        prefixes, lengths, hashes, p_b, p_nb = state
        lp, t = inp                                    # lp (B, A)
        active = t < logit_lengths                     # (B,)

        last = jnp.where(
            lengths > 0,
            jnp.take_along_axis(
                prefixes, jnp.maximum(lengths - 1, 0)[:, :, None],
                axis=2)[:, :, 0],
            -1)                                        # (B, W)
        tot = _lse2(p_b, p_nb)

        # --- stay candidates (prefix unchanged) ------------------------------
        stay_pb = tot + lp[:, blank][:, None]
        stay_pnb = jnp.where(
            lengths > 0,
            p_nb + jnp.take_along_axis(lp, jnp.maximum(last, 0), axis=1),
            NEG)

        # --- extend candidates (append symbol c) -----------------------------
        lp_sym = lp[:, sym_ids]                        # (B, nsym)
        is_rep = last[:, :, None] == sym_ids[None, None, :]
        ext_pnb = (jnp.where(is_rep, p_b[:, :, None], tot[:, :, None])
                   + lp_sym[:, None, :])               # (B, W, nsym)
        can_grow = lengths < L
        ext_pnb = jnp.where(can_grow[:, :, None], ext_pnb, NEG)
        ext_hash = prefix_hash_extend(hashes[:, :, None],
                                      sym_ids[None, None, :])

        ext_prefix = jnp.broadcast_to(prefixes[:, :, None, :],
                                      (B, W, nsym, L))
        widx = jnp.minimum(lengths, L - 1)
        ext_prefix = ext_prefix.at[
            jnp.arange(B)[:, None, None],
            jnp.arange(W)[None, :, None],
            jnp.arange(nsym)[None, None, :],
            widx[:, :, None]].set(
            jnp.broadcast_to(sym_ids[None, None, :], (B, W, nsym)))
        ext_len = jnp.minimum(lengths + 1, L)

        # --- assemble candidates: stays first, then extends ------------------
        cand_prefix = jnp.concatenate(
            [prefixes, ext_prefix.reshape(B, W * nsym, L)], axis=1)
        cand_len = jnp.concatenate(
            [lengths, jnp.repeat(ext_len, nsym, axis=1)], axis=1)
        cand_hash = jnp.concatenate(
            [hashes, ext_hash.reshape(B, W * nsym)], axis=1)
        cand_pb = jnp.concatenate(
            [stay_pb, jnp.full((B, W * nsym), NEG)], axis=1)
        cand_pnb = jnp.concatenate(
            [stay_pnb, ext_pnb.reshape(B, W * nsym)], axis=1)

        # --- fused hash merge + top-W ----------------------------------------
        idx, mpb, mpnb = merge_topk(cand_hash, cand_pb, cand_pnb, W=W)

        new_state = (
            jnp.take_along_axis(cand_prefix, idx[:, :, None], axis=1),
            jnp.take_along_axis(cand_len, idx, axis=1),
            jnp.take_along_axis(cand_hash, idx, axis=1),
            mpb, mpnb)
        new_state = jax.tree_util.tree_map(
            lambda n, o: jnp.where(
                active.reshape((B,) + (1,) * (n.ndim - 1)), n, o),
            new_state, state)
        return new_state, None

    lps = jnp.swapaxes(log_probs, 0, 1)                # (T, B, A)
    ts = jnp.arange(T)
    (prefixes, lengths, hashes, p_b, p_nb), _ = jax.lax.scan(
        step, (prefixes, lengths, hashes, p_b, p_nb), (lps, ts))

    score = _lse2(p_b, p_nb)
    order = jnp.argsort(-score, axis=1)
    return (jnp.take_along_axis(prefixes, order[:, :, None], axis=1),
            jnp.take_along_axis(lengths, order, axis=1),
            jnp.take_along_axis(score, order, axis=1))


def _hash_beam_strips(log_probs, logit_lengths, mode, *, W: int, blank: int,
                      L: int, F: int):
    """Strip-mode body of ``ctc_beam_search_hash_batch``.

    One ``beam_merge_multiframe`` launch advances the narrow beam state
    (hashes / log-masses / last symbol / lengths) through F frames with
    the state resident in VMEM; prefix CONTENT — too wide to keep
    resident — is rebuilt afterwards by replaying the per-frame winner
    indices, an index-only gather/scatter scan with no float math, so the
    final (prefixes, lengths, scores) are bitwise the per-frame path's.

    The frame axis is zero-padded up to a multiple of F; padded frames
    are inactive for every example (``active`` masks on the TRUE lengths)
    and the kernel emits identity indices for them, which makes the
    replay a natural no-op there too.
    """
    from repro.kernels import registry as _registry

    B, T, A = log_probs.shape
    nsym = A - 1
    sym_ids = jnp.array([c for c in range(A) if c != blank], jnp.int32)
    strip_op = _registry.get_op("beam_merge_multiframe", mode)

    S = -(-T // F)
    Tp = S * F
    lps = jnp.pad(log_probs.astype(jnp.float32),
                  ((0, 0), (0, Tp - T), (0, 0)))
    active = (jnp.arange(Tp)[None, :]
              < logit_lengths[:, None]).astype(jnp.int32)     # (B, Tp)

    prefixes = jnp.full((B, W, L), -1, jnp.int32)
    lengths = jnp.zeros((B, W), jnp.int32)
    keys = jnp.zeros((B, W), jnp.int32)   # uint32 hash bit patterns
    last = jnp.full((B, W), -1, jnp.int32)
    p_b = jnp.full((B, W), NEG).at[:, 0].set(0.0)
    p_nb = jnp.full((B, W), NEG)

    bi = jnp.arange(B)[:, None]
    wi = jnp.arange(W)[None, :]

    def replay(st, idx_f):
        """One frame of prefix reconstruction from winner indices.

        idx < W is a stay of beam ``idx``; idx >= W is beam
        ``(idx-W)//nsym`` extended by symbol ``sym_ids[(idx-W)%nsym]`` —
        the per-frame decoder's candidate layout.
        """
        prefixes, lengths = st
        is_ext = idx_f >= W                                   # (B, W)
        src = jnp.where(is_ext, (idx_f - W) // nsym, idx_f)
        sym = jnp.take(sym_ids, jnp.where(is_ext, (idx_f - W) % nsym, 0))
        prev_prefix = jnp.take_along_axis(prefixes, src[:, :, None], axis=1)
        prev_len = jnp.take_along_axis(lengths, src, axis=1)
        widx = jnp.minimum(prev_len, L - 1)
        cur = prev_prefix[bi, wi, widx]
        newp = prev_prefix.at[bi, wi, widx].set(
            jnp.where(is_ext, sym, cur))
        newl = jnp.where(is_ext, jnp.minimum(prev_len + 1, L), prev_len)
        return (newp, newl), None

    def strip_step(state, inp):
        prefixes, lengths, keys, last, p_b, p_nb = state
        lp_strip, act_strip = inp                 # (B, F, A), (B, F)
        idx, keys, p_b, p_nb, last, _lens = strip_op(
            lp_strip, act_strip, keys, p_b, p_nb, last, lengths,
            blank=blank, L=L)
        # lengths from the replay are provably the kernel's ``_lens``
        (prefixes, lengths), _ = jax.lax.scan(
            replay, (prefixes, lengths), jnp.moveaxis(idx, 1, 0))
        return (prefixes, lengths, keys, last, p_b, p_nb), None

    xs = (jnp.moveaxis(lps.reshape(B, S, F, A), 1, 0),
          jnp.moveaxis(active.reshape(B, S, F), 1, 0))
    (prefixes, lengths, keys, last, p_b, p_nb), _ = jax.lax.scan(
        strip_step, (prefixes, lengths, keys, last, p_b, p_nb), xs)

    score = _lse2(p_b, p_nb)
    order = jnp.argsort(-score, axis=1)
    return (jnp.take_along_axis(prefixes, order[:, :, None], axis=1),
            jnp.take_along_axis(lengths, order, axis=1),
            jnp.take_along_axis(score, order, axis=1))


def ctc_beam_search_hash(log_probs, beam_width: int = 10, blank: int = -1,
                         max_len: int | None = None, logit_length=None,
                         backend=None, strip_frames: int | None = None
                         ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Hash-merge beam search over a single (T, A) example.

    Same contract as ``ctc_beam_search`` (the dense-merge oracle), decoded
    on the fused ``beam_merge_topk`` registry op (or the persistent
    ``beam_merge_multiframe`` strips when ``strip_frames`` > 1).
    """
    ll = None if logit_length is None else jnp.asarray(
        logit_length, jnp.int32).reshape(1)
    prefixes, lengths, scores = ctc_beam_search_hash_batch(
        log_probs[None], beam_width=beam_width, blank=blank,
        max_len=max_len, logit_lengths=ll, backend=backend,
        strip_frames=strip_frames)
    return prefixes[0], lengths[0], scores[0]
