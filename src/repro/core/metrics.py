"""Base-calling metrics: edit distance (paper §2.2), read/vote error rates."""
from __future__ import annotations

import numpy as np


def edit_distance(a, b) -> int:
    """Levenshtein distance — the paper's base-calling error count."""
    a, b = list(a), list(b)
    if len(a) < len(b):
        a, b = b, a
    prev = list(range(len(b) + 1))
    for i, ca in enumerate(a, 1):
        cur = [i]
        for j, cb in enumerate(b, 1):
            cur.append(min(prev[j] + 1, cur[j - 1] + 1,
                           prev[j - 1] + (ca != cb)))
        prev = cur
    return prev[-1]


def error_rate(pred, pred_len, truth, truth_len) -> float:
    """Mean edit distance / truth length over a batch (numpy arrays)."""
    total_err = 0
    total_len = 0
    for p, pl, t, tl in zip(pred, pred_len, truth, truth_len):
        total_err += edit_distance(p[: int(pl)], t[: int(tl)])
        total_len += int(tl)
    return total_err / max(total_len, 1)


def accuracy(pred, pred_len, truth, truth_len) -> float:
    return 1.0 - error_rate(pred, pred_len, truth, truth_len)
