"""Composable LM family covering the 10 assigned architectures.

One decoder stack with per-arch options (GQA, QKV bias, SWA, RoPE/M-RoPE,
MoE every-layer or alternating, Mamba-1, Hymba-style parallel attn+SSM,
optional encoder + cross-attention for seamless-m4t), plus the Helix
quantization hooks (``core.quant.qdense``) on every projection.

Layers are lax.scan-stacked (llama4 scans over [dense, MoE] super-blocks) so
HLO size — and dry-run compile time on 512 host devices — stays O(1) in
depth.  Residual activations carry a sequence-parallel sharding constraint
between blocks (see dist/sharding.py).

Public API:
  init_lm(key, cfg)                            -> params
  forward(params, cfg, batch)                  -> logits          (train/eval)
  lm_loss(params, cfg, batch)                  -> (loss, metrics)
  prefill(params, cfg, batch, max_len)         -> (logits, cache)
  decode_step(params, cfg, cache, tokens/embeds) -> (logits, cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig, fq_weight, qdense
from repro.dist.sharding import constrain
from repro.models.layers import (MoEConfig, SSMConfig, apply_rope,
                                 decode_attention, flash_attention,
                                 layer_norm, mamba_mix, moe_ff, rms_norm)


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    n_layers: int = 24
    causal: bool = False


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: Optional[int] = None
    # block structure
    block_pattern: str = "attn"   # attn | moe | mamba | hybrid | alt_dense_moe
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    d_ff_dense: Optional[int] = None    # alt_dense_moe: dense sublayer ff
    # attention flavour
    qkv_bias: bool = False
    window: Optional[int] = None        # sliding-window attention
    rope_theta: float = 1e6
    mrope_sections: Optional[Tuple[int, ...]] = None
    attn_chunk: int = 512
    # ff / norm flavour
    ff_type: str = "swiglu"             # swiglu | gelu
    norm_type: str = "rms"              # rms | ln
    norm_eps: float = 1e-5
    # io
    embed_inputs: bool = True           # False => batch carries "embeds"
    encoder: Optional[EncoderConfig] = None
    tie_embeddings: bool = False
    # numerics / execution
    dtype: Any = jnp.float32
    remat: bool = True
    act_shard: bool = True
    quant: QuantConfig = QuantConfig()
    # §Perf knobs (EXPERIMENTS.md): static causal block skipping in flash
    # attention; python-unrolled layer loop for decode (buffer aliasing)
    attn_causal_skip: bool = False
    scan_layers: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so embedding/head tables shard evenly
        over the 16-way model axis (hymba's 32001 etc.)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def n_blocks(self) -> int:
        return (self.n_layers // 2 if self.block_pattern == "alt_dense_moe"
                else self.n_layers)

    def param_count(self) -> int:
        """Analytical parameter count (embeddings included)."""
        d, hd = self.d_model, self.hd
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * d
        if self.qkv_bias:
            attn += hd * (self.n_heads + 2 * self.n_kv_heads)
        ff_sw = 3 * d * self.d_ff
        ff_ge = 2 * d * self.d_ff + self.d_ff + d
        ff = ff_sw if self.ff_type == "swiglu" else ff_ge
        if self.ssm is not None:
            di = self.ssm.inner(d)
            r = self.ssm.rank(d)
            n = self.ssm.d_state
            mam = (d * 2 * di + self.ssm.d_conv * di + di +
                   di * (r + 2 * n) + r * di + di + di * n + di + di * d)
        else:
            mam = 0
        if self.moe is not None:
            moe = d * self.moe.n_experts + 3 * self.moe.n_experts * d * self.d_ff
            if self.moe.shared_expert:
                moe += 3 * d * self.d_ff
        else:
            moe = 0
        nw = d * (2 if self.norm_type == "ln" else 1)   # one norm's params
        per, norms = {
            "attn": (attn + ff, 2 * nw),
            "moe": (attn + moe, 2 * nw),
            "mamba": (mam, nw),
            "hybrid": (attn + ff + mam, 2 * nw),
            "alt_dense_moe": (attn + 3 * d * (self.d_ff_dense or self.d_ff)
                              + attn + moe, 4 * nw),
        }[self.block_pattern]
        if self.encoder is not None:
            norms += nw                                  # lnx (cross-attn)
        total = (per + norms) * self.n_blocks + nw       # + final_norm
        if self.embed_inputs or self.encoder is not None:
            total += self.padded_vocab * d      # embed
        if not self.tie_embeddings:
            total += d * self.padded_vocab      # head
        if self.encoder is not None:
            total += self.encoder.n_layers * (attn + ff + 2 * nw) + nw \
                + self.n_layers * attn          # encoder + dec cross-attn
        return total

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        moe_total = 3 * self.moe.n_experts * self.d_model * self.d_ff
        moe_active = 3 * self.moe.top_k * self.d_model * self.d_ff
        n_moe_layers = (self.n_blocks if self.block_pattern != "alt_dense_moe"
                        else self.n_blocks)
        return full - n_moe_layers * (moe_total - moe_active)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _norm_params(cfg, key):
    if cfg.norm_type == "rms":
        return {"w": jnp.ones((cfg.d_model,), cfg.dtype)}
    return {"w": jnp.ones((cfg.d_model,), cfg.dtype),
            "b": jnp.zeros((cfg.d_model,), cfg.dtype)}


def _init(key, shape, cfg, scale=0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(
        cfg.dtype)


def _attn_params(key, cfg: LMConfig):
    k = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.hd
    p = {"wq": _init(k[0], (d, cfg.n_heads * hd), cfg),
         "wk": _init(k[1], (d, cfg.n_kv_heads * hd), cfg),
         "wv": _init(k[2], (d, cfg.n_kv_heads * hd), cfg),
         "wo": _init(k[3], (cfg.n_heads * hd, d), cfg)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), cfg.dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), cfg.dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), cfg.dtype)
    return p


def _ff_params(key, cfg: LMConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    k = jax.random.split(key, 3)
    d = cfg.d_model
    if cfg.ff_type == "swiglu":
        return {"w1": _init(k[0], (d, d_ff), cfg),
                "w3": _init(k[1], (d, d_ff), cfg),
                "w2": _init(k[2], (d_ff, d), cfg)}
    return {"w1": _init(k[0], (d, d_ff), cfg),
            "b1": jnp.zeros((d_ff,), cfg.dtype),
            "w2": _init(k[1], (d_ff, d), cfg),
            "b2": jnp.zeros((d,), cfg.dtype)}


def _moe_params(key, cfg: LMConfig):
    assert cfg.moe is not None
    k = jax.random.split(key, 7)
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    p = {"router": _init(k[0], (d, E), cfg),
         "w1": _init(k[1], (E, d, f), cfg),
         "w3": _init(k[2], (E, d, f), cfg),
         "w2": _init(k[3], (E, f, d), cfg)}
    if cfg.moe.shared_expert:
        p.update({"sw1": _init(k[4], (d, f), cfg),
                  "sw3": _init(k[5], (d, f), cfg),
                  "sw2": _init(k[6], (f, d), cfg)})
    return p


def _mamba_params(key, cfg: LMConfig):
    assert cfg.ssm is not None
    s = cfg.ssm
    d = cfg.d_model
    di, r, n = s.inner(d), s.rank(d), s.d_state
    k = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": _init(k[0], (d, 2 * di), cfg),
        "conv_w": _init(k[1], (s.d_conv, di), cfg, scale=0.1),
        "conv_b": jnp.zeros((di,), cfg.dtype),
        "x_proj": _init(k[2], (di, r + 2 * n), cfg),
        "dt_proj": _init(k[3], (r, di), cfg),
        "dt_bias": jnp.full((di,), -4.0, cfg.dtype),  # softplus ~ small dt
        "A_log": jnp.log(A).astype(cfg.dtype),
        "D": jnp.ones((di,), cfg.dtype),
        "out_proj": _init(k[4], (di, d), cfg),
    }


def _block_params(key, cfg: LMConfig, kind: str):
    ks = jax.random.split(key, 8)
    if kind == "attn":
        return {"ln1": _norm_params(cfg, ks[0]),
                "attn": _attn_params(ks[1], cfg),
                "ln2": _norm_params(cfg, ks[2]),
                "mlp": _ff_params(ks[3], cfg)}
    if kind == "moe":
        return {"ln1": _norm_params(cfg, ks[0]),
                "attn": _attn_params(ks[1], cfg),
                "ln2": _norm_params(cfg, ks[2]),
                "moe": _moe_params(ks[3], cfg)}
    if kind == "mamba":
        return {"ln1": _norm_params(cfg, ks[0]),
                "mamba": _mamba_params(ks[1], cfg)}
    if kind == "hybrid":
        return {"ln1": _norm_params(cfg, ks[0]),
                "attn": _attn_params(ks[1], cfg),
                "mamba": _mamba_params(ks[2], cfg),
                "ln2": _norm_params(cfg, ks[3]),
                "mlp": _ff_params(ks[4], cfg)}
    if kind == "alt_dense_moe":
        return {"ln1a": _norm_params(cfg, ks[0]),
                "attn_a": _attn_params(ks[1], cfg),
                "ln2a": _norm_params(cfg, ks[2]),
                "mlp": _ff_params(ks[3], cfg, cfg.d_ff_dense),
                "ln1b": _norm_params(cfg, ks[4]),
                "attn_b": _attn_params(ks[5], cfg),
                "ln2b": _norm_params(cfg, ks[6]),
                "moe": _moe_params(ks[7], cfg)}
    if kind == "encdec":   # decoder block with cross-attention
        return {"ln1": _norm_params(cfg, ks[0]),
                "attn": _attn_params(ks[1], cfg),
                "lnx": _norm_params(cfg, ks[2]),
                "xattn": _attn_params(ks[3], cfg),
                "ln2": _norm_params(cfg, ks[4]),
                "mlp": _ff_params(ks[5], cfg)}
    raise ValueError(kind)


def _decoder_kind(cfg: LMConfig) -> str:
    if cfg.encoder is not None:
        return "encdec"
    return cfg.block_pattern


def init_lm(key, cfg: LMConfig):
    keys = jax.random.split(key, 6)
    params: dict = {}
    if cfg.embed_inputs or cfg.encoder is not None:
        params["embed"] = _init(keys[0], (cfg.padded_vocab, cfg.d_model),
                                cfg)
    kind = _decoder_kind(cfg)
    bkeys = jax.random.split(keys[1], cfg.n_blocks)
    params["blocks"] = jax.vmap(
        lambda k: _block_params(k, cfg, kind))(bkeys)
    params["final_norm"] = _norm_params(cfg, keys[2])
    if not cfg.tie_embeddings:
        params["head"] = _init(keys[3], (cfg.d_model, cfg.padded_vocab),
                               cfg)
    if cfg.encoder is not None:
        ekeys = jax.random.split(keys[4], cfg.encoder.n_layers)
        params["enc_blocks"] = jax.vmap(
            lambda k: _block_params(k, cfg, "attn"))(ekeys)
        params["enc_norm"] = _norm_params(cfg, keys[5])
    return params


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def _norm(x, p, cfg):
    if cfg.norm_type == "rms":
        return rms_norm(x, p["w"], cfg.norm_eps)
    return layer_norm(x, p["w"], p["b"], cfg.norm_eps)


def _qkv(x, p, cfg: LMConfig, positions):
    B, S, _ = x.shape
    q = qdense(x, p["wq"], cfg.quant, p.get("bq"))
    k = qdense(x, p["wk"], cfg.quant, p.get("bk"))
    v = qdense(x, p["wv"], cfg.quant, p.get("bv"))
    q = q.reshape(B, S, cfg.n_heads, cfg.hd)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.hd)
    if positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def _self_attn(x, p, cfg: LMConfig, positions, causal=True):
    B, S, _ = x.shape
    q, k, v = _qkv(x, p, cfg, positions)
    out = flash_attention(q, k, v, causal=causal, window=cfg.window,
                          bq=cfg.attn_chunk, bk=cfg.attn_chunk,
                          causal_skip=cfg.attn_causal_skip and causal)
    return qdense(out.reshape(B, S, -1), p["wo"], cfg.quant)


def _cross_attn(x, p, cfg: LMConfig, enc_kv):
    B, S, _ = x.shape
    q = qdense(x, p["wq"], cfg.quant).reshape(B, S, cfg.n_heads, cfg.hd)
    k, v = enc_kv
    out = flash_attention(q, k, v, causal=False, window=None,
                          bq=cfg.attn_chunk, bk=cfg.attn_chunk)
    return qdense(out.reshape(B, S, -1), p["wo"], cfg.quant)


def _mlp(x, p, cfg: LMConfig, d_ff=None):
    if cfg.ff_type == "swiglu":
        h = jax.nn.silu(qdense(x, p["w1"], cfg.quant)) * qdense(
            x, p["w3"], cfg.quant)
        return qdense(h, p["w2"], cfg.quant)
    h = jax.nn.gelu(qdense(x, p["w1"], cfg.quant, p["b1"]))
    return qdense(h, p["w2"], cfg.quant, p["b2"])


def _moe_apply(x, p, cfg: LMConfig):
    """MoE with DATA-LOCAL dispatch under a mesh.

    Routing/sort/scatter run per data shard via shard_map (partial-manual:
    the "model" axis stays auto so expert weights keep their EP sharding
    inside). A global dispatch makes GSPMD replicate the token sort and the
    (E*C, d) buffers on every device — 400+ GiB/device at 1M tokens.
    """
    from repro.dist import sharding as shd
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    mesh = shd.get_mesh()
    dp = tuple(a for a in ("pod", "data") if
               (mesh is not None and a in mesh.axis_names))
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if mesh is None or n_dp <= 1 or T % n_dp != 0 or T < 2 * n_dp:
        y, aux = moe_ff(xt, p, cfg.moe)
        return y.reshape(B, S, d), aux

    def local(xt_l, p_l):
        # token activations cross the boundary in bf16 (saved per layer as
        # scan residuals — f32 would double multi-GiB stacks); f32 compute
        # starts inside.
        y, aux = moe_ff(xt_l.astype(jnp.float32), p_l, cfg.moe)
        aux = {k: jax.lax.pmean(v, dp) for k, v in aux.items()}
        return y.astype(xt.dtype), aux

    # expert WEIGHTS must enter the manual region already f32: XLA 0.8's
    # CPU backend CHECK-fails ("invalid binary instruction opcode copy") on
    # the backward of bf16 weight tensors crossing a partial-manual
    # shard_map boundary. (The fsdp all-gather across "data" therefore
    # moves f32 — a known 2x on that link, revisit when the XLA bug dies.)
    p32 = jax.tree_util.tree_map(lambda w: w.astype(jnp.float32), p)
    y, aux = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None), P()),
        out_specs=(P(dp, None), P()),
        axis_names=set(dp), check_vma=False)(xt, p32)
    return y.reshape(B, S, d), aux


def _block(x, bp, cfg: LMConfig, positions, enc_kv=None):
    """One decoder block in train/prefill mode. Returns (x, aux)."""
    kind = _decoder_kind(cfg)
    aux = {}
    if kind == "attn":
        x = x + _self_attn(_norm(x, bp["ln1"], cfg), bp["attn"], cfg,
                           positions)
        x = x + _mlp(_norm(x, bp["ln2"], cfg), bp["mlp"], cfg)
    elif kind == "moe":
        x = x + _self_attn(_norm(x, bp["ln1"], cfg), bp["attn"], cfg,
                           positions)
        y, aux = _moe_apply(_norm(x, bp["ln2"], cfg), bp["moe"], cfg)
        x = x + y
    elif kind == "mamba":
        y, _ = mamba_mix(_norm(x, bp["ln1"], cfg), bp["mamba"], cfg.ssm,
                         cfg.d_model)
        x = x + y
    elif kind == "hybrid":
        h = _norm(x, bp["ln1"], cfg)
        att = _self_attn(h, bp["attn"], cfg, positions)
        ssm, _ = mamba_mix(h, bp["mamba"], cfg.ssm, cfg.d_model)
        x = x + 0.5 * (att + ssm)
        x = x + _mlp(_norm(x, bp["ln2"], cfg), bp["mlp"], cfg)
    elif kind == "alt_dense_moe":
        x = x + _self_attn(_norm(x, bp["ln1a"], cfg), bp["attn_a"], cfg,
                           positions)
        x = x + _mlp(_norm(x, bp["ln2a"], cfg), bp["mlp"], cfg,
                     cfg.d_ff_dense)
        x = x + _self_attn(_norm(x, bp["ln1b"], cfg), bp["attn_b"], cfg,
                           positions)
        y, aux = _moe_apply(_norm(x, bp["ln2b"], cfg), bp["moe"], cfg)
        x = x + y
    elif kind == "encdec":
        x = x + _self_attn(_norm(x, bp["ln1"], cfg), bp["attn"], cfg,
                           positions)
        x = x + _cross_attn(_norm(x, bp["lnx"], cfg), bp["xattn"], cfg,
                            enc_kv)
        x = x + _mlp(_norm(x, bp["ln2"], cfg), bp["mlp"], cfg)
    else:
        raise ValueError(kind)
    if cfg.act_shard:
        x = constrain(x, ("dp", "tp", None))
    return x, aux


def _positions(cfg: LMConfig, B: int, S: int, offset=0):
    """offset: scalar or per-batch (B,) int32 (per-slot decode positions)."""
    offset = jnp.asarray(offset, jnp.int32)
    if offset.ndim == 1:
        pos = jnp.arange(S, dtype=jnp.int32)[None] + offset[:, None]
    else:
        pos = jnp.arange(S, dtype=jnp.int32)[None] + offset
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[..., None],
                               (B, S, len(cfg.mrope_sections)))
    return pos


def _run_encoder(params, cfg: LMConfig, enc_embeds):
    """Encoder stack over precomputed frontend embeddings (B, S_enc, d)."""
    x = enc_embeds.astype(cfg.dtype)
    B, S, _ = x.shape
    pos = _positions(cfg, B, S)

    def body(x, bp):
        x = x + _self_attn(_norm(x, bp["ln1"], cfg), bp["attn"], cfg, pos,
                           causal=False)
        x = x + _mlp(_norm(x, bp["ln2"], cfg), bp["mlp"], cfg)
        if cfg.act_shard:
            x = constrain(x, ("dp", "tp", None))
        return x, None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn, x, params["enc_blocks"])
    return _norm(x, params["enc_norm"], cfg)


def _enc_kv(params_block, cfg, enc_out):
    """Precompute cross-attention K/V once per decode session / fwd pass."""
    B, S, _ = enc_out.shape
    k = qdense(enc_out, params_block["wk"], cfg.quant).reshape(
        B, S, cfg.n_kv_heads, cfg.hd)
    v = qdense(enc_out, params_block["wv"], cfg.quant).reshape(
        B, S, cfg.n_kv_heads, cfg.hd)
    return k, v


def embed_lookup(embed: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Embedding lookup.

    Untied tables are d-sharded (dist/sharding.py): the backward scatter-add
    then touches only the local d-slice and stays fully sharded.  (A
    vocab-sharded table's scatter gradient gets replicated by GSPMD —
    multi-GiB f32 (V, d) buffers; only the tied-embedding archs keep the
    vocab layout, where the table doubles as the CE head.)
    """
    return embed[tokens]


def forward_hidden(params, cfg: LMConfig, batch):
    """Backbone -> final-norm hidden states (B, S, d) + aux.

    batch: {"tokens": (B,S)} and/or {"embeds": (B,S,d)};
    enc-dec additionally {"enc_embeds": (B,S_enc,d)}.
    """
    if cfg.embed_inputs:
        x = embed_lookup(params["embed"], batch["tokens"]).astype(cfg.dtype)
    else:
        x = batch["embeds"].astype(cfg.dtype)
    B, S, _ = x.shape
    pos = _positions(cfg, B, S)
    if cfg.act_shard:
        x = constrain(x, ("dp", "tp", None))

    enc_out = None
    if cfg.encoder is not None:
        enc_out = _run_encoder(params, cfg, batch["enc_embeds"])

    def body(x, bp):
        if cfg.encoder is not None:
            ekv = _enc_kv(bp["xattn"], cfg, enc_out)
            x, aux = _block(x, bp, cfg, pos, enc_kv=ekv)
        else:
            x, aux = _block(x, bp, cfg, pos)
        lb = aux.get("lb_loss", jnp.zeros((), jnp.float32))
        return x, lb

    fn = jax.checkpoint(body) if cfg.remat else body
    x, lbs = jax.lax.scan(fn, x, params["blocks"])
    x = _norm(x, params["final_norm"], cfg)
    return x, {"lb_loss": lbs.mean() if cfg.n_blocks else 0.0}


def _head(params, cfg: LMConfig):
    return params["embed"].T if cfg.tie_embeddings else params["head"]


def forward(params, cfg: LMConfig, batch):
    """Eval forward -> full logits (B, S, padded_vocab). For small models /
    tests; the training path uses the chunked CE below and never
    materializes (B, S, V)."""
    x, aux = forward_hidden(params, cfg, batch)
    logits = qdense(x, _head(params, cfg), cfg.quant)
    if cfg.act_shard:
        logits = constrain(logits, ("dp", None, "tp"))
    return logits, aux


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token CE in f32; vocab axis may be sharded (psum'd LSE)."""
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(lf.max(-1, keepdims=True))
    lse = jnp.log(jnp.exp(lf - m).sum(-1, keepdims=True)) + m
    V = lf.shape[-1]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
              == targets[..., None])
    gold = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1, keepdims=True)
    return (lse - gold).mean()


def chunked_cross_entropy(hidden, head, targets, cfg: LMConfig,
                          chunk: int = 512) -> jnp.ndarray:
    """CE without materializing (B, S, V): scan over sequence chunks, the
    (B, chunk, V) logits live only inside a rematerialized scan body.

    The gold logit is extracted with an iota-compare masked reduce (never a
    gather) so the vocab axis stays sharded end to end.
    """
    B, S, d = hidden.shape
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    Sp = hidden.shape[1]
    n = Sp // chunk
    hc = hidden.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, n, chunk).transpose(1, 0, 2)
    valid = (jnp.arange(Sp) < S).reshape(n, 1, chunk)

    def body(carry, xs):
        h, t, ok = xs
        lf = qdense(h, head, cfg.quant).astype(jnp.float32)
        m = jax.lax.stop_gradient(lf.max(-1))
        lse = jnp.log(jnp.exp(lf - m[..., None]).sum(-1)) + m
        onehot = (jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
                  == t[..., None])
        gold = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
        return carry + jnp.sum(jnp.where(ok, lse - gold, 0.0)), None

    fn = jax.checkpoint(body) if cfg.remat else body
    total, _ = jax.lax.scan(fn, jnp.zeros((), jnp.float32), (hc, tc, valid))
    return total / (B * S)


def lm_loss(params, cfg: LMConfig, batch):
    """Next-token LM loss. batch needs "labels" (B, S) (== tokens for LM)."""
    hidden, aux = forward_hidden(params, cfg, batch)
    labels = batch.get("labels", batch.get("tokens"))
    loss = chunked_cross_entropy(hidden[:, :-1], _head(params, cfg),
                                 labels[:, 1:], cfg)
    if cfg.moe is not None:
        loss = loss + 0.01 * aux["lb_loss"]
    return loss, {"ce": loss, **aux}


# ---------------------------------------------------------------------------
# quantize-once serving artifact
# ---------------------------------------------------------------------------

# the sub-dicts / matrix names ``qdense`` weight-quantizes in-trace; MoE
# experts (``moe_ff``), mamba mixers and norms never quantize their
# weights, so packing must leave them untouched to stay bitwise identical
_QDENSE_BLOCK_KEYS = ("attn", "attn_a", "attn_b", "xattn", "mlp")
_QDENSE_MAT_KEYS = ("wq", "wk", "wv", "wo", "w1", "w2", "w3")


def pack_lm_serving(params, cfg: LMConfig):
    """(checkpoint, cfg) -> (packed params, serving cfg): quantize ONCE.

    Snaps every matrix that ``qdense`` would fake-quantize in-trace to the
    b-bit grid at pack time — per LAYER (a ``vmap`` over the stacked
    ``blocks`` leaves, matching the per-slice scales the scan body
    computes) — and returns a config whose ``quant.weights_prequantized``
    makes ``fq_weight`` the identity.  Tied embeddings are materialized
    into an explicit pre-snapped ``head`` (the float ``embed`` table keeps
    serving the lookup path untouched).  Bitwise identical to the per-call
    quantization it replaces; a no-op when quantization is off.
    """
    q = cfg.quant
    if not q.enabled or q.weights_prequantized:
        return params, cfg
    snap = jax.jit(lambda w: fq_weight(w, q))
    snap_stacked = jax.jit(jax.vmap(lambda w: fq_weight(w, q)))

    def snap_blocks(blocks):
        out = dict(blocks)
        for bk in _QDENSE_BLOCK_KEYS:
            if bk in blocks:
                sub = dict(blocks[bk])
                for mk in _QDENSE_MAT_KEYS:
                    if mk in sub:
                        sub[mk] = snap_stacked(sub[mk])
                out[bk] = sub
        return out

    packed = dict(params)
    packed["blocks"] = snap_blocks(params["blocks"])
    if "enc_blocks" in params:
        packed["enc_blocks"] = snap_blocks(params["enc_blocks"])
    if cfg.tie_embeddings:
        packed["head"] = snap(params["embed"].T)
        cfg = dataclasses.replace(cfg, tie_embeddings=False)
    elif "head" in params:
        packed["head"] = snap(params["head"])
    return packed, dataclasses.replace(cfg, quant=q.as_prequantized())
