"""Prefill + single-token decode with per-family caches.

Cache kinds (leading n_blocks dim, scanned together with the block params):
  attn / moe          : {"k","v"} (B, L, Kv, hd)    L = max_len or SWA window
  mamba               : {"h"} (B, di, n), {"conv"} (B, K-1, di)   O(1) state
  hybrid (hymba)      : attn ∪ mamba caches
  alt_dense_moe       : two attn caches (sublayers a, b)
  encdec (seamless)   : self {"k","v"} + fixed cross {"xk","xv"}

SWA uses a ring buffer of size ``window`` — this is what makes
``long_500k`` decodable for h2o-danube/hymba with O(window) memory, and the
SSM state is what makes it O(1) for falcon-mamba (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import qdense
from repro.dist.sharding import constrain
from repro.models import lm as lm_lib
from repro.models.layers import (decode_attention, mamba_mix,
                                 paged_decode_attention)
from repro.models.lm import (LMConfig, _block, _enc_kv, _mlp, _moe_apply,
                             _norm, _positions, _qkv, _run_encoder,
                             _self_attn)


def cache_len(cfg: LMConfig, max_len: int) -> int:
    return min(cfg.window, max_len) if cfg.window else max_len


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------

def _attn_cache(cfg: LMConfig, B: int, L: int, prefix=""):
    shape = (B, L, cfg.n_kv_heads, cfg.hd)
    return {prefix + "k": jnp.zeros(shape, cfg.dtype),
            prefix + "v": jnp.zeros(shape, cfg.dtype)}


def _mamba_cache(cfg: LMConfig, B: int):
    di = cfg.ssm.inner(cfg.d_model)
    return {"h": jnp.zeros((B, di, cfg.ssm.d_state), jnp.float32),
            "conv": jnp.zeros((B, cfg.ssm.d_conv - 1, di), cfg.dtype)}


def _block_cache(cfg: LMConfig, B: int, L: int, enc_len: int = 0):
    kind = lm_lib._decoder_kind(cfg)
    if kind in ("attn", "moe"):
        return _attn_cache(cfg, B, L)
    if kind == "mamba":
        return _mamba_cache(cfg, B)
    if kind == "hybrid":
        return {**_attn_cache(cfg, B, L), **_mamba_cache(cfg, B)}
    if kind == "alt_dense_moe":
        return {**_attn_cache(cfg, B, L, "a_"), **_attn_cache(cfg, B, L, "b_")}
    if kind == "encdec":
        c = _attn_cache(cfg, B, L)
        c["xk"] = jnp.zeros((B, enc_len, cfg.n_kv_heads, cfg.hd), cfg.dtype)
        c["xv"] = jnp.zeros((B, enc_len, cfg.n_kv_heads, cfg.hd), cfg.dtype)
        return c
    raise ValueError(kind)


def init_cache(cfg: LMConfig, B: int, max_len: int, enc_len: int = 0):
    """pos is PER-SLOT (B,) so continuous batching can admit requests into
    individual lanes while others keep decoding."""
    L = cache_len(cfg, max_len)
    blocks = jax.vmap(lambda _: _block_cache(cfg, B, L, enc_len))(
        jnp.arange(cfg.n_blocks))
    return {"blocks": blocks, "pos": jnp.zeros((B,), jnp.int32)}


def init_paged_cache(cfg: LMConfig, B: int, n_kv_blocks: int,
                     block_size: int):
    """Paged KV cache: ONE pooled arena of fixed-size blocks per layer.

    Instead of a dense per-lane (B, L, Kv, hd) ring, every layer holds a
    (n_kv_blocks, block_size, Kv, hd) arena; which blocks a lane owns (and
    in what order) lives OUTSIDE the trace in the engine's block tables.
    Total KV memory is ``n_kv_blocks * block_size`` tokens shared by all
    lanes — lane count decouples from max context (the vLLM layout,
    SNIPPETS.md snippets 1-2).

    Only plain attention stacks page (kinds "attn"/"moe"); sliding-window
    configs keep the dense ring (the window wrap IS the intended layout)
    and SSM/hybrid state is O(1) per lane already.
    """
    kind = lm_lib._decoder_kind(cfg)
    if kind not in ("attn", "moe"):
        raise ValueError(
            f"paged KV cache supports attention decoders only, not "
            f"{kind!r} (SSM/hybrid state is O(1) per lane; use the dense "
            "cache)")
    if cfg.window:
        raise ValueError(
            "paged KV cache does not apply to sliding-window configs; "
            "the dense ring (cache_len = min(window, max_len)) is the "
            "intended layout there")

    def one_layer(_):
        shape = (n_kv_blocks, block_size, cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(shape, cfg.dtype),
                "v": jnp.zeros(shape, cfg.dtype)}

    blocks = jax.vmap(one_layer)(jnp.arange(cfg.n_blocks))
    return {"blocks": blocks, "pos": jnp.zeros((B,), jnp.int32)}


def _shard_cache(cache, cfg):
    if not cfg.act_shard:
        return cache

    def f(x):
        if x.ndim == 5:     # (layers, B, L, Kv, hd)
            return constrain(x, (None, "dp", None, "tp", None))
        if x.ndim == 4:     # (layers, B, di, n) or (layers, B, K-1, di)
            return constrain(x, (None, "dp", None, "tp")) \
                if x.shape[-1] > x.shape[-2] else \
                constrain(x, (None, "dp", "tp", None))
        return x

    blocks = jax.tree_util.tree_map(f, cache["blocks"])
    return {"blocks": blocks, "pos": cache["pos"]}


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def _ring_write(k: jnp.ndarray, L: int) -> jnp.ndarray:
    """Write a (B, S, Kv, hd) prefix into an L-slot ring (slot = pos % L)."""
    B, S, Kv, hd = k.shape
    buf = jnp.zeros((B, L, Kv, hd), k.dtype)
    if S <= L:
        return buf.at[:, :S].set(k)
    tail = k[:, -L:]
    slots = (jnp.arange(S - L, S)) % L
    return buf.at[:, slots].set(tail)


def _prefill_attn(x, bp, cfg, pos, L):
    """Self-attention sublayer that also emits its KV cache."""
    B, S, _ = x.shape
    h = x
    q, k, v = _qkv(h, bp, cfg, pos)
    out = lm_lib.flash_attention(q, k, v, causal=True, window=cfg.window,
                                 bq=cfg.attn_chunk, bk=cfg.attn_chunk,
                                 causal_skip=cfg.attn_causal_skip)
    out = qdense(out.reshape(B, S, -1), bp["wo"], cfg.quant)
    return out, {"k": _ring_write(k, L), "v": _ring_write(v, L)}


def prefill(params, cfg: LMConfig, batch, max_len: int,
            last_only: bool = True):
    """Full-sequence forward that returns (logits, cache ready for decode).

    ``last_only`` (production default) emits only the last position's
    logits — materializing (B, S, V) at 32k prefill is ~20 GB/device of
    pure waste when the server only samples the next token.
    """
    if cfg.embed_inputs:
        x = params["embed"][batch["tokens"]].astype(cfg.dtype)
    else:
        x = batch["embeds"].astype(cfg.dtype)
    B, S, _ = x.shape
    L = cache_len(cfg, max_len)
    pos = _positions(cfg, B, S)
    kind = lm_lib._decoder_kind(cfg)
    if cfg.act_shard:
        x = constrain(x, ("dp", "tp", None))

    enc_out = None
    if cfg.encoder is not None:
        enc_out = _run_encoder(params, cfg, batch["enc_embeds"])

    def body(x, bp):
        cache = {}
        if kind in ("attn", "moe"):
            y, kv = _prefill_attn(_norm(x, bp["ln1"], cfg), bp["attn"], cfg,
                                  pos, L)
            x = x + y
            cache.update(kv)
            if kind == "attn":
                x = x + _mlp(_norm(x, bp["ln2"], cfg), bp["mlp"], cfg)
            else:
                y, _ = _moe_apply(_norm(x, bp["ln2"], cfg), bp["moe"], cfg)
                x = x + y
        elif kind == "mamba":
            y, st = mamba_mix(_norm(x, bp["ln1"], cfg), bp["mamba"], cfg.ssm,
                              cfg.d_model)
            x = x + y
            cache.update(st)
        elif kind == "hybrid":
            h = _norm(x, bp["ln1"], cfg)
            att, kv = _prefill_attn(h, bp["attn"], cfg, pos, L)
            ssm, st = mamba_mix(h, bp["mamba"], cfg.ssm, cfg.d_model)
            x = x + 0.5 * (att + ssm)
            x = x + _mlp(_norm(x, bp["ln2"], cfg), bp["mlp"], cfg)
            cache.update(kv)
            cache.update(st)
        elif kind == "alt_dense_moe":
            y, kva = _prefill_attn(_norm(x, bp["ln1a"], cfg), bp["attn_a"],
                                   cfg, pos, L)
            x = x + y
            x = x + _mlp(_norm(x, bp["ln2a"], cfg), bp["mlp"], cfg,
                         cfg.d_ff_dense)
            y, kvb = _prefill_attn(_norm(x, bp["ln1b"], cfg), bp["attn_b"],
                                   cfg, pos, L)
            x = x + y
            y, _ = _moe_apply(_norm(x, bp["ln2b"], cfg), bp["moe"], cfg)
            x = x + y
            cache.update({"a_" + n: t for n, t in kva.items()})
            cache.update({"b_" + n: t for n, t in kvb.items()})
        elif kind == "encdec":
            y, kv = _prefill_attn(_norm(x, bp["ln1"], cfg), bp["attn"], cfg,
                                  pos, L)
            x = x + y
            cache.update(kv)
            xk, xv = _enc_kv(bp["xattn"], cfg, enc_out)
            x = x + lm_lib._cross_attn(_norm(x, bp["lnx"], cfg), bp["xattn"],
                                       cfg, (xk, xv))
            x = x + _mlp(_norm(x, bp["ln2"], cfg), bp["mlp"], cfg)
            cache.update({"xk": xk, "xv": xv})
        else:
            raise ValueError(kind)
        if cfg.act_shard:
            x = constrain(x, ("dp", "tp", None))
        return x, cache

    fn = jax.checkpoint(body) if cfg.remat else body
    x, caches = jax.lax.scan(fn, x, params["blocks"])
    x = _norm(x, params["final_norm"], cfg)
    if last_only:
        x = x[:, -1:]
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = qdense(x, head, cfg.quant)
    cache = {"blocks": caches, "pos": jnp.full((B,), S, jnp.int32)}
    return logits, _shard_cache(cache, cfg)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _decode_attn(x, bp, cfg: LMConfig, cache, prefix, p, active):
    """One-token self-attention against the ring cache.

    x: (B, 1, d); p: (B,) per-slot positions; active: (B,) bool — inactive
    lanes neither write their KV (write slot is dropped) nor advance.
    """
    B = x.shape[0]
    k_c, v_c = cache[prefix + "k"], cache[prefix + "v"]
    L = k_c.shape[1]
    pos = _positions(cfg, B, 1, offset=p)
    q, k, v = _qkv(x, bp, cfg, pos)
    slot = jnp.where(active, p % L, L)            # L => dropped write
    k_c = k_c.at[jnp.arange(B), slot].set(k[:, 0], mode="drop")
    v_c = v_c.at[jnp.arange(B), slot].set(v[:, 0], mode="drop")
    n_valid = jnp.minimum(p + 1, L)
    valid = jnp.arange(L)[None] < n_valid[:, None]
    out = decode_attention(q, k_c, v_c, valid)
    out = qdense(out.reshape(B, 1, -1), bp["wo"], cfg.quant)
    return out, {prefix + "k": k_c, prefix + "v": v_c}


def _decode_attn_paged(x, bp, cfg: LMConfig, cache, p, active,
                       block_tables):
    """One-token self-attention against the paged block arena.

    x: (B, 1, d); cache holds per-layer arenas {"k","v"} of shape
    (N, bs, Kv, hd); block_tables: (B, nb) int32 — lane i's logical block
    j lives at arena row ``block_tables[i, j]`` (unallocated entries are
    clipped to 0 by the engine and masked by ``n_valid``).

    The per-step ``slot_mapping`` is derived IN-TRACE from (pos,
    block_tables): token position p writes arena slot
    ``block_tables[i, p // bs] * bs + p % bs``.  Inactive lanes map to the
    out-of-range slot N*bs, which ``mode="drop"`` turns into a no-op —
    the same freeze contract as the dense ring.
    """
    B = x.shape[0]
    k_a, v_a = cache["k"], cache["v"]
    N, bs, Kv, hd = k_a.shape
    pos = _positions(cfg, B, 1, offset=p)
    q, k, v = _qkv(x, bp, cfg, pos)
    blk = jnp.take_along_axis(block_tables, (p // bs)[:, None], axis=1)[:, 0]
    slot = jnp.where(active, blk * bs + p % bs, N * bs)   # N*bs => dropped
    k_a = k_a.reshape(N * bs, Kv, hd).at[slot].set(
        k[:, 0], mode="drop").reshape(N, bs, Kv, hd)
    v_a = v_a.reshape(N * bs, Kv, hd).at[slot].set(
        v[:, 0], mode="drop").reshape(N, bs, Kv, hd)
    out = paged_decode_attention(q, k_a, v_a, block_tables, p + 1)
    out = qdense(out.reshape(B, 1, -1), bp["wo"], cfg.quant)
    return out, {"k": k_a, "v": v_a}


def lm_stage_boundaries() -> Tuple[str, ...]:
    """The LM decode step's declared sharding stage boundaries.

    Single source of truth for ``repro.analysis``: each name must appear
    as a ``stage:<name>`` scope on a sharding constraint in the meshed
    serving trace of ``decode_step`` (both dense and paged).  The step
    batch shards lane-major over "dp" — mirroring
    ``models.basecaller.serving_stage_boundaries``.
    """
    return ("lm_embed", "lm_logits")


def decode_step(params, cfg: LMConfig, cache, tokens=None, embeds=None,
                active=None, block_tables=None):
    """One decoding step for the whole batch.

    tokens: (B,) int32 (or embeds (B, 1, d) for stub-frontend archs).
    active: optional (B,) bool — continuous batching lane mask.
    block_tables: optional (B, nb) int32 — selects the PAGED cache layout
        (cache from ``init_paged_cache``; attention gathers K/V through
        the table instead of a per-lane ring).
    Returns (logits (B, vocab), new cache).
    """
    if tokens is not None:
        x = params["embed"][tokens][:, None].astype(cfg.dtype)
    else:
        x = embeds.astype(cfg.dtype)
    B = x.shape[0]
    # declared dp boundary: under an ambient mesh the step batch shards
    # lane-major (engines keep B = batch_slots * dp); a no-op otherwise
    with jax.named_scope("stage:lm_embed"):
        x = constrain(x, ("dp", None, None))
    if active is None:
        active = jnp.ones((B,), bool)
    p = cache["pos"]
    kind = lm_lib._decoder_kind(cfg)
    if block_tables is not None and kind not in ("attn", "moe"):
        raise ValueError(f"paged decode supports attention decoders only, "
                         f"not {kind!r}")

    def keep(new, old):
        """Mask recurrent-state updates for inactive lanes."""
        ex = (slice(None),) + (None,) * (new.ndim - 1)
        return jnp.where(active[ex], new, old)

    def body(x, bp_cache):
        bp, bc = bp_cache
        new_c = dict(bc)
        if kind in ("attn", "moe"):
            if block_tables is not None:
                y, kv = _decode_attn_paged(_norm(x, bp["ln1"], cfg),
                                           bp["attn"], cfg, bc, p, active,
                                           block_tables)
            else:
                y, kv = _decode_attn(_norm(x, bp["ln1"], cfg), bp["attn"],
                                     cfg, bc, "", p, active)
            x = x + y
            new_c.update(kv)
            if kind == "attn":
                x = x + _mlp(_norm(x, bp["ln2"], cfg), bp["mlp"], cfg)
            else:
                y, _ = _moe_apply(_norm(x, bp["ln2"], cfg), bp["moe"], cfg)
                x = x + y
        elif kind == "mamba":
            y, st = mamba_mix(_norm(x, bp["ln1"], cfg), bp["mamba"], cfg.ssm,
                              cfg.d_model,
                              state={"h": bc["h"], "conv": bc["conv"]})
            x = x + y
            new_c.update({k_: keep(v_, bc[k_]) for k_, v_ in st.items()})
        elif kind == "hybrid":
            h = _norm(x, bp["ln1"], cfg)
            att, kv = _decode_attn(h, bp["attn"], cfg, bc, "", p, active)
            ssm, st = mamba_mix(h, bp["mamba"], cfg.ssm, cfg.d_model,
                                state={"h": bc["h"], "conv": bc["conv"]})
            x = x + 0.5 * (att + ssm)
            x = x + _mlp(_norm(x, bp["ln2"], cfg), bp["mlp"], cfg)
            new_c.update(kv)
            new_c.update({k_: keep(v_, bc[k_]) for k_, v_ in st.items()})
        elif kind == "alt_dense_moe":
            y, kva = _decode_attn(_norm(x, bp["ln1a"], cfg), bp["attn_a"],
                                  cfg, bc, "a_", p, active)
            x = x + y
            x = x + _mlp(_norm(x, bp["ln2a"], cfg), bp["mlp"], cfg,
                         cfg.d_ff_dense)
            y, kvb = _decode_attn(_norm(x, bp["ln1b"], cfg), bp["attn_b"],
                                  cfg, bc, "b_", p, active)
            x = x + y
            y, _ = _moe_apply(_norm(x, bp["ln2b"], cfg), bp["moe"], cfg)
            x = x + y
            new_c.update(kva)
            new_c.update(kvb)
        elif kind == "encdec":
            y, kv = _decode_attn(_norm(x, bp["ln1"], cfg), bp["attn"], cfg,
                                 bc, "", p, active)
            x = x + y
            new_c.update(kv)
            B_ = x.shape[0]
            enc_valid = jnp.ones((B_, bc["xk"].shape[1]), bool)
            q = qdense(_norm(x, bp["lnx"], cfg), bp["xattn"]["wq"],
                       cfg.quant).reshape(B_, 1, cfg.n_heads, cfg.hd)
            att = decode_attention(q, bc["xk"], bc["xv"], enc_valid)
            x = x + qdense(att.reshape(B_, 1, -1), bp["xattn"]["wo"],
                           cfg.quant)
            x = x + _mlp(_norm(x, bp["ln2"], cfg), bp["mlp"], cfg)
        else:
            raise ValueError(kind)
        return x, new_c

    if cfg.scan_layers:
        x, new_blocks = jax.lax.scan(body, x, (params["blocks"],
                                               cache["blocks"]))
    else:
        # §Perf H3: python-unrolled decode layers — per-layer cache slices
        # update in place via .at[i].set (XLA aliases the donated buffers,
        # where the while-loop form double-buffers the whole cache)
        new_blocks = cache["blocks"]
        for i in range(cfg.n_blocks):
            bp_i = jax.tree_util.tree_map(lambda t: t[i], params["blocks"])
            bc_i = jax.tree_util.tree_map(lambda t: t[i], cache["blocks"])
            x, nc_i = body(x, (bp_i, bc_i))
            new_blocks = jax.tree_util.tree_map(
                lambda full, new: full.at[i].set(new), new_blocks, nc_i)
    x = _norm(x, params["final_norm"], cfg)
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = qdense(x[:, 0], head, cfg.quant)
    with jax.named_scope("stage:lm_logits"):
        logits = constrain(logits, ("dp", None))
    new_cache = {"blocks": new_blocks,
                 "pos": jnp.where(active, p + 1, p)}
    return logits, _shard_cache(new_cache, cfg)
