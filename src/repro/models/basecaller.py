"""DNN base-callers (paper Table 3): Conv -> GRU/LSTM stack -> FC -> CTC.

Guppy / Scrappie / Chiron are instances of one configurable family:
convolutional feature extraction over the raw current signal, a recurrent
stack integrating those features into base probabilities, and a linear head
over [A, C, G, T, blank].

All projections route through ``core.quant.qdense`` so a single
``QuantConfig`` turns the whole model into its FQN-style fake-quantized twin
(the serving engine swaps these matmuls for the ``quant_matmul`` Pallas
kernel).  Parameters are plain pytrees; ``init_basecaller``/
``apply_basecaller`` are the public API, plus the train-vs-serve split:
``pack_basecaller`` builds the quantize-once ``PackedParams`` serving
artifact (weights pre-quantized, zero weight-quant ops in the serving
trace) that ``apply_basecaller`` accepts polymorphically.

Note on Table 3: the paper's MAC/param numbers are internally inconsistent
(see DESIGN.md §8); presets reproduce the stated *structures* and
``benchmarks/table3_models.py`` reports our computed counts next to the
paper's.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant as quant_lib
from repro.core.quant import (QuantConfig, fake_quant, fq_act, fq_weight,
                              qdense)
from repro.dist.sharding import constrain
from repro.kernels.registry import Backend

N_BASES = 4
N_CLASSES = 5  # A C G T blank
BLANK = 4


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    kernel: int
    channels: int
    stride: int = 1


@dataclasses.dataclass(frozen=True)
class BasecallerConfig:
    name: str = "guppy"
    input_len: int = 300            # signal window (paper: 300 x 1)
    in_channels: int = 1
    conv: Tuple[ConvSpec, ...] = (ConvSpec(11, 96, 2),)
    rnn_type: str = "gru"           # "gru" | "lstm"
    rnn_layers: int = 5
    rnn_hidden: int = 96
    rnn_direction: str = "alt"      # "uni" | "bidi" | "alt"
    n_classes: int = N_CLASSES
    quant: QuantConfig = QuantConfig()

    @property
    def output_len(self) -> int:
        return self.output_frames(self.input_len)

    def output_frames(self, samples):
        """Output frames covering ``samples`` input samples (int or array).

        The conv stack's "SAME" ceil-div downsampling, applied per stage —
        this maps a window's valid-sample count to the decoder's
        ``logit_length`` so zero-padded tails are not decoded.
        """
        t = samples
        for c in self.conv:
            t = -(-t // c.stride)  # ceil div ("SAME" padding)
        return t

    def with_quant(self, q: QuantConfig) -> "BasecallerConfig":
        return dataclasses.replace(self, quant=q)


# presets approximating paper Table 3 structures
GUPPY = BasecallerConfig(
    name="guppy", conv=(ConvSpec(11, 96, 2),),
    rnn_type="gru", rnn_layers=5, rnn_hidden=96, rnn_direction="alt")
SCRAPPIE = BasecallerConfig(
    name="scrappie", conv=(ConvSpec(11, 96, 5),),
    rnn_type="gru", rnn_layers=5, rnn_hidden=64, rnn_direction="alt")
CHIRON = BasecallerConfig(
    name="chiron",
    conv=tuple([ConvSpec(1, 256, 1)] +
               [s for _ in range(5) for s in
                (ConvSpec(1, 256, 1), ConvSpec(3, 256, 1), ConvSpec(1, 256, 1))]),
    rnn_type="lstm", rnn_layers=3, rnn_hidden=100, rnn_direction="bidi")

PRESETS = {"guppy": GUPPY, "scrappie": SCRAPPIE, "chiron": CHIRON}


def tiny_preset(name: str = "guppy") -> BasecallerConfig:
    """Reduced config for CPU tests: same family, small widths."""
    base = PRESETS[name]
    conv = tuple(ConvSpec(c.kernel, 16, c.stride) for c in base.conv[:2])
    return dataclasses.replace(base, input_len=120, conv=conv,
                               rnn_layers=2, rnn_hidden=16)


def demo_preset(name: str = "guppy") -> BasecallerConfig:
    """CPU-trainable demo config: learns a 1-mer pore channel to ~70 %
    read accuracy in ~300 steps (examples/, benchmarks/fig21)."""
    base = PRESETS[name]
    return dataclasses.replace(base, input_len=120,
                               conv=(ConvSpec(9, 24, 2),),
                               rnn_layers=2, rnn_hidden=32)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _dense_init(key, shape, scale=None):
    scale = scale or (1.0 / jnp.sqrt(shape[0]))
    return jax.random.normal(key, shape, jnp.float32) * scale


def init_basecaller(key, cfg: BasecallerConfig):
    keys = jax.random.split(key, 2 + len(cfg.conv) + cfg.rnn_layers)
    params = {"conv": [], "rnn": [], "fc": None}
    cin = cfg.in_channels
    for i, spec in enumerate(cfg.conv):
        k = keys[i]
        w = _dense_init(k, (spec.kernel, cin, spec.channels),
                        1.0 / jnp.sqrt(spec.kernel * cin))
        params["conv"].append({"w": w, "b": jnp.zeros((spec.channels,))})
        cin = spec.channels

    gates = 3 if cfg.rnn_type == "gru" else 4
    h = cfg.rnn_hidden
    feat = cin
    for i in range(cfg.rnn_layers):
        k1, k2 = jax.random.split(keys[len(cfg.conv) + i])
        layer_in = feat if i == 0 else (
            2 * h if cfg.rnn_direction == "bidi" else h)
        params["rnn"].append({
            "w": _dense_init(k1, (layer_in, gates * h)),
            "u": _dense_init(k2, (h, gates * h)),
            "b": jnp.zeros((gates * h,)),
        })
    head_in = 2 * h if cfg.rnn_direction == "bidi" else h
    params["fc"] = {"w": _dense_init(keys[-1], (head_in, cfg.n_classes)),
                    "b": jnp.zeros((cfg.n_classes,))}
    return params


# ---------------------------------------------------------------------------
# packed serving artifact
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedParams:
    """The quantize-once serving artifact for one base-caller.

    Built ONCE by ``pack_basecaller`` from a float training checkpoint;
    every weight arrives at the jitted serving trace already on the b-bit
    grid, so the trace contains zero weight-quantization ops (only
    activation packing + the registry's integer kernels):

      conv : [{"w"  (K, Cin, Cout) pre-fake-quantized fp32, "b"}]
      rnn  : [{"wq" (F, gates*H) int8, "sw" (1, gates*H) fp32,
               "u"  (H, gates*H) pre-snapped fp32 (fused-kernel / recurrent
               fake-quant path consumes it as-is), "b"}]
      fc   : {"wq" int8, "sw" fp32, "b"}

    With quantization disabled the matrices stay plain fp32 under "w".
    A registered pytree, so it rides through ``jax.jit`` like any params
    tree — its distinct treedef keeps packed and float traces separate.
    """
    conv: list
    rnn: list
    fc: dict

    def tree_flatten(self):
        return ((self.conv, self.rnn, self.fc), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    def as_tree(self) -> dict:
        return {"conv": self.conv, "rnn": self.rnn, "fc": self.fc}


def _pack_matrix(w, q: QuantConfig) -> dict:
    if not q.enabled:
        return {"w": w}
    wq, sw = quant_lib.pack_weight(w, q.bits_w)
    return {"wq": wq, "sw": sw}


@functools.partial(jax.jit, static_argnames="cfg")
def pack_basecaller(params, cfg: BasecallerConfig) -> PackedParams:
    """Float checkpoint -> packed serving artifact (quantize ONCE).

    Uses the exact quantizers the per-call serving path used in-trace
    (``pack_weight`` for integer projections, ``fq_weight`` for conv and
    recurrent matrices), so ``apply_basecaller(packed, ...)`` is bitwise
    identical to the old repack-per-call path on every backend.

    Jitted on purpose — not for speed, for BITS: inside jit the b-bit grid
    divisor is a trace constant and XLA folds it exactly as it did inside
    the per-call serving trace; op-by-op eager execution constant-folds
    differently and drifts the low bit of the scales.
    """
    q = cfg.quant
    conv = [{"w": fq_weight(p["w"], q), "b": p["b"]} for p in params["conv"]]
    rnn = [dict(_pack_matrix(p["w"], q), u=fq_weight(p["u"], q), b=p["b"])
           for p in params["rnn"]]
    fc = dict(_pack_matrix(params["fc"]["w"], q), b=params["fc"]["b"])
    return PackedParams(conv=conv, rnn=rnn, fc=fc)


def is_packed(params) -> bool:
    return isinstance(params, PackedParams)


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def _qdense_backend(x, layer, q: QuantConfig, backend: Backend,
                    b: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Dense projection on the integer serving path.

    ``layer`` is one projection's weights: ``{"wq", "sw"}`` pre-packed
    codes from the serving artifact, or ``{"w"}`` float (packed on the fly
    — the legacy repack-per-call path).  With quantization enabled the
    matmul runs as int8-container codes on the registry's ``quant_matmul``
    op (the paper's NVM dot-product engine on the MXU); otherwise it is a
    plain fp matmul.  Inference-only: the packed-integer path has no STE
    gradients.

    Activations carry PER-ROW scales (folded into the epilogue outside the
    kernel, whose dequant wants a scalar) so each example's numerics are
    independent of who else shares the batch — the continuous-batching
    engine and the fixed-batch pipeline then agree bit for bit.
    """
    if q.enabled:
        if "wq" in layer:                    # quantize-once artifact
            from repro.kernels.quant_matmul import ops as qmm_ops
            y = qmm_ops.qmm_packed(x, layer["wq"], layer["sw"],
                                   bits_a=q.bits_a, backend=backend.mode)
        else:                                # legacy repack-per-call
            lead, F = x.shape[:-1], x.shape[-1]
            x2 = x.reshape(-1, F)
            xq, sx = quant_lib.pack_act_rows(x2, q.bits_a)   # (M,1) scales
            wq, sw = quant_lib.pack_weight(layer["w"], q.bits_w)
            one = jnp.ones((1, 1), jnp.float32)
            y = (backend.op("quant_matmul")(xq, wq, one, sw) * sx) \
                .reshape(lead + (wq.shape[-1],))
    else:
        y = x @ layer["w"]
    return y if b is None else y + b


def _conv1d(x, w, b, stride, q: QuantConfig, per_example: bool = False):
    """x: (B, T, C) 'SAME' conv with quantization-aware weights/acts.

    ``per_example`` scales activations per batch row (serving path — see
    ``_qdense_backend``); training keeps the FQN per-tensor scale.
    """
    if per_example and q.enabled:
        xq = fake_quant(x, q.bits_a, axis=(1, 2))
    else:
        xq = fq_act(x, q)
    wq = fq_weight(w, q)
    y = jax.lax.conv_general_dilated(
        xq, wq, window_strides=(stride,), padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"))
    return y + b


def gru_cell(h, x_proj, u, b, q: QuantConfig):
    """One GRU step given the precomputed input projection x_proj=(B,3h)."""
    hdim = h.shape[-1]
    gates = qdense(h, u, q) + x_proj + b
    z = jax.nn.sigmoid(gates[..., :hdim])
    r = jax.nn.sigmoid(gates[..., hdim:2 * hdim])
    # candidate uses r ⊗ h inside the U product (Eq. 1) — recompute that slice
    n_x = x_proj[..., 2 * hdim:] + b[2 * hdim:]
    n_h = qdense(r * h, u[:, 2 * hdim:], q)
    h_new = jax.nn.tanh(n_x + n_h)
    return z * h + (1.0 - z) * h_new


def lstm_cell(state, x_proj, u, b, q: QuantConfig):
    h, c = state
    hdim = h.shape[-1]
    gates = qdense(h, u, q) + x_proj + b
    i = jax.nn.sigmoid(gates[..., :hdim])
    f = jax.nn.sigmoid(gates[..., hdim:2 * hdim] + 1.0)  # forget bias 1
    g = jax.nn.tanh(gates[..., 2 * hdim:3 * hdim])
    o = jax.nn.sigmoid(gates[..., 3 * hdim:])
    c_new = f * c + i * g
    return (o * jax.nn.tanh(c_new), c_new)


def init_rnn_state(cfg: BasecallerConfig, batch: int):
    """Zero chunk-boundary recurrent state: one entry per RNN layer.

    GRU layers carry ``(B, H)`` hidden state; LSTM layers carry an
    ``((B, H), (B, H))`` (h, c) pair.  Feeding this to
    ``apply_basecaller(..., rnn_state=...)`` is exactly the cold start
    every whole-window call performs implicitly.
    """
    z = jnp.zeros((batch, cfg.rnn_hidden))
    if cfg.rnn_type == "gru":
        return [z for _ in range(cfg.rnn_layers)]
    return [(z, z) for _ in range(cfg.rnn_layers)]


def _run_rnn(x, layer, cfg: BasecallerConfig, reverse: bool,
             backend: Optional[Backend] = None, fused_rnn: bool = True,
             h0=None, return_h: bool = False):
    """x: (B, T, F) -> (B, T, H). Input projection hoisted out of the scan.

    With a ``backend``, the input projection runs on the integer
    ``quant_matmul`` op and the GRU hot loop on the persistent ``gru_seq``
    kernel — the whole layer/direction walk in ONE launch, hidden state
    and recurrent weights resident in VMEM across timesteps.
    ``fused_rnn=False`` keeps the per-step ``gru_cell``-under-``lax.scan``
    path (one launch per timestep), which serves as the differential
    oracle for the persistent walk and the only serving path for LSTM;
    both are bitwise identical per backend.  Without a backend it is the
    differentiable fake-quant training path.

    ``h0``/``return_h`` expose the walk's state-in/state-out contract
    (``gru_seq`` already takes h0 explicitly; the scans' carry is the
    final state): running ``[T1; T2]`` whole is bitwise identical to
    running ``T1`` then ``T2`` with the state handed over — the
    chunk-boundary contract streaming sessions rely on.  Forward
    (``reverse=False``) only: a reversed walk's "final" state belongs to
    the earliest timestep and cannot seed a future chunk.
    """
    if (h0 is not None or return_h) and reverse:
        raise ValueError("RNN state I/O is a forward-walk contract; "
                         "a reversed layer's state cannot cross chunks")
    q = cfg.quant
    B, T, F = x.shape
    h = cfg.rnn_hidden
    if backend is None:
        x_proj = qdense(x, layer["w"], q)    # (B, T, gates*h)
    else:
        x_proj = _qdense_backend(x, layer, q, backend)
    x_proj = jnp.swapaxes(x_proj, 0, 1)      # (T, B, gates*h)

    if cfg.rnn_type == "gru":
        init = jnp.zeros((B, h)) if h0 is None else h0
        if backend is None:
            def step(hs, xp):
                hn = gru_cell(hs, xp, layer["u"], layer["b"], q)
                return hn, hn
        else:
            # recurrent weights on the same b-bit grid the model trained
            # on (the fused kernel computes h @ u in fp — only the weight
            # quantization carries over; h itself stays fp per step)
            u_q = fq_weight(layer["u"], q)
            if fused_rnn:
                # persistent walk: flip-run-flip is bitwise the
                # reverse=True scan (same per-step math, same order)
                xs = jnp.flip(x_proj, axis=0) if reverse else x_proj
                ys = backend.op("gru_seq")(xs, init, u_q, layer["b"])
                if reverse:
                    ys = jnp.flip(ys, axis=0)
                out = jnp.swapaxes(ys, 0, 1)
                # state-out IS the walk's last emitted hidden row — the
                # gru_seq state-in/state-out contract
                return (out, ys[-1]) if return_h else out
            fused = backend.op("gru_cell")

            def step(hs, xp):
                hn = fused(xp, hs, u_q, layer["b"])
                return hn, hn
    else:
        def step(hs, xp):
            hn = lstm_cell(hs, xp, layer["u"], layer["b"], q)
            return hn, hn[0]
        if h0 is None:
            init = (jnp.zeros((B, h)), jnp.zeros((B, h)))
        else:
            init = h0

    carry, ys = jax.lax.scan(step, init, x_proj, reverse=reverse)
    out = jnp.swapaxes(ys, 0, 1)
    return (out, carry) if return_h else out


def apply_basecaller(params, signal, cfg: BasecallerConfig,
                     backend: Optional[Backend] = None,
                     fused_rnn: bool = True,
                     rnn_state=None, return_state: bool = False):
    """signal: (B, T, C) -> log-probs (B, T_out, n_classes).

    ``backend`` (a ``repro.kernels.registry.Backend``) switches the whole
    model onto the registry's accelerated serving path: integer
    ``quant_matmul`` projections + the persistent ``gru_seq`` walk (or the
    per-step ``gru_cell`` scan with ``fused_rnn=False`` — the differential
    oracle; see ``_run_rnn``).  Leave it None for training — the backend
    path carries no STE gradients.

    Polymorphic over ``params``: a float checkpoint pytree quantizes
    weights in-trace (training, or the legacy repack-per-call serving
    path); a ``PackedParams`` artifact consumes its pre-quantized weights
    as-is — ``fq_weight`` becomes the identity and the trace carries zero
    weight-quantization ops (asserted by ``tests/test_packed.py``).

    ``rnn_state``/``return_state`` expose the CHUNK-BOUNDARY state I/O of
    the recurrent stack (``init_rnn_state`` builds the zero state;
    ``return_state=True`` additionally returns the per-layer final
    states): for a forward-only stack (``rnn_direction="uni"``) the
    recurrent walk over ``[T1; T2]`` equals walking ``T1`` then ``T2``
    with the state handed across, bitwise — the contract
    ``serve.streaming`` documents for per-lane state threading.  Only the
    RNN layers carry state; the conv front-end is stateless, so exact
    whole-model split parity additionally needs the conv receptive field's
    halo of samples re-fed at the boundary (trivially satisfied by
    kernel-1 convs).  Raises for "bidi"/"alt" stacks — their reversed
    layers integrate FUTURE samples and have no streamable state.
    """
    if rnn_state is not None or return_state:
        if cfg.rnn_direction != "uni":
            raise ValueError(
                f"chunk-boundary RNN state I/O needs rnn_direction='uni'; "
                f"{cfg.rnn_direction!r} stacks run reversed layers that "
                f"integrate future samples, so no per-chunk state exists "
                f"(stream whole windows instead — serve.streaming does)")
    if is_packed(params):
        if backend is None:
            raise ValueError(
                "PackedParams is a serving artifact: pass a kernel Backend "
                "(training uses float params + the fake-quant STE path)")
        cfg = cfg.with_quant(cfg.quant.as_prequantized())
        params = params.as_tree()
    # SERVING path only (backend is not None): windows stay split over the
    # logical "dp" axis through every stage when a dist.sharding mesh is
    # ambient.  The training path must stay constraint-free — constrain
    # bakes the ambient mesh into the jaxpr at trace time, and the
    # trainer's jits (unlike the pipeline's serving jits) are not keyed
    # per mesh, so a baked mesh would silently outlive its use_mesh block.
    # Each boundary is DECLARED by name (``stage:<name>`` named_scope) so
    # repro.analysis can verify that every boundary listed by
    # ``serving_stage_boundaries`` realizes a sharding constraint in the
    # meshed serving trace — intent checked by name, not by magic counts.
    def _dp(t, name):
        if backend is None:
            return t
        with jax.named_scope(f"stage:{name}"):
            return constrain(t, ("dp", None, None))

    x = _dp(signal, "signal_in")
    for ci, (p, spec) in enumerate(zip(params["conv"], cfg.conv)):
        x = jax.nn.relu(_conv1d(x, p["w"], p["b"], spec.stride, cfg.quant,
                                per_example=backend is not None))
        x = _dp(x, f"conv{ci}")

    state_out = []
    for i, layer in enumerate(params["rnn"]):
        if cfg.rnn_direction == "bidi":
            fwd = _run_rnn(x, layer, cfg, reverse=False, backend=backend,
                           fused_rnn=fused_rnn)
            bwd = _run_rnn(x, layer, cfg, reverse=True, backend=backend,
                           fused_rnn=fused_rnn)
            x = jnp.concatenate([fwd, bwd], axis=-1)
        else:
            reverse = (cfg.rnn_direction == "alt") and (i % 2 == 1)
            h0 = None if rnn_state is None else rnn_state[i]
            if return_state:
                x, hT = _run_rnn(x, layer, cfg, reverse=reverse,
                                 backend=backend, fused_rnn=fused_rnn,
                                 h0=h0, return_h=True)
                state_out.append(hT)
            else:
                x = _run_rnn(x, layer, cfg, reverse=reverse,
                             backend=backend, fused_rnn=fused_rnn, h0=h0)
        x = _dp(x, f"rnn{i}")

    if backend is None:
        logits = qdense(x, params["fc"]["w"], cfg.quant, params["fc"]["b"])
    else:
        logits = _qdense_backend(x, params["fc"], cfg.quant, backend,
                                 params["fc"]["b"])
    lps = _dp(jax.nn.log_softmax(logits, axis=-1), "logits")
    return (lps, state_out) if return_state else lps


def serving_stage_boundaries(cfg: BasecallerConfig) -> Tuple[str, ...]:
    """The model's declared sharding stage boundaries, in dataflow order.

    This is the single source of truth ``repro.analysis`` checks against:
    each name here must appear as a ``stage:<name>`` scope on a
    ``sharding_constraint`` in the meshed serving trace of
    ``apply_basecaller``.  Add a stage here AND a ``_dp(x, name)`` call in
    the forward when introducing a new pipeline stage.
    """
    return (("signal_in",)
            + tuple(f"conv{i}" for i in range(len(cfg.conv)))
            + tuple(f"rnn{i}" for i in range(cfg.rnn_layers))
            + ("logits",))


def apply_basecaller_packed(packed: PackedParams, signal,
                            cfg: BasecallerConfig,
                            backend: Optional[Backend] = None,
                            fused_rnn: bool = True):
    """Serving forward over the quantize-once artifact (explicit-name
    alias of the polymorphic ``apply_basecaller``).  Serving only:
    requires a ``backend``; bitwise identical to the repack-per-call path
    on every backend."""
    if not is_packed(packed):
        raise TypeError("apply_basecaller_packed wants PackedParams "
                        "(build one with pack_basecaller)")
    return apply_basecaller(packed, signal, cfg, backend,
                            fused_rnn=fused_rnn)


# ---------------------------------------------------------------------------
# accounting (benchmarks/table3)
# ---------------------------------------------------------------------------

def count_params(params) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


def count_macs(cfg: BasecallerConfig) -> dict:
    """Analytical MAC counts per stage for one input window."""
    t = cfg.input_len
    cin = cfg.in_channels
    conv_macs = 0
    for c in cfg.conv:
        t = -(-t // c.stride)
        conv_macs += t * c.kernel * cin * c.channels
        cin = c.channels
    gates = 3 if cfg.rnn_type == "gru" else 4
    h = cfg.rnn_hidden
    ndir = 2 if cfg.rnn_direction == "bidi" else 1
    rnn_macs = 0
    feat = cin
    for i in range(cfg.rnn_layers):
        fin = feat if i == 0 else ndir * h
        rnn_macs += ndir * t * gates * (fin * h + h * h)
    fc_macs = t * ndir * h * cfg.n_classes
    return {"conv": conv_macs, "rnn": rnn_macs, "fc": fc_macs,
            "total": conv_macs + rnn_macs + fc_macs}
