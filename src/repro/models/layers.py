"""LM building blocks: norms, RoPE/M-RoPE, flash-chunked attention, SwiGLU,
MoE (top-k capacity dispatch), Mamba-1 SSM.

Design constraints (see DESIGN.md §5/§6):
* pure functions over parameter pytrees — pjit shards them by path-name rules;
* attention never materializes the (S, S) score matrix: a two-level
  lax.scan over (q-chunk, kv-chunk) with an online softmax keeps the working
  set O(bq*bk) per device (flash-attention structure, pure jnp so the
  multi-pod dry-run compiles on any backend);
* MoE uses sort-based capacity dispatch (static shapes, EP-shardable);
* Mamba's selective scan uses an associative scan over time for training
  and an O(1) carried state for decode.

All matmuls take ``preferred_element_type=f32`` where accumulation matters;
activations run in the config dtype (bf16 on TPU, f32 in CPU tests).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG = -1.0e9


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps=1e-5):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, w, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE + qwen2-vl's M-RoPE sections)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               sections: Optional[Tuple[int, ...]] = None) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) or (B, S, n_sections) for M-RoPE.

    M-RoPE (qwen2-vl): the D/2 frequency lanes are partitioned into
    ``sections`` (temporal/height/width); each section rotates by its own
    position stream.  With all streams equal it degenerates to plain RoPE.
    """
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                      # (D/2,)
    if positions.ndim == 2:
        pos = positions[..., None].astype(jnp.float32)      # (B, S, 1)
        angles = pos * freqs                                 # (B, S, D/2)
    else:
        n = positions.shape[-1]
        assert sections is not None and sum(sections) == D // 2, (
            sections, D)
        sec_id = jnp.repeat(jnp.arange(n), jnp.asarray(sections),
                            total_repeat_length=D // 2)      # (D/2,)
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),
            jnp.broadcast_to(sec_id, positions.shape[:2] + (D // 2,)),
            axis=-1)                                         # (B, S, D/2)
        angles = pos * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# flash-chunked attention (training / prefill)
# ---------------------------------------------------------------------------

def _block_mask(qpos, kpos, causal, window, T):
    ok = (kpos < T)[None, :]
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        ok &= (qpos[:, None] - kpos[None, :]) < window
    return ok


# Masking is ADDITIVE (a small (bq, bk) f32 bias), never a broadcast bool:
# XLA hoists the layer-invariant mask computation out of the layers loop,
# and a (B, Kv, G, bq, bk)-broadcast pred stacked over (nq, nk) blocks is
# GiB-scale; the f32 bias stack is (nq, nk, bq, bk) — a few MiB. The online
# softmax keeps masked lanes at exp(<= MASK_NEG - M_INIT) == 0 because the
# running max is floored at M_INIT > MASK_NEG.
MASK_NEG = -1.0e9
M_INIT = -0.5e9


def _block_bias(qpos, kpos, causal, window, T):
    return jnp.where(_block_mask(qpos, kpos, causal, window, T),
                     0.0, MASK_NEG).astype(jnp.float32)


def _flash_blocks(q, k, v, bq, bk):
    """Pad + reshape into (n, B, blk, heads..., D) chunk-major layouts."""
    B, S, H, D = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    pq, pk = (-S) % bq, (-T) % bk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    nq, nk = (S + pq) // bq, (T + pk) // bk
    qb = qp.reshape(B, nq, bq, Kv, G, D).transpose(1, 0, 2, 3, 4, 5)
    kb = kp.reshape(B, nk, bk, Kv, D).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nk, bk, Kv, D).transpose(1, 0, 2, 3, 4)
    return qb, kb, vb, nq, nk


def _kv_range(qi, bq, bk, nk, causal, window, q_offset):
    """Static kv-chunk range [lo, hi) a causal/windowed q-chunk touches."""
    hi = nk
    if causal:
        hi = min(nk, (q_offset + (qi + 1) * bq + bk - 1) // bk)
    lo = 0
    if window is not None:
        lo = max(0, (q_offset + qi * bq - window + 1) // bk)
    return min(lo, hi - 1), max(hi, lo + 1)


def _flash_fwd_impl(q, k, v, causal, window, bq, bk, q_offset,
                    causal_skip=False):
    """Returns (out (B,S,H,D), lse (B,Kv,G,Sp)) — O(bq*bk) working set.

    ``causal_skip`` (§Perf hillclimb H1): unroll the q-chunk loop in Python
    and give each chunk a STATICALLY sliced kv range, skipping fully-masked
    blocks — ~2x fewer attention FLOPs for causal self-attention, at the
    cost of O(nq) HLO size (use when nq is small, e.g. <= 16).
    """
    B, S, H, D = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    scale = D ** -0.5
    bq, bk = min(bq, S), min(bk, T)
    qb, kb, vb, nq, nk = _flash_blocks(q, k, v, bq, bk)
    qb = qb.astype(jnp.float32) * scale

    def run_q_block(qi, qblk, kb_sl, vb_sl, kj0):
        qpos = jnp.arange(bq) + q_offset + qi * bq

        def kv_block(carry, kj_and_blocks):
            m, l, acc = carry
            kj, kblk, vblk = kj_and_blocks
            kpos = jnp.arange(bk) + kj * bk
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk,
                           kblk.astype(jnp.float32))        # (B,Kv,G,bq,bk)
            s = s + _block_bias(qpos, kpos, causal, window, T)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = (acc * corr[..., None] +
                       jnp.einsum("bhgqk,bkhd->bhgqd", p,
                                  vblk.astype(jnp.float32)))
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, Kv, G, bq), M_INIT, jnp.float32),
                jnp.zeros((B, Kv, G, bq), jnp.float32),
                jnp.zeros((B, Kv, G, bq, D), jnp.float32))
        nk_sl = kb_sl.shape[0]
        (m, l, acc), _ = jax.lax.scan(
            kv_block, init, (jnp.arange(kj0, kj0 + nk_sl), kb_sl, vb_sl))
        out = acc / jnp.maximum(l, 1e-20)[..., None]        # (B,Kv,G,bq,D)
        lse = m + jnp.log(jnp.maximum(l, 1e-20))            # (B,Kv,G,bq)
        return out.transpose(0, 3, 1, 2, 4), lse

    if causal_skip and nq > 1:
        outs, lses = [], []
        for qi in range(nq):
            lo, hi = _kv_range(qi, bq, bk, nk, causal, window, q_offset)
            o, s_ = run_q_block(qi, qb[qi], kb[lo:hi], vb[lo:hi], lo)
            outs.append(o)
            lses.append(s_)
        outs, lses = jnp.stack(outs), jnp.stack(lses)
    else:
        def q_block(_, qi_and_block):
            qi, qblk = qi_and_block                         # (B,bq,Kv,G,D)
            return None, run_q_block(qi, qblk, kb, vb, 0)

        _, (outs, lses) = jax.lax.scan(q_block, None, (jnp.arange(nq), qb))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * bq, H, D)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, Kv, G, nq * bq)
    return out[:, :S].astype(q.dtype), lse


def _q_range(kj, bq, bk, nq, causal, window, q_offset):
    """Static q-chunk range [lo, hi) that touches kv chunk kj."""
    lo = 0
    if causal:
        lo = max(0, (kj * bk - q_offset - bq + 1 + bq - 1) // bq)
        lo = max(0, (kj * bk - q_offset) // bq)
    hi = nq
    if window is not None:
        # q_pos - k_pos < window  =>  qi*bq + q_offset < kj*bk + bk + window
        hi = min(nq, (kj * bk + bk - 1 + window - q_offset) // bq + 1)
    lo = min(lo, hi - 1)
    return max(lo, 0), max(hi, lo + 1)


def _flash_fwd(q, k, v, causal, window, bq, bk, q_offset, causal_skip):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, bq, bk, q_offset,
                               causal_skip)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, bq, bk, q_offset, causal_skip, res, dout):
    """FA2-style backward: recompute p blockwise from (q,k,v,lse); two
    chunked passes (dq; then dk/dv). Saves only O(S*D) residuals."""
    q, k, v, out, lse = res
    B, S, H, D = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    scale = D ** -0.5
    bq_, bk_ = min(bq, S), min(bk, T)
    qb, kb, vb, nq, nk = _flash_blocks(q, k, v, bq_, bk_)
    qb = qb.astype(jnp.float32) * scale
    dob = _flash_blocks(dout, k, v, bq_, bk_)[0]
    ob = _flash_blocks(out, k, v, bq_, bk_)[0]
    # delta_i = rowsum(dout * out): (B,Kv,G,bq) per q block
    delta = jnp.einsum("nbqhgd,nbqhgd->nbhgq",
                       dob.astype(jnp.float32), ob.astype(jnp.float32))
    pq = nq * bq_ - S
    lse_b = (jnp.pad(lse, ((0, 0),) * 3 + ((0, pq),))
             .reshape(B, Kv, G, nq, bq_).transpose(3, 0, 1, 2, 4))

    def p_block(qblk, kblk, lse_i, qpos, kpos):
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk.astype(jnp.float32))
        s = s + _block_bias(qpos, kpos, causal, window, T)
        return jnp.exp(s - lse_i[..., None])

    # ---- pass A: dq -------------------------------------------------------
    def dq_block(_, xs):
        qi, qblk, do_i, dl_i, lse_i = xs
        qpos = jnp.arange(bq_) + q_offset + qi * bq_
        do_f = do_i.astype(jnp.float32)

        def inner(dq_acc, kv):
            kj, kblk, vblk = kv
            kpos = jnp.arange(bk_) + kj * bk_
            p = p_block(qblk, kblk, lse_i, qpos, kpos)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", do_f,
                            vblk.astype(jnp.float32))
            ds = p * (dp - dl_i[..., None])
            dq_acc += jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                                 kblk.astype(jnp.float32))
            return dq_acc, None

        lo, hi = ((0, nk) if not causal_skip else
                  _kv_range(qi_static, bq_, bk_, nk, causal, window,
                            q_offset))
        dq_i, _ = jax.lax.scan(inner, jnp.zeros_like(qblk),
                               (jnp.arange(lo, hi), kb[lo:hi], vb[lo:hi]))
        return None, dq_i * scale

    if causal_skip and nq > 1:
        dq_list = []
        for qi_static in range(nq):
            _, dq_i = dq_block(None, (qi_static, qb[qi_static],
                                      dob[qi_static], delta[qi_static],
                                      lse_b[qi_static]))
            dq_list.append(dq_i)
        dqs = jnp.stack(dq_list)
    else:
        qi_static = None
        _, dqs = jax.lax.scan(dq_block, None,
                              (jnp.arange(nq), qb, dob, delta, lse_b))
    dq = (dqs.transpose(1, 0, 2, 3, 4, 5)
          .reshape(B, nq * bq_, H, D)[:, :S].astype(q.dtype))

    # ---- pass B: dk, dv ---------------------------------------------------
    def dkv_block(_, xs):
        kj, kblk, vblk = xs
        kpos = jnp.arange(bk_) + kj * bk_
        kf = kblk.astype(jnp.float32)
        vf = vblk.astype(jnp.float32)

        def inner(carry, qs):
            dk_acc, dv_acc = carry
            qi, qblk, do_i, dl_i, lse_i = qs
            qpos = jnp.arange(bq_) + q_offset + qi * bq_
            p = p_block(qblk, kblk, lse_i, qpos, kpos)
            do_f = do_i.astype(jnp.float32)
            dv_acc += jnp.einsum("bhgqk,bqhgd->bkhd", p, do_f)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", do_f, vf)
            ds = p * (dp - dl_i[..., None])
            dk_acc += jnp.einsum("bhgqk,bqhgd->bkhd", ds, qblk)
            return (dk_acc, dv_acc), None

        lo, hi = ((0, nq) if not causal_skip else
                  _q_range(kj_static, bq_, bk_, nq, causal, window,
                           q_offset))
        (dk_j, dv_j), _ = jax.lax.scan(
            inner, (jnp.zeros_like(kf), jnp.zeros_like(vf)),
            (jnp.arange(lo, hi), qb[lo:hi], dob[lo:hi], delta[lo:hi],
             lse_b[lo:hi]))
        return None, (dk_j, dv_j)

    if causal_skip and nk > 1:
        dk_list, dv_list = [], []
        for kj_static in range(nk):
            _, (dk_j, dv_j) = dkv_block(None, (kj_static, kb[kj_static],
                                               vb[kj_static]))
            dk_list.append(dk_j)
            dv_list.append(dv_j)
        dks, dvs = jnp.stack(dk_list), jnp.stack(dv_list)
    else:
        kj_static = None
        _, (dks, dvs) = jax.lax.scan(dkv_block, None,
                                     (jnp.arange(nk), kb, vb))
    dk = (dks.transpose(1, 0, 2, 3, 4)
          .reshape(B, nk * bk_, Kv, D)[:, :T].astype(k.dtype))
    dv = (dvs.transpose(1, 0, 2, 3, 4)
          .reshape(B, nk * bk_, Kv, D)[:, :T].astype(v.dtype))
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, bq, bk, q_offset, causal_skip):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, bq, bk, q_offset,
                             causal_skip)
    return out


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    bq: int = 512, bk: int = 512,
                    q_offset: int = 0,
                    causal_skip: bool = False) -> jnp.ndarray:
    """Online-softmax chunked attention with a flash-style custom VJP.

    q: (B, S, H, D); k, v: (B, T, Kv, D) with H % Kv == 0 (GQA).
    Never materializes (S, T) — in either direction: the backward recomputes
    score blocks from the saved (q, k, v, out, lse), so autodiff does NOT
    stash per-(q-chunk, kv-chunk) residuals (that would be the full score
    matrix again, the dominant memory hog in the 4k-seq train dry-run).
    """
    return _flash(q, k, v, causal, window, bq, bk, q_offset,
                  causal_skip)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, valid: jnp.ndarray,
                     backend=None) -> jnp.ndarray:
    """Single-token attention over a cache.

    q: (B, 1, H, D); caches: (B, L, Kv, D); valid: (B, L) bool slot mask.

    ``backend`` (a ``repro.kernels.registry.Backend``) routes onto the
    tiled ``decode_attn`` Pallas kernel.  The kernel models validity as a
    per-lane count, so it only applies when ``valid`` is a prefix mask
    (every caller here builds it as ``arange(L) < n``).
    """
    B, _, H, D = q.shape
    if backend is not None:
        Kv = k_cache.shape[2]
        n_valid = valid.sum(-1).astype(jnp.int32)
        out = backend.op("decode_attn")(q[:, 0], k_cache, v_cache, n_valid,
                                        groups=H // Kv)
        return out[:, None].astype(q.dtype)
    Kv = k_cache.shape[2]
    G = H // Kv
    qf = q.reshape(B, Kv, G, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("bhgd,blhd->bhgl", qf, k_cache.astype(jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgl,blhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


def paged_decode_attention(q: jnp.ndarray, k_arena: jnp.ndarray,
                           v_arena: jnp.ndarray, block_tables: jnp.ndarray,
                           n_valid: jnp.ndarray,
                           backend=None) -> jnp.ndarray:
    """Single-token attention over a PAGED block arena.

    q: (B, 1, H, D); arenas: (N, bs, Kv, D) pooled KV blocks shared by all
    lanes; block_tables: (B, nb) int32 — lane i's logical block j lives at
    arena row ``block_tables[i, j]``; n_valid: (B,) int32 tokens written
    so far (validity is a PREFIX of the gathered sequence, so unallocated
    table entries may point anywhere in-range — the engine clips them
    to 0).

    ``backend`` (a ``repro.kernels.registry.Backend``) routes onto the
    ``paged_decode_attn`` Pallas kernel, which streams blocks through the
    table with a scalar-prefetch index map instead of materializing the
    (B, nb*bs, Kv, D) gather below.
    """
    B, _, H, D = q.shape
    N, bs, Kv, _ = k_arena.shape
    if backend is not None:
        out = backend.op("paged_decode_attn")(
            q[:, 0], k_arena, v_arena, block_tables,
            n_valid.astype(jnp.int32), groups=H // Kv)
        return out[:, None].astype(q.dtype)
    nb = block_tables.shape[1]
    k = k_arena[block_tables].reshape(B, nb * bs, Kv, D)
    v = v_arena[block_tables].reshape(B, nb * bs, Kv, D)
    valid = jnp.arange(nb * bs)[None, :] < n_valid[:, None]
    return decode_attention(q, k, v, valid)


# ---------------------------------------------------------------------------
# feed-forward: SwiGLU / GELU
# ---------------------------------------------------------------------------

def swiglu(x, w1, w3, w2):
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def gelu_ff(x, w1, b1, w2, b2):
    return jax.nn.gelu(x @ w1 + b1) @ w2 + b2


# ---------------------------------------------------------------------------
# Mixture of Experts: top-k routing with sort-based capacity dispatch
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 64
    top_k: int = 8
    capacity_factor: float = 1.25
    shared_expert: bool = False     # llama4: one always-on shared expert


def moe_capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)   # round up to 8 for tiling


def moe_ff(x: jnp.ndarray, p: dict, cfg: MoEConfig):
    """x: (T, d) token-major. Returns (T, d) plus aux losses dict.

    p: router (d, E); w1, w3 (E, d, f); w2 (E, f, d)
    [+ sw1, sw3, sw2 for the shared expert].

    Dispatch: flatten (token, k) assignments, sort by expert id, keep the
    first C per expert (capacity drop), run batched expert einsums, scatter
    back weighted by router prob.  Static shapes throughout; the expert
    dimension shards over the "model" mesh axis (EP) and the per-expert
    ff dimension over "data" (FSDP-style 2-D expert sharding).
    """
    T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = moe_capacity(T, cfg)

    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    top_p, top_e = jax.lax.top_k(probs, K)                    # (T, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(T * K)
    flat_p = top_p.reshape(T * K)
    flat_t = jnp.repeat(jnp.arange(T), K)

    order = jnp.argsort(flat_e)                               # stable
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]
    # rank of each assignment within its expert
    start = jnp.searchsorted(se, jnp.arange(E))               # (E,)
    rank = jnp.arange(T * K) - start[se]
    keep = rank < C
    slot = jnp.where(keep, se * C + rank, E * C)              # drop slot

    buf = jnp.zeros((E * C, d), x.dtype)
    buf = buf.at[slot].set(x[st], mode="drop").reshape(E, C, d)

    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    h = jax.nn.silu(h) * g
    eout = jnp.einsum("ecf,efd->ecd", h, p["w2"]).reshape(E * C, d)

    contrib = jnp.where(keep, sp, 0.0).astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype)
    y = y.at[st].add(eout[jnp.minimum(slot, E * C - 1)] * contrib[:, None],
                     mode="drop")

    if cfg.shared_expert:
        y = y + swiglu(x, p["sw1"], p["sw3"], p["sw2"])

    # load-balance aux (Switch-style): E * Σ_e f_e * P_e
    frac = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * K)
    mean_p = probs.mean(0)
    aux = {"lb_loss": E * jnp.sum(frac * mean_p),
           "drop_frac": 1.0 - keep.mean()}
    return y, aux


# ---------------------------------------------------------------------------
# Mamba-1 selective SSM
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0        # 0 => d_model // 16

    def inner(self, d_model: int) -> int:
        return self.expand * d_model

    def rank(self, d_model: int) -> int:
        return self.dt_rank or max(1, d_model // 16)


def _causal_depthwise_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, C); w: (K, C) depthwise causal conv."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):           # K is tiny (4): unrolled adds, no gather
        out = out + xp[:, i: i + x.shape[1]] * w[i]
    return out


def mamba_scan(decay: jnp.ndarray, inc: jnp.ndarray,
               h0: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Linear recurrence h_t = decay_t * h_{t-1} + inc_t over axis 1.

    decay/inc: (B, S, di, n). Associative scan => O(log S) depth.
    """
    if h0 is not None:
        inc = inc.at[:, 0].add(decay[:, 0] * h0)

    def combine(a, b):
        da, ia = a
        db, ib = b
        return da * db, db * ia + ib

    _, h = jax.lax.associative_scan(combine, (decay, inc), axis=1)
    return h


def mamba_mix(x: jnp.ndarray, p: dict, cfg: SSMConfig, d_model: int,
              state: Optional[dict] = None):
    """Mamba-1 block. x: (B, S, d). Returns (out, new_state).

    state (decode): {"h": (B, di, n), "conv": (B, K-1, di)}.
    """
    B, S, _ = x.shape
    di = cfg.inner(d_model)
    n = cfg.d_state
    r = cfg.rank(d_model)

    xz = x @ p["in_proj"]                         # (B, S, 2di)
    xin_raw, z = jnp.split(xz, 2, axis=-1)

    if state is not None:
        conv_in = jnp.concatenate([state["conv"], xin_raw], axis=1)
        new_conv = conv_in[:, -(cfg.d_conv - 1):]
        xin = _causal_depthwise_conv(conv_in, p["conv_w"])[:, -S:]
    else:
        pad = max(cfg.d_conv - 1 - S, 0)
        new_conv = jnp.pad(xin_raw, ((0, 0), (pad, 0), (0, 0))
                           )[:, -(cfg.d_conv - 1):]
        xin = _causal_depthwise_conv(xin_raw, p["conv_w"])
    xin = jax.nn.silu(xin + p["conv_b"])

    dbc = xin @ p["x_proj"]                       # (B, S, r + 2n)
    dt = jax.nn.softplus(dbc[..., :r] @ p["dt_proj"] + p["dt_bias"])
    Bs = dbc[..., r: r + n].astype(jnp.float32)
    Cs = dbc[..., r + n:].astype(jnp.float32)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, n)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf[..., None] * A)                       # (B,S,di,n)
    inc = (dtf * xin.astype(jnp.float32))[..., None] * Bs[:, :, None, :]

    if state is not None and S == 1:
        h = decay[:, 0] * state["h"] + inc[:, 0]              # (B, di, n)
        y = (h * Cs[:, 0, None, :]).sum(-1)[:, None]          # (B, 1, di)
        new_h = h
    else:
        h0 = state["h"] if state is not None else None
        h = mamba_scan(decay, inc, h0)                        # (B,S,di,n)
        y = (h * Cs[:, :, None, :]).sum(-1)                   # (B, S, di)
        new_h = h[:, -1]

    y = y.astype(x.dtype) + p["D"] * xin
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return out, {"h": new_h, "conv": new_conv}
