"""Window chunking + read stitching for arbitrarily long raw-signal reads.

A nanopore read is minutes of current samples; the base-caller consumes
fixed windows (paper: 300 x 1).  ``chunk_signal`` slices a long read into
overlapping windows on the host (data prep, not a hot loop — the hot loop
is the batched model/decode over the resulting array), and
``stitch_reads`` votes the per-window reads back into one consensus via
the longest-match alignment of ``core.voting`` (paper §4.3/Fig 19 — the
window order is known, consecutive windows overlap).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import voting as voting_lib


@dataclasses.dataclass(frozen=True)
class ChunkConfig:
    window: int            # samples the model consumes per call
    hop: int               # window start stride; hop < window => overlap
    batch_windows: int = 8  # windows batched per device call (memory bound)

    def __post_init__(self):
        if not (0 < self.hop <= self.window):
            raise ValueError(
                f"hop must be in (0, window]; got hop={self.hop} "
                f"window={self.window}")


def n_windows(n_samples: int, cfg: ChunkConfig) -> int:
    """Windows covering ``n_samples`` (final partial window zero-padded).

    An empty signal has ZERO windows — fabricating an all-zero window for
    it would decode garbage and waste a device call; callers get an empty
    read instead (``BasecallPipeline.basecall`` / ``BasecallEngine``).
    """
    if n_samples <= 0:
        return 0
    if n_samples <= cfg.window:
        return 1
    return 1 + -(-(n_samples - cfg.window) // cfg.hop)


def window_valid_samples(n_samples: int, cfg: ChunkConfig) -> np.ndarray:
    """(N,) true sample count per window (== window except a padded tail).

    ``chunk_signal`` zero-pads the final partial window; decoding those
    padded frames produces garbage bases, so the pipeline converts these
    counts to per-window ``logit_lengths`` for the beam decoder.
    """
    N = n_windows(n_samples, cfg)
    starts = np.arange(N, dtype=np.int64) * cfg.hop
    return np.minimum(cfg.window, np.maximum(n_samples - starts, 0)) \
        .astype(np.int32)


def chunk_signal(signal: np.ndarray, cfg: ChunkConfig) -> np.ndarray:
    """(T,) or (T, C) raw read -> (n_windows, window, C) float32.

    The tail window is zero-padded — the pore signal is standardized to
    zero mean so padding is inert rather than a level step.
    """
    sig = np.asarray(signal, np.float32)
    if sig.ndim == 1:
        sig = sig[:, None]
    T, C = sig.shape
    N = n_windows(T, cfg)
    out = np.zeros((N, cfg.window, C), np.float32)
    for i in range(N):
        s = i * cfg.hop
        piece = sig[s: s + cfg.window]
        out[i, : piece.shape[0]] = piece
    return out


def stitch_reads(reads: jnp.ndarray, lengths: jnp.ndarray,
                 span: int | None = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Vote per-window reads (N, L) back into one consensus read.

    Thin alias over ``core.voting.vote`` so the pipeline has a single
    stitching entry point.  Returns (consensus (span,) padded -1, length).
    """
    return voting_lib.vote(reads, lengths, span=span)
