"""Window chunking + read stitching for arbitrarily long raw-signal reads.

A nanopore read is minutes of current samples; the base-caller consumes
fixed windows (paper: 300 x 1).  ``chunk_signal`` slices a long read into
overlapping windows on the host (data prep, not a hot loop — the hot loop
is the batched model/decode over the resulting array), and
``stitch_reads`` votes the per-window reads back into one consensus via
the longest-match alignment of ``core.voting`` (paper §4.3/Fig 19 — the
window order is known, consecutive windows overlap).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import voting as voting_lib


@dataclasses.dataclass(frozen=True)
class ChunkConfig:
    window: int            # samples the model consumes per call
    hop: int               # window start stride; hop < window => overlap
    batch_windows: int = 8  # windows batched per device call (memory bound)

    def __post_init__(self):
        if not (0 < self.hop <= self.window):
            raise ValueError(
                f"hop must be in (0, window]; got hop={self.hop} "
                f"window={self.window}")


def n_windows(n_samples: int, cfg: ChunkConfig) -> int:
    """Windows covering ``n_samples`` (final partial window zero-padded).

    An empty signal has ZERO windows — fabricating an all-zero window for
    it would decode garbage and waste a device call; callers get an empty
    read instead (``BasecallPipeline.basecall`` / ``BasecallEngine``).
    """
    if n_samples <= 0:
        return 0
    if n_samples <= cfg.window:
        return 1
    return 1 + -(-(n_samples - cfg.window) // cfg.hop)


def window_valid_samples(n_samples: int, cfg: ChunkConfig) -> np.ndarray:
    """(N,) true sample count per window (== window except a padded tail).

    ``chunk_signal`` zero-pads the final partial window; decoding those
    padded frames produces garbage bases, so the pipeline converts these
    counts to per-window ``logit_lengths`` for the beam decoder.
    """
    N = n_windows(n_samples, cfg)
    starts = np.arange(N, dtype=np.int64) * cfg.hop
    return np.minimum(cfg.window, np.maximum(n_samples - starts, 0)) \
        .astype(np.int32)


def complete_windows(n_samples: int, cfg: ChunkConfig) -> int:
    """Windows fully determined by the first ``n_samples`` of a stream.

    Window ``i`` covers samples ``[i*hop, i*hop + window)``; it is
    *complete* — its contents can never change as more samples arrive —
    once ``n_samples >= i*hop + window``.  The remaining (tail) windows of
    :func:`n_windows` only exist once the stream ENDS, because whether the
    tail is zero-padded depends on the final total length.
    """
    if n_samples < cfg.window:
        return 0
    return 1 + (n_samples - cfg.window) // cfg.hop


def overlap_depth(cfg: ChunkConfig) -> int:
    """Max windows any sample position can fall into (= ceil(window/hop)).

    The streaming stitcher's horizon: once this many newer windows have
    opened past a consensus position, no further window can vote there —
    the position's overlap window has closed.
    """
    return -(-cfg.window // cfg.hop)


class WindowBuffer:
    """Incremental :func:`chunk_signal`: samples in, windows out.

    Accumulates raw-signal chunks (``feed``) and hands out each overlap
    window exactly once (``next_window``) as soon as its samples are
    complete — bitwise identical to slicing the concatenated signal with
    :func:`chunk_signal`.  Consumed samples no window can still need are
    dropped, so memory is bounded by ``window + hop`` samples regardless
    of stream length.  ``end()`` closes the stream, releasing the
    zero-padded tail window (whose padding depends on the final length).
    """

    def __init__(self, cfg: ChunkConfig):
        self.cfg = cfg
        self.n_fed = 0          # total samples ever fed
        self.emitted = 0        # windows handed out so far
        self.ended = False
        self._buf: Optional[np.ndarray] = None   # (n, C) pending samples
        self._base = 0          # stream index of _buf[0]

    def feed(self, chunk: np.ndarray) -> int:
        """Append one raw chunk ((t,) or (t, C)); returns samples added.

        Chunks may be any size — including empty, or smaller than one
        window (nothing becomes ready until a window's worth arrives).
        """
        if self.ended:
            raise RuntimeError("WindowBuffer.feed after end()")
        sig = np.asarray(chunk, np.float32)
        if sig.ndim == 1:
            sig = sig[:, None]
        if sig.ndim != 2:
            raise ValueError(f"chunk must be (t,) or (t, C); "
                             f"got shape {sig.shape}")
        if sig.shape[0] == 0:
            if self._buf is None and sig.shape[1] != 1:
                self._buf = sig          # pin C even from an empty chunk
            return 0
        if self._buf is not None and sig.shape[1] != self._buf.shape[1]:
            raise ValueError(f"chunk has {sig.shape[1]} channels; "
                             f"stream started with {self._buf.shape[1]}")
        if self._buf is None or self._buf.shape[0] == 0:
            self._buf = sig.copy()
        else:
            self._buf = np.concatenate([self._buf, sig])
        self.n_fed += sig.shape[0]
        return sig.shape[0]

    def end(self) -> None:
        """Mark the stream complete: tail windows become ready."""
        self.ended = True

    @property
    def total_windows(self) -> Optional[int]:
        """Final window count (None until ``end()``)."""
        return n_windows(self.n_fed, self.cfg) if self.ended else None

    def ready(self) -> int:
        """Windows ready to emit right now (complete, or tail after end)."""
        done = (n_windows(self.n_fed, self.cfg) if self.ended
                else complete_windows(self.n_fed, self.cfg))
        return done - self.emitted

    def next_window(self) -> Tuple[np.ndarray, int]:
        """Pop the next ready window: ((window, C) float32, valid_samples).

        ``valid_samples`` is the window's true sample count (< window only
        for the zero-padded tail) — feed it through
        ``BasecallerConfig.output_frames`` for the decoder's
        ``logit_length``.  Raises when nothing is ready (check
        :meth:`ready`).
        """
        if self.ready() <= 0:
            raise RuntimeError("no window ready (buffer more samples, "
                               "or end() the stream for the tail)")
        cfg, i = self.cfg, self.emitted
        start = i * cfg.hop
        valid = min(cfg.window, self.n_fed - start)
        C = 1 if self._buf is None else self._buf.shape[1]
        out = np.zeros((cfg.window, C), np.float32)
        lo = start - self._base
        out[:valid] = self._buf[lo: lo + valid]
        self.emitted += 1
        # drop samples below the next unemitted window's start — bounded
        # memory is the point of streaming
        keep_from = self.emitted * cfg.hop
        if keep_from > self._base and self._buf is not None:
            drop = min(keep_from, self._base + self._buf.shape[0]) \
                - self._base
            self._buf = self._buf[drop:]
            self._base += drop
        return out, valid


def chunk_signal(signal: np.ndarray, cfg: ChunkConfig) -> np.ndarray:
    """(T,) or (T, C) raw read -> (n_windows, window, C) float32.

    The tail window is zero-padded — the pore signal is standardized to
    zero mean so padding is inert rather than a level step.
    """
    sig = np.asarray(signal, np.float32)
    if sig.ndim == 1:
        sig = sig[:, None]
    T, C = sig.shape
    N = n_windows(T, cfg)
    out = np.zeros((N, cfg.window, C), np.float32)
    for i in range(N):
        s = i * cfg.hop
        piece = sig[s: s + cfg.window]
        out[i, : piece.shape[0]] = piece
    return out


def stitch_reads(reads: jnp.ndarray, lengths: jnp.ndarray,
                 span: int | None = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Vote per-window reads (N, L) back into one consensus read.

    Thin alias over ``core.voting.vote`` so the pipeline has a single
    stitching entry point.  Returns (consensus (span,) padded -1, length).
    """
    return voting_lib.vote(reads, lengths, span=span)
