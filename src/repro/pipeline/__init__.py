"""Unified base-calling pipeline API (see ``pipeline.BasecallPipeline``)."""
from repro.pipeline.chunking import ChunkConfig, chunk_signal, stitch_reads
from repro.pipeline.pipeline import BasecallPipeline, BasecallResult
from repro.pipeline.training import PhasedTrainer, TrainPolicy

__all__ = ["BasecallPipeline", "BasecallResult", "ChunkConfig",
           "PhasedTrainer", "TrainPolicy", "chunk_signal", "stitch_reads"]
