"""``BasecallPipeline`` — the one facade over the Helix base-calling path.

The paper's end-to-end claim is about the *whole* pipeline: quantized DNN
inference, CTC decode, and read voting as one accelerated path.  This
class wires those stages together once, so callers stop re-plumbing
model -> ``ctc_beam_search_batch`` -> ``consensus_reads`` by hand:

    pipe = BasecallPipeline.from_preset("guppy",
                                        quant=QuantConfig(enabled=True),
                                        backend="auto")
    params = pipe.init_params(jax.random.PRNGKey(0))
    result = pipe.basecall(long_raw_signal)          # chunk/batch/decode/vote

Compute routes through ``repro.kernels.registry``: the ``backend`` switch
("auto" | "pallas" | "interpret" | "ref") picks the integer Pallas serving
path or the jnp oracle for every matmul/GRU step in one place.

Three call surfaces:
  basecall(signal)        — arbitrarily long raw read: overlapping windows,
                            batched model + CTC beam decode, voted consensus
  basecall_iter(signal)   — same, streaming one window-batch at a time
                            (bounded device memory for very long reads)
  basecall_windows(batch) — fixed (B, window+2*margin) signal windows through
                            the fused SEAT-view + consensus serving path
                            (what the serving engine batches over slots)
plus ``trainer()`` — the warm-up/SEAT two-phase policy (pipeline/training).

Serving consumes the quantize-once ``PackedParams`` artifact
(``serving_params()``: packed lazily, cached on checkpoint identity,
invalidated by ``init_params``/``params`` rebinds), while training keeps
the float checkpoint — the train-vs-serve split of ARCHITECTURE.md.
``packed=False`` preserves the legacy repack-per-call path as an oracle.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ctc as ctc_lib
from repro.core import seat as seat_lib
from repro.core.quant import QuantConfig
from repro.data import genome
from repro.dist import sharding as shd
from repro.kernels.registry import Backend
from repro.models import basecaller as bc
from repro.pipeline import chunking
from repro.pipeline.training import PhasedTrainer, TrainPolicy

_SCALES = {"full": lambda n: bc.PRESETS[n], "demo": bc.demo_preset,
           "tiny": bc.tiny_preset}


def _fifo_put(cache: dict, key, value, cap: int = 4) -> None:
    """Insert into a small bounded cache, evicting the oldest entry.

    The one eviction policy behind the pipeline's pack/placement/per-mesh
    caches — values hold strong refs to whatever pins their id()-based
    keys, so a bounded FIFO is all the invalidation these need."""
    if len(cache) >= cap:
        cache.pop(next(iter(cache)))
    cache[key] = value

# the LSTM "no fused kernel" notice is a property of the build, not of any
# one pipeline — emit it once per process, not once per construction
_LSTM_KERNEL_WARNED = False


def _warn_lstm_once(mode: str) -> None:
    global _LSTM_KERNEL_WARNED
    if _LSTM_KERNEL_WARNED:
        return
    _LSTM_KERNEL_WARNED = True
    warnings.warn(
        "LSTM stacks have no fused kernel: the recurrent loop runs "
        "on the fake-quant path; only projections use the integer "
        f"backend ({mode}).", stacklevel=3)


def _reset_lstm_warning() -> None:
    """Test hook: make the next LSTM pipeline warn again."""
    global _LSTM_KERNEL_WARNED
    _LSTM_KERNEL_WARNED = False


@dataclasses.dataclass
class BasecallResult:
    """One long read's consensus + the per-window reads that voted it."""
    read: np.ndarray            # (span,) int32 base ids, padded -1
    length: int
    window_reads: np.ndarray    # (n_windows, max_read_len)
    window_lengths: np.ndarray  # (n_windows,)

    def sequence(self, alphabet: str = "ACGT") -> str:
        """The consensus read as a base string (e.g. ``"ACGT..."``)."""
        return "".join(alphabet[b] for b in self.read[: self.length])

    @classmethod
    def empty(cls, max_read_len: int) -> "BasecallResult":
        """The zero-window result (empty signal): one definition shared by
        the pipeline and the engine so they cannot diverge."""
        return cls(read=np.full((max_read_len,), -1, np.int32), length=0,
                   window_reads=np.zeros((0, max_read_len), np.int32),
                   window_lengths=np.zeros((0,), np.int32))

    @classmethod
    def from_window_reads(cls, reads: np.ndarray, lengths: np.ndarray,
                          *, max_read_len: int,
                          span: Optional[int] = None) -> "BasecallResult":
        """Vote one read's per-window decodes into its consensus.

        THE single finalization of the serving path: ``BasecallPipeline.
        basecall`` and ``serve.BasecallEngine`` both call this, which is
        what keeps engine ≡ pipeline bit for bit (zero windows -> empty,
        one window -> that read, else overlap-stitched consensus)."""
        reads = np.asarray(reads)
        lengths = np.asarray(lengths, np.int32)
        if reads.shape[0] == 0:
            return cls.empty(max_read_len)
        if reads.shape[0] == 1:
            cons, clen = reads[0], int(lengths[0])
        else:
            span = span or max_read_len * reads.shape[0]
            cons, clen = chunking.stitch_reads(
                jnp.asarray(reads), jnp.asarray(lengths), span=span)
            cons, clen = np.asarray(cons), int(clen)
        return cls(read=cons, length=clen, window_reads=reads,
                   window_lengths=lengths)


class BasecallPipeline:
    """The one facade over chunk → quantized model → CTC decode → vote.

    Construct via :meth:`from_preset` (paper presets) or directly from a
    ``models.basecaller.BasecallerConfig``; then ``init_params`` (or bind a
    checkpoint via ``params=``) and call one of the three serving surfaces
    — :meth:`basecall`, :meth:`basecall_iter`, :meth:`basecall_windows` —
    or train through :meth:`trainer`.

    Under an ambient ``dist.sharding.use_mesh`` mesh every serving surface
    runs dp-sharded: the window batch splits over the mesh's data-parallel
    devices (params replicated), per-window reads are all-gathered before
    the shared stitch/vote, and results are bitwise identical to the
    single-device path.

    Args:
        mcfg: the base-caller architecture/quantization config.
        backend: kernel registry backend ("auto" | "pallas" | "interpret"
            | "ref") threaded through every projection and recurrent step.
        scfg: SEAT view/consensus config (defaults derived from ``mcfg``).
        chunk: long-read windowing config; ``chunk.window`` must equal
            ``mcfg.input_len``.
        beam_width: CTC beam width (1 = greedy).
        max_read_len: decode pad length per window (default
            ``mcfg.output_len``).
        decode_strip: frames per persistent ``beam_merge_multiframe``
            launch in the hash beam decode (``None``/``1`` = the per-frame
            ``beam_merge_topk`` oracle loop; results are bitwise equal).
        packed: serve from the quantize-once ``PackedParams`` artifact
            (False keeps the repack-per-call oracle path).
        params: optional float checkpoint to bind immediately.

    Example::

        pipe = BasecallPipeline.from_preset("guppy", scale="demo",
                                            backend="auto")
        pipe.init_params(jax.random.PRNGKey(0))
        result = pipe.basecall(long_raw_signal)
    """

    def __init__(self, mcfg: bc.BasecallerConfig, *,
                 backend: str | Backend = "auto",
                 scfg: Optional[seat_lib.SEATConfig] = None,
                 chunk: Optional[chunking.ChunkConfig] = None,
                 beam_width: int = 5,
                 max_read_len: Optional[int] = None,
                 decode_strip: Optional[int] = 8,
                 packed: bool = True,
                 params=None):
        self.mcfg = mcfg
        self.backend = (backend if isinstance(backend, Backend)
                        else Backend(backend))
        self.scfg = scfg or seat_lib.SEATConfig(
            n_views=3, view_stride=8, max_read_len=mcfg.output_len,
            consensus_span=2 * mcfg.output_len)
        self.chunk = chunk or chunking.ChunkConfig(
            window=mcfg.input_len, hop=max(1, mcfg.input_len // 2))
        if self.chunk.window != mcfg.input_len:
            raise ValueError(
                f"chunk window {self.chunk.window} != model input_len "
                f"{mcfg.input_len}")
        self.beam_width = beam_width
        self.max_read_len = max_read_len or mcfg.output_len
        self.decode_strip = decode_strip
        self.packed = packed
        # id(float tree) -> (float tree, artifact); the strong ref pins the
        # id. Small FIFO so pipeline-default + engine/params= overrides of
        # different checkpoints coexist without repacking each other out.
        self._pack_cache: dict = {}
        # (id(tree), id(mesh)) -> mesh-replicated copy of a serving tree;
        # same bounded-FIFO discipline (strong refs pin both ids)
        self._placed_cache: dict = {}
        self.params = params
        self._trainer: Optional[PhasedTrainer] = None
        if mcfg.rnn_type == "lstm" and self.backend.mode != "ref":
            _warn_lstm_once(self.backend.mode)

    # -- construction ------------------------------------------------------
    @classmethod
    def from_preset(cls, name: str, *, quant: Optional[QuantConfig] = None,
                    backend: str | Backend = "auto", scale: str = "demo",
                    **kw) -> "BasecallPipeline":
        """Pipeline for a paper preset ("guppy"/"scrappie"/"chiron").

        Args:
            name: preset name — one of ``models.basecaller.PRESETS``.
            quant: optional ``QuantConfig`` replacing the preset's.
            backend: kernel registry backend (see class docstring).
            scale: "full" (Table 3 structure), "demo" (CPU-trainable), or
                "tiny" (unit-test widths).
            **kw: forwarded to the constructor (``beam_width``, ``chunk``,
                ``packed``, ...).

        Returns:
            A ready-to-init :class:`BasecallPipeline`.

        Example::

            pipe = BasecallPipeline.from_preset("guppy", scale="tiny",
                                                backend="ref")
        """
        if name not in bc.PRESETS:
            raise KeyError(f"unknown preset {name!r}; "
                           f"one of {sorted(bc.PRESETS)}")
        if scale not in _SCALES:
            raise KeyError(f"unknown scale {scale!r}; "
                           f"one of {sorted(_SCALES)}")
        mcfg = _SCALES[scale](name)
        if quant is not None:
            mcfg = mcfg.with_quant(quant)
        return cls(mcfg, backend=backend, **kw)

    # -- params + the quantize-once serving artifact -----------------------
    @property
    def params(self):
        """The float training checkpoint (pack-source for serving)."""
        return self._params_value

    @params.setter
    def params(self, value):
        # any rebind (init_params, trainer checkpoint) invalidates the
        # packed artifacts so serving repacks from the new generation
        self._params_value = value
        self._pack_cache.clear()
        self._placed_cache.clear()

    def init_params(self, key):
        """Initialize (and bind) a fresh float checkpoint from ``key``."""
        self.params = bc.init_basecaller(key, self.mcfg)
        return self.params

    def serving_params(self, params=None):
        """The weights the serving closures consume.

        With ``packed=True`` (default) this is the quantize-once
        ``PackedParams`` artifact: built lazily on first use and cached
        keyed on the float tree's identity (a small bounded cache, so the
        pipeline default and ``params=`` overrides — e.g. an engine
        serving a different checkpoint — each pack once).  ``init_params``
        / ``pipe.params = ...`` rebinds clear the cache, so a checkpoint
        re-trained mid-session is re-packed, never served stale.
        ``packed=False`` returns the float tree (the legacy
        repack-per-call path, kept as the benchmark baseline and
        differential oracle).
        """
        p = self._params(params)
        if not self.packed or bc.is_packed(p):
            return p
        hit = self._pack_cache.get(id(p))
        if hit is not None and hit[0] is p:
            return hit[1]
        artifact = bc.pack_basecaller(p, self.mcfg)
        _fifo_put(self._pack_cache, id(p), (p, artifact))
        return artifact

    def pack_artifact(self, params=None):
        """Build the quantize-once serving artifact WITHOUT touching the
        pipeline's own cache.

        The external-cache hook (``serve.registry.ModelRegistry``'s
        evict -> re-pack path): the packer is jitted and deterministic, so
        every call returns a bitwise-identical artifact and the caller
        fully owns its lifetime — evicting it frees the memory.  With
        ``packed=False`` (or already-packed ``params``) the weights pass
        through unchanged, like :meth:`serving_params`."""
        p = self._params(params)
        if not self.packed or bc.is_packed(p):
            return p
        return bc.pack_basecaller(p, self.mcfg)

    def data_config(self, *, kmer: int = 1, mean_dwell: float = 6.0,
                    max_label_len: Optional[int] = None
                    ) -> genome.SignalConfig:
        """Synthetic-channel config matching this model's window/margins."""
        return genome.SignalConfig(
            window=self.mcfg.input_len, margin=self.scfg.margin,
            max_label_len=max_label_len or self.scfg.max_read_len,
            kmer=kmer, mean_dwell=mean_dwell)

    def _params(self, params):
        p = params if params is not None else self.params
        if p is None:
            raise ValueError("no params: pass params= or call init_params()")
        return p

    def _place_params(self, params, mesh):
        """Replicate a serving tree onto ``mesh``, cached per (tree, mesh).

        dp shards *windows*, never weights: every device holds the whole
        serving artifact (``dist.sharding.replicated_sharding_tree`` — the
        param-rule machinery under a match-all REPLICATE override).  The
        mesh keys by VALUE (like ``_per_mesh``'s jit cache), so a caller
        building an equal-but-new Mesh per call does not re-transfer the
        whole artifact each time; the tree keys by identity (strong ref in
        the value pins the id)."""
        key = (id(params), mesh)
        hit = self._placed_cache.get(key)
        if hit is not None and hit[0] is params:
            return hit[1]
        placed = jax.device_put(
            params, shd.replicated_sharding_tree(params, mesh))
        _fifo_put(self._placed_cache, key, (params, placed))
        return placed

    # -- jitted stages -----------------------------------------------------
    def _per_mesh(self, build):
        """One jitted instance per ambient mesh (bounded cache).

        ``dist.sharding.constrain`` resolves the ambient mesh at TRACE
        time and bakes it into the jaxpr, while ``jax.jit`` caches traces
        on abstract values only — so a single jit object traced under mesh
        A would silently reuse A's constraints (or crash on incompatible
        devices) under mesh B.  Each mesh therefore gets its own jit
        instance, first-traced under its own ``use_mesh``."""
        fns: dict = {}

        def dispatch(*args):
            key = shd.get_mesh()                 # hashable; None off-mesh
            fn = fns.get(key)
            if fn is None:
                fn = build()
                _fifo_put(fns, key, fn)
            return fn(*args)

        dispatch.cache = fns  # mesh -> jit fn; analysis retrace guard hook
        return dispatch

    @functools.cached_property
    def _decode_windows(self):
        """(params, windows (N, window, C), logit_lengths (N,)) ->
        (reads (N, L), lens (N,), scores (N,)).

        Decode runs on the hash-merge beam decoder (``ctc_beam_search_hash
        _batch``) whose per-frame merge/top-k dispatches through the kernel
        registry on this pipeline's backend; ``logit_lengths`` masks the
        zero-padded frames of tail windows out of the decode.  ``scores``
        is the top beam's total log-probability per window (greedy: the
        best path's summed per-frame max) — the confidence signal the
        streaming eject policy consumes.  Dispatches to one jitted
        instance per ambient mesh (see ``_per_mesh``).
        """
        return self._per_mesh(self._build_decode_windows)

    def _build_decode_windows(self):
        mcfg, backend = self.mcfg, self.backend
        W, L = self.beam_width, self.max_read_len
        strip = self.decode_strip

        @jax.jit
        def fn(params, windows, logit_lengths):
            # under an ambient mesh the window batch stays split over the
            # logical "dp" axis through model + decode; the final replicate
            # is the all-gather that hands the host the full window set
            # for the shared stitch/vote (no-ops without a mesh)
            with jax.named_scope("stage:windows_in"):
                windows = shd.constrain(windows, ("dp", None, None))
            with jax.named_scope("stage:lengths_in"):
                logit_lengths = shd.constrain(logit_lengths, ("dp",))
            lps = bc.apply_basecaller(params, windows, mcfg, backend=backend)
            if W > 1:
                with jax.named_scope("stage:beam_in"):
                    lps = shd.constrain(lps, ("dp", None, None))
                reads, lens, scores = ctc_lib.ctc_beam_search_hash_batch(
                    lps, beam_width=W, max_len=L,
                    logit_lengths=logit_lengths, backend=backend,
                    strip_frames=strip)
                reads, lens, scores = reads[:, 0], lens[:, 0], scores[:, 0]
            else:
                reads, lens = jax.vmap(
                    lambda lp, ll: ctc_lib.ctc_greedy_decode(
                        lp, logit_length=ll))(lps, logit_lengths)
                reads = reads[:, :L] if reads.shape[1] >= L else jnp.pad(
                    reads, ((0, 0), (0, L - reads.shape[1])),
                    constant_values=-1)
                lens = jnp.minimum(lens, L)
                # greedy confidence: the best path's log-probability over
                # the valid (non-padded) frames — the W==1 analogue of the
                # top beam's total score
                T = lps.shape[1]
                frame_max = jnp.max(lps, axis=-1)              # (N, T)
                valid = jnp.arange(T)[None, :] < logit_lengths[:, None]
                scores = jnp.sum(jnp.where(valid, frame_max, 0.0), axis=-1)
            with jax.named_scope("stage:reads_out"):
                reads = shd.replicate(reads)
            with jax.named_scope("stage:lens_out"):
                lens = shd.replicate(lens)
            with jax.named_scope("stage:scores_out"):
                scores = shd.replicate(scores)
            return reads, lens, scores

        return fn

    @functools.cached_property
    def _windows_fused(self):
        """Fused SEAT-view serving path over (B, window+2*margin, C).

        One jitted instance per ambient mesh (see ``_per_mesh``)."""
        return self._per_mesh(self._build_windows_fused)

    def _build_windows_fused(self):
        mcfg, scfg, backend = self.mcfg, self.scfg, self.backend
        W = self.beam_width
        strip = self.decode_strip

        @jax.jit
        def fn(params, signal):
            with jax.named_scope("stage:fused_signal_in"):
                signal = shd.constrain(
                    signal, ("dp",) + (None,) * (signal.ndim - 1))
            views, center = seat_lib.make_views(signal, scfg)
            lps = jnp.stack([
                bc.apply_basecaller(params, v, mcfg, backend=backend)
                for v in views])
            C, C_len = seat_lib.consensus_reads(lps, center, scfg)
            with jax.named_scope("stage:beam_in"):
                center_lps = shd.constrain(lps[center], ("dp", None, None))
            reads, lens, scores = ctc_lib.ctc_beam_search_hash_batch(
                center_lps, beam_width=W, max_len=scfg.max_read_len,
                backend=backend, strip_frames=strip)
            with jax.named_scope("stage:fused_out"):
                return tuple(shd.replicate(t) for t in
                             (C, C_len, reads[:, 0], lens[:, 0],
                              scores[:, 0]))

        return fn

    # -- declared sharding boundaries (read by repro.analysis) -------------
    def decode_stage_boundaries(self) -> Tuple[str, ...]:
        """Stage boundaries of the jitted decode-windows trace, in order.

        Every name must realize a ``sharding_constraint`` under an
        ambient mesh (``stage:<name>`` scopes above + the model's own
        ``serving_stage_boundaries``); ``repro.analysis`` enforces this.
        """
        names = (("windows_in", "lengths_in")
                 + bc.serving_stage_boundaries(self.mcfg))
        if self.beam_width > 1:
            names += ("beam_in",)
        return names + ("reads_out", "lens_out", "scores_out")

    def fused_stage_boundaries(self) -> Tuple[str, ...]:
        """Stage boundaries of the fused SEAT-view serving trace."""
        return (("fused_signal_in",)
                + bc.serving_stage_boundaries(self.mcfg)
                + ("beam_in", "fused_out"))

    def window_logit_lengths(self, n_samples: int) -> np.ndarray:
        """(N,) decoder ``logit_lengths`` for one read's chunked windows."""
        valid = chunking.window_valid_samples(n_samples, self.chunk)
        return np.asarray(self.mcfg.output_frames(valid), np.int32)

    # -- long-read base-calling --------------------------------------------
    def basecall_iter(self, signal, params=None
                      ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """Stream (window_reads, window_lengths) one window-batch at a time.

        Device memory is bounded by ``chunk.batch_windows`` windows
        regardless of read length; the final partial batch is padded to
        the batch shape (one compiled program) and trimmed on host.

        Under an ambient ``dist.sharding.use_mesh`` mesh each batch is
        device-put split over the logical "dp" axis (the batch is rounded
        up to a multiple of the dp device count with inert zero-padding
        first — padded lanes carry ``logit_length == 0``, decode nothing,
        and are trimmed on host), params are replicated, and the decoded
        reads are all-gathered — so the yielded arrays are bitwise
        identical to the single-device path.

        Args:
            signal: (T,) or (T, C) raw current samples, any length.
            params: optional checkpoint override (defaults to the bound
                pipeline params; packed lazily via :meth:`serving_params`).

        Returns:
            An iterator of ``(reads (n, L) int32, lengths (n,) int32)``
            per window-batch, in window order.

        Example::

            for reads, lens in pipe.basecall_iter(sig):
                ...
        """
        # resolve params and the ambient mesh EAGERLY — a generator body
        # would not run until first next(), by which time the caller's
        # use_mesh block may have exited (the pin-at-creation contract)
        params = self.serving_params(params)
        mesh = shd.get_mesh()
        dp = shd.dp_size(mesh)
        if mesh is not None:
            params = self._place_params(params, mesh)
        return self._basecall_iter(signal, params, mesh, dp)

    def _basecall_iter(self, signal, params, mesh, dp
                       ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        windows = chunking.chunk_signal(signal, self.chunk)
        frame_lens = self.window_logit_lengths(np.asarray(signal).shape[0])
        N = windows.shape[0]
        B = self.chunk.batch_windows
        if B % dp:
            B += dp - B % dp          # every device batch divides "dp"
        for s in range(0, N, B):
            grp = windows[s: s + B]
            fl = frame_lens[s: s + B]
            n = grp.shape[0]
            if n < B:
                grp = np.concatenate(
                    [grp, np.zeros((B - n,) + grp.shape[1:], grp.dtype)])
                fl = np.concatenate([fl, np.zeros((B - n,), fl.dtype)])
            grp, fl = jnp.asarray(grp), jnp.asarray(fl)
            if mesh is not None:
                grp = jax.device_put(grp, shd.batch_sharding(mesh, grp.ndim))
                fl = jax.device_put(fl, shd.batch_sharding(mesh, fl.ndim))
            # re-pin the mesh captured at generator creation: a consumer
            # advancing this generator under a *different* ambient mesh
            # (or none) must not mix this batch's placement with a decode
            # trace built for that other mesh (use_mesh(None) masks outer
            # meshes the same way)
            with shd.use_mesh(mesh):
                reads, lens, _scores = self._decode_windows(params, grp, fl)
            yield np.asarray(reads[:n]), np.asarray(lens[:n])

    def basecall(self, signal, params=None,
                 span: Optional[int] = None) -> BasecallResult:
        """Base-call one arbitrarily long raw read end to end.

        Chunks into overlapping windows, batches them through the
        quantized model + CTC beam decode, and votes the per-window reads
        into a consensus aligned by their longest matches.  Runs
        dp-sharded (bitwise identically) under an ambient
        ``dist.sharding.use_mesh`` mesh — see :meth:`basecall_iter`.

        Args:
            signal: (T,) or (T, C) raw current samples; an empty signal
                returns an empty result, never a crash.
            params: optional checkpoint override.
            span: consensus grid length for the stitch/vote (defaults to
                ``max_read_len * n_windows``).

        Returns:
            A :class:`BasecallResult` — voted consensus read plus the
            per-window reads that elected it.

        Example::

            result = pipe.basecall(long_raw_signal)
            print(result.sequence())
        """
        reads, lens = [], []
        for r, l in self.basecall_iter(signal, params):
            reads.append(r)
            lens.append(l)
        if not reads:
            # empty signal => zero windows: an empty read, not a crash
            return BasecallResult.empty(self.max_read_len)
        return BasecallResult.from_window_reads(
            np.concatenate(reads), np.concatenate(lens),
            max_read_len=self.max_read_len, span=span)

    def stream(self, params=None):
        """Open an incremental :class:`~repro.serve.streaming.
        StreamingSession` bound to this pipeline.

        Feed raw-signal chunks as they arrive from a pore
        (``session.feed``), read provisional bases as overlap windows
        close, and ``session.finalize()`` into a :class:`BasecallResult`
        bitwise identical to :meth:`basecall` on the concatenated signal —
        chunk boundaries never change the result.  Captures the ambient
        ``dist.sharding.use_mesh`` mesh at creation, like
        :meth:`basecall_iter`.

        Args:
            params: optional checkpoint override (defaults to the bound
                pipeline params; packed via :meth:`serving_params`).

        Returns:
            A live ``StreamingSession`` decoding windows as they complete.

        Example::

            sess = pipe.stream()
            for chunk in chunks:
                sess.feed(chunk)
            result = sess.finalize()     # == pipe.basecall(full_signal)
        """
        # local import: serve.streaming imports this module for the
        # shared BasecallResult finalization
        from repro.serve.streaming import StreamingSession
        return StreamingSession(self, params=params)

    # -- fixed-window serving ----------------------------------------------
    def basecall_windows(self, signal_batch, params=None):
        """(B, window+2*margin, C) signal windows -> fused serving outputs.

        The SEAT 3-view vote next to the center view's best beam, all in
        one jitted call.  Under an ambient ``dist.sharding.use_mesh`` mesh
        the window batch is split over the logical "dp" axis; unlike
        :meth:`basecall` this surface serves a *caller-fixed* batch, so a
        batch that does not divide the dp device count raises a clear
        ``ValueError`` instead of being padded (padding here would change
        the shapes the caller handed us).

        Args:
            signal_batch: (B, window + 2*margin, C) fixed signal windows
                (the serving engine's slot batch shape).
            params: optional checkpoint override.

        Returns:
            ``(consensus (B, L), consensus_len (B,), top_read (B, L'),
            top_len (B,), top_score (B,))``.

        Example::

            C, C_len, top, top_len, score = pipe.basecall_windows(batch)
        """
        params = self.serving_params(params)
        batch = jnp.asarray(signal_batch)
        mesh = shd.get_mesh()
        if mesh is not None:
            dp = shd.dp_size(mesh)
            if batch.shape[0] % dp:
                raise ValueError(
                    f"basecall_windows: batch of {batch.shape[0]} windows "
                    f"does not divide the mesh's dp={dp} devices; pad the "
                    f"batch to a multiple of {dp} (basecall/basecall_iter "
                    f"pad automatically)")
            params = self._place_params(params, mesh)
            batch = jax.device_put(batch, shd.batch_sharding(mesh,
                                                             batch.ndim))
        return self._windows_fused(params, batch)

    # -- training ----------------------------------------------------------
    def trainer(self, policy: Optional[TrainPolicy] = None,
                opt=None) -> PhasedTrainer:
        """The warm-up + SEAT phase policy for THIS model's training path
        (fake-quant STE — never the integer serving backend)."""
        if self._trainer is None or policy is not None or opt is not None:
            mcfg = self.mcfg
            self._trainer = PhasedTrainer(
                lambda p, s: bc.apply_basecaller(p, s, mcfg),
                self.scfg, policy or TrainPolicy(), opt)
        return self._trainer

    def train_step(self, params, opt_state, batch, step: int):
        """One policy-scheduled update (see ``pipeline.training``)."""
        return self.trainer().step(params, opt_state, batch, step)
