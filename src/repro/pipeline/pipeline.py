"""``BasecallPipeline`` — the one facade over the Helix base-calling path.

The paper's end-to-end claim is about the *whole* pipeline: quantized DNN
inference, CTC decode, and read voting as one accelerated path.  This
class wires those stages together once, so callers stop re-plumbing
model -> ``ctc_beam_search_batch`` -> ``consensus_reads`` by hand:

    pipe = BasecallPipeline.from_preset("guppy",
                                        quant=QuantConfig(enabled=True),
                                        backend="auto")
    params = pipe.init_params(jax.random.PRNGKey(0))
    result = pipe.basecall(long_raw_signal)          # chunk/batch/decode/vote

Compute routes through ``repro.kernels.registry``: the ``backend`` switch
("auto" | "pallas" | "interpret" | "ref") picks the integer Pallas serving
path or the jnp oracle for every matmul/GRU step in one place.

Three call surfaces:
  basecall(signal)        — arbitrarily long raw read: overlapping windows,
                            batched model + CTC beam decode, voted consensus
  basecall_iter(signal)   — same, streaming one window-batch at a time
                            (bounded device memory for very long reads)
  basecall_windows(batch) — fixed (B, window+2*margin) signal windows through
                            the fused SEAT-view + consensus serving path
                            (what the serving engine batches over slots)
plus ``trainer()`` — the warm-up/SEAT two-phase policy (pipeline/training).

Serving consumes the quantize-once ``PackedParams`` artifact
(``serving_params()``: packed lazily, cached on checkpoint identity,
invalidated by ``init_params``/``params`` rebinds), while training keeps
the float checkpoint — the train-vs-serve split of ARCHITECTURE.md.
``packed=False`` preserves the legacy repack-per-call path as an oracle.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ctc as ctc_lib
from repro.core import seat as seat_lib
from repro.core.quant import QuantConfig
from repro.data import genome
from repro.kernels.registry import Backend
from repro.models import basecaller as bc
from repro.pipeline import chunking
from repro.pipeline.training import PhasedTrainer, TrainPolicy

_SCALES = {"full": lambda n: bc.PRESETS[n], "demo": bc.demo_preset,
           "tiny": bc.tiny_preset}

# the LSTM "no fused kernel" notice is a property of the build, not of any
# one pipeline — emit it once per process, not once per construction
_LSTM_KERNEL_WARNED = False


def _warn_lstm_once(mode: str) -> None:
    global _LSTM_KERNEL_WARNED
    if _LSTM_KERNEL_WARNED:
        return
    _LSTM_KERNEL_WARNED = True
    warnings.warn(
        "LSTM stacks have no fused kernel: the recurrent loop runs "
        "on the fake-quant path; only projections use the integer "
        f"backend ({mode}).", stacklevel=3)


def _reset_lstm_warning() -> None:
    """Test hook: make the next LSTM pipeline warn again."""
    global _LSTM_KERNEL_WARNED
    _LSTM_KERNEL_WARNED = False


@dataclasses.dataclass
class BasecallResult:
    """One long read's consensus + the per-window reads that voted it."""
    read: np.ndarray            # (span,) int32 base ids, padded -1
    length: int
    window_reads: np.ndarray    # (n_windows, max_read_len)
    window_lengths: np.ndarray  # (n_windows,)

    def sequence(self, alphabet: str = "ACGT") -> str:
        return "".join(alphabet[b] for b in self.read[: self.length])

    @classmethod
    def empty(cls, max_read_len: int) -> "BasecallResult":
        """The zero-window result (empty signal): one definition shared by
        the pipeline and the engine so they cannot diverge."""
        return cls(read=np.full((max_read_len,), -1, np.int32), length=0,
                   window_reads=np.zeros((0, max_read_len), np.int32),
                   window_lengths=np.zeros((0,), np.int32))

    @classmethod
    def from_window_reads(cls, reads: np.ndarray, lengths: np.ndarray,
                          *, max_read_len: int,
                          span: Optional[int] = None) -> "BasecallResult":
        """Vote one read's per-window decodes into its consensus.

        THE single finalization of the serving path: ``BasecallPipeline.
        basecall`` and ``serve.BasecallEngine`` both call this, which is
        what keeps engine ≡ pipeline bit for bit (zero windows -> empty,
        one window -> that read, else overlap-stitched consensus)."""
        reads = np.asarray(reads)
        lengths = np.asarray(lengths, np.int32)
        if reads.shape[0] == 0:
            return cls.empty(max_read_len)
        if reads.shape[0] == 1:
            cons, clen = reads[0], int(lengths[0])
        else:
            span = span or max_read_len * reads.shape[0]
            cons, clen = chunking.stitch_reads(
                jnp.asarray(reads), jnp.asarray(lengths), span=span)
            cons, clen = np.asarray(cons), int(clen)
        return cls(read=cons, length=clen, window_reads=reads,
                   window_lengths=lengths)


class BasecallPipeline:
    def __init__(self, mcfg: bc.BasecallerConfig, *,
                 backend: str | Backend = "auto",
                 scfg: Optional[seat_lib.SEATConfig] = None,
                 chunk: Optional[chunking.ChunkConfig] = None,
                 beam_width: int = 5,
                 max_read_len: Optional[int] = None,
                 packed: bool = True,
                 params=None):
        self.mcfg = mcfg
        self.backend = (backend if isinstance(backend, Backend)
                        else Backend(backend))
        self.scfg = scfg or seat_lib.SEATConfig(
            n_views=3, view_stride=8, max_read_len=mcfg.output_len,
            consensus_span=2 * mcfg.output_len)
        self.chunk = chunk or chunking.ChunkConfig(
            window=mcfg.input_len, hop=max(1, mcfg.input_len // 2))
        if self.chunk.window != mcfg.input_len:
            raise ValueError(
                f"chunk window {self.chunk.window} != model input_len "
                f"{mcfg.input_len}")
        self.beam_width = beam_width
        self.max_read_len = max_read_len or mcfg.output_len
        self.packed = packed
        # id(float tree) -> (float tree, artifact); the strong ref pins the
        # id. Small FIFO so pipeline-default + engine/params= overrides of
        # different checkpoints coexist without repacking each other out.
        self._pack_cache: dict = {}
        self.params = params
        self._trainer: Optional[PhasedTrainer] = None
        if mcfg.rnn_type == "lstm" and self.backend.mode != "ref":
            _warn_lstm_once(self.backend.mode)

    # -- construction ------------------------------------------------------
    @classmethod
    def from_preset(cls, name: str, *, quant: Optional[QuantConfig] = None,
                    backend: str | Backend = "auto", scale: str = "demo",
                    **kw) -> "BasecallPipeline":
        """Pipeline for a paper preset ("guppy"/"scrappie"/"chiron").

        ``scale``: "full" (Table 3 structure), "demo" (CPU-trainable), or
        "tiny" (unit-test widths).
        """
        if name not in bc.PRESETS:
            raise KeyError(f"unknown preset {name!r}; "
                           f"one of {sorted(bc.PRESETS)}")
        if scale not in _SCALES:
            raise KeyError(f"unknown scale {scale!r}; "
                           f"one of {sorted(_SCALES)}")
        mcfg = _SCALES[scale](name)
        if quant is not None:
            mcfg = mcfg.with_quant(quant)
        return cls(mcfg, backend=backend, **kw)

    # -- params + the quantize-once serving artifact -----------------------
    @property
    def params(self):
        """The float training checkpoint (pack-source for serving)."""
        return self._params_value

    @params.setter
    def params(self, value):
        # any rebind (init_params, trainer checkpoint) invalidates the
        # packed artifacts so serving repacks from the new generation
        self._params_value = value
        self._pack_cache.clear()

    def init_params(self, key):
        self.params = bc.init_basecaller(key, self.mcfg)
        return self.params

    def serving_params(self, params=None):
        """The weights the serving closures consume.

        With ``packed=True`` (default) this is the quantize-once
        ``PackedParams`` artifact: built lazily on first use and cached
        keyed on the float tree's identity (a small bounded cache, so the
        pipeline default and ``params=`` overrides — e.g. an engine
        serving a different checkpoint — each pack once).  ``init_params``
        / ``pipe.params = ...`` rebinds clear the cache, so a checkpoint
        re-trained mid-session is re-packed, never served stale.
        ``packed=False`` returns the float tree (the legacy
        repack-per-call path, kept as the benchmark baseline and
        differential oracle).
        """
        p = self._params(params)
        if not self.packed or bc.is_packed(p):
            return p
        hit = self._pack_cache.get(id(p))
        if hit is not None and hit[0] is p:
            return hit[1]
        artifact = bc.pack_basecaller(p, self.mcfg)
        if len(self._pack_cache) >= 4:                   # bounded, FIFO
            self._pack_cache.pop(next(iter(self._pack_cache)))
        self._pack_cache[id(p)] = (p, artifact)
        return artifact

    def data_config(self, *, kmer: int = 1, mean_dwell: float = 6.0,
                    max_label_len: Optional[int] = None
                    ) -> genome.SignalConfig:
        """Synthetic-channel config matching this model's window/margins."""
        return genome.SignalConfig(
            window=self.mcfg.input_len, margin=self.scfg.margin,
            max_label_len=max_label_len or self.scfg.max_read_len,
            kmer=kmer, mean_dwell=mean_dwell)

    def _params(self, params):
        p = params if params is not None else self.params
        if p is None:
            raise ValueError("no params: pass params= or call init_params()")
        return p

    # -- jitted stages -----------------------------------------------------
    @functools.cached_property
    def _decode_windows(self):
        """(params, windows (N, window, C), logit_lengths (N,)) ->
        (reads (N, L), lens (N,)).

        Decode runs on the hash-merge beam decoder (``ctc_beam_search_hash
        _batch``) whose per-frame merge/top-k dispatches through the kernel
        registry on this pipeline's backend; ``logit_lengths`` masks the
        zero-padded frames of tail windows out of the decode.
        """
        mcfg, backend = self.mcfg, self.backend
        W, L = self.beam_width, self.max_read_len

        @jax.jit
        def fn(params, windows, logit_lengths):
            lps = bc.apply_basecaller(params, windows, mcfg, backend=backend)
            if W > 1:
                reads, lens, _ = ctc_lib.ctc_beam_search_hash_batch(
                    lps, beam_width=W, max_len=L,
                    logit_lengths=logit_lengths, backend=backend)
                return reads[:, 0], lens[:, 0]
            reads, lens = jax.vmap(
                lambda lp, ll: ctc_lib.ctc_greedy_decode(lp, logit_length=ll)
            )(lps, logit_lengths)
            reads = reads[:, :L] if reads.shape[1] >= L else jnp.pad(
                reads, ((0, 0), (0, L - reads.shape[1])), constant_values=-1)
            return reads, jnp.minimum(lens, L)

        return fn

    @functools.cached_property
    def _windows_fused(self):
        """Fused SEAT-view serving path over (B, window+2*margin, C)."""
        mcfg, scfg, backend = self.mcfg, self.scfg, self.backend
        W = self.beam_width

        @jax.jit
        def fn(params, signal):
            views, center = seat_lib.make_views(signal, scfg)
            lps = jnp.stack([
                bc.apply_basecaller(params, v, mcfg, backend=backend)
                for v in views])
            C, C_len = seat_lib.consensus_reads(lps, center, scfg)
            reads, lens, scores = ctc_lib.ctc_beam_search_hash_batch(
                lps[center], beam_width=W, max_len=scfg.max_read_len,
                backend=backend)
            return C, C_len, reads[:, 0], lens[:, 0], scores[:, 0]

        return fn

    def window_logit_lengths(self, n_samples: int) -> np.ndarray:
        """(N,) decoder ``logit_lengths`` for one read's chunked windows."""
        valid = chunking.window_valid_samples(n_samples, self.chunk)
        return np.asarray(self.mcfg.output_frames(valid), np.int32)

    # -- long-read base-calling --------------------------------------------
    def basecall_iter(self, signal, params=None
                      ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """Stream (window_reads, window_lengths) one window-batch at a time.

        Device memory is bounded by ``chunk.batch_windows`` windows
        regardless of read length; the final partial batch is padded to
        the batch shape (one compiled program) and trimmed on host.
        """
        params = self.serving_params(params)
        windows = chunking.chunk_signal(signal, self.chunk)
        frame_lens = self.window_logit_lengths(np.asarray(signal).shape[0])
        N = windows.shape[0]
        B = self.chunk.batch_windows
        for s in range(0, N, B):
            grp = windows[s: s + B]
            fl = frame_lens[s: s + B]
            n = grp.shape[0]
            if n < B:
                grp = np.concatenate(
                    [grp, np.zeros((B - n,) + grp.shape[1:], grp.dtype)])
                fl = np.concatenate([fl, np.zeros((B - n,), fl.dtype)])
            reads, lens = self._decode_windows(params, jnp.asarray(grp),
                                               jnp.asarray(fl))
            yield np.asarray(reads[:n]), np.asarray(lens[:n])

    def basecall(self, signal, params=None,
                 span: Optional[int] = None) -> BasecallResult:
        """Base-call one arbitrarily long raw read end to end.

        Chunks into overlapping windows, batches them through the
        quantized model + CTC beam decode, and votes the per-window reads
        into a consensus aligned by their longest matches.
        """
        reads, lens = [], []
        for r, l in self.basecall_iter(signal, params):
            reads.append(r)
            lens.append(l)
        if not reads:
            # empty signal => zero windows: an empty read, not a crash
            return BasecallResult.empty(self.max_read_len)
        return BasecallResult.from_window_reads(
            np.concatenate(reads), np.concatenate(lens),
            max_read_len=self.max_read_len, span=span)

    # -- fixed-window serving ----------------------------------------------
    def basecall_windows(self, signal_batch, params=None):
        """(B, window+2*margin, C) signal windows -> fused serving outputs.

        Returns (consensus (B, L), consensus_len (B,), top_read (B, L'),
        top_len (B,), top_score (B,)) — the SEAT 3-view vote next to the
        center view's best beam, all in one jitted call.
        """
        return self._windows_fused(self.serving_params(params),
                                   jnp.asarray(signal_batch))

    # -- training ----------------------------------------------------------
    def trainer(self, policy: Optional[TrainPolicy] = None,
                opt=None) -> PhasedTrainer:
        """The warm-up + SEAT phase policy for THIS model's training path
        (fake-quant STE — never the integer serving backend)."""
        if self._trainer is None or policy is not None or opt is not None:
            mcfg = self.mcfg
            self._trainer = PhasedTrainer(
                lambda p, s: bc.apply_basecaller(p, s, mcfg),
                self.scfg, policy or TrainPolicy(), opt)
        return self._trainer

    def train_step(self, params, opt_state, batch, step: int):
        """One policy-scheduled update (see ``pipeline.training``)."""
        return self.trainer().step(params, opt_state, batch, step)
