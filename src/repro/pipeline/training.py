"""Two-phase base-caller training as a policy object.

The paper's own observation (§4.1/Fig 10): "when the read error rate is
high, it is faster to improve the quality of each read independently" —
so training warms up on the plain CTC loss and only then enables SEAT's
consensus term.  ``TrainPolicy`` owns that schedule; ``PhasedTrainer``
compiles ONE jitted step per phase and picks by step index, replacing the
hand-rolled two-phase loop the quickstart used to carry.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import seat as seat_lib
from repro.train.optimizer import AdamW, warmup_cosine


@dataclasses.dataclass(frozen=True)
class TrainPolicy:
    """Phase schedule: [0, warmup_steps) plain CTC, then SEAT."""
    warmup_steps: int = 220
    seat_steps: int = 80
    lr: float = 4e-3
    lr_warmup: int = 15

    @property
    def total_steps(self) -> int:
        return self.warmup_steps + self.seat_steps

    def phase(self, step: int) -> str:
        return "warmup" if step < self.warmup_steps else "seat"

    def make_optimizer(self) -> AdamW:
        return AdamW(lr=warmup_cosine(self.lr, self.lr_warmup,
                                      self.total_steps))


class PhasedTrainer:
    """Jitted warm/SEAT train steps sharing one optimizer state.

    ``logits_fn(params, signal) -> log-probs`` is the model closure (the
    pipeline passes the fake-quant training path — never the integer
    serving backend, which has no STE gradients).
    """

    def __init__(self, logits_fn: Callable, scfg: seat_lib.SEATConfig,
                 policy: TrainPolicy, opt: AdamW | None = None):
        self.policy = policy
        self.opt = opt or policy.make_optimizer()
        self._steps = {
            "warmup": self._make_step(
                logits_fn, dataclasses.replace(scfg, enabled=False)),
            "seat": self._make_step(logits_fn, scfg),
        }

    def init(self, params):
        return self.opt.init(params)

    def _make_step(self, logits_fn, scfg):
        opt = self.opt

        @jax.jit
        def train_step(params, opt_state, batch):
            def loss_fn(p):
                fn = lambda s: logits_fn(p, s)  # noqa: E731
                return seat_lib.seat_loss(fn, batch["signal"],
                                          batch["labels"],
                                          batch["label_length"], scfg)

            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, loss, metrics

        return train_step

    def step(self, params, opt_state, batch, step: int
             ) -> Tuple[dict, dict, jnp.ndarray, Dict[str, jnp.ndarray]]:
        """One phase-appropriate update; returns (params, state, loss, m)."""
        fn = self._steps[self.policy.phase(step)]
        return fn(params, opt_state, batch)
