"""Sharded host data loader with background prefetch.

Production input pipeline: a generator thread produces per-step batches
(deterministic in the global step — restart replay, see data/genome.py),
a bounded queue overlaps host data generation with device compute, and
``device_put`` places each batch with the trainer's NamedSharding so the
jitted step never blocks on host->device transfer of an unsharded array.

On a pod each process feeds its addressable shard
(``jax.make_array_from_process_local_data`` path); in this single-process
container ``device_put`` with a NamedSharding covers both cases.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax


class PrefetchLoader:
    def __init__(self, batch_fn: Callable[[int], dict], start_step: int = 0,
                 prefetch: int = 2, sharding=None):
        """batch_fn(step) -> pytree of host arrays."""
        self.batch_fn = batch_fn
        self.sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self.sharding is None:
            return batch
        if isinstance(self.sharding, dict):
            return {k: jax.device_put(v, self.sharding.get(k))
                    for k, v in batch.items()}
        return jax.tree_util.tree_map(
            lambda x: jax.device_put(x, self.sharding), batch)

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                batch = self._place(self.batch_fn(step))
            except Exception as e:  # surface generator failures to consumer
                self._q.put(e)
                return
            self._q.put((step, batch))
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
