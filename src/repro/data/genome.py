"""Synthetic nanopore sequencing channel (data gate — see DESIGN.md §8).

Real R9.4 fast5 training data is not available offline, so we simulate the
physics the paper describes (§2.2, §5.2):

  DNA sequence --(k-mer pore model)--> current levels
              --(stochastic dwell)---> non-uniform sample counts per base
              --(additive noise)-----> raw signal
              --(chunk normalize)----> (signal - mean) / std     [paper §5.2]

The pore model is a fixed pseudo-random 6-mer -> current table (the shape of
real pore tables: each 6-mer has a characteristic pA level).  Dwell times are
geometric-ish (1 + clipped Poisson), reproducing the "no alignment between
signal and read" property that makes CTC necessary.

Everything is jit/vmap-compatible with fixed shapes so the loader can run on
device and per-example keys make data fully deterministic+resumable (the
fault-tolerance story: a restarted trainer regenerates identical batches from
the step index).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

N_BASES = 4


@dataclasses.dataclass(frozen=True)
class SignalConfig:
    window: int = 300          # center window samples (paper: 300 x 1)
    margin: int = 0            # extra samples each side (SEAT views)
    kmer: int = 6              # pore model context
    mean_dwell: float = 8.0    # samples per base
    noise_std: float = 0.25    # channel noise (relative to level std)
    max_label_len: int = 96    # label pad length
    genome_chunk: int = 0      # bases simulated per chunk (0 => auto)

    @property
    def total_samples(self) -> int:
        return self.window + 2 * self.margin

    @property
    def chunk_bases(self) -> int:
        if self.genome_chunk:
            return self.genome_chunk
        # enough bases that Σ dwell >= total samples with huge probability
        return int(self.total_samples / self.mean_dwell * 2.5) + self.kmer + 4


def pore_table(kmer: int = 6, seed: int = 7) -> jnp.ndarray:
    """Fixed pseudo-random pore model: 4^k current levels, standardized."""
    n = N_BASES ** kmer
    tbl = jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32)
    return (tbl - tbl.mean()) / tbl.std()


_PORE_CACHE: dict = {}


def _pore(kmer: int) -> jnp.ndarray:
    if kmer not in _PORE_CACHE:
        _PORE_CACHE[kmer] = pore_table(kmer)
    return _PORE_CACHE[kmer]


def _levels_and_dwell(seq, cfg: SignalConfig, k_dwell):
    """Shared channel core: per-base pore current levels + stochastic dwell.

    seq (nb,) base ids -> (levels (nb,) f32, dwell (nb,) int32).  k-mer ids
    come from a base-4 rolling window over ``seq``; dwell is 1 +
    clipped-Poisson(mean-1).  jit/vmap-safe (shapes fixed by ``seq``).
    """
    nb = seq.shape[0]
    powers = N_BASES ** jnp.arange(cfg.kmer)
    padded = jnp.concatenate([jnp.zeros((cfg.kmer - 1,), seq.dtype), seq])
    windows = jnp.stack([padded[i: i + nb] for i in range(cfg.kmer)], axis=0)
    kmer_ids = jnp.tensordot(powers, windows, axes=1)          # (nb,)
    levels = _pore(cfg.kmer)[kmer_ids]                         # (nb,)

    lam = cfg.mean_dwell - 1.0
    dwell = 1 + jnp.clip(jax.random.poisson(k_dwell, lam, (nb,)), 0,
                         int(4 * cfg.mean_dwell)).astype(jnp.int32)
    return levels, dwell


def sample_example(key, cfg: SignalConfig):
    """One training example.

    Returns dict:
      signal: (total_samples, 1) normalized current
      labels: (max_label_len,) base ids for the CENTER window, padded 0
      label_length: () int32
    """
    k_seq, k_dwell, k_noise = jax.random.split(key, 3)
    nb = cfg.chunk_bases
    seq = jax.random.randint(k_seq, (nb,), 0, N_BASES)
    levels, dwell = _levels_and_dwell(seq, cfg, k_dwell)
    ends = jnp.cumsum(dwell)                                   # (nb,)
    # base index for each output sample
    t = jnp.arange(cfg.total_samples)
    base_idx = jnp.searchsorted(ends, t, side="right")
    base_idx = jnp.minimum(base_idx, nb - 1)

    raw = levels[base_idx]
    raw = raw + cfg.noise_std * jax.random.normal(
        k_noise, raw.shape, jnp.float32)
    signal = (raw - raw.mean()) / (raw.std() + 1e-6)           # paper §5.2

    # labels: distinct consecutive bases covered by the CENTER window
    ct = jnp.arange(cfg.margin, cfg.margin + cfg.window)
    cidx = jnp.minimum(jnp.searchsorted(ends, ct, side="right"), nb - 1)
    first = jnp.concatenate([jnp.ones((1,), bool), cidx[1:] != cidx[:-1]])
    n_lab = first.sum().astype(jnp.int32)
    wpos = jnp.cumsum(first.astype(jnp.int32)) - 1
    labels = jnp.zeros((cfg.max_label_len,), jnp.int32)
    labels = labels.at[jnp.where(first, jnp.minimum(wpos, cfg.max_label_len - 1),
                                 cfg.max_label_len)].set(
        seq[cidx].astype(jnp.int32), mode="drop")
    n_lab = jnp.minimum(n_lab, cfg.max_label_len)

    return {"signal": signal[:, None], "labels": labels,
            "label_length": n_lab}


def render_signal(seq, cfg: SignalConfig, key):
    """Raw current trace for a GIVEN base sequence (golden-read fixtures).

    Same channel physics as ``sample_example`` — k-mer pore levels,
    stochastic dwell, additive noise, standardization — but driven by a
    caller-supplied sequence over its full (variable) length, so tests can
    round-trip genome -> signal -> basecall against known truth.  Host-side
    data prep: shapes depend on the drawn dwells, so this is not jittable.

    Returns (signal (sum(dwell),) float32, dwell (len(seq),) int32).
    """
    seq = jnp.asarray(seq, jnp.int32)
    k_dwell, k_noise = jax.random.split(key)
    levels, dwell = _levels_and_dwell(seq, cfg, k_dwell)
    raw = jnp.repeat(levels, dwell)
    raw = raw + cfg.noise_std * jax.random.normal(
        k_noise, raw.shape, jnp.float32)
    signal = (raw - raw.mean()) / (raw.std() + 1e-6)
    return signal, dwell


def sample_batch(key, batch: int, cfg: SignalConfig):
    keys = jax.random.split(key, batch)
    return jax.vmap(lambda k: sample_example(k, cfg))(keys)


def batch_for_step(step: int, batch: int, cfg: SignalConfig, seed: int = 0):
    """Deterministic batch for a global step (restart-safe data order)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    return sample_batch(key, batch, cfg)
