"""Fake-multi-device host bootstrap (jax-free on purpose).

XLA locks the host device count at first backend initialization, so the
``--xla_force_host_platform_device_count`` flag must land in ``XLA_FLAGS``
BEFORE anything imports jax.  This module therefore imports nothing that
does: tests' conftest, doc-snippet subprocess launchers, and standalone
benchmarks all call :func:`force_host_devices` as their very first step.
"""
from __future__ import annotations

import os
from typing import MutableMapping

FLAG = "--xla_force_host_platform_device_count"


def force_host_devices(n: int,
                       env: MutableMapping[str, str] = os.environ,
                       override: bool = False) -> None:
    """Prepend ``{FLAG}={n}`` to ``env["XLA_FLAGS"]``.

    By default an already-present flag wins (a user/caller-set count is
    respected); ``override=True`` replaces it — what the doc-snippet
    subprocess launcher uses so a stray flag inherited from the parent
    environment cannot change the device count its snippets rely on.
    No-op once jax has initialized its backend — call it first."""
    flags = env.get("XLA_FLAGS", "")
    if FLAG in flags:
        if not override:
            return
        flags = " ".join(t for t in flags.split() if not t.startswith(FLAG))
    env["XLA_FLAGS"] = (f"{FLAG}={n} " + flags).strip()
