"""Guppy base-caller (paper Table 3): 1 conv + 5 GRU + FC + CTC."""
from repro.models.basecaller import GUPPY as CONFIG
from repro.models.basecaller import tiny_preset


def smoke_config():
    return tiny_preset("guppy")
