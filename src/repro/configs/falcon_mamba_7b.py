"""falcon-mamba-7b [ssm]: attention-free Mamba-1 stack.

64L d_model=4096 (attn-free) d_ff=0 vocab=65024 ssm_state=16
[arXiv:2410.05355]. d_inner=8192 (expand 2), dt_rank=256, conv 4.
long_500k RUNS: O(1) recurrent state per layer.
"""
import dataclasses

from repro.models.layers import SSMConfig
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="falcon-mamba-7b",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=65024,
    block_pattern="mamba", ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, vocab_size=256,
        ssm=SSMConfig(d_state=4, d_conv=4, expand=2),
        remat=False, act_shard=False)
