"""llama3.2-3b [dense]: small llama3.

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256
[hf:meta-llama/Llama-3.2-3B].
"""
import dataclasses

from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="llama3.2-3b",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=128256,
    rope_theta=5e5,
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, attn_chunk=32, remat=False,
        act_shard=False)
