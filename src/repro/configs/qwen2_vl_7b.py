"""qwen2-vl-7b [vlm]: dense decoder with M-RoPE, dynamic-resolution ViT stub.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 [arXiv:2409.12191].
M-RoPE sections (t,h,w)=(16,24,24) over head_dim=128.  The vision frontend
is a STUB: input_specs() provides pre-merged patch+text embeddings
(B, S, 3584); position streams are degenerate (text mode) in the dry-run.
"""
import dataclasses

from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="qwen2-vl-7b",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064,
    qkv_bias=True, mrope_sections=(16, 24, 24), rope_theta=1e6,
    embed_inputs=False,
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, mrope_sections=(2, 3, 3), attn_chunk=32,
        remat=False, act_shard=False)
