"""llama4-maverick-400b-a17b [moe]: 128-expert top-1 MoE, alternating layers.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 (expert) vocab=202048, MoE 128e
top-1 with a shared expert, MoE every OTHER layer (interleave step 2, dense
layers use d_ff=16384) [hf:meta-llama/Llama-4-Maverick; config arithmetic:
24*(2*attn + dense_ff + 128e moe + shared) + embeds = ~400B total / ~17B
active].  Early-fusion multimodality is out of scope (text backbone only).
long_500k SKIPPED: the published model's 1-in-4 global-attention layers keep
a full-length KV at 500k (DESIGN.md §5).
"""
import dataclasses

from repro.models.layers import MoEConfig
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, d_ff_dense=16384, vocab_size=202048,
    block_pattern="alt_dense_moe",
    moe=MoEConfig(n_experts=128, top_k=1, shared_expert=True),
    rope_theta=5e5,
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, d_ff_dense=64, vocab_size=256,
        moe=MoEConfig(n_experts=8, top_k=1, shared_expert=True),
        attn_chunk=32, remat=False, act_shard=False)
