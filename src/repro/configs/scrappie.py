"""Scrappie base-caller (paper Table 3): 1 conv(stride 5) + 5 GRU + FC."""
from repro.models.basecaller import SCRAPPIE as CONFIG
from repro.models.basecaller import tiny_preset


def smoke_config():
    return tiny_preset("scrappie")
