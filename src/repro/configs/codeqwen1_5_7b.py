"""codeqwen1.5-7b [dense]: qwen1.5 architecture (MHA + QKV bias).

32L d_model=4096 32H (GQA kv=32 == MHA) d_ff=13440 vocab=92416
[hf:Qwen/CodeQwen1.5-7B].
"""
import dataclasses

from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="codeqwen1.5-7b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=13440, vocab_size=92416,
    qkv_bias=True, rope_theta=1e6,
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, attn_chunk=32, remat=False,
        act_shard=False)
