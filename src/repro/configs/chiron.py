"""Chiron base-caller (paper Table 3): conv blocks + bidi LSTM + FC."""
from repro.models.basecaller import CHIRON as CONFIG
from repro.models.basecaller import tiny_preset


def smoke_config():
    return tiny_preset("chiron")
