"""qwen2.5-3b [dense]: GQA with QKV bias, tied embeddings.

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936
[hf:Qwen/Qwen2.5-3B].
"""
import dataclasses

from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="qwen2.5-3b",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, head_dim=128,
    d_ff=11008, vocab_size=151936,
    qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, attn_chunk=32, remat=False,
        act_shard=False)
