"""hymba-1.5b [hybrid]: parallel attention + Mamba heads in every layer.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001 ssm_state=16
[arXiv:2411.13676].  head_dim=64 (25*64=1600).  Sliding-window attention
(the published model uses SWA in all but 3 layers; we window every layer —
the parallel SSM path carries global context, see DESIGN.md §5). Meta-token
prepending is not modeled.  long_500k RUNS: O(window) ring + O(1) SSM state.
"""
import dataclasses

from repro.models.layers import SSMConfig
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="hymba-1.5b",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    block_pattern="hybrid", ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    window=1024, rope_theta=1e4,
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, ssm=SSMConfig(d_state=4, d_conv=4, expand=2),
        window=16, attn_chunk=32, remat=False, act_shard=False)
