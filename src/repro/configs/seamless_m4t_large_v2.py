"""seamless-m4t-large-v2 [audio]: enc-dec multimodal transformer.

24L d_model=1024 16H (MHA kv=16) d_ff=8192 vocab=256206 [arXiv:2308.11596].
Interpreted as 24 encoder + 24 decoder layers (the published model pairs a
24-layer speech encoder with a 24-layer text decoder).  The audio frontend
(fbank -> conformer adaptor) is a STUB: input_specs() supplies precomputed
frame embeddings (B, S_enc, 1024).  GELU FF + LayerNorm per the fairseq2
stack; RoPE replaces learned positions (TPU-era adaptation, DESIGN.md §5).
"""
import dataclasses

from repro.models.lm import EncoderConfig, LMConfig

CONFIG = LMConfig(
    name="seamless-m4t-large-v2",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    encoder=EncoderConfig(n_layers=24),
    ff_type="gelu", norm_type="ln", rope_theta=1e4,
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, encoder=EncoderConfig(n_layers=2), attn_chunk=32,
        remat=False, act_shard=False)
