"""olmoe-1b-7b [moe]: 64-expert top-8 MoE in every layer.

16L d_model=2048 16H (MHA kv=16) d_ff=1024 (per expert) vocab=50304
MoE 64e top-8 [arXiv:2409.02060]. ~6.9B total / ~1.3B active.
"""
import dataclasses

from repro.models.layers import MoEConfig
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="olmoe-1b-7b",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304,
    block_pattern="moe", moe=MoEConfig(n_experts=64, top_k=8),
    rope_theta=1e4,
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=32, vocab_size=256, moe=MoEConfig(n_experts=8, top_k=2),
        attn_chunk=32, remat=False, act_shard=False)
