"""h2o-danube-1.8b [dense]: llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000 [arXiv:2401.16818].
head_dim=80. SWA window=4096 => long_500k RUNS with an O(window) ring cache.
"""
import dataclasses

from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="h2o-danube-1.8b",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=80,
    d_ff=6912, vocab_size=32000,
    window=4096, rope_theta=1e4,
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, window=16, attn_chunk=32, remat=False,
        act_shard=False)
