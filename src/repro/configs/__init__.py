"""Architecture registry: the paper's base-callers + 10 assigned LM archs.

``list_archs()``        -> all known arch ids.
``get_config(arch_id)`` -> full published config (dry-run / roofline only).
``get_smoke(arch_id)``  -> reduced same-family config (CPU tests).
"""
from __future__ import annotations

import difflib
import importlib

BASECALLER_IDS = ("guppy", "scrappie", "chiron")

LM_IDS = (
    "seamless-m4t-large-v2",
    "qwen2-vl-7b",
    "hymba-1.5b",
    "codeqwen1.5-7b",
    "llama3.2-3b",
    "h2o-danube-1.8b",
    "qwen2.5-3b",
    "olmoe-1b-7b",
    "llama4-maverick-400b-a17b",
    "falcon-mamba-7b",
)

ARCH_IDS = LM_IDS + BASECALLER_IDS

_MODULES = {
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "hymba-1.5b": "hymba_1_5b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "llama3.2-3b": "llama3_2_3b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "qwen2.5-3b": "qwen2_5_3b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "guppy": "guppy",
    "scrappie": "scrappie",
    "chiron": "chiron",
}


def list_archs() -> tuple:
    """All registered architecture ids (base-callers + LMs)."""
    return ARCH_IDS


def _module(arch_id: str):
    if arch_id not in _MODULES:
        close = difflib.get_close_matches(arch_id, _MODULES, n=1)
        hint = f"; did you mean '{close[0]}'?" if close else ""
        raise KeyError(f"unknown arch '{arch_id}'{hint} "
                       f"(known: {sorted(_MODULES)})")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str):
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str):
    return _module(arch_id).smoke_config()


#: multi-tenant serving tiers: named speed/accuracy points a fleet hosts
#: side by side (small triages ReadUntil streams, large makes the final
#: calls).  Each maps a tier id -> (basecaller arch, pipeline kwargs);
#: ``serve_tier_pipeline`` turns one into a ready BasecallPipeline for
#: ``ModelRegistry.register_basecaller``.
SERVE_TIERS = {
    "small": ("guppy", {"scale": "tiny", "beam_width": 3}),
    "large": ("chiron", {"scale": "tiny", "beam_width": 5}),
}


def serve_tier_pipeline(tier_id: str, seed: int = 0, **overrides):
    """Build the named serving tier's ``BasecallPipeline``, params
    initialized from ``seed`` (overrides forward to ``from_preset`` —
    e.g. ``backend=``, ``batch_windows=``)."""
    import jax

    from repro.pipeline.pipeline import BasecallPipeline
    if tier_id not in SERVE_TIERS:
        raise KeyError(f"unknown serving tier {tier_id!r} "
                       f"(known: {sorted(SERVE_TIERS)})")
    arch, kw = SERVE_TIERS[tier_id]
    pipe = BasecallPipeline.from_preset(arch, **{**kw, **overrides})
    pipe.init_params(jax.random.PRNGKey(seed))
    return pipe
