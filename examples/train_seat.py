"""End-to-end driver (the paper's kind): train a quantized base-caller with
SEAT for a few hundred steps, with checkpoints + fault tolerance.

    PYTHONPATH=src python examples/train_seat.py \
        [--steps 300] [--bits 5] [--no-seat] [--arch guppy] \
        [--ckpt-dir /tmp/seat_ckpt] [--resume]

Uses the production Trainer (deterministic per-step data, async atomic
checkpoints, straggler detection, crash-restart supervisor) on the reduced
config; swap in models.basecaller.PRESETS[arch] for the full Table 3 model.
"""
import argparse
import dataclasses
import functools

import jax
import numpy as np

from repro.core import seat as seat_lib
from repro.core.quant import QuantConfig
from repro.data import genome
from repro.models import basecaller as bc
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.trainer import Trainer, TrainerConfig


def build(args):
    scfg = seat_lib.SEATConfig(n_views=3, view_stride=8, max_read_len=40,
                               consensus_span=80,
                               enabled=not args.no_seat)
    q = (QuantConfig(enabled=True, bits_w=args.bits, bits_a=args.bits)
         if args.bits < 32 else QuantConfig())
    mcfg = bc.demo_preset(args.arch).with_quant(q)
    dcfg = genome.SignalConfig(window=mcfg.input_len, margin=scfg.margin,
                               max_label_len=40, kmer=1, mean_dwell=6.0)

    def loss_fn(params, batch):
        fn = lambda s: bc.apply_basecaller(params, s, mcfg)
        return seat_lib.seat_loss(fn, batch["signal"], batch["labels"],
                                  batch["label_length"], scfg)

    def data_fn(step):
        return genome.batch_for_step(step, args.batch, dcfg, seed=1)

    params = bc.init_basecaller(jax.random.PRNGKey(0), mcfg)
    opt = AdamW(lr=warmup_cosine(2e-3, 20, args.steps))
    tcfg = TrainerConfig(steps=args.steps, log_every=20,
                         ckpt_every=50, ckpt_dir=args.ckpt_dir)
    return Trainer(loss_fn, data_fn, params, opt, tcfg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--bits", type=int, default=5)
    ap.add_argument("--no-seat", action="store_true")
    ap.add_argument("--arch", default="guppy",
                    choices=("guppy", "scrappie", "chiron"))
    ap.add_argument("--ckpt-dir", default="/tmp/seat_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    import logging
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    trainer = build(args)
    if args.resume:
        trainer.run()          # resilient path: restore latest + supervise
    else:
        trainer.run_from(0)
    losses = [l for _, l in trainer.history]
    print(f"\ndone: loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{args.steps} steps "
          f"({'SEAT' if not args.no_seat else 'loss0'}, {args.bits}-bit)")
    assert losses[-1] < losses[0], "training should reduce the loss"


if __name__ == "__main__":
    main()
