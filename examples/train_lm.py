"""Train an assigned-architecture LM (reduced config) on synthetic tokens.

    PYTHONPATH=src python examples/train_lm.py --arch qwen2.5-3b --steps 60

Exercises the same lm_loss/chunked-CE/optimizer path the dry-run lowers for
the production mesh, on a smoke-scale config with a local device mesh.
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import configs as cfg_reg
from repro.models import lm as lm_lib
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b",
                    choices=list(cfg_reg.LM_IDS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = cfg_reg.get_smoke(args.arch)
    params = lm_lib.init_lm(jax.random.PRNGKey(0), cfg)
    n = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
    print(f"{args.arch} (smoke config): {n/1e3:.0f}k params")

    def data_fn(step):
        key = jax.random.fold_in(jax.random.PRNGKey(11), step)
        # synthetic structured tokens: noisy arithmetic sequences, so the
        # loss has signal to descend (not pure noise)
        base = jax.random.randint(key, (args.batch, 1), 0,
                                  cfg.vocab_size // 2)
        ramp = (base + jnp.arange(args.seq)[None]) % cfg.vocab_size
        flip = jax.random.bernoulli(key, 0.05, ramp.shape)
        rand = jax.random.randint(key, ramp.shape, 0, cfg.vocab_size)
        tokens = jnp.where(flip, rand, ramp)
        batch = {"tokens": tokens}
        if not cfg.embed_inputs:
            emb = jax.random.normal(key, (args.batch, args.seq,
                                          cfg.d_model)) * 0.1
            batch = {"embeds": emb, "labels": tokens}
        if cfg.encoder is not None:
            batch["enc_embeds"] = jax.random.normal(
                key, (args.batch, args.seq, cfg.d_model)) * 0.1
        return batch

    def loss_fn(params, batch):
        return lm_lib.lm_loss(params, cfg, batch)

    opt = AdamW(lr=warmup_cosine(3e-3, 10, args.steps), weight_decay=0.01)
    trainer = Trainer(loss_fn, data_fn, params, opt,
                      TrainerConfig(steps=args.steps, log_every=10,
                                    ckpt_every=0))
    import logging
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    trainer.run_from(0)
    losses = [l for _, l in trainer.history]
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
