"""Quickstart: the Helix pipeline end to end in ~a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. simulate nanopore reads (synthetic pore model),
2. train a reduced Guppy for a few dozen steps with the SEAT loss,
3. base-call with CTC beam search,
4. vote a consensus read and score it against the ground truth.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ctc as ctc_lib
from repro.core import metrics, seat as seat_lib
from repro.core.quant import QuantConfig
from repro.data import genome
from repro.models import basecaller as bc
from repro.train.optimizer import AdamW

import dataclasses

WARM_STEPS, SEAT_STEPS, BATCH = 220, 80, 8


def main():
    scfg = seat_lib.SEATConfig(n_views=3, view_stride=8, max_read_len=40,
                               consensus_span=80)
    mcfg = bc.demo_preset("guppy").with_quant(
        QuantConfig(enabled=True, bits_w=5, bits_a=5))
    # 1-mer demo channel (6-mer is the realistic default but needs hours)
    dcfg = genome.SignalConfig(window=mcfg.input_len, margin=scfg.margin,
                               max_label_len=40, kmer=1, mean_dwell=6.0)

    params = bc.init_basecaller(jax.random.PRNGKey(0), mcfg)
    from repro.train.optimizer import warmup_cosine
    opt = AdamW(lr=warmup_cosine(4e-3, 15, WARM_STEPS + SEAT_STEPS))
    state = opt.init(params)

    def make_step(cfg_seat):
        @jax.jit
        def train_step(params, state, batch):
            def loss_fn(p):
                fn = lambda s: bc.apply_basecaller(p, s, mcfg)
                return seat_lib.seat_loss(fn, batch["signal"],
                                          batch["labels"],
                                          batch["label_length"], cfg_seat)
            (loss, m), g = jax.value_and_grad(loss_fn,
                                              has_aux=True)(params)
            params, state = opt.update(g, state, params)
            return params, state, loss, m["consensus_gap"]
        return train_step

    # the paper's own observation (§4.1/Fig 10): "when the read error rate
    # is high, it is faster to improve the quality of each read
    # independently" — warm up with loss0, then enable the SEAT term
    warm = make_step(dataclasses.replace(scfg, enabled=False))
    full = make_step(scfg)
    print(f"phase 1: 5-bit quantized Guppy, plain CTC, {WARM_STEPS} steps")
    for i in range(WARM_STEPS):
        batch = genome.batch_for_step(i, BATCH, dcfg)
        params, state, loss, gap = warm(params, state, batch)
        if i % 40 == 0:
            print(f"  step {i:3d}  loss {float(loss):8.3f}")
    print(f"phase 2: SEAT (Eq. 4) for {SEAT_STEPS} more steps")
    for i in range(WARM_STEPS, WARM_STEPS + SEAT_STEPS):
        batch = genome.batch_for_step(i, BATCH, dcfg)
        params, state, loss, gap = full(params, state, batch)
        if i % 20 == 0:
            print(f"  step {i:3d}  loss {float(loss):8.3f}  "
                  f"consensus_gap {float(gap):6.3f}")

    # --- base-call + vote on held-out reads --------------------------------
    batch = genome.batch_for_step(9999, BATCH, dcfg)
    views, center = seat_lib.make_views(batch["signal"], scfg)
    lps = jnp.stack([bc.apply_basecaller(params, v, mcfg) for v in views])
    beam = functools.partial(ctc_lib.ctc_beam_search_batch, beam_width=5,
                             max_len=40)
    reads, lens, _ = beam(lps[center])
    C, C_len = seat_lib.consensus_reads(lps, center, scfg)

    truth, tlen = np.asarray(batch["labels"]), np.asarray(batch["label_length"])
    read_acc = metrics.accuracy(np.asarray(reads[:, 0]),
                                np.asarray(lens[:, 0]), truth, tlen)
    vote_acc = metrics.accuracy(np.asarray(C), np.asarray(C_len), truth,
                                tlen)
    print(f"\nread accuracy (beam search):   {read_acc:.3f}")
    print(f"vote accuracy (3-view census): {vote_acc:.3f}")
    bases = "ACGT"
    print("example consensus:",
          "".join(bases[b] for b in np.asarray(C[0][: int(C_len[0])])))
    print("ground truth:     ",
          "".join(bases[b] for b in truth[0][: int(tlen[0])]))


if __name__ == "__main__":
    main()
