"""Quickstart: the Helix pipeline end to end in ~a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. simulate nanopore reads (synthetic pore model),
2. train a reduced Guppy through the pipeline's warm-up + SEAT policy,
3. base-call a long raw read: chunk -> batch -> CTC decode -> vote,
all through ``repro.pipeline.BasecallPipeline`` — no hand-wired
decode/vote plumbing.

Step counts honour ``QUICKSTART_STEPS`` (total; CI sets a small value).
"""
import os

import jax
import numpy as np

from repro.core import metrics
from repro.core.quant import QuantConfig
from repro.data import genome
from repro.pipeline import BasecallPipeline, TrainPolicy

BATCH = 8


def make_policy() -> TrainPolicy:
    total = int(os.environ.get("QUICKSTART_STEPS", "300"))
    warm = max(1, int(total * 0.73))          # the 220/80 split, scaled
    return TrainPolicy(warmup_steps=warm, seat_steps=max(1, total - warm))


def main():
    # the paper's 5-bit headline config on the CPU-trainable demo preset;
    # 1-mer demo channel (6-mer is the realistic default but needs hours)
    pipe = BasecallPipeline.from_preset(
        "guppy", scale="demo",
        quant=QuantConfig(enabled=True, bits_w=5, bits_a=5),
        backend="auto", beam_width=5)
    dcfg = pipe.data_config(kmer=1, mean_dwell=6.0, max_label_len=40)

    params = pipe.init_params(jax.random.PRNGKey(0))
    policy = make_policy()
    trainer = pipe.trainer(policy)
    state = trainer.init(params)

    print(f"phase 1: 5-bit quantized Guppy, plain CTC, "
          f"{policy.warmup_steps} steps")
    print(f"phase 2: SEAT (Eq. 4) for {policy.seat_steps} more steps")
    for step in range(policy.total_steps):
        batch = genome.batch_for_step(step, BATCH, dcfg)
        params, state, loss, m = pipe.train_step(params, state, batch, step)
        if step % 40 == 0 or step == policy.warmup_steps:
            phase = pipe.trainer().policy.phase(step)
            gap = float(m["consensus_gap"])
            print(f"  step {step:3d} [{phase:6s}]  loss {float(loss):8.3f}"
                  + (f"  consensus_gap {gap:6.3f}" if phase == "seat" else ""))

    # --- fixed-window base-call + vote on held-out reads -------------------
    batch = genome.batch_for_step(9999, BATCH, dcfg)
    C, C_len, top, top_len, _ = pipe.basecall_windows(batch["signal"],
                                                      params)
    truth = np.asarray(batch["labels"])
    tlen = np.asarray(batch["label_length"])
    read_acc = metrics.accuracy(np.asarray(top), np.asarray(top_len),
                                truth, tlen)
    vote_acc = metrics.accuracy(np.asarray(C), np.asarray(C_len), truth,
                                tlen)
    print(f"\nread accuracy (beam search):   {read_acc:.3f}")
    print(f"vote accuracy (3-view census): {vote_acc:.3f}")
    bases = "ACGT"
    print("example consensus:",
          "".join(bases[b] for b in np.asarray(C[0][: int(C_len[0])])))
    print("ground truth:     ",
          "".join(bases[b] for b in truth[0][: int(tlen[0])]))

    # --- long-read path: chunk -> batch -> decode -> stitch ----------------
    long_sig = np.concatenate([
        np.asarray(genome.batch_for_step(5000 + i, 1, dcfg)["signal"][0, :, 0])
        for i in range(4)])
    result = pipe.basecall(long_sig, params)
    print(f"\nlong read: {long_sig.shape[0]} samples -> "
          f"{result.window_reads.shape[0]} windows -> "
          f"{result.length}-base consensus")
    print("consensus:", result.sequence()[:48])


if __name__ == "__main__":
    main()
