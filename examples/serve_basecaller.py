"""Batched base-calling service: raw reads in -> consensus reads out.

    PYTHONPATH=src python examples/serve_basecaller.py [--requests 6]

Two serving modes, both through the unified pipeline API:

* fixed-window batches via ``BasecallPipeline.basecall_windows`` — the
  paper's fused quantized-DNN -> CTC beam -> 3-view vote in ONE jitted
  call per batch ("everything on one engine", DESIGN.md §4);
* long raw reads via ``BasecallEngine`` — slot-based continuous batching
  over signal windows: short reads retire early, long reads never block
  the pool (the LM engine's scheduler, reused).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.quant import QuantConfig
from repro.data import genome
from repro.pipeline import BasecallPipeline
from repro.serve.basecall_engine import BasecallEngine, ReadRequest

BASES = "ACGT"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "pallas", "interpret", "ref"])
    args = ap.parse_args()

    pipe = BasecallPipeline.from_preset(
        "guppy", scale="demo",
        quant=QuantConfig(enabled=True, bits_w=5, bits_a=5),
        backend=args.backend, beam_width=5)
    dcfg = pipe.data_config(kmer=1, mean_dwell=6.0, max_label_len=40)
    params = pipe.init_params(jax.random.PRNGKey(0))

    # --- mode 1: fixed-window batches (the fused serving path) -------------
    total_bases = 0
    t0 = time.perf_counter()
    for r in range(args.requests):
        batch = genome.batch_for_step(r, args.batch, dcfg, seed=7)
        C, C_len, top, top_len, score = pipe.basecall_windows(
            batch["signal"], params)
        total_bases += int(jnp.sum(C_len))
        acc = metrics.accuracy(np.asarray(C), np.asarray(C_len),
                               np.asarray(batch["labels"]),
                               np.asarray(batch["label_length"]))
        read = "".join(BASES[b] for b in np.asarray(C[0][: int(C_len[0])]))
        print(f"req {r}: {args.batch} windows -> consensus acc {acc:.3f} "
              f"(untrained weights), first read {read[:32]}...")
    dt = time.perf_counter() - t0
    print(f"\nserved {args.requests} window batches, {total_bases} bases in "
          f"{dt:.2f}s ({total_bases/dt:.0f} bp/s)")

    # --- mode 2: long reads through the continuous-batching engine ---------
    rng = np.random.default_rng(0)
    eng = BasecallEngine(pipe, batch_slots=args.slots)
    read_lens = [3, 1, 5, 2, 4, 1][: args.requests]
    for i, n_chunks in enumerate(read_lens):
        sig = np.concatenate([
            np.asarray(genome.batch_for_step(100 * i + j, 1, dcfg,
                                             seed=11)["signal"][0, :, 0])
            for j in range(n_chunks)])
        sig += 0.01 * rng.standard_normal(sig.shape).astype(np.float32)
        eng.submit(ReadRequest(rid=i, signal=sig))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    print(f"\ncontinuous batching: {len(done)} long reads through "
          f"{args.slots} slots in {eng.steps} engine steps ({dt:.2f}s)")
    for rid in sorted(done):
        res = done[rid].result
        print(f"  read {rid}: {done[rid].windows.shape[0]:2d} windows -> "
              f"{res.length:3d} bases  {res.sequence()[:24]}...")


if __name__ == "__main__":
    main()
