"""Batched base-calling service: signals in -> consensus reads out.

    PYTHONPATH=src python examples/serve_basecaller.py [--requests 6]

The serving pipeline is the paper's full quantized path fused into one
jitted function per batch: quantized DNN -> CTC beam search -> 3-view read
vote — the TPU rendition of "everything on one engine" (DESIGN.md §4).
"""
import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ctc as ctc_lib
from repro.core import metrics, seat as seat_lib
from repro.core.quant import QuantConfig
from repro.data import genome
from repro.models import basecaller as bc

BASES = "ACGT"


class BasecallServer:
    def __init__(self, params, mcfg, scfg, beam_width=5):
        self.params, self.mcfg, self.scfg = params, mcfg, scfg

        @jax.jit
        def pipeline(params, signal):
            views, center = seat_lib.make_views(signal, scfg)
            lps = jnp.stack([bc.apply_basecaller(params, v, mcfg)
                             for v in views])
            C, C_len = seat_lib.consensus_reads(lps, center, scfg)
            reads, lens, scores = ctc_lib.ctc_beam_search_batch(
                lps[center], beam_width=beam_width,
                max_len=scfg.max_read_len)
            return C, C_len, reads[:, 0], lens[:, 0], scores[:, 0]

        self._pipeline = pipeline

    def __call__(self, signal_batch):
        return self._pipeline(self.params, signal_batch)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    scfg = seat_lib.SEATConfig(n_views=3, view_stride=8, max_read_len=40,
                               consensus_span=80)
    mcfg = bc.demo_preset("guppy").with_quant(
        QuantConfig(enabled=True, bits_w=5, bits_a=5))
    dcfg = genome.SignalConfig(window=mcfg.input_len, margin=scfg.margin,
                               max_label_len=40, kmer=1, mean_dwell=6.0)
    params = bc.init_basecaller(jax.random.PRNGKey(0), mcfg)
    server = BasecallServer(params, mcfg, scfg)

    total_bases = 0
    t0 = time.perf_counter()
    for r in range(args.requests):
        batch = genome.batch_for_step(r, args.batch, dcfg, seed=7)
        C, C_len, top, top_len, score = server(batch["signal"])
        total_bases += int(jnp.sum(C_len))
        acc = metrics.accuracy(np.asarray(C), np.asarray(C_len),
                               np.asarray(batch["labels"]),
                               np.asarray(batch["label_length"]))
        read = "".join(BASES[b] for b in np.asarray(C[0][: int(C_len[0])]))
        print(f"req {r}: {args.batch} signals -> consensus acc {acc:.3f} "
              f"(untrained weights), first read {read[:32]}...")
    dt = time.perf_counter() - t0
    print(f"\nserved {args.requests} requests, {total_bases} bases in "
          f"{dt:.2f}s ({total_bases/dt:.0f} bp/s on CPU)")


if __name__ == "__main__":
    main()
