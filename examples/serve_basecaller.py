"""Batched base-calling service: raw reads in -> consensus reads out.

    PYTHONPATH=src python examples/serve_basecaller.py [--requests 6]

Two serving modes, both through the unified pipeline API:

* fixed-window batches via ``BasecallPipeline.basecall_windows`` — the
  paper's fused quantized-DNN -> CTC beam -> 3-view vote in ONE jitted
  call per batch ("everything on one engine", DESIGN.md §4);
* long raw reads via the ``repro.serve.Server`` request lifecycle over
  ``BasecallEngine``: submit -> bounded queue -> slot-based continuous
  batching over signal windows -> per-window streaming -> retire.  Short
  reads retire early, long reads never block the pool, and the run ends
  with a ``metrics()`` snapshot (requests/s, occupancy, p50/p99).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.quant import QuantConfig
from repro.data import genome
from repro.pipeline import BasecallPipeline
from repro.serve import BasecallRequest, Server
from repro.serve.basecall_engine import BasecallEngine

BASES = "ACGT"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "pallas", "interpret", "ref"])
    args = ap.parse_args()

    pipe = BasecallPipeline.from_preset(
        "guppy", scale="demo",
        quant=QuantConfig(enabled=True, bits_w=5, bits_a=5),
        backend=args.backend, beam_width=5)
    dcfg = pipe.data_config(kmer=1, mean_dwell=6.0, max_label_len=40)
    params = pipe.init_params(jax.random.PRNGKey(0))

    # --- mode 1: fixed-window batches (the fused serving path) -------------
    total_bases = 0
    t0 = time.perf_counter()
    for r in range(args.requests):
        batch = genome.batch_for_step(r, args.batch, dcfg, seed=7)
        C, C_len, top, top_len, score = pipe.basecall_windows(
            batch["signal"], params)
        total_bases += int(jnp.sum(C_len))
        acc = metrics.accuracy(np.asarray(C), np.asarray(C_len),
                               np.asarray(batch["labels"]),
                               np.asarray(batch["label_length"]))
        read = "".join(BASES[b] for b in np.asarray(C[0][: int(C_len[0])]))
        print(f"req {r}: {args.batch} windows -> consensus acc {acc:.3f} "
              f"(untrained weights), first read {read[:32]}...")
    dt = time.perf_counter() - t0
    print(f"\nserved {args.requests} window batches, {total_bases} bases in "
          f"{dt:.2f}s ({total_bases/dt:.0f} bp/s)")

    # --- mode 2: long reads through the serving API ------------------------
    rng = np.random.default_rng(0)
    eng = BasecallEngine(pipe, batch_slots=args.slots)
    srv = Server(eng, max_queue=max(args.requests, 1), backpressure="block")
    read_lens = [3, 1, 5, 2, 4, 1][: args.requests]
    sigs = []
    for i, n_chunks in enumerate(read_lens):
        sig = np.concatenate([
            np.asarray(genome.batch_for_step(100 * i + j, 1, dcfg,
                                             seed=11)["signal"][0, :, 0])
            for j in range(n_chunks)])
        sig += 0.01 * rng.standard_normal(sig.shape).astype(np.float32)
        sigs.append(sig)

    # stream the first read window by window, submit the rest as futures
    if sigs:
        print("\nstreaming read 0:")
        for ev in srv.stream(BasecallRequest(signal=sigs[0])):
            if ev.kind == "window":
                read, length = ev.payload
                txt = "".join(BASES[b]
                              for b in np.asarray(read)[:length][:16])
                print(f"  window {ev.index}: {length:3d} bases  {txt}...")
    futs = [srv.submit(BasecallRequest(signal=s)) for s in sigs[1:]]
    for f in futs:
        f.result()                    # drive the loop to completion

    m = srv.metrics()
    print(f"\nserving API: {m.completed} long reads through {args.slots} "
          f"slots in {m.steps} engine steps (occupancy {m.occupancy:.2f}, "
          f"{m.requests_per_s:.2f} req/s, p50 {m.latency_p50_s:.3f}s "
          f"p99 {m.latency_p99_s:.3f}s)")
    for res in sorted(srv.results.values(), key=lambda r: r.rid):
        bres = res.value
        print(f"  read {res.rid}: {bres.window_reads.shape[0]:2d} windows -> "
              f"{bres.length:3d} bases  {bres.sequence()[:24]}...")


if __name__ == "__main__":
    main()
